"""Mesh serving with overlapped exchange collectives vs the roofline.

GraVF-M's evaluation claim (§6) is that the generated system reaches
94% of the §5 model's projected limit — which is only attainable when
network transfer overlaps local compute (eq. 9's ``min`` implicitly
assumes every resource runs concurrently). This benchmark stands the
claim up on a real 4-device mesh (subprocess with
``--xla_force_host_platform_device_count=4``, the SNIPPETS.md idiom,
plus the XLA latency-hiding flags for GPU) and measures the pipelined
exchange schedule end to end on the combined-exchange R-MAT workload:

  * **bit-identity**: the overlapped schedule's BFS/SSSP results equal
    the synchronous schedule's exactly (states, supersteps, messages);
  * **zero steady-state re-traces**: repeated runs — and toggling
    ``overlap`` per run — re-trace nothing once both schedules are warm;
  * **throughput**: steady-state TEPS under the overlapped schedule vs
    synchronous on the same engine (the act-stream elision plus the
    window pipeline must actually pay, not just not regress);
  * **roofline**: the §6 methodology applied to the overlap claim —
    profile the synchronous schedule's phase split (exchange wall E,
    local-compute wall A), project the overlapped superstep floor
    ``max(E, A)`` via :func:`perfmodel.overlapped_projection`, and
    compare the measured overlapped superstep wall against it.

``GRAVFM_BENCH_CI=1`` turns the comparisons into gates:
    bit-identical results, zero steady-state re-traces
    overlapped TEPS >= 1.15x synchronous (combined-exchange R-MAT BFS)
    measured/projected overlapped-pipeline efficiency >= 0.7

The run always writes ``bench-mesh.json`` (or ``$GRAVFM_MESH_OUT``);
the CI workflow uploads it and appends the ``BENCH_mesh.json``
trajectory.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import emit

_SCRIPT = r"""
import os
flags = ["--xla_force_host_platform_device_count=4"]
if os.environ.get("GRAVFM_MESH_GPU"):
    # latency-hiding scheduler flags (SNIPPETS.md idiom): let XLA issue
    # the exchange collective asynchronously on its own stream
    flags += ["--xla_gpu_enable_async_collectives=true",
              "--xla_gpu_enable_latency_hiding_scheduler=true",
              "--xla_gpu_enable_highest_priority_async_stream=true"]
os.environ["XLA_FLAGS"] = " ".join(flags)
import json, time
import numpy as np
import jax.numpy as jnp
from repro.core import graph as G, partition as PT, algorithms as ALG
from repro.core.engine_shardmap import ShardEngine
from repro.launch.mesh import make_serving_mesh

SCALE, EDGE_FACTOR, P, W = %(scale)d, %(edge_factor)d, 4, 8
ITERS = %(iters)d
g = G.rmat(SCALE, EDGE_FACTOR, seed=7)
pg = PT.partition_graph(g, P, method="greedy", pad_multiple=16)
mesh = make_serving_mesh(P)
# a high-out-degree root reaches the frontier's bulk and gives a deep,
# message-heavy run (a leaf root can quiesce in one superstep)
root = int(np.argmax(g.out_degrees()))

out = {"num_vertices": g.num_vertices, "num_edges": g.num_edges,
       "P": P, "W": W, "root": root, "iters": ITERS}

# ---- bit-identity + steady-state retrace (run path), BFS and SSSP ----
state = {}
for kern in ("bfs", "sssp"):
    eng = ShardEngine(ALG.bfs() if kern == "bfs" else ALG.sssp(), pg,
                      mesh=mesh, exchange="combined", backend="ref")
    for ov in (False, True):
        r0 = eng.run(root=np.int32(root), overlap=ov)     # traces
        warm = eng.traces
        r1 = eng.run(root=np.int32(root), overlap=ov)     # steady state
        state[(kern, ov)] = {k: np.asarray(v)
                             for k, v in r1["state"].items()}
        out["%%s_%%s" %% (kern, "ov" if ov else "sync")] = {
            "supersteps": int(r1["supersteps"]),
            "messages": int(r1["messages"]),
            "wire_words": float(r1["comm"]["wire_words"]),
            "retraced": eng.traces != warm,
        }
    # toggling back re-traces nothing either (both programs warm)
    warm = eng.traces
    eng.run(root=np.int32(root), overlap=False)
    eng.run(root=np.int32(root), overlap=True)
    out["%%s_toggle_retraced" %% kern] = eng.traces != warm
out["identical"] = all(
    np.array_equal(state[(k, False)][s], state[(k, True)][s])
    for k in ("bfs", "sssp") for s in state[(k, False)])

# ---- steady-state TEPS, overlapped vs synchronous (combined BFS) -----
eng = ShardEngine(ALG.bfs(), pg, mesh=mesh, exchange="combined",
                  backend="ref")
teps = {}
for ov in (False, True):
    eng.run(root=np.int32(root), overlap=ov)              # warm
    t0 = time.perf_counter()
    msgs = 0
    for _ in range(ITERS):
        msgs += int(eng.run(root=np.int32(root), overlap=ov)["messages"])
    wall = time.perf_counter() - t0
    teps["ov" if ov else "sync"] = msgs / wall
    out["teps_%%s" %% ("ov" if ov else "sync")] = msgs / wall
out["teps_ratio"] = teps["ov"] / teps["sync"]

# ---- roofline: profiled sync phase split -> overlapped projection ----
# Drive the step-granular steppers over the same alive schedule: the
# profiled synchronous stepper yields the exchange wall E and the
# local-compute wall A per superstep; perfmodel.overlapped_projection
# says the pipelined superstep floor is max(E, A); the measured
# overlapped stepper wall is compared against that floor (§6 applied
# to the overlap claim).
roots = {"root": jnp.full((W,), np.int32(root))}
st_sync = eng.make_stepper(W, overlap=False)
st_ov = eng.make_stepper(W, overlap=True)

def drive(st, profile, reps=3):
    st.profile = profile
    walls, phases = [], []
    for _ in range(reps):
        carry, act, steps = st.init(roots)
        alive = np.asarray(act)
        t0 = time.perf_counter()
        n = 0
        while alive.any():
            carry, act, steps = st.step(carry, alive)
            if profile and getattr(st, "last_phases", None):
                phases.append(dict(st.last_phases))
            alive = np.asarray(act)
            n += 1
        walls.append((time.perf_counter() - t0, n))
    wall, n = min(walls)                 # best-of over jitter
    return wall / n, n, phases

per_step_sync_prof, depth, phases = drive(st_sync, True)
E = float(np.median([p["exchange"] for p in phases]))
A = float(np.median([p.get("scatter", 0.0) + p.get("combine", 0.0)
                     + p.get("apply", 0.0) for p in phases]))
per_step_ov, _, _ = drive(st_ov, False)
per_step_sync, _, _ = drive(st_sync, False)
out["depth"] = depth
out["phase_exchange_s"] = E
out["phase_compute_s"] = A
out["superstep_sync_s"] = per_step_sync
out["superstep_ov_s"] = per_step_ov
print("MESH-JSON:" + json.dumps(out))
"""


def mesh():
    ci = bool(os.environ.get("GRAVFM_BENCH_CI"))
    scale, edge_factor, iters = (10, 64, 5)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SCRIPT % {"scale": scale, "edge_factor": edge_factor,
                        "iters": iters}
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(src)
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError("mesh subprocess failed:\n"
                           + proc.stderr[-3000:])
    line = next(ln for ln in proc.stdout.splitlines()
                if ln.startswith("MESH-JSON:"))
    meas = json.loads(line[len("MESH-JSON:"):])

    from repro.core import perfmodel as pm
    # projected overlapped superstep floor from the measured sync phase
    # split (time domain), plus the rate-domain model gain for context
    proj = pm.overlapped_projection(meas["phase_compute_s"],
                                    meas["phase_exchange_s"])
    overlap_eff = (proj["overlapped_s"] / meas["superstep_ov_s"]
                   if meas["superstep_ov_s"] > 0 else 0.0)
    wl = pm.Workload(meas["num_vertices"], meas["num_edges"])
    lim = pm.limits(pm.PAPER_PLATFORM, pm.PAPER_ALGOS["bfs"], wl,
                    n_nodes=meas["P"], exchange="combined")
    model = pm.overlapped_limits(lim)

    retraced = any(meas[k]["retraced"] for k in
                   ("bfs_sync", "bfs_ov", "sssp_sync", "sssp_ov"))
    retraced = (retraced or meas["bfs_toggle_retraced"]
                or meas["sssp_toggle_retraced"])
    emit("mesh/rmat%d_ef%d/teps" % (scale, edge_factor),
         meas["superstep_ov_s"] * 1e6,
         "sync=%.0f;ov=%.0f;ratio=%.2fx;identical=%s;retraced=%s"
         % (meas["teps_sync"], meas["teps_ov"], meas["teps_ratio"],
            meas["identical"], retraced))
    emit("mesh/rmat%d_ef%d/overlap" % (scale, edge_factor),
         meas["superstep_sync_s"] * 1e6,
         "E=%.0fus;A=%.0fus;proj=%.0fus;meas_ov=%.0fus;eff=%.2f;"
         "model_gain=%.2fx"
         % (meas["phase_exchange_s"] * 1e6, meas["phase_compute_s"] * 1e6,
            proj["overlapped_s"] * 1e6, meas["superstep_ov_s"] * 1e6,
            overlap_eff, model["overlap_gain"]))

    out_path = os.environ.get("GRAVFM_MESH_OUT", "bench-mesh.json")
    with open(out_path, "w") as f:
        json.dump({"measured": meas,
                   "projected": {**proj, "model_overlap_gain":
                                 model["overlap_gain"],
                                 "T_serial": model["T_serial"],
                                 "T_overlap": model["T_overlap"]},
                   "overlap_efficiency": overlap_eff,
                   "teps_ratio": meas["teps_ratio"]}, f, indent=2)

    if ci:
        assert meas["identical"], "overlapped result != synchronous"
        assert not retraced, "steady state re-traced"
        assert meas["teps_ratio"] >= 1.15, (
            "overlapped TEPS only %.2fx of synchronous (< 1.15x)"
            % meas["teps_ratio"])
        assert overlap_eff >= 0.7, (
            "measured overlapped superstep %.0fus vs projected floor "
            "%.0fus: efficiency %.2f < 0.7"
            % (meas["superstep_ov_s"] * 1e6, proj["overlapped_s"] * 1e6,
               overlap_eff))
