"""Roofline summary: reads the dry-run artifacts (experiments/dryrun) and
prints the per-(arch x shape x mesh) table for EXPERIMENTS.md §Roofline.

Run after ``python -m repro.launch.dryrun --all``. Falls back to a note if
no artifacts exist (the sweep is a separate, longer job)."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def roofline_table():
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    files = [f for f in files if not f.endswith("summary.json")]
    if not files:
        emit("roofline/missing", 0.0,
             f"no dry-run artifacts in {DRYRUN_DIR}; run "
             "PYTHONPATH=src python -m repro.launch.dryrun --all")
        return
    for f in files:
        with open(f) as fh:
            c = json.load(fh)
        if c.get("status") != "ok":
            emit(f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}", 0.0,
                 f"status={c.get('status')};reason={c.get('reason', '')[:60]}")
            continue
        r = c["roofline"]
        extra = (f";teps_bound={c['teps_bound']:.3e}"
                 if "teps_bound" in c else
                 f";fits={c.get('fits_hbm', '-')}")
        emit(f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
             r["roofline_step_s"] * 1e6,
             f"bound={r['bound_by']};"
             f"tc={r['t_compute_s']:.4f};tm={r['t_memory_s']:.4f};"
             f"tn={r['t_collective_s']:.4f};"
             f"useful={r['useful_flop_ratio']:.3f};"
             f"mfu_bound={r['mfu_bound']:.4f}" + extra)
