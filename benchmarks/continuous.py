"""Continuous vs bucketed scheduling under a mixed-depth workload.

The dataset is the serving-scale uniform-16 graph with a disconnected
deep "probe tail" (a line component, the Fig. 10/11 latency-probe idea):
BFS rooted in the uniform core quiesces in ~4 supersteps, BFS rooted at
the tail head runs ~tail-length supersteps. The request stream mixes
them 3:1.

Both schedulers answer the SAME stream with the same parallel width
(max_batch == slots), so throughput is comparable; the metric that
separates them is latency. Bucketed batching runs every batch to its
slowest member's depth — a short query co-batched with a tail query
pays the whole tail. The continuous scheduler retires each query the
superstep its own termination mask flips and splices queued roots into
the freed slots, so p50 (short-query-dominated) drops while the deep
queries proceed undisturbed.

``GRAVFM_BENCH_CI=1`` shrinks the workload, applies a tight superstep
cap, and exits non-zero if continuous p50 fails to beat bucketed p50 —
the CI smoke gate against scheduler regressions.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import graph as G
from repro.service import GraphQueryService, QueryRequest

from .common import emit


def _mixed_graph(n_core: int, avg_degree: float, tail: int,
                 seed: int = 0) -> G.Graph:
    """uniform(n_core, avg_degree) plus a DISCONNECTED line of ``tail``
    vertices — core roots are shallow, tail roots are deep."""
    core = G.uniform(n_core, avg_degree, seed=seed).symmetrized()
    n = n_core + tail
    cs = np.arange(n_core, n - 1, dtype=np.int32)
    src = np.concatenate([core.src, cs, cs + 1]).astype(np.int32)
    dst = np.concatenate([core.dst, cs + 1, cs]).astype(np.int32)
    return G.Graph(n, src, dst)


def continuous_vs_bucketed():
    ci = bool(os.environ.get("GRAVFM_BENCH_CI"))
    # the tail must be MUCH deeper than the core (that asymmetry is the
    # workload continuous batching exists for); the CI cap bounds
    # runtime while keeping the ~5:24 depth mix
    n_core, deg, tail = (1024, 8.0, 24) if ci else (4096, 16.0, 48)
    cap = 24 if ci else None
    n_queries = 32 if ci else 64
    width = 16

    g = _mixed_graph(n_core, deg, tail)
    rng = np.random.default_rng(0)
    short_roots = rng.integers(0, n_core, size=n_queries).astype(np.int32)
    roots = [int(r) for r in short_roots]
    for i in range(0, n_queries, 4):
        roots[i] = n_core            # every 4th query starts the deep tail

    def measure(sched: str) -> dict:
        svc = GraphQueryService(num_shards=4, max_batch=width, slots=width,
                                scheduling=sched, max_supersteps=cap,
                                result_cache_size=0)   # pure scheduling
        svc.add_graph("uniform-16-tail", g)
        svc.warm("uniform-16-tail", "bfs")
        # open-loop arrival: every request is stamped BEFORE any
        # dispatch, so queue wait behind earlier batches counts into
        # latency for both schedulers alike
        reqs = [QueryRequest("uniform-16-tail", "bfs", {"root": r},
                             deadline_ms=60_000) for r in roots]
        t0 = time.perf_counter()
        futs = [svc.submit(r) for r in reqs]
        svc.flush()
        for f in futs:
            f.result()
        wall = time.perf_counter() - t0
        snap = svc.stats_snapshot()
        emit(f"service_bfs_{sched}_mixed", wall / n_queries * 1e6,
             f"qps={n_queries / wall:.1f};"
             f"p50_ms={snap['latency_p50_ms']:.1f};"
             f"p95_ms={snap['latency_p95_ms']:.1f};"
             f"p99_ms={snap['latency_p99_ms']:.1f};"
             f"supersteps={snap['supersteps_total']}")
        return snap

    # wall-clock comparison on shared runners is noisy; the structural
    # advantage is large (multiples), so retry once before declaring a
    # regression and require only a clear win, not a fixed ratio
    attempts = 2 if ci else 1
    for attempt in range(attempts):
        p50 = {s: measure(s)["latency_p50_ms"]
               for s in ("bucketed", "continuous")}
        speedup = p50["bucketed"] / max(p50["continuous"], 1e-9)
        emit("service_bfs_continuous_p50_speedup", 0.0, f"x{speedup:.2f}")
        if p50["continuous"] < p50["bucketed"]:
            break
    else:
        if ci:
            raise SystemExit(
                f"continuous p50 {p50['continuous']:.1f}ms did not beat "
                f"bucketed p50 {p50['bucketed']:.1f}ms in {attempts} "
                f"attempts — scheduler regression")
