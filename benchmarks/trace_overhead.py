"""Tracing overhead on the continuous-scheduling hot path.

Runs the continuous benchmark's mixed-depth BFS stream twice through
identical services — tracing off, then tracing on — and reports the qps
ratio. The TraceBus is designed to be negligible on the hot path (one
enabled-flag read when off, one leaf-lock deque append per event when
on), so the two runs should be statistically indistinguishable.

``GRAVFM_BENCH_CI=1`` turns the ratio into a gate: qps with tracing on
must stay >= ``GATE`` (95%) of tracing off, with retries because shared
runners make single wall-clock samples noisy. When ``--trace-out PATH``
was passed to the harness, the tracing-on service's Chrome-trace JSON
is exported there (the CI workflow uploads it as an artifact).
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.service import GraphQueryService, QueryRequest

from . import common
from .common import emit
from .continuous import _mixed_graph

GATE = 0.95


def _measure(tracing: bool, g, roots, cap, width: int,
             trace_out=None) -> float:
    svc = GraphQueryService(num_shards=4, max_batch=width, slots=width,
                            scheduling="continuous", max_supersteps=cap,
                            result_cache_size=0, tracing=tracing)
    svc.add_graph("uniform-16-tail", g)
    svc.warm("uniform-16-tail", "bfs")
    reqs = [QueryRequest("uniform-16-tail", "bfs", {"root": r},
                         deadline_ms=60_000) for r in roots]
    t0 = time.perf_counter()
    futs = [svc.submit(r) for r in reqs]
    svc.flush()
    for f in futs:
        f.result()
    wall = time.perf_counter() - t0
    if tracing and trace_out:
        path = svc.dump_trace(trace_out)
        emit("trace_export", 0.0,
             f"path={path};events={svc.trace.emitted};"
             f"dropped={svc.trace.dropped}")
    return len(roots) / wall


def trace_overhead():
    ci = bool(os.environ.get("GRAVFM_BENCH_CI"))
    n_core, deg, tail = (1024, 8.0, 24) if ci else (4096, 16.0, 48)
    cap = 24 if ci else None
    n_queries = 32 if ci else 64
    width = 16

    g = _mixed_graph(n_core, deg, tail)
    rng = np.random.default_rng(0)
    roots = [int(r) for r in
             rng.integers(0, n_core, size=n_queries).astype(np.int32)]
    for i in range(0, n_queries, 4):
        roots[i] = n_core

    attempts = 3 if ci else 1
    for attempt in range(attempts):
        qps_off = _measure(False, g, roots, cap, width)
        qps_on = _measure(True, g, roots, cap, width,
                          trace_out=common.TRACE_OUT)
        ratio = qps_on / max(qps_off, 1e-9)
        emit("service_bfs_tracing_overhead",
             0.0, f"qps_off={qps_off:.1f};qps_on={qps_on:.1f};"
                  f"ratio={ratio:.3f}")
        if ratio >= GATE:
            break
    else:
        if ci:
            raise SystemExit(
                f"tracing-on qps is {ratio:.3f}x tracing-off "
                f"(< {GATE}) after {attempts} attempts — tracing "
                "overhead regression on the continuous hot path")
