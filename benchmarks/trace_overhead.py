"""Tracing + metrics/watchdog overhead on the continuous hot path.

Runs the continuous benchmark's mixed-depth BFS stream through
identical services with the observability layers toggled and reports
the qps ratios:

  * tracing off vs tracing on — the TraceBus is designed to be
    negligible (one enabled-flag read when off, one leaf-lock deque
    append per event when on);
  * observability off vs metrics registry + SLO watchdog on — the
    registry is pull-time (collectors run at scrape, not per query)
    and the watchdog samples a stats snapshot a few times a second, so
    serving should again be statistically indistinguishable.

``GRAVFM_BENCH_CI=1`` turns both ratios into gates: qps with the layer
on must stay >= ``GATE`` (95%) of off, with retries because shared
runners make single wall-clock samples noisy. ``--trace-out PATH``
exports the tracing-on service's Chrome-trace JSON; ``--metrics-out
PATH`` dumps the metrics-on service's registry snapshot (both uploaded
as CI artifacts).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.service import GraphQueryService, QueryRequest

from . import common
from .common import emit
from .continuous import _mixed_graph

GATE = 0.95


def _measure(tracing: bool, g, roots, cap, width: int,
             trace_out=None, metrics: bool = False,
             watchdog: bool = False, metrics_out=None) -> float:
    svc = GraphQueryService(num_shards=4, max_batch=width, slots=width,
                            scheduling="continuous", max_supersteps=cap,
                            result_cache_size=0, tracing=tracing,
                            metrics=metrics)
    svc.add_graph("uniform-16-tail", g)
    svc.warm("uniform-16-tail", "bfs")
    if watchdog:
        svc.start_watchdog()
    reqs = [QueryRequest("uniform-16-tail", "bfs", {"root": r},
                         deadline_ms=60_000) for r in roots]
    t0 = time.perf_counter()
    futs = [svc.submit(r) for r in reqs]
    svc.flush()
    for f in futs:
        f.result()
    wall = time.perf_counter() - t0
    if watchdog:
        svc.stop_watchdog()
    if tracing and trace_out:
        path = svc.dump_trace(trace_out)
        emit("trace_export", 0.0,
             f"path={path};events={svc.trace.emitted};"
             f"dropped={svc.trace.dropped}")
    if metrics and metrics_out:
        with open(metrics_out, "w") as f:
            json.dump(svc.metrics_snapshot(), f, indent=1)
        emit("metrics_export", 0.0, f"path={metrics_out}")
    return len(roots) / wall


def _gated(label: str, ci: bool, run_off, run_on) -> None:
    attempts = 3 if ci else 1
    for _ in range(attempts):
        qps_off = run_off()
        qps_on = run_on()
        ratio = qps_on / max(qps_off, 1e-9)
        emit(label, 0.0, f"qps_off={qps_off:.1f};qps_on={qps_on:.1f};"
                         f"ratio={ratio:.3f}")
        if ratio >= GATE:
            return
    if ci:
        raise SystemExit(
            f"{label}: on-qps is {ratio:.3f}x off-qps (< {GATE}) after "
            f"{attempts} attempts — observability overhead regression "
            "on the continuous hot path")


def trace_overhead():
    ci = bool(os.environ.get("GRAVFM_BENCH_CI"))
    n_core, deg, tail = (1024, 8.0, 24) if ci else (4096, 16.0, 48)
    cap = 24 if ci else None
    n_queries = 32 if ci else 64
    width = 16

    g = _mixed_graph(n_core, deg, tail)
    rng = np.random.default_rng(0)
    roots = [int(r) for r in
             rng.integers(0, n_core, size=n_queries).astype(np.int32)]
    for i in range(0, n_queries, 4):
        roots[i] = n_core

    _gated("service_bfs_tracing_overhead", ci,
           lambda: _measure(False, g, roots, cap, width),
           lambda: _measure(True, g, roots, cap, width,
                            trace_out=common.TRACE_OUT))
    # metrics + watchdog gate: tracing on both sides so the delta is
    # the registry + watchdog alone
    _gated("service_bfs_metrics_overhead", ci,
           lambda: _measure(True, g, roots, cap, width),
           lambda: _measure(True, g, roots, cap, width, metrics=True,
                            watchdog=True,
                            metrics_out=common.METRICS_OUT))
