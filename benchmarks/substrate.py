"""Substrate micro-benchmarks beyond the paper's tables: kernel layout
quality, LM train-step throughput on reduced configs, gradient
compression wire model, exchange-schedule comparison."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import algorithms as ALG
from repro.core import graph as G
from repro.core import partition as PT
from repro.core.engine import Engine
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.kernels.layout import build_layout
from repro.models import layers as L
from repro.models import lm as LM
from repro.train import compress as CMP
from repro.train.loop import make_train_step
from repro.train.optimizer import AdamWConfig, adamw_init

from .common import emit, time_call


def kernel_layout_overhead():
    """Padding overhead of the Pallas tile layout across graph families
    (the §5.4 granularity term analogue)."""
    for name, g in (("uniform16", G.uniform(4096, 16.0, seed=0)),
                    ("rmat8", G.rmat(12, 8, seed=0)),
                    ("road", G.road(64, seed=0))):
        pg = PT.partition_graph(g, 4, pad_multiple=32)
        seg = (np.arange(4)[:, None] * (pg.v_max + 1)
               + pg.in_dst_local).reshape(-1)
        lo = build_layout(np.sort(seg), 4 * (pg.v_max + 1),
                          tile_e=512, tile_r=256)
        emit(f"layout/{name}", 0.0,
             f"pad_overhead={lo.pad_overhead:.3f};tiles={lo.n_tiles}")


def lm_train_throughput():
    """Reduced-config train-step wall time for three representative
    architectures (dense / MoE / recurrent)."""
    for arch in ("qwen3-4b", "deepseek-moe-16b", "xlstm-350m"):
        cfg = configs.get(arch, reduced=True)
        params = L.init_params(jax.random.PRNGKey(0), LM.lm_spec(cfg))
        opt = adamw_init(params)
        dc = DataConfig(vocab=cfg.vocab, global_batch=4, seq_len=64)
        batch = SyntheticTokens(dc).batch(0)
        if cfg.family == "vlm":
            continue
        step = jax.jit(make_train_step(cfg, AdamWConfig()))
        s = jnp.int32(0)
        out = step(params, opt, batch, s)  # compile+run
        jax.block_until_ready(out[2]["loss"])

        def call():
            r = step(params, opt, batch, s)
            jax.block_until_ready(r[2]["loss"])
        us = time_call(call, warmup=1, iters=3)
        toks = dc.global_batch * dc.seq_len
        emit(f"substrate/train_step/{arch}", us,
             f"tokens_per_s_cpu={toks / (us / 1e6):.0f}")


def compression_wire():
    for n in (10 ** 6, 10 ** 8):
        wb = CMP.wire_bytes(n)
        emit(f"substrate/grad_compress/n{n}", 0.0,
             f"f32_bytes={wb['f32_psum']};int8_bytes={wb['int8_allgather']};"
             f"ratio={wb['ratio']:.2f}x")


def frontier_vs_dense_words():
    """Beyond-paper: frontier-compressed exchange vs dense broadcast on a
    sparse-frontier BFS (measured words, global engine counters)."""
    g = G.ladder(16, 128, 2, seed=1)
    pg = PT.partition_graph(g, 4, pad_multiple=16)
    eng, = (Engine(ALG.bfs(0), pg, mode="gravfm", backend="ref"),)
    res = eng.run()
    dense_words = res.comm["bcast_naive_words"]
    filt_words = res.comm["bcast_filtered_words"]
    emit("substrate/frontier_bfs", 0.0,
         f"naive_words={dense_words:.0f};filtered_words={filt_words:.0f};"
         f"reduction={dense_words / max(filt_words, 1):.2f}x")
