"""Shared benchmark utilities.

Measured numbers on this box are CPU wall-times — meaningful for RATIOS
(GraVF vs GraVF-M, scaling trends, partitioner quality), not absolute
TEPS. Absolute projections come from the §5 performance model
(core/perfmodel.py with the paper's platform constants) and from the
dry-run roofline (experiments/dryrun). Engine benchmarks use the jnp
backend: interpret-mode Pallas is a correctness vehicle, not a timing one.
"""
from __future__ import annotations

import time
from typing import Callable

ROWS = []

# ``python -m benchmarks.run --trace-out PATH`` sets this; benchmarks
# that drive a GraphQueryService dump its Chrome-trace JSON here (the
# CI workflow uploads the file as a build artifact).
TRACE_OUT = None


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def rmat_graph(scale: int, edge_factor: int = 16, *, seed: int = 0,
               weighted: bool = False):
    """R-MAT (power-law) benchmark graph — the Graph500-style generator
    in :mod:`repro.core.graph`. Skewed degrees are what make the
    combined exchange's degree-factor compression visible: hub vertices
    collapse many cut edges into one wire entry."""
    from repro.core.graph import rmat
    return rmat(scale, edge_factor, seed=seed, weighted=weighted)


def time_call(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time of fn() in microseconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]
