"""Shared benchmark utilities.

Measured numbers on this box are CPU wall-times — meaningful for RATIOS
(GraVF vs GraVF-M, scaling trends, partitioner quality), not absolute
TEPS. Absolute projections come from the §5 performance model
(core/perfmodel.py with the paper's platform constants) and from the
dry-run roofline (experiments/dryrun). Engine benchmarks use the jnp
backend: interpret-mode Pallas is a correctness vehicle, not a timing one.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, List, Optional

ROWS = []

# ``python -m benchmarks.run --trace-out PATH`` sets this; benchmarks
# that drive a GraphQueryService dump its Chrome-trace JSON here (the
# CI workflow uploads the file as a build artifact).
TRACE_OUT = None

# ``--metrics-out PATH``: the service-driving benchmarks dump a
# MetricsRegistry JSON snapshot here (also a CI artifact).
METRICS_OUT = None

# git-tracked trajectory history entries kept per suite
TRAJECTORY_CAP = 200


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def append_trajectory(suite: str, rows: List[str], wall_s: float,
                      root: Optional[str] = None) -> str:
    """Append one run's rows to ``BENCH_<suite>.json`` at the repo root
    — the git-tracked performance trajectory (each CI run extends it;
    diffs show the numbers moving). Returns the file path."""
    root = root or os.path.dirname(os.path.dirname(os.path.abspath(
        __file__)))
    path = os.path.join(root, f"BENCH_{suite}.json")
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
        except (OSError, ValueError):
            history = []   # a corrupt history never fails the suite
    parsed = []
    for row in rows:
        name, us, derived = row.split(",", 2)
        parsed.append({"name": name, "us_per_call": float(us),
                       "derived": derived})
    history.append({"ts": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                        time.gmtime()),
                    "wall_s": round(wall_s, 3), "rows": parsed})
    history = history[-TRAJECTORY_CAP:]
    with open(path, "w") as f:
        json.dump(history, f, indent=1)
        f.write("\n")
    return path


def rmat_graph(scale: int, edge_factor: int = 16, *, seed: int = 0,
               weighted: bool = False):
    """R-MAT (power-law) benchmark graph — the Graph500-style generator
    in :mod:`repro.core.graph`. Skewed degrees are what make the
    combined exchange's degree-factor compression visible: hub vertices
    collapse many cut edges into one wire entry."""
    from repro.core.graph import rmat
    return rmat(scale, edge_factor, seed=seed, weighted=weighted)


def time_call(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time of fn() in microseconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]
