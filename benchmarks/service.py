"""Query-service benchmark: queries/sec and per-query latency vs batch
size for batched BFS on the uniform-16 dataset (4096 vertices, avg
degree 16 — examples/graph_analytics.py's serving-scale graph).

Each batch size b answers the SAME 64-root query stream in ceil(64/b)
engine invocations through the warmed plan cache, so the ratio of rows
is the amortization the batched query axis buys: the per-superstep
broadcast and the fixed dispatch cost are shared by b queries instead
of paid per query.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import graph as G
from repro.service import GraphQueryService, QueryRequest, percentile

from .common import emit

N_QUERIES = 64
BATCH_SIZES = (1, 8, 32)


def service_throughput():
    g = G.uniform(4096, 16.0, seed=0).symmetrized()
    rng = np.random.default_rng(0)
    roots = rng.integers(0, g.num_vertices, size=N_QUERIES).astype(np.int32)

    for b in BATCH_SIZES:
        svc = GraphQueryService(num_shards=4, max_batch=b)
        svc.add_graph("uniform-16", g)
        svc.warm("uniform-16", "bfs", batch_sizes=[b])

        lat_ms = []
        t0 = time.perf_counter()
        for start in range(0, N_QUERIES, b):
            chunk = roots[start:start + b]
            tb = time.perf_counter()
            futs = [svc.submit(QueryRequest(
                "uniform-16", "bfs", {"root": int(r)},
                deadline_ms=10_000)) for r in chunk]
            svc.flush()
            for f in futs:
                f.result()
            lat_ms.extend([(time.perf_counter() - tb) * 1e3] * len(chunk))
        wall = time.perf_counter() - t0

        snap = svc.stats_snapshot()
        qps = N_QUERIES / wall
        emit(f"service_bfs_batch{b}", wall / N_QUERIES * 1e6,
             f"qps={qps:.1f};p50_ms={percentile(lat_ms, 50):.1f};"
             f"p95_ms={percentile(lat_ms, 95):.1f};"
             f"teps={snap['teps']:.2e};retraces_after_warm="
             f"{snap['plan_traces'] - 1}")
