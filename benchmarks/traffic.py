"""Degree-factor exchange compression: measured wire words vs the §5
traffic model.

The paper's headline systems claim is that combining updates **at the
source shard** before they cross the inter-FPGA network cuts traffic by
roughly the average degree: every cut edge aimed at the same remote
vertex collapses into one (id, payload) wire entry. This benchmark
measures it end to end on a power-law (R-MAT) graph:

  * run the same BFS under ``exchange="unicast"`` (one word per cut
    edge) and ``exchange="combined"`` (one combined entry per distinct
    remote destination) on a 4-device mesh (subprocess — the main
    process keeps 1 CPU device);
  * assert the two runs are **bit-identical** and that steady-state
    re-submission **re-traces nothing**;
  * compare the measured reduction against the perfmodel's analytic
    prediction (uniform-partition shape estimates) and its exact-layout
    prediction (the engine's own padded ``e_pair_max``/``comb_max``),
    which must reproduce the measured counters to within 20%.

``GRAVFM_BENCH_CI=1`` turns the comparisons into gates (exit non-zero
on violation):
    measured reduction >= 5x          (avg degree 64 graph)
    measured reduction >= 0.8x of the analytic degree-factor prediction
    measured combined words within 20% of the exact-layout prediction
    bit-identical results, zero steady-state re-traces

The run always writes ``bench-traffic.json`` (or ``$GRAVFM_TRAFFIC_OUT``)
with the raw numbers; the CI workflow uploads it as a build artifact.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import emit

_SCRIPT = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
from repro.core import graph as G, partition as PT, algorithms as ALG
from repro.core.engine_shardmap import ShardEngine
from repro.launch.mesh import compat_make_mesh

SCALE, EDGE_FACTOR, P = %(scale)d, %(edge_factor)d, 4
g = G.rmat(SCALE, EDGE_FACTOR, seed=7)
pg = PT.partition_graph(g, P, method="greedy", pad_multiple=16)
mesh = compat_make_mesh((P,), ("graph",))

out = {"num_vertices": g.num_vertices, "num_edges": g.num_edges, "P": P}
state = {}
for exch in ("unicast", "combined"):
    eng = ShardEngine(ALG.bfs(), pg, mesh=mesh, exchange=exch,
                      backend="ref")
    r0 = eng.run(root=np.int32(0))          # traces
    traces_warm = eng.traces
    t0 = time.perf_counter()
    r1 = eng.run(root=np.int32(0))          # steady state
    wall = time.perf_counter() - t0
    state[exch] = {k: np.asarray(v) for k, v in r1["state"].items()}
    out[exch] = {
        "wire_words": float(r1["comm"]["wire_words"]),
        "supersteps": int(r1["supersteps"]),
        "messages": int(r1["messages"]),
        "wall_us": wall * 1e6,
        "retraced": eng.traces != traces_warm,
    }
    m = eng.meta
    out.setdefault("layout", {}).update(
        v_max=int(m.v_max), e_pair_max=int(m.e_pair_max),
        comb_max=int(m.comb_max))
out["identical"] = all(
    np.array_equal(state["unicast"][k], state["combined"][k])
    for k in state["unicast"])
print("TRAFFIC-JSON:" + json.dumps(out))
"""


def traffic():
    ci = bool(os.environ.get("GRAVFM_BENCH_CI"))
    scale, edge_factor = (10, 128)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SCRIPT % {"scale": scale, "edge_factor": edge_factor}
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(src)
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError("traffic subprocess failed:\n"
                           + proc.stderr[-3000:])
    line = next(ln for ln in proc.stdout.splitlines()
                if ln.startswith("TRAFFIC-JSON:"))
    meas = json.loads(line[len("TRAFFIC-JSON:"):])

    from repro.core import perfmodel as pm
    wl = pm.Workload(meas["num_vertices"], meas["num_edges"])
    P = meas["P"]
    lay = meas["layout"]
    # analytic prediction: uniform-partition shape estimates only
    red_analytic = pm.traffic_reduction(wl, P)
    # exact-layout prediction: the engine's own padded counters — must
    # reproduce the measured wire words (the counters ARE the layout)
    steps = meas["combined"]["supersteps"]
    pred_comb = steps * pm.words_per_superstep(
        "combined", wl, P, e_pair_max=lay["e_pair_max"],
        remote_dst_max=lay["comb_max"])["total"]
    pred_uni = steps * pm.words_per_superstep(
        "unicast", wl, P, e_pair_max=lay["e_pair_max"])["total"]
    w_uni = meas["unicast"]["wire_words"]
    w_comb = meas["combined"]["wire_words"]
    red_meas = w_uni / max(w_comb, 1e-9)
    model_err = abs(w_comb - pred_comb) / max(pred_comb, 1e-9)

    emit("traffic/rmat%d_ef%d/unicast" % (scale, edge_factor),
         meas["unicast"]["wall_us"],
         "wire_words=%.0f;modeled=%.0f" % (w_uni, pred_uni))
    emit("traffic/rmat%d_ef%d/combined" % (scale, edge_factor),
         meas["combined"]["wall_us"],
         "wire_words=%.0f;modeled=%.0f;model_err=%.3f"
         % (w_comb, pred_comb, model_err))
    emit("traffic/rmat%d_ef%d/reduction" % (scale, edge_factor), 0.0,
         "measured=%.2fx;analytic=%.2fx;identical=%s;retraced=%s"
         % (red_meas, red_analytic, meas["identical"],
            meas["unicast"]["retraced"] or meas["combined"]["retraced"]))

    out_path = os.environ.get("GRAVFM_TRAFFIC_OUT", "bench-traffic.json")
    with open(out_path, "w") as f:
        json.dump({"measured": meas, "predicted": {
            "combined_words": pred_comb, "unicast_words": pred_uni,
            "reduction_analytic": red_analytic},
            "reduction_measured": red_meas,
            "model_err": model_err}, f, indent=2)

    if ci:
        assert meas["identical"], "combined result != unicast result"
        assert not meas["unicast"]["retraced"], "unicast re-traced"
        assert not meas["combined"]["retraced"], "combined re-traced"
        assert red_meas >= 5.0, (
            "measured reduction %.2fx < 5x" % red_meas)
        assert red_meas >= 0.8 * red_analytic, (
            "measured %.2fx < 0.8 * analytic %.2fx"
            % (red_meas, red_analytic))
        assert model_err <= 0.20, (
            "measured combined words %.0f off exact-layout model %.0f "
            "by %.1f%%" % (w_comb, pred_comb, 100 * model_err))
