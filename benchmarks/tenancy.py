"""Multi-tenant serving under a device-memory budget, with the
host-spill residency tier.

Three phases over one memory-budgeted service:

  cold    — each tenant's FIRST burst: partition compile + engine
            trace + upload. The price of a never-seen (or discarded)
            graph, measured per tenant.
  churn   — N tenant graphs round-robin through a budget that fits only
            K of them. Every return to an evicted tenant *faults* — but
            eviction now demotes to the host-spill tier, so the fault
            is a device re-upload: no partitioner re-run and **zero
            re-traces** (the plan cache keeps spilled versions' plans).
            Churn bursts must be dramatically cheaper than cold ones.
  steady  — the same service then serves only K tenants. Their graphs
            stay resident: zero faults, zero re-traces, and per-burst
            latency drops to pure execution.

Then a **fair-share** phase: two tenants flood one query class at
weights 2:1; while the slot array is contended, per-tenant completions
must track the weights (the acceptance bound is ±20%).

``GRAVFM_BENCH_CI=1`` shrinks the workload and exits non-zero unless
(a) churn evicts, spills and faults, (b) churn re-traces nothing and
its spilled faults are >=5x cheaper than cold materialization,
(c) steady state faults and re-traces nothing, (d) the weighted
throughput ratio lands within 20% of the configured 2:1.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import graph as G
from repro.core import partition as PT
from repro.service import GraphQueryService, QueryRequest

from .common import emit


def _tenant_graphs(n_tenants: int, n_vertices: int, deg: float):
    return {f"tenant{i}": G.uniform(n_vertices, deg, seed=10 + i)
            .symmetrized() for i in range(n_tenants)}


def _burst(svc, gid: str, roots, tenant: str) -> float:
    """Submit one burst for ``tenant`` and drain it; returns wall s."""
    t0 = time.perf_counter()
    futs = [svc.submit(QueryRequest(gid, "bfs", {"root": int(r)},
                                    tenant=tenant, deadline_ms=600_000))
            for r in roots]
    svc.flush()
    for f in futs:
        f.result()
    return time.perf_counter() - t0


def tenancy():
    ci = bool(os.environ.get("GRAVFM_BENCH_CI"))
    n_vertices, deg = (256, 4.0) if ci else (1024, 8.0)
    n_tenants, keep = (3, 1) if ci else (4, 2)
    slots = 4 if ci else 8
    burst_q = 4 if ci else 8
    rounds = 2 if ci else 3

    graphs = _tenant_graphs(n_tenants, n_vertices, deg)
    pad = 64
    per_graph = PT.partition_graph(graphs["tenant0"], 4,
                                   pad_multiple=pad).device_nbytes
    budget = (keep + 0.5) * per_graph      # fits `keep` of `n_tenants`

    svc = GraphQueryService(num_shards=4, max_batch=slots, slots=slots,
                            scheduling="continuous",
                            memory_budget=budget, result_cache_size=0)
    for gid, g in graphs.items():
        svc.add_graph(gid, g, pad_multiple=pad)
    rng = np.random.default_rng(0)

    # ---- cold: each tenant's first burst compiles its plans -----------
    cold_lat = []
    for gid in graphs:
        roots = rng.integers(0, n_vertices, size=burst_q)
        cold_lat.append(_burst(svc, gid, roots, tenant=gid))
    cold_snap = svc.stats_snapshot()
    emit("tenancy_cold_burst", float(np.mean(cold_lat)) * 1e6,
         f"tenants={n_tenants};traces={cold_snap['plan_traces']:.0f}")

    # ---- churn: working set (= all tenants) exceeds the budget --------
    # every burst refaults a SPILLED tenant: device re-upload, zero
    # re-traces — the plan cache kept the spilled versions' plans
    churn_lat = []
    for _ in range(rounds):
        for gid in graphs:
            roots = rng.integers(0, n_vertices, size=burst_q)
            churn_lat.append(_burst(svc, gid, roots, tenant=gid))
    churn_snap = svc.stats_snapshot()
    churn_faults = churn_snap["store_faults"] - cold_snap["store_faults"]
    churn_spills = churn_snap["store_spills"] - cold_snap["store_spills"]
    churn_evictions = (churn_snap["store_evictions"]
                       - cold_snap["store_evictions"])
    churn_traces = churn_snap["plan_traces"] - cold_snap["plan_traces"]
    churn_upload_ms = (churn_snap["store_refault_upload_ms"]
                       - cold_snap["store_refault_upload_ms"])
    cold_over_churn = np.mean(cold_lat) / max(np.mean(churn_lat), 1e-9)
    emit("tenancy_churn_burst", float(np.mean(churn_lat)) * 1e6,
         f"tenants={n_tenants};budget_fits={keep};"
         f"faults={churn_faults:.0f};evictions={churn_evictions:.0f};"
         f"spills={churn_spills:.0f};retraces={churn_traces:.0f};"
         f"cold_to_churn_x={cold_over_churn:.1f};"
         f"refault_upload_ms={churn_upload_ms:.2f};"
         f"resident_mb={churn_snap['store_resident_bytes'] / 1e6:.2f};"
         f"spilled_mb={churn_snap['store_spilled_bytes'] / 1e6:.2f}")

    # ---- steady state: working set fits — zero faults, zero re-traces -
    hot = list(graphs)[:keep]
    for gid in hot:                        # fault the hot set back in once
        _burst(svc, gid, rng.integers(0, n_vertices, size=burst_q),
               tenant=gid)
    pre = svc.stats_snapshot()
    steady_lat = []
    for _ in range(rounds * 2):
        for gid in hot:
            roots = rng.integers(0, n_vertices, size=burst_q)
            steady_lat.append(_burst(svc, gid, roots, tenant=gid))
    post = svc.stats_snapshot()
    steady_faults = post["store_faults"] - pre["store_faults"]
    steady_traces = post["plan_traces"] - pre["plan_traces"]
    emit("tenancy_steady_burst", float(np.mean(steady_lat)) * 1e6,
         f"faults={steady_faults:.0f};retraces={steady_traces:.0f};"
         f"fault_to_steady_x="
         f"{np.mean(churn_lat) / max(np.mean(steady_lat), 1e-9):.1f}")

    # ---- weighted fair share: 2:1 under contention --------------------
    fair = GraphQueryService(num_shards=4, max_batch=slots, slots=slots,
                             scheduling="continuous", result_cache_size=0)
    gid = "shared"
    fair.add_graph(gid, graphs["tenant0"], pad_multiple=pad)
    fair.set_tenant("heavy", weight=2.0)
    fair.set_tenant("light", weight=1.0)
    fair.warm(gid, "bfs")
    n_each = 6 * slots
    futs = {"heavy": [], "light": []}
    for _ in range(n_each):
        for t in ("heavy", "light"):
            futs[t].append(fair.submit(QueryRequest(
                gid, "bfs", {"root": int(rng.integers(0, n_vertices))},
                tenant=t, deadline_ms=600_000)))
    done_h = done_l = 0
    for _ in range(10_000):
        fair.poll()
        done_h = sum(f.done() for f in futs["heavy"])
        done_l = sum(f.done() for f in futs["light"])
        if done_h + done_l >= n_each:      # still contended at this point
            break
    ratio = done_h / max(done_l, 1)
    fair.flush()
    for fs in futs.values():
        for f in fs:
            f.result()
    emit("tenancy_fair_share_ratio", 0.0,
         f"target=2.0;measured={ratio:.2f};"
         f"heavy={done_h};light={done_l}")

    if ci:
        errs = []
        if churn_evictions <= 0 or churn_faults <= 0 or churn_spills <= 0:
            errs.append(f"churn did not exercise the spill tier "
                        f"(evictions={churn_evictions}, "
                        f"faults={churn_faults}, spills={churn_spills})")
        if churn_traces != 0:
            errs.append(f"churn re-traced {churn_traces}x under eviction "
                        "pressure (spilled versions must keep their "
                        "compiled plans)")
        if cold_over_churn < 5.0:
            errs.append(f"spilled churn faults only {cold_over_churn:.1f}x "
                        "cheaper than cold materialization (expected >=5x "
                        "— refault must skip partition + trace)")
        if steady_faults != 0:
            errs.append(f"steady state faulted {steady_faults}x "
                        "with a resident working set")
        if steady_traces != 0:
            errs.append(f"steady state re-traced {steady_traces}x "
                        "(plan cache regression)")
        if not (2.0 * 0.8 <= ratio <= 2.0 * 1.25):
            errs.append(f"fair-share ratio {ratio:.2f} outside 2.0 +/-20% "
                        f"(heavy={done_h}, light={done_l})")
        if errs:
            raise SystemExit("tenancy benchmark failed: " + "; ".join(errs))
