"""One benchmark per paper table/figure (see DESIGN.md §11 index).

Each function prints CSV rows ``name,us_per_call,derived`` where derived
carries the figure's headline quantity (speedup, MTEPS ratio, imbalance,
modeled GTEPS, ...).
"""
from __future__ import annotations

import numpy as np

from repro.core import algorithms as ALG
from repro.core import graph as G
from repro.core import partition as PT
from repro.core import perfmodel as pm
from repro.core.engine import Engine

from .common import emit, time_call


def _run(kernel, pg, mode, **kw):
    eng = Engine(kernel, pg, mode=mode, backend="ref", **kw)
    res = eng.run()
    return eng, res


# ---------------------------------------------------------------------------
# Fig. 7 — multi-node scaling, GraVF vs GraVF-M
# ---------------------------------------------------------------------------

def fig7_scaling():
    g = G.uniform(4096, 16.0, seed=0).symmetrized()
    for algo_name, kfn in (("bfs", lambda: ALG.bfs(0)),
                           ("wcc", ALG.wcc),
                           ("pagerank", lambda: ALG.pagerank(10))):
        for p in (1, 2, 4):
            pg = PT.partition_graph(g, p, method="greedy", pad_multiple=32)
            for mode in ("gravf", "gravfm"):
                eng, res = _run(kfn(), pg, mode)
                us = time_call(lambda: eng.run(), warmup=1, iters=3)
                mteps = res.messages / us  # messages per microsecond
                emit(f"fig7/{algo_name}/{mode}/p{p}", us,
                     f"mteps_cpu={mteps:.2f};msgs={res.messages}")
        # the paper's headline: modeled 4-node speedup GraVF-M/GraVF
        wl = pm.Workload(g.num_vertices, g.num_edges)
        a = pm.PAPER_ALGOS.get(algo_name, pm.PAPER_ALGOS["wcc"])
        m = pm.limits(pm.PAPER_PLATFORM, a, wl, n_nodes=4, mode="gravfm")
        b = pm.limits(pm.PAPER_PLATFORM, a, wl, n_nodes=4, mode="gravf")
        emit(f"fig7/{algo_name}/model_speedup_4node", 0.0,
             f"{m['T_sys'] / b['T_sys']:.2f}x"
             f";paper_range=2.2-2.8x")


# ---------------------------------------------------------------------------
# Fig. 8 — single-node GraVF vs GraVF-M
# ---------------------------------------------------------------------------

def fig8_single_node():
    g = G.uniform(4096, 16.0, seed=1).symmetrized()
    pg = PT.partition_graph(g, 1, pad_multiple=32)
    for algo_name, kfn in (("bfs", lambda: ALG.bfs(0)), ("wcc", ALG.wcc)):
        rows = {}
        for mode in ("gravf", "gravfm"):
            eng, res = _run(kfn(), pg, mode)
            rows[mode] = time_call(lambda: eng.run(), iters=3)
        emit(f"fig8/{algo_name}/single_node", rows["gravfm"],
             f"gravf_us={rows['gravf']:.0f};"
             f"ratio={rows['gravfm'] / rows['gravf']:.2f}"
             f";paper=GraVF_faster_on_1node")


# ---------------------------------------------------------------------------
# Fig. 9 — effect of average degree
# ---------------------------------------------------------------------------

def fig9_degree():
    wl_v = 2048
    for deg in (2, 8, 32, 64):
        g = G.uniform(wl_v, float(deg), seed=2).symmetrized()
        pg = PT.partition_graph(g, 4, method="greedy", pad_multiple=32)
        eng, res = _run(ALG.wcc(), pg, "gravfm")
        us = time_call(lambda: eng.run(), iters=3)
        # measured broadcast advantage grows with degree (paper Fig. 9)
        adv = res.comm["unicast_words"] / max(
            res.comm["bcast_filtered_words"], 1)
        wl = pm.Workload(g.num_vertices, g.num_edges)
        lif = pm.limits(pm.PAPER_PLATFORM, pm.PAPER_ALGOS["wcc"], wl,
                        n_nodes=4)["L_if"]
        emit(f"fig9/wcc/deg{deg}", us,
             f"bcast_advantage={adv:.2f};model_L_if_GTEPS={lif / 1e9:.2f}")


# ---------------------------------------------------------------------------
# Fig. 10/11 — latency (ladder graphs)
# ---------------------------------------------------------------------------

def fig11_latency():
    total_v = 2048
    for w, d in ((512, 4), (128, 16), (32, 64), (8, 256)):
        g = G.ladder(w, d, 3, seed=3)
        pg = PT.partition_graph(g, 4, pad_multiple=16)
        eng, res = _run(ALG.bfs(0), pg, "gravfm")
        us = time_call(lambda: eng.run(), iters=2)
        per_ss = us / max(res.supersteps, 1)
        emit(f"fig11/bfs/w{w}_d{d}", us,
             f"supersteps={res.supersteps};us_per_superstep={per_ss:.1f}")
    # w=1 line graph: pure synchronization latency (paper: 676 cyc/ss)
    g = G.line(256)
    pg = PT.partition_graph(g, 4, pad_multiple=16)
    eng, res = _run(ALG.bfs(0), pg, "gravfm")
    us = time_call(lambda: eng.run(), iters=2)
    emit("fig11/bfs/line256", us,
         f"us_per_superstep={us / max(res.supersteps, 1):.1f}"
         f";supersteps={res.supersteps}")


# ---------------------------------------------------------------------------
# Fig. 12/13 — partitioning strategies
# ---------------------------------------------------------------------------

def fig12_partitioning():
    g = G.rmat(12, 8, seed=4)
    for method in ("round_robin", "greedy", "snake_lpt", "ldg"):
        pg = PT.partition_graph(g, 8, method=method, pad_multiple=32)
        bal = PT.edge_balance(pg)
        eng, res = _run(ALG.wcc(), pg, "gravfm")
        us = time_call(lambda: eng.run(), iters=2)
        emit(f"fig12/wcc/{method}", us,
             f"max_over_mean={bal['max_over_mean']:.3f};"
             f"cross_frac={bal['cross_frac']:.3f}")


# ---------------------------------------------------------------------------
# Table 2 — platform constants (model echo)
# ---------------------------------------------------------------------------

def table2_network():
    p = pm.PAPER_PLATFORM
    emit("table2/paper_bw_if", 0.0,
         f"{p.bw_if / 1024 ** 3:.1f}GiB/s;send={p.bw_if / 2 / 1024 ** 3:.2f}"
         f";paper_4fpga_send=5.85GiB/s")
    t = pm.TPU_V5E
    emit("table2/tpu_profile", 0.0,
         f"hbm={t.bw_mem / 1e9:.0f}GB/s;ici={t.bw_if / 1e9:.0f}GB/s"
         f";peak_bf16=197TFLOPs")


# ---------------------------------------------------------------------------
# Table 3 — comparison vs ForeGraph (model projection)
# ---------------------------------------------------------------------------

def table3_comparison():
    foregraph = {"pagerank": 1856e6, "bfs": 1458e6, "wcc": 1727e6}
    paper = {"pagerank": 4623e6, "bfs": 5493e6, "wcc": 5791e6}
    wl = pm.Workload(2 ** 21, 32 * 2 ** 21)
    for algo in ("pagerank", "bfs", "wcc"):
        lim = pm.limits(pm.PAPER_PLATFORM, pm.PAPER_ALGOS[algo], wl,
                        n_nodes=4, mode="gravfm")
        emit(f"table3/{algo}", 0.0,
             f"model_T_sys_MTEPS={lim['T_sys'] / 1e6:.0f};"
             f"paper_MTEPS={paper[algo] / 1e6:.0f};"
             f"foregraph_MTEPS={foregraph[algo] / 1e6:.0f};"
             f"paper_vs_model={paper[algo] / lim['T_sys']:.2%}")
