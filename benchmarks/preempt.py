"""Preemption under deep background load: tight-deadline foreground
arrivals vs slot-hogging deep queries.

The workload is the mixed-depth serving graph (uniform core + a
disconnected deep line tail): background tenants keep every lane busy
with tail-rooted BFS (~tail-length supersteps each), while a foreground
tenant submits shallow core-rooted BFS (~4 supersteps) with a tight
deadline and ``priority=1``. Without preemption a foreground query
waits for a whole background lane to retire — its latency is the
background's *remaining depth*. With preemption the scheduler
checkpoints the laxest background lane's carry to host (zero
re-traces), admits the foreground query into the freed slot, and
restores the parked lane afterwards — foreground latency collapses to
its own depth while background queries still complete bit-identically.

``GRAVFM_BENCH_CI=1`` shrinks the workload and exits non-zero unless
  * foreground p95 improves >= 3x with preemption on vs off,
  * at least one lane was actually preempted and restored, and
  * the preempted queries completed with ZERO re-traces after warm
    (``plan_traces`` flat across every park/restore cycle).
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.service import GraphQueryService, QueryRequest
from repro.service.stats import percentile

from .common import emit
from .continuous import _mixed_graph


def preempt():
    ci = bool(os.environ.get("GRAVFM_BENCH_CI"))
    n_core, deg, tail = (1024, 8.0, 48) if ci else (4096, 16.0, 96)
    slots = 4
    n_fg = 8 if ci else 16
    n_bg = 8 if ci else 16

    g = _mixed_graph(n_core, deg, tail)
    rng = np.random.default_rng(0)
    fg_roots = rng.integers(0, n_core, size=n_fg).astype(np.int32)
    # all background roots sit at the tail head: every lane is ~tail
    # supersteps deep, so without preemption a foreground arrival waits
    # most of a full tail traversal for its slot
    bg_roots = [n_core + (i % 4) for i in range(n_bg)]

    def measure(preemption: bool) -> dict:
        svc = GraphQueryService(num_shards=4, max_batch=slots, slots=slots,
                                scheduling="continuous",
                                result_cache_size=0,
                                preemption=preemption)
        svc.add_graph("mixed", g)
        svc.warm("mixed", "bfs")     # incl. the park/restore programs
        traces0 = svc.stats_snapshot()["plan_traces"]
        # background load: deep queries saturate every lane
        bg = [svc.submit(QueryRequest("mixed", "bfs", {"root": int(r)},
                                      deadline_ms=600_000, tenant="batch"))
              for r in bg_roots]
        for _ in range(3):
            svc.poll()               # lanes fill and go deep
        # foreground: tight-deadline arrivals, one at a time (each must
        # cut ahead of the in-flight deep herd to meet its deadline)
        fg_lat_ms = []
        for r in fg_roots:
            req = QueryRequest("mixed", "bfs", {"root": int(r)},
                               deadline_ms=25, priority=1,
                               tenant="online")
            fut = svc.submit(req)
            while not fut.done():
                svc.poll()
            fg_lat_ms.append(
                (time.perf_counter() - req.arrival_s) * 1e3)
            svc.poll()               # background keeps making progress
        svc.flush()                  # drain (and restore) the background
        for f in bg:
            assert f.result().supersteps > 0
        snap = svc.stats_snapshot()
        # interpolated percentiles (the stats-module reference), not the
        # nearest-rank index — at n=8 the old form reported the 6th of 8
        # samples as "p95"
        p95 = percentile(fg_lat_ms, 95)
        tag = "on" if preemption else "off"
        emit(f"preempt_{tag}_fg", p95 * 1e3,    # us column = p95
             f"p50_ms={percentile(fg_lat_ms, 50):.2f};"
             f"p95_ms={p95:.2f};"
             f"preemptions={snap['preemptions']};"
             f"restores={snap['lane_restores']};"
             f"park_restore_ms={snap['park_restore_ms']:.2f};"
             f"retraces={snap['plan_traces'] - traces0}")
        snap["fg_p95_ms"] = p95
        snap["retraces"] = snap["plan_traces"] - traces0
        return snap

    on = measure(True)
    off = measure(False)
    speedup = off["fg_p95_ms"] / max(on["fg_p95_ms"], 1e-9)
    emit("preempt_fg_p95_speedup", 0.0, f"x{speedup:.2f}")

    # per-root depth prediction: interleave shallow core roots (~4
    # supersteps) with deep tail roots (~tail supersteps) so both
    # populations keep retiring into the same class EWMA. The flat
    # per-class estimate settles on a blend that is wrong for both;
    # the degree-decile buckets separate them (tail roots have
    # out-degree 1, core roots ~deg), so the bucketed predictor is
    # near-exact for each.
    def depth_ab(depth_buckets: bool) -> float:
        svc = GraphQueryService(num_shards=4, max_batch=slots,
                                slots=slots, scheduling="continuous",
                                result_cache_size=0,
                                root_depth_buckets=depth_buckets)
        svc.add_graph("mixed", g)
        svc.warm("mixed", "bfs")
        for i in range(n_fg):
            for r in (int(fg_roots[i]), n_core + (i % 4)):
                fut = svc.submit(QueryRequest(
                    "mixed", "bfs", {"root": r}, deadline_ms=600_000))
                while not fut.done():
                    svc.poll()
        return svc.stats_snapshot()["depth_pred_abs_err"]

    err_b = depth_ab(True)
    err_f = depth_ab(False)
    depth_gain = err_f / max(err_b, 1e-9)
    emit("preempt_depth_pred_abs_err", err_b,
         f"bucketed={err_b:.2f};flat={err_f:.2f};"
         f"improvement=x{depth_gain:.2f}")

    if ci:
        if on["preemptions"] < 1 or on["lane_restores"] < 1:
            raise SystemExit(
                f"preemption never fired: preemptions="
                f"{on['preemptions']} restores={on['lane_restores']}")
        if on["retraces"] != 0:
            raise SystemExit(
                f"park/restore cycles re-traced {on['retraces']} "
                "programs after warm — the zero-re-trace contract broke")
        if on["parked_lanes"] != 0:
            raise SystemExit(
                f"{on['parked_lanes']} lanes left parked after drain")
        if speedup < 3.0:
            raise SystemExit(
                f"foreground p95 speedup x{speedup:.2f} < x3.0 "
                f"(on={on['fg_p95_ms']:.2f}ms off={off['fg_p95_ms']:.2f}"
                "ms) — preemption regression")
        if depth_gain < 1.5:
            raise SystemExit(
                f"degree-decile depth buckets only improved "
                f"depth_pred_abs_err x{depth_gain:.2f} (< x1.5): "
                f"bucketed={err_b:.2f} flat={err_f:.2f}")
