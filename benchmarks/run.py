"""Benchmark harness — one function per paper table/figure plus substrate
micro-benchmarks. Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig7 fig12 # subset by prefix
  PYTHONPATH=src python -m benchmarks.run traceov --trace-out trace.json

Each suite also appends its rows to ``BENCH_<suite>.json`` at the repo
root — the git-tracked performance trajectory (``--no-trajectory``
skips the write, e.g. for scratch runs).
"""
import argparse
import time

from . import common
from . import continuous as CONT
from . import mesh as MESH
from . import paper_figures as PF
from . import preempt as PRE
from . import roofline_table as RT
from . import service as SVC
from . import substrate as SUB
from . import tenancy as TEN
from . import trace_overhead as TRC
from . import traffic as TRF

ALL = {
    "fig7": PF.fig7_scaling,
    "fig8": PF.fig8_single_node,
    "fig9": PF.fig9_degree,
    "fig11": PF.fig11_latency,
    "fig12": PF.fig12_partitioning,
    "table2": PF.table2_network,
    "table3": PF.table3_comparison,
    "layout": SUB.kernel_layout_overhead,
    "train": SUB.lm_train_throughput,
    "compress": SUB.compression_wire,
    "frontier": SUB.frontier_vs_dense_words,
    "roofline": RT.roofline_table,
    "service": SVC.service_throughput,
    "continuous": CONT.continuous_vs_bucketed,
    "tenancy": TEN.tenancy,
    "mesh": MESH.mesh,
    "preempt": PRE.preempt,
    "traceov": TRC.trace_overhead,
    "traffic": TRF.traffic,
}


def main() -> None:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run",
        description="GraVF-M benchmark harness (CSV rows on stdout)")
    ap.add_argument("prefixes", nargs="*",
                    help="run only benchmarks whose name starts with one "
                         "of these (default: all)")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="export a Chrome-trace JSON (Perfetto-loadable) "
                         "of a service benchmark's query lifecycle here")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="dump a service benchmark's metrics-registry "
                         "JSON snapshot here")
    ap.add_argument("--no-trajectory", action="store_true",
                    help="don't append this run to BENCH_<suite>.json")
    args = ap.parse_args()
    common.TRACE_OUT = args.trace_out
    common.METRICS_OUT = args.metrics_out
    print("name,us_per_call,derived")
    for key, fn in ALL.items():
        if args.prefixes and not any(key.startswith(w)
                                     for w in args.prefixes):
            continue
        rows0 = len(common.ROWS)
        t0 = time.perf_counter()
        fn()
        if not args.no_trajectory:
            common.append_trajectory(key, common.ROWS[rows0:],
                                     time.perf_counter() - t0)


if __name__ == "__main__":
    main()
