"""Quickstart: weakly connected components on an RMAT graph with GraVF-M.

The ~30-line user-facing algorithm definition lives in
repro/core/algorithms.py (the same WCC the paper uses as its worked
example); here we generate a graph, partition it, run both architectures,
and print the measured communication the §4.1 optimization saves.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import algorithms as ALG
from repro.core import graph as G
from repro.core import partition as PT
from repro.core.engine import Engine

def main():
    g = G.rmat(12, 16, seed=0).symmetrized()
    print(f"graph: |V|={g.num_vertices} |E|={g.num_edges} "
          f"avg_degree={g.avg_degree:.1f}")
    pg = PT.partition_graph(g, num_parts=4, method="greedy")
    print(f"partitioned into {pg.num_parts} shards; "
          f"balance={PT.edge_balance(pg)}")

    for mode in ("gravf", "gravfm"):
        res = Engine(ALG.wcc(), pg, mode=mode, backend="ref").run()
        n_comp = len(np.unique(res.state["label"]))
        print(f"[{mode:6s}] components={n_comp} supersteps={res.supersteps}"
              f" traversed_edges={res.messages}")
        if mode == "gravfm":
            c = res.comm
            print(f"         network words: unicast(GraVF)="
                  f"{c['unicast_words']:.0f} "
                  f"broadcast+filter(GraVF-M)="
                  f"{c['bcast_filtered_words']:.0f} "
                  f"-> {c['unicast_words']/max(c['bcast_filtered_words'],1):.1f}x less traffic")

if __name__ == "__main__":
    main()
