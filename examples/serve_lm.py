"""Serving demo: batched prefill + greedy decode with KV caches for a
dense arch and O(1)-state decode for a recurrent arch.

  PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np
import jax

from repro import configs
from repro.models import layers as L
from repro.models import lm as LM
from repro.serve.engine import greedy_generate

def main():
    rng = np.random.default_rng(0)
    for arch in ("qwen3-4b", "xlstm-350m", "gemma3-27b"):
        cfg = configs.get(arch, reduced=True)
        params = L.init_params(jax.random.PRNGKey(0), LM.lm_spec(cfg))
        prompts = rng.integers(1, cfg.vocab, (4, 16)).astype(np.int32)
        out = greedy_generate(cfg, params, prompts, num_new=12)
        print(f"{arch:12s} generated {out.shape[1]} tokens/request "
              f"batch={out.shape[0]}; sample row: {out[0][:8]}")

if __name__ == "__main__":
    main()
