"""Query-service quickstart: serve many BFS/SSSP queries over one shared
partitioned graph, with batching, plan caching, and live stats.

  PYTHONPATH=src python examples/query_service.py
"""
import numpy as np

from repro.core import graph as G
from repro.service import GraphQueryService, QueryRequest


def main():
    g = G.uniform(4096, 16.0, seed=0).symmetrized().with_unit_weights()

    svc = GraphQueryService(num_shards=4, max_batch=32)
    svc.add_graph("uniform-16", g)           # partition once, pin on device
    svc.warm("uniform-16", "bfs")            # pre-trace the hot plans

    # --- synchronous one-off -------------------------------------------
    res = svc.query("uniform-16", "bfs", root=0)
    hops = (res.state["parent"] >= 0).sum()
    print(f"bfs root=0: reached {hops}/{g.num_vertices} vertices "
          f"in {res.supersteps} supersteps")

    # --- a traffic burst: 64 queries batched under a deadline ----------
    svc.start()                               # async scheduler thread
    rng = np.random.default_rng(1)
    futs = [svc.submit(QueryRequest("uniform-16", "bfs",
                                    {"root": int(r)}, deadline_ms=100))
            for r in rng.integers(0, g.num_vertices, size=64)]
    depths = [max(f.result().supersteps for f in futs)]
    svc.stop()
    print(f"burst of {len(futs)} bfs queries served; max depth {depths[0]}")

    # --- stats endpoint -------------------------------------------------
    snap = svc.stats_snapshot()
    print("stats:", {k: (round(v, 2) if isinstance(v, float) else v)
                     for k, v in snap.items()
                     if k in ("queries_completed", "batches_dispatched",
                              "avg_batch_size", "plan_cache_hits",
                              "plan_cache_misses", "plan_traces",
                              "qps_busy", "latency_p50_ms",
                              "latency_p95_ms", "teps")})

    # --- continuous scheduling ------------------------------------------
    # scheduling="continuous" drives one superstep at a time: each query
    # retires at ITS OWN depth (not the batch maximum) and queued roots
    # splice into freed slots between supersteps. Identical resubmissions
    # hit the result cache without executing at all.
    csvc = GraphQueryService(num_shards=4, max_batch=16, slots=16,
                             scheduling="continuous")
    csvc.add_graph("uniform-16", g)
    csvc.warm("uniform-16", "bfs")
    croots = [int(r) for r in rng.integers(0, g.num_vertices, size=32)]
    futs = [csvc.submit(QueryRequest("uniform-16", "bfs", {"root": r},
                                     deadline_ms=5000)) for r in croots]
    csvc.flush()                              # pump supersteps to drain
    csvc.submit(QueryRequest("uniform-16", "bfs",
                             {"root": croots[0]}))  # result-cache hit
    csnap = csvc.stats_snapshot()
    print(f"continuous: {csnap['queries_completed']} served, "
          f"p50={csnap['latency_p50_ms']:.1f}ms, "
          f"result_cache_hits={csnap['result_cache_hits']}, "
          f"re-traces={csnap['plan_traces']}")


if __name__ == "__main__":
    main()
