"""End-to-end driver: train a ~100M-parameter qwen3-family model for a few
hundred steps on the synthetic pipeline, with checkpointing — kill the
process at any step and re-run to resume (fault tolerance demo).

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--d-model 512]
"""
import argparse
import dataclasses

from repro import configs
from repro.data.pipeline import DataConfig
from repro.train.loop import TrainConfig, Trainer
from repro.train.optimizer import AdamWConfig

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: d=512, 8 layers, vocab 32k (reduced family config)
    cfg = configs.get(args.arch, reduced=True)
    cfg = dataclasses.replace(
        cfg, d_model=args.d_model, n_heads=8, n_kv=4, head_dim=64,
        d_ff=args.d_model * 4, vocab=32768, repeats=args.layers,
        q_chunk=128, kv_chunk=128)
    from repro.models.lm import num_params
    print(f"arch={cfg.name} params={num_params(cfg)/1e6:.1f}M")

    dc = DataConfig(vocab=cfg.vocab, global_batch=args.batch,
                    seq_len=args.seq)
    oc = AdamWConfig(lr_peak=3e-4, warmup_steps=20, total_steps=args.steps)
    tc = TrainConfig(steps=args.steps, ckpt_every=50,
                     ckpt_dir=args.ckpt_dir, log_every=10)
    out = Trainer(cfg, dc, oc, tc).run()
    print("loss curve:", [(s, round(l, 3)) for s, l in out["losses"]])
    print(f"trained to step {out['final_step']} in {out['seconds']:.0f}s")

if __name__ == "__main__":
    main()
