"""Multi-tenant serving: versioned graphs under a device-memory budget,
with per-tenant quotas and fair-share weights.

  PYTHONPATH=src python examples/multi_tenant.py
"""
import numpy as np

from repro.core import graph as G
from repro.core import partition as PT
from repro.service import AdmissionError, GraphQueryService, QueryRequest


def main():
    # three tenants, each with their own graph
    graphs = {f"tenant-{c}": G.uniform(1024, 8.0, seed=s).symmetrized()
              for c, s in (("a", 1), ("b", 2), ("c", 3))}

    # a budget that fits TWO of the three layouts: the store LRU-evicts
    # the coldest tenant into the HOST-SPILL tier and transparently
    # faults it back on its next query — a device re-upload, not a
    # re-partition + re-trace (Platform.m_board is the real-deployment
    # analogue; spill_budget= caps the host tier, 0 disables spilling)
    per_graph = PT.partition_graph(graphs["tenant-a"], 4).device_nbytes
    svc = GraphQueryService(num_shards=4, max_batch=16, slots=16,
                            scheduling="continuous",
                            memory_budget=2.5 * per_graph)
    for gid, g in graphs.items():
        svc.add_graph(gid, g)

    # tenant policy: "a" gets 2x the slot share of "b"; "c" is rate-capped
    svc.set_tenant("tenant-a", weight=2.0)
    svc.set_tenant("tenant-b", weight=1.0)
    svc.set_tenant("tenant-c", weight=1.0, rate_qps=50, burst=5)

    rng = np.random.default_rng(0)
    for round_ in range(2):
        for gid in graphs:
            futs = [svc.submit(QueryRequest(
                gid, "bfs", {"root": int(r)}, tenant=gid,
                deadline_ms=60_000))
                for r in rng.integers(0, 1024, size=8)]
            svc.flush()
            shed = sum(1 for f in futs if isinstance(f.exception(),
                                                     AdmissionError))
            print(f"round {round_} {gid}: {len(futs) - shed} served, "
                  f"{shed} shed by quota")

    snap = svc.stats_snapshot()
    print(f"\nstore: {snap['store_resident_graphs']} of "
          f"{snap['store_graphs']} graphs resident "
          f"({snap['store_resident_bytes'] / 1e6:.2f} MB / "
          f"{snap['store_budget_bytes'] / 1e6:.2f} MB budget), "
          f"{snap['store_spilled_graphs']:.0f} spilled "
          f"({snap['store_spilled_bytes'] / 1e6:.2f} MB host), "
          f"{snap['store_evictions']:.0f} evictions, "
          f"{snap['store_faults']:.0f} faults "
          f"({snap['store_refault_upload_ms']:.1f} ms re-uploading), "
          f"{snap['store_discards']:.0f} discards")
    for name, t in snap["tenants"].items():
        print(f"  {name}: completed={t['completed']} shed={t['shed']} "
              f"p50={t['latency_p50_ms']:.1f}ms")

    # --- atomic version publish ----------------------------------------
    # re-publishing an id swaps in version N+1: in-flight queries drain
    # on N, new arrivals bind N+1, N's plans drop after the drain
    v2 = svc.publish("tenant-a", G.uniform(1024, 8.0, seed=99).symmetrized())
    res = svc.query("tenant-a", "bfs", root=0, tenant="tenant-a",
                    deadline_ms=60_000)
    print(f"\npublished tenant-a v{v2}; fresh query ran "
          f"{res.supersteps} supersteps on the new graph")


if __name__ == "__main__":
    main()
