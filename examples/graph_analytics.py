"""Graph-analytics suite: BFS, WCC, PageRank, SSSP on several datasets —
the paper's §6 benchmark set end-to-end, printing per-algorithm stats.

  PYTHONPATH=src python examples/graph_analytics.py
"""
import time

import numpy as np

from repro.core import algorithms as ALG
from repro.core import graph as G
from repro.core import partition as PT
from repro.core.engine import Engine

DATASETS = {
    "uniform-16": lambda: G.uniform(4096, 16.0, seed=0).symmetrized(),
    "rmat-8": lambda: G.rmat(12, 8, seed=1).symmetrized(),
    "road": lambda: G.road(64, seed=2),
}

ALGOS = {
    "bfs": lambda: ALG.bfs(0),
    "wcc": ALG.wcc,
    "pagerank": lambda: ALG.pagerank(20),
    "sssp": lambda: ALG.sssp(0),
}

def main():
    for dname, gfn in DATASETS.items():
        g = gfn()
        if "sssp" in ALGOS and g.weights is None:
            g = g.with_unit_weights()
        pg = PT.partition_graph(g, 4, method="greedy")
        print(f"== {dname}: |V|={g.num_vertices} |E|={g.num_edges}")
        for aname, kfn in ALGOS.items():
            eng = Engine(kfn(), pg, mode="gravfm", backend="ref")
            t0 = time.perf_counter()
            res = eng.run()
            dt = time.perf_counter() - t0
            print(f"   {aname:9s} supersteps={res.supersteps:4d} "
                  f"edges_traversed={res.messages:9d} "
                  f"wall={dt*1e3:7.1f}ms")

if __name__ == "__main__":
    main()
