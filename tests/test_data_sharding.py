"""Data pipeline determinism + sharding rule unit tests."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding as SH
from repro.data.pipeline import DataConfig, SyntheticTokens


def test_data_determinism_and_restart_safety():
    cfg = DataConfig(vocab=1000, global_batch=8, seq_len=64)
    a = SyntheticTokens(cfg)
    b = SyntheticTokens(cfg)  # a "restarted" pipeline
    for step in (0, 5, 17):
        ba, bb = a.batch(step), b.batch(step)
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
        np.testing.assert_array_equal(ba["labels"], bb["labels"])
    assert not np.array_equal(a.batch(0)["tokens"], a.batch(1)["tokens"])


def test_data_label_shift():
    cfg = DataConfig(vocab=1000, global_batch=2, seq_len=32)
    b = SyntheticTokens(cfg).batch(0)
    assert b["tokens"].shape == (2, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_process_sharding():
    cfg = DataConfig(vocab=100, global_batch=8, seq_len=16)
    parts = [SyntheticTokens(cfg, process_index=i, process_count=4)
             .batch(3)["tokens"] for i in range(4)]
    assert all(p.shape == (2, 16) for p in parts)
    # different processes see different rows
    assert not np.array_equal(parts[0], parts[1])


def test_logical_to_spec_divisibility():
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((1,), ("model",))
    # 'model' size 1: everything maps but is trivial; use the table only.
    spec = SH.logical_to_spec(mesh, ("batch", None, "vocab"), (8, 4, 100))
    assert isinstance(spec, P)


def test_vocab_padding():
    from repro import configs
    cfg = configs.get("seamless-m4t-medium")
    assert cfg.vocab == 256206           # logical vocab: exact assignment
    assert cfg.vocab_padded % 2048 == 0  # physical table: TP-divisible
    assert cfg.vocab_padded >= cfg.vocab
    for name in configs.ARCH_IDS:
        c = configs.get(name)
        if name != "seamless-m4t-medium":
            assert c.vocab_padded == c.vocab  # others are already divisible


def test_arch_registry_complete():
    from repro import configs
    assert len(configs.ARCH_IDS) == 10
    for name in configs.ARCH_IDS:
        full = configs.get(name)
        red = configs.get(name, reduced=True)
        assert full.name == name
        assert red.n_layers <= full.n_layers
        assert red.d_model < full.d_model
        # reduced preserves the family and pattern structure
        assert red.family == full.family
        assert len(red.block_pattern) == len(full.block_pattern)
        assert [k.mixer for k in red.block_pattern] == \
               [k.mixer for k in full.block_pattern]


def test_assigned_dimensions_exact():
    """The exact assignment table (spot-check every arch)."""
    from repro import configs
    expect = {
        "xlstm-350m": (24, 1024, 4, 0, 50304),
        "seamless-m4t-medium": (24, 1024, 16, 4096, 256206),
        "qwen3-4b": (36, 2560, 32, 9728, 151936),
        "qwen2-72b": (80, 8192, 64, 29568, 152064),
        "gemma3-27b": (62, 5376, 32, 21504, 262144),
        "minitron-4b": (32, 3072, 24, 9216, 256000),
        "internvl2-76b": (80, 8192, 64, 28672, 128256),
        "recurrentgemma-9b": (38, 4096, 16, 12288, 256000),
        "deepseek-moe-16b": (28, 2048, 16, 1408, 102400),
        "deepseek-v2-236b": (60, 5120, 128, 1536, 102400),
    }
    for name, (L_, d, h, ff, v) in expect.items():
        c = configs.get(name)
        n_layers = c.n_layers if c.family != "encdec" else c.n_enc + c.n_dec
        assert n_layers == L_, name
        assert c.d_model == d, name
        assert c.n_heads == h, name
        assert c.d_ff == ff, name
        assert c.vocab == v, name
    # MoE extras
    dm = configs.get("deepseek-moe-16b").moe
    assert (dm.n_routed, dm.n_shared, dm.topk) == (64, 2, 6)
    dv = configs.get("deepseek-v2-236b")
    assert (dv.moe.n_routed, dv.moe.n_shared, dv.moe.topk) == (160, 2, 6)
    assert dv.mla.kv_lora == 512
