"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED config of each family, run one forward + one train step on CPU,
assert output shapes and no NaNs; plus serve-path consistency (prefill +
decode == full forward) which validates every cache layout end-to-end."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import encdec as ED
from repro.models import layers as L
from repro.models import lm as LM
from repro.train.loop import make_train_step
from repro.train.optimizer import AdamWConfig, adamw_init

ARCHS = list(configs.ARCH_IDS)


def _setup(arch):
    cfg = configs.get(arch, reduced=True)
    if cfg.family == "encdec":
        spec = ED.encdec_spec(cfg, cfg.n_enc, cfg.n_dec)
    else:
        spec = LM.lm_spec(cfg)
    params = L.init_params(jax.random.PRNGKey(0), spec)
    return cfg, params


def _batch(cfg, B=2, S=24, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32),
         "labels": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)}
    if cfg.family == "vlm":
        b["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.prefix_len, cfg.d_model)),
            jnp.bfloat16)
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(
            rng.standard_normal((B, 16, cfg.d_model)), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, params = _setup(arch)
    b = _batch(cfg)
    if cfg.family == "encdec":
        logits = ED.encdec_forward(params, b["frames"], b["tokens"], cfg)
        exp_len = b["tokens"].shape[1]
    elif cfg.family == "vlm":
        logits = LM.lm_forward(params, b["tokens"], cfg,
                               prefix_embeds=b["patch_embeds"])
        exp_len = b["tokens"].shape[1] + cfg.prefix_len
    else:
        logits = LM.lm_forward(params, b["tokens"], cfg)
        exp_len = b["tokens"].shape[1]
    assert logits.shape == (2, exp_len, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg, params = _setup(arch)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=1)))
    # step 1: lr == lr_peak (at step 0 the warmup lr is exactly 0)
    p2, opt2, metrics = step(params, opt, _batch(cfg), jnp.int32(1))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    d = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, p2))
    assert max(d) > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if configs.get(a).family != "encdec"])
def test_prefill_decode_consistency(arch):
    """Logits from (prefill T tokens, then decode token T) must match the
    full forward at position T — validates KV caches, recurrent states,
    masked cache updates, and rope positioning for every mixer type."""
    cfg, params = _setup(arch)
    B, T = 2, 12
    rng = np.random.default_rng(1)
    tokens = rng.integers(1, cfg.vocab, (B, T + 1)).astype(np.int32)
    kw = {}
    if cfg.family == "vlm":
        kw["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.prefix_len, cfg.d_model)),
            jnp.bfloat16)
    full = LM.lm_forward(params, tokens, cfg, **kw)

    from repro.serve.engine import make_serve_fns, place_prefill_cache
    prefill, decode, init_cache = make_serve_fns(
        cfg, None, batch=B, max_len=T + 8)
    _, pre_cache = prefill(params, tokens[:, :T], kw.get("prefix_embeds"))
    cache = place_prefill_cache(cfg, pre_cache, init_cache(), T)
    pos = T + (cfg.prefix_len if cfg.family == "vlm" else 0)
    lg, _ = decode(params, cache, jnp.asarray(tokens[:, T:T + 1]),
                   jnp.int32(pos))
    a = np.asarray(full[:, -1, :], np.float32)
    b = np.asarray(lg[:, -1, :], np.float32)
    # bf16 compute: compare top-1 agreement and closeness
    np.testing.assert_allclose(a, b, atol=0.75, rtol=0.1)
    assert (a.argmax(-1) == b.argmax(-1)).mean() >= 0.5


def test_encdec_decode_consistency():
    cfg, params = _setup("seamless-m4t-medium")
    B, T = 2, 10
    rng = np.random.default_rng(2)
    tokens = rng.integers(1, cfg.vocab, (B, T + 1)).astype(np.int32)
    frames = jnp.asarray(rng.standard_normal((B, 12, cfg.d_model)),
                         jnp.bfloat16)
    full = ED.encdec_forward(params, frames, tokens, cfg)
    enc = ED.encode(params, frames, cfg)
    cache = ED.init_encdec_cache(cfg, cfg.n_dec, B, T + 8, 12)
    cache = ED.fill_cross_cache(params, enc, cache, cfg)
    # teacher-force through decode steps
    lg = None
    for t in range(T + 1):
        lg, cache = ED.encdec_decode_step(
            params, cache, jnp.asarray(tokens[:, t:t + 1]), jnp.int32(t),
            cfg)
    a = np.asarray(full[:, -1, :], np.float32)
    b = np.asarray(lg[:, -1, :], np.float32)
    np.testing.assert_allclose(a, b, atol=0.75, rtol=0.1)


def test_moe_routing_is_sparse_and_complete():
    """Every token reaches exactly topk routed experts (within capacity)."""
    from repro.models.moe import _dispatch_compute, moe_spec
    rng = jax.random.PRNGKey(0)
    T, d, E, k = 64, 16, 8, 2
    spec = moe_spec(d, 32, E, 0)
    p = L.init_params(rng, spec)
    x2 = jax.random.normal(rng, (T, d), jnp.bfloat16)
    y = _dispatch_compute(x2, p["router"], p["we_gate"], p["we_up"],
                          p["we_down"], topk=k, capacity=T * k,
                          n_routed=E, e_start=0, e_local=E,
                          renormalize=True)
    assert y.shape == (T, d)
    assert bool(jnp.all(jnp.isfinite(y)))
    # capacity-1 drops most tokens -> output mostly zero rows
    y2 = _dispatch_compute(x2, p["router"], p["we_gate"], p["we_up"],
                           p["we_down"], topk=k, capacity=1,
                           n_routed=E, e_start=0, e_local=E,
                           renormalize=True)
    zero_rows = (jnp.abs(y2.astype(jnp.float32)).sum(-1) == 0).mean()
    assert float(zero_rows) > 0.5


def test_blockwise_attention_matches_naive():
    """Flash-style blockwise attention == naive softmax attention, incl.
    causal + sliding window + GQA."""
    rng = np.random.default_rng(0)
    B, S, H, Hkv, hd = 2, 37, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, hd)), jnp.float32)

    def naive(q, k, v, window):
        G_ = H // Hkv
        qg = q.reshape(B, S, Hkv, G_, hd)
        s = jnp.einsum("bshgd,bthd->bhgst", qg, k) / np.sqrt(hd)
        pos = np.arange(S)
        mask = pos[None, :] <= pos[:, None]
        if window:
            mask &= pos[None, :] > pos[:, None] - window
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgst,bthd->bshgd", a, v)
        return o.reshape(B, S, H, hd)

    from repro.models.layers import blockwise_attention
    for window in (None, 8):
        got = blockwise_attention(q, k, v, causal=True, window=window,
                                  q_chunk=16, kv_chunk=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(
            naive(q, k, v, window)), atol=2e-5, rtol=1e-4)
