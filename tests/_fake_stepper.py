"""Shared fake-stepper harness for scheduler tests.

Implements the full LaneStepper protocol over host numpy arrays —
including the preemption verbs (``fetch_lane``/``restore``), so a
restored lane's step counter RESUMES (the fake's bit-identity) — plus
the hooks the lock/accounting regressions gate on:

  * ``step_hook`` fires inside ``step()`` while the scheduler lock is
    held, so tests can gate superstep boundaries deterministically;
  * ``trace_on_first_step`` makes the fake engine 'trace' once, for the
    compile-wall accounting tests.

A query with kwarg ``depth=d`` is alive for exactly ``d`` steps.
"""
from concurrent.futures import Future
from types import SimpleNamespace

import numpy as np

from repro.service import QueryClass, QueryRequest
from repro.service.continuous import ContinuousScheduler


class FakeEngine:
    def __init__(self, trace_on_first_step=False):
        self.traces = 0
        self.kernel = SimpleNamespace(query_params=("depth",),
                                      max_supersteps=None)
        self._trace_pending = trace_on_first_step

    def lane_result(self, host, lane):
        return SimpleNamespace(messages=1,
                               supersteps=int(host["steps"][lane]))


class FakeStepper:
    def __init__(self, width, engine, step_hook=None):
        self.width = width
        self.engine = engine
        self.step_hook = step_hook or (lambda: None)

    def _probe(self, carry):
        return carry["remaining"] > 0, carry["steps"].copy()

    def init(self, qkw):
        carry = {"remaining": qkw["depth"].astype(np.int64).copy(),
                 "steps": np.zeros(self.width, np.int64)}
        return (carry, *self._probe(carry))

    def admit(self, carry, qkw, fresh):
        carry = {k: v.copy() for k, v in carry.items()}
        carry["remaining"][fresh] = qkw["depth"][fresh]
        carry["steps"][fresh] = 0
        return (carry, *self._probe(carry))

    def step(self, carry, alive):
        self.step_hook()
        if self.engine._trace_pending:
            self.engine.traces += 1
            self.engine._trace_pending = False
        carry = {k: v.copy() for k, v in carry.items()}
        carry["remaining"][alive] -= 1
        carry["steps"][alive] += 1
        return (carry, *self._probe(carry))

    def fetch(self, carry):
        return carry

    def fetch_lane(self, carry, lane):
        return {k: v[lane].copy() for k, v in carry.items()}

    def restore(self, carry, lane_carry, fresh):
        carry = {k: v.copy() for k, v in carry.items()}
        for k in carry:
            carry[k][fresh] = lane_carry[k]
        return (carry, *self._probe(carry))


def fake_scheduler(slots=2, stats=None, trace_on_first_step=False,
                   step_hook=None, **kw):
    """(ContinuousScheduler over a fake stepper, its QueryClass)."""
    eng = FakeEngine(trace_on_first_step)
    splan = SimpleNamespace(engine=eng,
                            stepper=FakeStepper(slots, eng, step_hook),
                            query_params=("depth",))
    sched = ContinuousScheduler(slots=slots, stats=stats,
                                get_stepper=lambda qc: splan, **kw)
    qclass = QueryClass("g", "fake", "gravfm", 4, "ref", 1)
    return sched, qclass


def submit_fake(sched, qclass, depth, deadline_ms=600_000, priority=0,
                tenant="default"):
    fut = Future()
    sched.submit(qclass, QueryRequest("g", "fake", {"depth": depth},
                                      deadline_ms=deadline_ms,
                                      priority=priority, tenant=tenant),
                 fut)
    return fut
