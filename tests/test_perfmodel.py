"""§5 analytical model: validated against the paper's own published
numbers (Table 3 peaks vs model limits, eq. 5 speedup, §5.7 optimizer)."""
import math

import pytest

from repro.core import perfmodel as pm

WL_PEAK = pm.Workload(num_vertices=2 ** 21, num_edges=32 * 2 ** 21)
# Table 3 peak MTEPS (paper, 4 FPGAs, edgefactor-32 dataset)
REPORTED = {"wcc": 5.791e9, "bfs": 5.493e9, "pagerank": 4.623e9}


@pytest.mark.parametrize("algo", ["wcc", "bfs", "pagerank"])
def test_paper_peaks_within_model_limits(algo):
    """The paper reports reaching 'up to 94% of the projected limit'.
    Check every reported peak is (a) below the model limit and (b) at
    least 85% of it — i.e. the model reproduces §6's relationship."""
    lim = pm.limits(pm.PAPER_PLATFORM, pm.PAPER_ALGOS[algo], WL_PEAK,
                    n_nodes=4, mode="gravfm")
    frac = REPORTED[algo] / lim["T_sys"]
    assert 0.85 <= frac <= 1.0, (algo, frac)


def test_pe_limit_is_binding_at_peak():
    """On the paper's platform at edgefactor 32, GraVF-M removes the
    network bottleneck: L_PE binds (paper §6.3.3)."""
    lim = pm.limits(pm.PAPER_PLATFORM, pm.PAPER_ALGOS["wcc"], WL_PEAK,
                    n_nodes=4, mode="gravfm")
    assert lim["bottleneck"] == "L_PE"


def test_gravf_baseline_is_network_bound():
    """...whereas GraVF (unicast) is interface-bound on the same setup,
    which is the paper's whole motivation (Fig. 7)."""
    lim = pm.limits(pm.PAPER_PLATFORM, pm.PAPER_ALGOS["wcc"], WL_PEAK,
                    n_nodes=4, mode="gravf")
    assert lim["bottleneck"] in ("L_if", "L_net")
    lim_m = pm.limits(pm.PAPER_PLATFORM, pm.PAPER_ALGOS["wcc"], WL_PEAK,
                      n_nodes=4, mode="gravfm")
    assert lim_m["T_sys"] > lim["T_sys"]


def test_eq5_speedup():
    s = pm.speedup_eq5(pm.PAPER_ALGOS["wcc"], WL_PEAK, 4)
    assert abs(s - 32 / 4) < 1e-9  # |E|/|V| / n * (m_u/m_m = 1)


def test_speedup_matches_limit_ratio_when_network_bound():
    """eq. 5 == L_if(GraVF-M)/L_if(GraVF) identically."""
    wl = pm.Workload(num_vertices=2 ** 20, num_edges=6 * 2 ** 20)
    a = pm.PAPER_ALGOS["bfs"]
    for n in (2, 3, 4):
        m = pm.limits(pm.PAPER_PLATFORM, a, wl, n_nodes=n, mode="gravfm")
        g = pm.limits(pm.PAPER_PLATFORM, a, wl, n_nodes=n, mode="gravf")
        assert math.isclose(m["L_if"] / g["L_if"],
                            pm.speedup_eq5(a, wl, n), rel_tol=1e-9)


def test_degree_dependence():
    """Fig. 9: GraVF-M network limit scales with |E|/|V|."""
    a = pm.PAPER_ALGOS["wcc"]
    lims = [pm.limits(pm.PAPER_PLATFORM, a,
                      pm.Workload(2 ** 20, d * 2 ** 20), n_nodes=4)
            ["L_if"] for d in (2, 8, 32)]
    assert lims[0] < lims[1] < lims[2]
    assert math.isclose(lims[2] / lims[0], 16.0, rel_tol=1e-9)


def test_memory_granularity_refinement():
    """§5.4: the access-granularity term reduces effective bandwidth, and
    saturates at one memory word per edge."""
    a = pm.PAPER_ALGOS["wcc"]
    wl = pm.Workload(2 ** 20, 2 * 2 ** 20)  # avg degree 2: worst case
    base = pm.limits(pm.PAPER_PLATFORM, a, wl, n_nodes=4,
                     granularity=False)["L_mem"]
    refined = pm.limits(pm.PAPER_PLATFORM, a, wl, n_nodes=4, n_pe=9,
                        granularity=True)["L_mem"]
    assert refined < base
    floor = 4 * pm.PAPER_PLATFORM.bw_mem / pm.PAPER_PLATFORM.m_memword
    assert refined >= floor * 0.99


def test_optimizer_picks_paper_configuration():
    """§5.7 on the paper's platform picks 4 FPGAs and full 9 PEs for WCC
    (compute-bound) at edgefactor 32."""
    out = pm.optimize(pm.PAPER_PLATFORM, pm.PAPER_ALGOS["wcc"], WL_PEAK)
    assert out["n_nodes"] == 4
    assert out["n_pe"] == 9


def test_optimizer_power_reduction_when_network_bound():
    """For a sparse graph (network-bound), §5.7 lowers n_PE below max."""
    wl = pm.Workload(2 ** 22, 2 * 2 ** 22)  # degree 2
    out = pm.optimize(pm.PAPER_PLATFORM, pm.PAPER_ALGOS["wcc"], wl,
                      mode="gravf")
    if out["bottleneck"] in ("L_if", "L_net"):
        assert out["n_pe"] < pm.PAPER_PLATFORM.n_pe_max


def test_min_nodes_for_memory():
    a = pm.PAPER_ALGOS["wcc"]
    wl = pm.Workload(10 ** 9, 16 * 10 ** 9)  # too big for one 4GB board
    assert pm.min_nodes_for_memory(pm.PAPER_PLATFORM, a, wl) > 1


# ---- exchange-schedule traffic model (degree-factor compression) ------

def test_words_allgather_reproduces_eq3():
    """The word-based L_if/L_net derivation must reproduce the paper's
    closed-form eq. 3/6 exactly for the allgather schedule with the
    analytic v_max = |V|/P."""
    a = pm.PAPER_ALGOS["bfs"]
    for n in (2, 4, 8):   # divide |V| evenly, so ceil() is exact
        wl = pm.Workload(2 ** 20, 12 * 2 ** 20)
        base = pm.limits(pm.PAPER_PLATFORM, a, wl, n_nodes=n)
        wlim = pm.limits(pm.PAPER_PLATFORM, a, wl, n_nodes=n,
                         exchange="allgather")
        assert math.isclose(base["L_if"], wlim["L_if"], rel_tol=1e-9)
        assert math.isclose(base["L_net"], wlim["L_net"], rel_tol=1e-9)


def test_combined_never_exceeds_unicast():
    """min(2*remote_dst, e_pair) clamps the combined schedule at the
    per-edge cost, so combined <= unicast for EVERY workload — sparse
    graphs degrade to per-edge blocks instead of paying the (id,
    payload) doubling on singleton destinations."""
    for deg in (1, 2, 4, 8, 32, 128):
        for p in (2, 4, 8):
            wl = pm.Workload(1 << 16, deg << 16)
            uni = pm.words_per_superstep("unicast", wl, p)["total"]
            comb = pm.words_per_superstep("combined", wl, p)["total"]
            assert comb <= uni + 1e-9, (deg, p, comb, uni)


def test_traffic_reduction_monotone_in_degree():
    """The degree-factor claim: as avg degree grows, more cut edges share
    each remote destination and the reduction grows monotonically."""
    reds = [pm.traffic_reduction(pm.Workload(1 << 16, d << 16), 4)
            for d in (2, 4, 8, 16, 32, 64, 128)]
    assert all(b >= a - 1e-9 for a, b in zip(reds, reds[1:])), reds
    assert reds[-1] > 10.0   # deg 128 over 4 shards: >> degree/2P floor


def test_exact_layout_overrides():
    """Passing the engine's padded layout counters reproduces its wire
    counters exactly: unicast = e_pair_max*(P-1)*P, combined =
    2*comb_max*(P-1)*P per superstep."""
    wl = pm.Workload(1024, 57266)
    uni = pm.words_per_superstep("unicast", wl, 4, e_pair_max=3784)
    comb = pm.words_per_superstep("combined", wl, 4, e_pair_max=3784,
                                  remote_dst_max=264)
    assert uni["total"] == 3784 * 3 * 4
    assert comb["total"] == 2 * 264 * 3 * 4


def test_combined_lifts_interface_limit_on_paper_platform():
    """On the paper's platform at edgefactor 32, switching the traffic
    term from per-edge unicast to combine-at-source lifts L_if by the
    degree factor — the systems claim the whole PR reproduces."""
    a = pm.PAPER_ALGOS["wcc"]
    uni = pm.limits(pm.PAPER_PLATFORM, a, WL_PEAK, n_nodes=4,
                    exchange="unicast")
    comb = pm.limits(pm.PAPER_PLATFORM, a, WL_PEAK, n_nodes=4,
                     exchange="combined")
    red = pm.traffic_reduction(WL_PEAK, 4)
    assert math.isclose(comb["L_if"] / uni["L_if"], red, rel_tol=1e-9)
    # dense graph: reduction saturates at deg/(2P) = 32/8 = 4x
    assert math.isclose(red, 4.0, rel_tol=1e-3)
    # measured-wire override takes precedence over the schedule name
    w = pm.words_per_superstep("combined", WL_PEAK, 4)["total"]
    meas = pm.limits(pm.PAPER_PLATFORM, a, WL_PEAK, n_nodes=4,
                     wire_words=w)
    assert math.isclose(meas["L_if"], comb["L_if"], rel_tol=1e-9)


def test_words_single_node_and_unknown_exchange():
    wl = pm.Workload(1 << 16, 8 << 16)
    assert pm.words_per_superstep("combined", wl, 1)["total"] == 0.0
    assert pm.limits(pm.PAPER_PLATFORM, pm.PAPER_ALGOS["wcc"], wl,
                     n_nodes=1, exchange="combined")["L_if"] == math.inf
    with pytest.raises(ValueError):
        pm.words_per_superstep("bogus", wl, 4)


def test_tpu_profile_mxu_flips_bottleneck():
    """The VPU mask kernel is compute-limited; the one-hot MXU variant
    moves the bottleneck to network/memory — the §Perf hillclimb axis."""
    wl = WL_PEAK
    vpu = pm.limits(pm.TPU_V5E, pm.tpu_algo("wcc", tile_r=256), wl,
                    n_nodes=256, n_pe=1)
    mxu = pm.limits(pm.TPU_V5E, pm.tpu_algo("wcc", tile_r=256, mxu=True),
                    wl, n_nodes=256, n_pe=1)
    assert vpu["bottleneck"] == "L_PE"
    assert mxu["bottleneck"] != "L_PE"
    assert mxu["T_sys"] > vpu["T_sys"]
