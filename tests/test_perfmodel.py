"""§5 analytical model: validated against the paper's own published
numbers (Table 3 peaks vs model limits, eq. 5 speedup, §5.7 optimizer)."""
import math

import pytest

from repro.core import perfmodel as pm

WL_PEAK = pm.Workload(num_vertices=2 ** 21, num_edges=32 * 2 ** 21)
# Table 3 peak MTEPS (paper, 4 FPGAs, edgefactor-32 dataset)
REPORTED = {"wcc": 5.791e9, "bfs": 5.493e9, "pagerank": 4.623e9}


@pytest.mark.parametrize("algo", ["wcc", "bfs", "pagerank"])
def test_paper_peaks_within_model_limits(algo):
    """The paper reports reaching 'up to 94% of the projected limit'.
    Check every reported peak is (a) below the model limit and (b) at
    least 85% of it — i.e. the model reproduces §6's relationship."""
    lim = pm.limits(pm.PAPER_PLATFORM, pm.PAPER_ALGOS[algo], WL_PEAK,
                    n_nodes=4, mode="gravfm")
    frac = REPORTED[algo] / lim["T_sys"]
    assert 0.85 <= frac <= 1.0, (algo, frac)


def test_pe_limit_is_binding_at_peak():
    """On the paper's platform at edgefactor 32, GraVF-M removes the
    network bottleneck: L_PE binds (paper §6.3.3)."""
    lim = pm.limits(pm.PAPER_PLATFORM, pm.PAPER_ALGOS["wcc"], WL_PEAK,
                    n_nodes=4, mode="gravfm")
    assert lim["bottleneck"] == "L_PE"


def test_gravf_baseline_is_network_bound():
    """...whereas GraVF (unicast) is interface-bound on the same setup,
    which is the paper's whole motivation (Fig. 7)."""
    lim = pm.limits(pm.PAPER_PLATFORM, pm.PAPER_ALGOS["wcc"], WL_PEAK,
                    n_nodes=4, mode="gravf")
    assert lim["bottleneck"] in ("L_if", "L_net")
    lim_m = pm.limits(pm.PAPER_PLATFORM, pm.PAPER_ALGOS["wcc"], WL_PEAK,
                      n_nodes=4, mode="gravfm")
    assert lim_m["T_sys"] > lim["T_sys"]


def test_eq5_speedup():
    s = pm.speedup_eq5(pm.PAPER_ALGOS["wcc"], WL_PEAK, 4)
    assert abs(s - 32 / 4) < 1e-9  # |E|/|V| / n * (m_u/m_m = 1)


def test_speedup_matches_limit_ratio_when_network_bound():
    """eq. 5 == L_if(GraVF-M)/L_if(GraVF) identically."""
    wl = pm.Workload(num_vertices=2 ** 20, num_edges=6 * 2 ** 20)
    a = pm.PAPER_ALGOS["bfs"]
    for n in (2, 3, 4):
        m = pm.limits(pm.PAPER_PLATFORM, a, wl, n_nodes=n, mode="gravfm")
        g = pm.limits(pm.PAPER_PLATFORM, a, wl, n_nodes=n, mode="gravf")
        assert math.isclose(m["L_if"] / g["L_if"],
                            pm.speedup_eq5(a, wl, n), rel_tol=1e-9)


def test_degree_dependence():
    """Fig. 9: GraVF-M network limit scales with |E|/|V|."""
    a = pm.PAPER_ALGOS["wcc"]
    lims = [pm.limits(pm.PAPER_PLATFORM, a,
                      pm.Workload(2 ** 20, d * 2 ** 20), n_nodes=4)
            ["L_if"] for d in (2, 8, 32)]
    assert lims[0] < lims[1] < lims[2]
    assert math.isclose(lims[2] / lims[0], 16.0, rel_tol=1e-9)


def test_memory_granularity_refinement():
    """§5.4: the access-granularity term reduces effective bandwidth, and
    saturates at one memory word per edge."""
    a = pm.PAPER_ALGOS["wcc"]
    wl = pm.Workload(2 ** 20, 2 * 2 ** 20)  # avg degree 2: worst case
    base = pm.limits(pm.PAPER_PLATFORM, a, wl, n_nodes=4,
                     granularity=False)["L_mem"]
    refined = pm.limits(pm.PAPER_PLATFORM, a, wl, n_nodes=4, n_pe=9,
                        granularity=True)["L_mem"]
    assert refined < base
    floor = 4 * pm.PAPER_PLATFORM.bw_mem / pm.PAPER_PLATFORM.m_memword
    assert refined >= floor * 0.99


def test_optimizer_picks_paper_configuration():
    """§5.7 on the paper's platform picks 4 FPGAs and full 9 PEs for WCC
    (compute-bound) at edgefactor 32."""
    out = pm.optimize(pm.PAPER_PLATFORM, pm.PAPER_ALGOS["wcc"], WL_PEAK)
    assert out["n_nodes"] == 4
    assert out["n_pe"] == 9


def test_optimizer_power_reduction_when_network_bound():
    """For a sparse graph (network-bound), §5.7 lowers n_PE below max."""
    wl = pm.Workload(2 ** 22, 2 * 2 ** 22)  # degree 2
    out = pm.optimize(pm.PAPER_PLATFORM, pm.PAPER_ALGOS["wcc"], wl,
                      mode="gravf")
    if out["bottleneck"] in ("L_if", "L_net"):
        assert out["n_pe"] < pm.PAPER_PLATFORM.n_pe_max


def test_min_nodes_for_memory():
    a = pm.PAPER_ALGOS["wcc"]
    wl = pm.Workload(10 ** 9, 16 * 10 ** 9)  # too big for one 4GB board
    assert pm.min_nodes_for_memory(pm.PAPER_PLATFORM, a, wl) > 1


def test_tpu_profile_mxu_flips_bottleneck():
    """The VPU mask kernel is compute-limited; the one-hot MXU variant
    moves the bottleneck to network/memory — the §Perf hillclimb axis."""
    wl = WL_PEAK
    vpu = pm.limits(pm.TPU_V5E, pm.tpu_algo("wcc", tile_r=256), wl,
                    n_nodes=256, n_pe=1)
    mxu = pm.limits(pm.TPU_V5E, pm.tpu_algo("wcc", tile_r=256, mxu=True),
                    wl, n_nodes=256, n_pe=1)
    assert vpu["bottleneck"] == "L_PE"
    assert mxu["bottleneck"] != "L_PE"
    assert mxu["T_sys"] > vpu["T_sys"]
