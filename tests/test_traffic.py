"""Degree-factor exchange compression, service-level.

Covers the satellite pieces of the traffic PR that aren't in
tests/test_engine_shardmap.py (which owns engine-level bit-identity):

  * the R-MAT generator really produces power-law degree skew (the
    property that makes combine-at-source pay off at the hubs);
  * the perfmodel's analytic degree-factor prediction tracks the exact
    layout-derived reduction on real partitioned graphs;
  * a served class can SWITCH exchange mode (per-request ``exchange``)
    with zero steady-state re-traces, bit-identical answers, and wire
    words flowing into the stats endpoint and superstep trace events.

The service test needs >1 device, so it runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=4."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import graph as G
from repro.core import partition as PT
from repro.core import perfmodel as pm

try:        # property-test over many seeds when hypothesis is around,
    from hypothesis import given, settings
    from hypothesis import strategies as st
    _wide_seeds = lambda f: settings(max_examples=10, deadline=None)(
        given(seed=st.integers(min_value=0, max_value=1000))(f))
except ImportError:   # otherwise a fixed-seed sweep of the same property
    _wide_seeds = pytest.mark.parametrize(
        "seed", [0, 7, 42, 123, 500, 999])


@_wide_seeds
def test_rmat_degree_skew(seed):
    """R-MAT is power-law: its max/avg total-degree ratio dwarfs a
    uniform graph of the same size (hubs exist for combining to win
    on)."""
    g = G.rmat(9, 16, seed=seed)
    deg = (np.bincount(g.dst, minlength=g.num_vertices)
           + np.bincount(g.src, minlength=g.num_vertices))
    u = G.uniform(g.num_vertices, g.num_edges / g.num_vertices, seed=seed)
    du = (np.bincount(u.dst, minlength=u.num_vertices)
          + np.bincount(u.src, minlength=u.num_vertices))
    skew_r = deg.max() / deg.mean()
    skew_u = du.max() / du.mean()
    assert skew_r > 8.0, skew_r           # heavy tail
    assert skew_r > 3.0 * skew_u, (skew_r, skew_u)


def test_benchmark_rmat_helper_matches_core():
    from benchmarks.common import rmat_graph
    a, b = rmat_graph(8, 8, seed=3), G.rmat(8, 8, seed=3)
    assert np.array_equal(a.src, b.src) and np.array_equal(a.dst, b.dst)


@pytest.mark.parametrize("scale,ef", [(9, 64), (10, 128)])
def test_layout_reduction_tracks_analytic_model(scale, ef):
    """The exact-layout reduction (e_pair_max / 2*comb_max, what the
    engine's wire counters measure) stays within 2x of the analytic
    coupon-collector prediction on real partitioned R-MAT graphs."""
    g = G.rmat(scale, ef, seed=7)
    pg = PT.partition_graph(g, 4, method="greedy", pad_multiple=16)
    cb = pg.combined_buckets()
    exact = pg.e_pair_max / (2.0 * cb["comb_max"])
    ana = pm.traffic_reduction(
        pm.Workload(g.num_vertices, g.num_edges), 4)
    assert exact > 1.0                     # combining pays off at all
    assert 0.5 * ana <= exact <= 2.0 * ana, (exact, ana)


def test_combined_buckets_invariants():
    """Per-(shard, peer) buckets: ranks are dense per bucket, invalid
    edges land in the discard rank, and comb_dst lists each bucket's
    distinct destinations."""
    g = G.rmat(8, 16, seed=1)
    pg = PT.partition_graph(g, 4, method="greedy", pad_multiple=16)
    cb = pg.combined_buckets()
    R = cb["comb_max"]
    P = pg.num_parts
    assert cb["dst_rank"].shape == (P, P, pg.e_pair_max)
    assert cb["comb_dst"].shape == (P, P, R)
    for p in range(P):
        for q in range(P):
            valid = cb["valid"][p, q]
            ranks = cb["dst_rank"][p, q]
            assert (ranks[~valid] == R).all()
            used = np.unique(ranks[valid])
            if used.size:
                assert used.max() < R
                # each valid edge's bucket entry names its destination
                assert (cb["comb_dst"][p, q][ranks[valid]]
                        == cb["dst_local"][p, q][valid]).all()
            # never-used rank slots hold the v_max sentinel
            unused = np.setdiff1d(np.arange(R), used)
            assert (cb["comb_dst"][p, q][unused] == pg.v_max).all()


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, {src!r})
import numpy as np
from repro.core import graph as G
from repro.service.server import GraphQueryService
from repro.service.batching import QueryRequest

g = G.rmat(8, 32, seed=5)

# ---- bucketed service, per-request exchange switching ----------------
svc = GraphQueryService(num_shards=4, max_batch=1, backend="ref",
                        exchange="unicast", result_cache_size=0)
svc.add_graph("rmat", g)

def run(root, exchange=""):
    req = QueryRequest("rmat", "bfs", {{"root": int(root)}},
                       exchange=exchange)
    fut, qclass = svc._submit(req)
    svc.flush(qclass)
    return fut.result()

# warm both exchange classes (each traces once)
base = run(0)                       # service default: unicast
comb = run(0, exchange="combined")
assert np.array_equal(base.state["parent"], comb.state["parent"])
traces_warm = svc.plans.sync_trace_counters()

# steady state: switching a served class's exchange mode re-traces
# NOTHING — each mode's plan stays cached independently
for root in (3, 9, 21, 40):
    a = run(root)
    b = run(root, exchange="combined")
    assert np.array_equal(a.state["parent"], b.state["parent"]), root
    assert a.supersteps == b.supersteps and a.messages == b.messages
    assert b.comm["exchange"] == "combined"
    assert 0 < b.comm["wire_words"] < a.comm["wire_words"], (
        root, b.comm["wire_words"], a.comm["wire_words"])
assert svc.plans.sync_trace_counters() == traces_warm

# wire words reached the stats endpoint, split per exchange class
snap = svc.stats_snapshot()
assert snap["wire_words_total"] > 0
per_class = {{ck: r["wire_words"] for ck, r in snap["roofline"].items()}}
assert any(ck.endswith("+combined") and w > 0
           for ck, w in per_class.items()), per_class
assert all(r["words_per_message"] >= 0 for r in snap["roofline"].values())

# ---- continuous service: superstep trace events carry wire words -----
svc2 = GraphQueryService(num_shards=4, max_batch=4, slots=4,
                         backend="ref", exchange="combined",
                         scheduling="continuous", result_cache_size=0)
svc2.add_graph("rmat", g)
futs = [svc2.submit(QueryRequest("rmat", "bfs", {{"root": r}}))
        for r in (0, 3, 9)]
svc2.flush()
ref = run(9, exchange="combined")
got = futs[2].result()
assert np.array_equal(got.state["parent"], ref.state["parent"])
steps = [ev for ev in svc2.trace_snapshot() if ev.kind == "superstep"]
assert steps and any(ev.attrs.get("words", 0.0) > 0 for ev in steps), (
    [ev.attrs for ev in steps[:3]])
assert svc2.stats_snapshot()["wire_words_total"] > 0
print("TRAFFIC-SERVICE-OK")
"""


@pytest.mark.slow
def test_service_exchange_switch_multidevice():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SCRIPT.format(src=os.path.abspath(src))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "TRAFFIC-SERVICE-OK" in proc.stdout
