"""Pallas edge-traversal kernel: shape/dtype sweeps + hypothesis
properties against the pure-jnp oracle (ref.py)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't abort collection
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.layout import build_layout


def _random_sorted_segments(rng, n_edges, n_segments):
    seg = np.sort(rng.integers(0, n_segments, size=n_edges)).astype(np.int64)
    return seg


def _run_both(seg, vals, num_segments, combiner, tile_e, tile_r):
    layout = build_layout(seg, num_segments, tile_e=tile_e, tile_r=tile_r)
    vals_padded = layout.place(np.asarray(vals), 0)
    ident = kops.identity_for(combiner, vals_padded.dtype)
    vp = jnp.where(jnp.asarray(layout.lane_valid), jnp.asarray(vals_padded),
                   ident)
    out_k = kops.segment_combine_layout(vp, layout, combiner,
                                        interpret=True)
    out_r = kref.segment_combine(jnp.asarray(vals),
                                 jnp.asarray(seg.astype(np.int32)),
                                 num_segments, combiner)
    return np.asarray(out_k), np.asarray(out_r)


@pytest.mark.parametrize("combiner,dtype", [
    ("min", np.float32), ("min", np.int32),
    ("max", np.float32), ("max", np.int32),
    ("add", np.float32), ("add", np.int32),
])
@pytest.mark.parametrize("n_edges,n_segments,tile_e,tile_r", [
    (0, 16, 32, 16),         # empty graph
    (1, 1, 32, 16),          # single edge
    (500, 64, 64, 32),       # dense-ish
    (500, 2000, 64, 32),     # sparse (most segments empty)
    (777, 130, 128, 64),     # non-multiple sizes
    (2048, 64, 256, 256),    # hub rows spanning many tiles
])
def test_kernel_vs_ref_sweep(combiner, dtype, n_edges, n_segments,
                             tile_e, tile_r):
    rng = np.random.default_rng(n_edges * 7 + n_segments)
    seg = _random_sorted_segments(rng, n_edges, n_segments)
    if np.issubdtype(dtype, np.floating):
        vals = rng.standard_normal(n_edges).astype(dtype)
    else:
        vals = rng.integers(-1000, 1000, size=n_edges).astype(dtype)
    out_k, out_r = _run_both(seg, vals, n_segments, combiner, tile_e,
                             tile_r)
    if combiner == "add" and np.issubdtype(dtype, np.floating):
        np.testing.assert_allclose(out_k, out_r, rtol=1e-5, atol=1e-5)
    else:
        np.testing.assert_array_equal(out_k, out_r)


@settings(max_examples=30, deadline=None)
@given(
    n_edges=st.integers(0, 300),
    n_segments=st.integers(1, 200),
    combiner=st.sampled_from(["min", "max", "add"]),
    seed=st.integers(0, 2 ** 16),
)
def test_kernel_vs_ref_hypothesis(n_edges, n_segments, combiner, seed):
    rng = np.random.default_rng(seed)
    seg = _random_sorted_segments(rng, n_edges, n_segments)
    vals = rng.integers(-50, 50, size=n_edges).astype(np.int32)
    out_k, out_r = _run_both(seg, vals, n_segments, combiner, 32, 16)
    np.testing.assert_array_equal(out_k, out_r)


@settings(max_examples=25, deadline=None)
@given(n_edges=st.integers(0, 400), n_segments=st.integers(1, 300),
       tile_e=st.sampled_from([16, 64, 256]),
       tile_r=st.sampled_from([8, 32, 128]), seed=st.integers(0, 99))
def test_layout_invariants(n_edges, n_segments, tile_e, tile_r, seed):
    """Structural invariants of the static tile layout:
    - every edge gets exactly one lane (injective placement),
    - window ids are non-decreasing (output blocks revisit contiguously),
    - a lane's window matches its edge's segment's window,
    - padding lanes carry rel == tile_r (match no row)."""
    rng = np.random.default_rng(seed)
    seg = _random_sorted_segments(rng, n_edges, n_segments)
    lo = build_layout(seg, n_segments, tile_e=tile_e, tile_r=tile_r)
    lanes = lo.lane_of_edge
    assert len(np.unique(lanes)) == n_edges
    assert (np.diff(lo.window_id) >= 0).all()
    lane_window = np.repeat(lo.window_id, tile_e)
    assert (lane_window[lanes] == seg // tile_r).all()
    pad = np.ones(lo.num_lanes, bool)
    pad[lanes] = False
    assert (lo.rel[pad] == tile_r).all()
    assert (lo.rel[lanes] == seg - (seg // tile_r) * tile_r).all()


def test_carry_combine_matches_lexicographic():
    """(key, carry) combine == lexicographic (min key, then min carry)."""
    rng = np.random.default_rng(0)
    n, s = 400, 37
    seg = _random_sorted_segments(rng, n, s)
    keys = rng.integers(0, 10, size=n).astype(np.float32)
    carry = rng.integers(0, 1000, size=n).astype(np.int32)
    acc, car = kref.segment_combine_carry(
        jnp.asarray(keys), jnp.asarray(carry),
        jnp.asarray(seg.astype(np.int32)), s, "min",
        np.iinfo(np.int32).max)
    acc, car = np.asarray(acc), np.asarray(car)
    for b in range(s):
        m = seg == b
        if not m.any():
            assert np.isinf(acc[b])
            continue
        kmin = keys[m].min()
        assert acc[b] == kmin
        assert car[b] == carry[m][keys[m] == kmin].min()
