"""Continuous batching: a query spliced into an in-flight superstep
loop at any step t must be bit-identical to a solo ``Engine.run`` (state,
superstep count, message count); steady-state slot recycling must
re-trace nothing; the service-level scheduler must retire finished
queries mid-flight, serve the result cache, and shed infeasible
deadlines. Plus regression pins: ``drain()`` keeps the
between-supersteps admission window open (lock released between pumps),
compile walls are accounted to ``compile_time_s`` instead of polluting
``busy_time_s``, and the linear-interpolation ``percentile`` fix."""
import threading
import time

import numpy as np
import pytest

from repro.core import algorithms as ALG
from repro.core import graph as G
from repro.core import partition as PT
from repro.core.engine import Engine
from repro.service import (AdmissionError, GraphQueryService, QueryClass,
                           QueryRequest, ServiceStats, percentile)


@pytest.fixture(scope="module")
def deep_graph():
    # ladder: BFS depth varies strongly with the root's rank, so lanes
    # genuinely retire at different supersteps
    return G.ladder(2, 30, 1, seed=0)


@pytest.fixture(scope="module")
def graph():
    return G.uniform(500, 8.0, seed=11, weighted=True).symmetrized()


def drive_continuous(eng, width, arrivals, cap=100_000):
    """Host-drive a LaneStepper: ``arrivals`` is a list of
    (join_at_global_superstep, query_kwargs); queries join the in-flight
    loop at (or after, when no slot is free) their step. Returns results
    in arrival order."""
    st = eng.make_stepper(width)
    lanes = [None] * width          # arrival index or None
    results = {}
    qkw = None
    carry = None
    pending = sorted(range(len(arrivals)), key=lambda i: arrivals[i][0])
    gstep = 0
    for _ in range(10_000):
        # admit everything due whose slot exists
        fresh = np.zeros(width, bool)
        for slot in range(width):
            if lanes[slot] is not None or not pending:
                continue
            if arrivals[pending[0]][0] > gstep:
                break
            idx = pending.pop(0)
            kw = arrivals[idx][1]
            if qkw is None:
                qkw = {p: np.full((width,), v, np.int32)
                       for p, v in kw.items()}
            for p, v in kw.items():
                qkw[p][slot] = v
            lanes[slot] = idx
            fresh[slot] = True
        if fresh.any():
            carry, act, steps = (st.init(qkw) if carry is None
                                 else st.admit(carry, qkw, fresh))
        occupied = np.array([ln is not None for ln in lanes], bool)
        if not occupied.any():
            if not pending:
                break
            gstep += 1
            continue
        act, steps = st.probe(carry)
        done = occupied & (~act | (steps >= cap))
        if done.any():
            host = st.fetch(carry)
            for slot in np.nonzero(done)[0]:
                results[lanes[slot]] = eng.lane_result(host, int(slot))
                lanes[slot] = None
            continue   # freed slots admit before the next step
        alive = occupied & act
        carry, act, steps = st.step(carry, alive)
        gstep += 1
    assert len(results) == len(arrivals), "scheduler failed to drain"
    return [results[i] for i in range(len(arrivals))]


# ---------------------------------------------------------------------------
# mid-flight join == solo run, across modes and backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["gravfm", "gravf"])
def test_join_midflight_matches_solo_ref(deep_graph, mode):
    pg = PT.partition_graph(deep_graph, 4, method="greedy", pad_multiple=16)
    eng = Engine(ALG.bfs(), pg, mode=mode, backend="ref")
    n = deep_graph.num_vertices
    # root 0 runs ~31 supersteps; the others join at steps 3/7/15 with
    # varying depths (roots near the far end quiesce almost immediately)
    arrivals = [(0, {"root": 0}), (3, {"root": n - 1}),
                (7, {"root": n // 2}), (15, {"root": 5})]
    outs = drive_continuous(eng, 3, arrivals)
    for (_, kw), res in zip(arrivals, outs):
        ref = Engine(ALG.bfs(int(kw["root"])), pg, mode=mode,
                     backend="ref").run()
        assert np.array_equal(res.state["parent"], ref.state["parent"])
        assert res.supersteps == ref.supersteps
        assert res.messages == ref.messages


def test_join_midflight_matches_solo_pallas(deep_graph):
    pg = PT.partition_graph(deep_graph, 4, method="greedy", pad_multiple=16)
    eng = Engine(ALG.bfs(), pg, mode="gravfm", backend="pallas",
                 tile_e=64, tile_r=32)
    n = deep_graph.num_vertices
    arrivals = [(0, {"root": 0}), (4, {"root": n - 2}), (9, {"root": 17})]
    outs = drive_continuous(eng, 2, arrivals)
    for (_, kw), res in zip(arrivals, outs):
        ref = Engine(ALG.bfs(int(kw["root"])), pg, mode="gravfm",
                     backend="pallas", tile_e=64, tile_r=32).run()
        assert np.array_equal(res.state["parent"], ref.state["parent"])
        assert res.supersteps == ref.supersteps


def test_join_midflight_sssp_carry(graph):
    """The argmin carry path (SSSP parent pointers) through the stepper."""
    pg = PT.partition_graph(graph, 4, method="greedy", pad_multiple=16)
    eng = Engine(ALG.sssp(), pg, mode="gravfm", backend="ref")
    arrivals = [(0, {"root": 0}), (2, {"root": 250}), (4, {"root": 77})]
    outs = drive_continuous(eng, 2, arrivals)
    for (_, kw), res in zip(arrivals, outs):
        ref = Engine(ALG.sssp(int(kw["root"])), pg, mode="gravfm",
                     backend="ref").run()
        assert np.array_equal(res.state["dist"].view(np.int32),
                              ref.state["dist"].view(np.int32))
        assert np.array_equal(res.state["parent"], ref.state["parent"])


def test_join_midflight_property(deep_graph):
    """Property form: random roots joining at random in-flight steps."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st_

    pg = PT.partition_graph(deep_graph, 4, method="greedy", pad_multiple=16)
    eng = Engine(ALG.bfs(), pg, mode="gravfm", backend="ref")
    n = deep_graph.num_vertices
    solo_cache = {}

    def solo(root):
        if root not in solo_cache:
            solo_cache[root] = Engine(ALG.bfs(int(root)), pg, mode="gravfm",
                                      backend="ref").run()
        return solo_cache[root]

    @settings(max_examples=10, deadline=None)
    @given(st_.lists(
        st_.tuples(st_.integers(0, 25), st_.integers(0, n - 1)),
        min_size=1, max_size=5))
    def check(joins):
        arrivals = [(t, {"root": r}) for t, r in sorted(joins)]
        outs = drive_continuous(eng, 2, arrivals)
        for (_, kw), res in zip(arrivals, outs):
            ref = solo(kw["root"])
            assert np.array_equal(res.state["parent"], ref.state["parent"])
            assert res.supersteps == ref.supersteps
            assert res.messages == ref.messages

    check()


def test_steady_state_slot_recycling_zero_retrace(graph):
    """After the first full admit/step/retire cycle, recycling slots
    through arbitrarily many queries must re-trace nothing."""
    pg = PT.partition_graph(graph, 4, method="greedy", pad_multiple=16)
    eng = Engine(ALG.bfs(), pg, mode="gravfm", backend="ref")
    drive_continuous(eng, 2, [(0, {"root": 0}), (1, {"root": 9})])
    traces0 = eng.traces
    assert traces0 >= 3   # init + admit + step
    drive_continuous(eng, 2, [(0, {"root": 3}), (2, {"root": 88}),
                              (5, {"root": 123}), (6, {"root": 200})])
    assert eng.traces == traces0


# ---------------------------------------------------------------------------
# service-level continuous scheduling
# ---------------------------------------------------------------------------

def test_service_continuous_end_to_end(graph):
    pg = PT.partition_graph(graph, 4, method="greedy", pad_multiple=16)
    svc = GraphQueryService(num_shards=4, max_batch=8,
                            scheduling="continuous", slots=4)
    svc.add_graph("g", graph, pad_multiple=16)
    futs = [svc.submit(QueryRequest("g", "bfs", {"root": int(r)}))
            for r in range(10)]
    svc.flush()
    for r, f in enumerate(futs):
        ref = Engine(ALG.bfs(r), pg, mode="gravfm", backend="ref").run()
        res = f.result(timeout=0)
        assert np.array_equal(res.state["parent"], ref.state["parent"])
        assert res.supersteps == ref.supersteps
    snap = svc.stats_snapshot()
    assert snap["queries_completed"] == 10
    assert snap["scheduling"] == "continuous"


def test_service_continuous_zero_retrace_and_mixed_retire(graph):
    svc = GraphQueryService(num_shards=4, max_batch=8,
                            scheduling="continuous", slots=4)
    svc.add_graph("g", graph, pad_multiple=16)
    svc.warm("g", "bfs")
    traces0 = svc.stats_snapshot()["plan_traces"]
    for wave in range(3):
        futs = [svc.submit(QueryRequest("g", "bfs",
                                        {"root": wave * 16 + r}))
                for r in range(8)]
        svc.flush()
        assert all(f.done() for f in futs)
    snap = svc.stats_snapshot()
    assert snap["plan_traces"] == traces0    # acceptance: zero re-traces
    assert snap["queries_completed"] == 24


def test_service_continuous_retires_midflight_and_admits(deep_graph):
    """Short queries must resolve while a deep query is still in
    flight, and the freed slots must take queued work."""
    pg = PT.partition_graph(deep_graph, 4, method="greedy", pad_multiple=16)
    svc = GraphQueryService(num_shards=4, max_batch=8,
                            scheduling="continuous", slots=2)
    svc.add_graph("g", deep_graph, pad_multiple=16)
    n = deep_graph.num_vertices
    deep_f = svc.submit(QueryRequest("g", "bfs", {"root": 0}))
    short_f = svc.submit(QueryRequest("g", "bfs", {"root": n - 1}))
    queued_f = svc.submit(QueryRequest("g", "bfs", {"root": n - 3}))
    # pump a few supersteps: the short query retires, the deep one
    # doesn't, and the queued query takes the freed slot
    for _ in range(8):
        svc.poll()
    assert short_f.done() and not deep_f.done()
    svc.flush()
    for root, f in ((0, deep_f), (n - 1, short_f), (n - 3, queued_f)):
        ref = Engine(ALG.bfs(int(root)), pg, mode="gravfm",
                     backend="ref").run()
        assert np.array_equal(f.result().state["parent"],
                              ref.state["parent"])


def test_service_continuous_respects_superstep_cap(deep_graph):
    svc = GraphQueryService(num_shards=4, max_batch=8,
                            scheduling="continuous", slots=2,
                            max_supersteps=3)
    svc.add_graph("g", deep_graph, pad_multiple=16)
    f = svc.submit(QueryRequest("g", "bfs", {"root": 0}))
    svc.flush()
    assert f.result().supersteps == 3


def test_service_continuous_step_failure_fails_futures(graph):
    """A device/program error mid-pump must resolve every affected
    Future with the exception (bucketed-batch contract), not strand
    them or kill the scheduler."""
    svc = GraphQueryService(num_shards=4, max_batch=8,
                            scheduling="continuous", slots=2)
    svc.add_graph("g", graph, pad_multiple=16)
    splan = svc.plans.get_stepper(svc._plan_key("g", "bfs", "gravfm", 2))

    def boom(carry, alive):
        raise RuntimeError("injected step failure")

    orig = splan.stepper.step
    splan.stepper.step = boom
    try:
        f1 = svc.submit(QueryRequest("g", "bfs", {"root": 0}))
        f2 = svc.submit(QueryRequest("g", "bfs", {"root": 1}))
        svc.poll()
        with pytest.raises(RuntimeError, match="injected"):
            f1.result(timeout=0)
        with pytest.raises(RuntimeError, match="injected"):
            f2.result(timeout=0)
        assert svc.pending() == 0
    finally:
        splan.stepper.step = orig
    # the class recovers on the next submit
    f3 = svc.submit(QueryRequest("g", "bfs", {"root": 2}))
    svc.flush()
    assert f3.result() is not None


# ---------------------------------------------------------------------------
# scheduler-lock + stats-accounting regressions (fake stepper harness,
# shared with tests/test_preempt.py)
# ---------------------------------------------------------------------------

from _fake_stepper import fake_scheduler as _fake_scheduler  # noqa: E402
from _fake_stepper import submit_fake as _submit_fake  # noqa: E402


def test_cancelled_straggler_does_not_livelock_class():
    """Regression: a queued request cancelled before admission must be
    purged by the next admission window — not pin pending() above zero
    forever, and not starve another tenant's live query behind the
    stride pick of an all-cancelled queue."""
    sched, qclass = _fake_scheduler(slots=1)
    dead = _submit_fake(sched, qclass, depth=3, tenant="a")
    assert dead.cancel()
    live = _submit_fake(sched, qclass, depth=2, tenant="b")
    sched.drain(max_pumps=1_000)
    assert live.result(timeout=0).supersteps == 2
    assert sched.pending() == 0 and not sched.has_work()


def test_drain_keeps_admission_window_open():
    """Regression: drain() used to hold the scheduler lock for the whole
    loop, so a concurrent submit blocked until everything finished. Now
    the lock is released between supersteps and the raced submit is
    drained by the SAME drain call."""
    gate = threading.Semaphore(0)
    in_step = threading.Event()

    def hook():                      # blocks each superstep (lock held)
        in_step.set()
        gate.acquire()

    sched, qclass = _fake_scheduler(step_hook=hook)
    fut1 = _submit_fake(sched, qclass, depth=6)
    order = []

    def drainer():
        sched.drain()
        order.append("drain")

    t = threading.Thread(target=drainer)
    t.start()
    assert in_step.wait(10)          # superstep 1 in progress
    fut2 = None
    got = {}

    def submitter():
        got["fut2"] = _submit_fake(sched, qclass, depth=2)
        order.append("submit")

    s = threading.Thread(target=submitter)
    s.start()
    # release supersteps one at a time until the raced submit lands —
    # with the old whole-drain lock it could only land after "drain"
    for _ in range(200):
        if not s.is_alive():
            break
        gate.release()
        s.join(0.05)
    s.join(10)
    assert not s.is_alive(), "submit never landed while draining"
    while t.is_alive():              # let the drain finish everything
        gate.release()
        t.join(0.01)
    assert order and order[0] == "submit", order
    fut2 = got["fut2"]
    assert fut1.done() and fut2.done()
    assert fut2.result().supersteps == 2   # drained by the same drain


def test_compile_wall_excluded_from_busy_time():
    """Regression: a traced step's wall must land in compile_time_s, not
    busy_time_s (which feeds qps_busy/TEPS) — only the EWMA was guarded
    before."""

    class _RecordingStats:
        def __init__(self):
            self.busy, self.compile, self.superstep = [], [], []
            self.pump_steps = 0

        def record_busy(self, w, class_key=None):
            self.busy.append(w)

        def record_compile(self, w):
            self.compile.append(w)

        def record_pump_step(self):
            self.pump_steps += 1

        def record_superstep_time(self, ck, w, n_steps=1):
            self.superstep.append((ck, w))

        def record_retire(self, messages, latency_ms, class_key=None):
            pass

        def record_deadline_miss(self, n=1):
            pass

        def record_query_depth(self, ck, supersteps):
            pass

        def record_depth_error(self, ck, abs_err):
            pass

        def record_preempt(self, wall_s):
            pass

        def record_restore(self, wall_s):
            pass

        def class_cost_model(self, ck):
            return (None, None)

        def depth_residual(self, ck):
            return None

        def record_tenant(self, tenant, **kw):
            pass

        def record_queue_wait(self, wait_ms):
            pass

    stats = _RecordingStats()
    sched, qclass = _fake_scheduler(stats=stats, trace_on_first_step=True)
    fut = _submit_fake(sched, qclass, depth=3)
    sched.pump()                     # first step traces
    assert len(stats.compile) == 1
    assert stats.busy == [] and stats.superstep == []
    sched.pump()                     # steady-state step
    assert len(stats.busy) == 1 and len(stats.superstep) == 1
    assert len(stats.compile) == 1
    assert stats.pump_steps == 2
    sched.drain()
    assert fut.result().supersteps == 3


def test_service_compile_time_surfaced_in_stats(graph):
    """End to end: the first continuous dispatch compiles; its wall goes
    to compile_time_s and busy_time_s stays execution-only."""
    svc = GraphQueryService(num_shards=4, max_batch=4,
                            scheduling="continuous", slots=4)
    svc.add_graph("g", graph, pad_multiple=16)
    svc.query("g", "bfs", root=0, deadline_ms=60_000)
    snap = svc.stats_snapshot()
    assert snap["compile_time_s"] > 0.0
    assert snap["busy_time_s"] > 0.0
    # the compile (seconds of tracing) dwarfs the executed supersteps
    assert snap["compile_time_s"] > snap["busy_time_s"]


def test_backlog_pending_lock_consistent():
    """backlog()/pending() take the scheduler lock: while a pump is
    mid-superstep (lock held), a stats read blocks instead of observing
    a half-spliced slot array."""
    gate = threading.Semaphore(0)
    in_step = threading.Event()

    def hook():                      # blocks the superstep, lock held
        in_step.set()
        gate.acquire()

    sched, qclass = _fake_scheduler(step_hook=hook)
    futs = [_submit_fake(sched, qclass, depth=3) for _ in range(3)]
    t = threading.Thread(target=sched.pump)
    t.start()
    assert in_step.wait(10)
    got = {}

    def reader():
        got["pending"] = sched.pending()
        got["backlog"] = sched.backlog(qclass)

    r = threading.Thread(target=reader)
    r.start()
    r.join(0.3)
    # the read must NOT complete while the pump holds the lock
    assert r.is_alive(), "pending() returned mid-pump (racy read)"
    gate.release()
    while t.is_alive():
        gate.release()
        t.join(0.01)
    r.join(10)
    assert not r.is_alive()
    # post-pump state is consistent: 2 in flight (slots) + 1 queued
    assert got["pending"] == 3
    assert got["backlog"] == 1
    for _ in range(100):             # let the remaining supersteps run
        gate.release()
    sched.drain()
    assert all(f.result().supersteps == 3 for f in futs)


# ---------------------------------------------------------------------------
# result cache
# ---------------------------------------------------------------------------

def test_result_cache_partitioned_by_tenant(graph):
    """One tenant's burst must not evict another tenant's hot results,
    and per-tenant hit counts surface in the stats endpoint."""
    svc = GraphQueryService(num_shards=4, max_batch=1,
                            result_cache_size=2)
    svc.add_graph("g", graph, pad_multiple=16)
    svc.query("g", "bfs", root=0, tenant="a")       # a's hot result
    # b floods ITS partition well past the bound
    for r in range(1, 6):
        svc.query("g", "bfs", root=r, tenant="b")
    assert len(svc._result_cache["b"]) == 2          # b's LRU bounded
    b0 = svc.stats_snapshot()["batches_dispatched"]
    svc.query("g", "bfs", root=0, tenant="a")        # still cached
    snap = svc.stats_snapshot()
    assert snap["result_cache_hits"] == 1
    assert snap["batches_dispatched"] == b0          # no re-execution
    assert snap["tenants"]["a"]["result_cache_hits"] == 1
    assert snap["tenants"]["b"]["result_cache_hits"] == 0
    # partitions are an isolation boundary: b never sees a's entry
    svc.query("g", "bfs", root=0, tenant="b")
    assert svc.stats_snapshot()["batches_dispatched"] == b0 + 1


def test_result_cache_hits_skip_execution(graph):
    svc = GraphQueryService(num_shards=4, max_batch=4)
    svc.add_graph("g", graph, pad_multiple=16)
    for r in range(4):
        svc.submit(QueryRequest("g", "bfs", {"root": r}))
    snap0 = svc.stats_snapshot()
    assert snap0["batches_dispatched"] == 1
    # identical resubmission: resolved from the cache, no dispatch
    f = svc.submit(QueryRequest("g", "bfs", {"root": 2}))
    assert f.done()
    snap = svc.stats_snapshot()
    assert snap["result_cache_hits"] == 1
    assert snap["batches_dispatched"] == 1
    assert svc.pending() == 0
    # a different root misses
    f2 = svc.submit(QueryRequest("g", "bfs", {"root": 99}))
    assert not f2.done()
    svc.flush()
    assert svc.stats_snapshot()["result_cache_hits"] == 1


def test_result_cache_hits_do_not_alias(graph, pg=None):
    """A client mutating its result in place must not poison the cache
    or later hits (store and lookup both copy)."""
    svc = GraphQueryService(num_shards=4, max_batch=1)
    svc.add_graph("g", graph, pad_multiple=16)
    r1 = svc.query("g", "bfs", root=3)
    clean = r1.state["parent"].copy()
    r1.state["parent"][:] = -99          # client scribbles on its copy
    f = svc.submit(QueryRequest("g", "bfs", {"root": 3}))
    r2 = f.result(timeout=0)
    assert svc.stats_snapshot()["result_cache_hits"] == 1
    assert np.array_equal(r2.state["parent"], clean)
    # and a hit's mutation doesn't leak back either
    r2.state["parent"][:] = -7
    r3 = svc.submit(QueryRequest("g", "bfs", {"root": 3})).result(timeout=0)
    assert np.array_equal(r3.state["parent"], clean)


def test_result_cache_lru_bound(graph):
    svc = GraphQueryService(num_shards=4, max_batch=1,
                            result_cache_size=2)
    svc.add_graph("g", graph, pad_multiple=16)
    for r in (0, 1, 2):     # evicts root 0
        svc.query("g", "bfs", root=r)
    # the cache is partitioned by tenant; one tenant -> one partition,
    # bounded to result_cache_size entries
    assert sum(len(p) for p in svc._result_cache.values()) == 2
    b0 = svc.stats_snapshot()["batches_dispatched"]
    svc.query("g", "bfs", root=0)   # evicted -> re-executed
    assert svc.stats_snapshot()["result_cache_hits"] == 0
    assert svc.stats_snapshot()["batches_dispatched"] == b0 + 1
    svc.query("g", "bfs", root=2)   # still resident -> hit, no dispatch
    snap = svc.stats_snapshot()
    assert snap["result_cache_hits"] == 1
    assert snap["batches_dispatched"] == b0 + 1


def test_result_cache_disabled(graph):
    svc = GraphQueryService(num_shards=4, max_batch=1,
                            result_cache_size=0)
    svc.add_graph("g", graph, pad_multiple=16)
    svc.query("g", "bfs", root=1)
    svc.query("g", "bfs", root=1)   # re-executed, not served from cache
    snap = svc.stats_snapshot()
    assert snap["result_cache_hits"] == 0
    assert snap["batches_dispatched"] == 2


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_admission_control_sheds_infeasible_deadline(graph):
    svc = GraphQueryService(num_shards=4, max_batch=4,
                            scheduling="continuous", slots=4,
                            admission_control=True)
    svc.add_graph("g", graph, pad_multiple=16)
    # cold class: no cost model yet -> everything admitted
    f = svc.submit(QueryRequest("g", "bfs", {"root": 0},
                                deadline_ms=0.0001))
    svc.flush()
    assert f.result() is not None
    # now the EWMA exists; an impossible deadline is shed immediately
    f2 = svc.submit(QueryRequest("g", "bfs", {"root": 1},
                                 deadline_ms=0.0001))
    with pytest.raises(AdmissionError):
        f2.result(timeout=0)
    snap = svc.stats_snapshot()
    assert snap["queries_shed"] == 1
    # and a feasible one still goes through
    f3 = svc.submit(QueryRequest("g", "bfs", {"root": 1},
                                 deadline_ms=60_000))
    svc.flush()
    assert f3.result() is not None
    assert svc.stats_snapshot()["queries_shed"] == 1


def test_admission_control_bucketed_mode(graph):
    svc = GraphQueryService(num_shards=4, max_batch=4,
                            admission_control=True)
    svc.add_graph("g", graph, pad_multiple=16)
    # two waves: the first dispatch compiles (excluded from the cost
    # model by design), the second feeds the superstep EWMA
    for r in range(8):
        svc.submit(QueryRequest("g", "bfs", {"root": r}))
    f = svc.submit(QueryRequest("g", "bfs", {"root": 9},
                                deadline_ms=0.0001))
    with pytest.raises(AdmissionError):
        f.result(timeout=0)
    assert svc.stats_snapshot()["queries_shed"] == 1


def test_admission_control_off_by_default(graph):
    svc = GraphQueryService(num_shards=4, max_batch=4)
    svc.add_graph("g", graph, pad_multiple=16)
    for r in range(4):
        svc.submit(QueryRequest("g", "bfs", {"root": r}))
    f = svc.submit(QueryRequest("g", "bfs", {"root": 9},
                                deadline_ms=0.0001))
    svc.flush()
    assert f.result() is not None   # late, but served


# ---------------------------------------------------------------------------
# percentile: linear interpolation + p99
# ---------------------------------------------------------------------------

def test_percentile_linear_interpolation():
    assert percentile([], 50) == 0.0
    assert percentile([7.0], 50) == 7.0
    # the banker's-rounding bug made p50 of 2 samples return vs[0];
    # linear interpolation gives the midpoint
    assert percentile([1.0, 2.0], 50) == pytest.approx(1.5)
    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == pytest.approx(2.5)
    assert percentile([1.0, 2.0, 3.0], 50) == pytest.approx(2.0)
    vs = list(map(float, range(1, 101)))
    assert percentile(vs, 0) == 1.0
    assert percentile(vs, 100) == 100.0
    assert percentile(vs, 99) == pytest.approx(99.01)
    assert percentile(vs, 95) == pytest.approx(95.05)


def test_snapshot_has_p99():
    stats = ServiceStats()
    stats.record_batch(n_queries=1, n_pad=0, wall_s=0.01, messages=10,
                       supersteps=2, latencies_ms=[1.0, 2.0, 3.0, 100.0])
    snap = stats.snapshot()
    assert "latency_p99_ms" in snap
    assert snap["latency_p50_ms"] == pytest.approx(2.5)
    assert snap["latency_p99_ms"] <= snap["latency_max_ms"]
