"""End-to-end behaviour tests: the GraVF-M engine vs independent oracles.

Covers the paper's three algorithms (BFS/WCC/PR) plus SSSP, on uniform and
RMAT graphs, in BOTH architectures (gravf unicast baseline / gravfm
broadcast) and both backends (pallas kernel / jnp ref), and checks the
§4.1 communication claim on measured counters.
"""
import numpy as np
import pytest

from repro.core import algorithms as ALG
from repro.core import graph as G
from repro.core import partition as PT
from repro.core.engine import Engine


def _union_find_labels(g):
    parent = list(range(g.num_vertices))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for s, d in zip(g.src, g.dst):
        a, b = find(int(s)), find(int(d))
        if a != b:
            parent[max(a, b)] = min(a, b)
    comp = np.array([find(v) for v in range(g.num_vertices)])
    labels = np.zeros(g.num_vertices, np.int64)
    for c in np.unique(comp):
        m = comp == c
        labels[m] = np.arange(g.num_vertices)[m].min()
    return labels


def _bfs_oracle(g, root=0):
    INF = 10 ** 9
    lvl = np.full(g.num_vertices, INF)
    lvl[root] = 0
    adj = {}
    for s, d in zip(g.src, g.dst):
        adj.setdefault(int(s), []).append(int(d))
    frontier, cur = [root], 0
    while frontier:
        nxt = []
        for u in frontier:
            for v in adj.get(u, []):
                if lvl[v] == INF:
                    lvl[v] = cur + 1
                    nxt.append(v)
        frontier = nxt
        cur += 1
    radj = {}
    for s, d in zip(g.src, g.dst):
        radj.setdefault(int(d), []).append(int(s))
    par = np.full(g.num_vertices, -1)
    par[root] = root
    for v in range(g.num_vertices):
        if lvl[v] < INF and v != root:
            par[v] = min(u for u in radj[v] if lvl[u] == lvl[v] - 1)
    return par, lvl


def _pr_oracle(g, iters=30):
    N = g.num_vertices
    outdeg = np.maximum(g.out_degrees(), 1)
    score = np.full(N, 1.0 / N)
    for _ in range(iters):
        contrib = score / outdeg
        acc = np.zeros(N)
        np.add.at(acc, g.dst, contrib[g.src])
        score = 0.15 / N + 0.85 * acc
    return score


def _sssp_oracle(g):
    dist = np.full(g.num_vertices, np.inf)
    dist[0] = 0.0
    for _ in range(g.num_vertices):
        nd = dist[g.src] + g.weights
        tmp = dist.copy()
        np.minimum.at(tmp, g.dst, nd)
        if np.allclose(tmp, dist, equal_nan=True):
            break
        dist = tmp
    return dist


@pytest.fixture(scope="module")
def graphs():
    return {
        "uniform": G.uniform(300, 5.0, seed=7).symmetrized(),
        "rmat": G.rmat(8, 6, seed=3).symmetrized(),
    }


@pytest.mark.parametrize("gname", ["uniform", "rmat"])
@pytest.mark.parametrize("mode,backend", [
    ("gravfm", "pallas"), ("gravfm", "ref"), ("gravf", "ref")])
def test_wcc(graphs, gname, mode, backend):
    g = graphs[gname]
    pg = PT.partition_graph(g, 4, method="greedy", pad_multiple=16)
    res = Engine(ALG.wcc(), pg, mode=mode, backend=backend,
                 tile_e=64, tile_r=32).run()
    assert np.array_equal(res.state["label"], _union_find_labels(g))
    assert res.supersteps > 1


@pytest.mark.parametrize("gname", ["uniform", "rmat"])
@pytest.mark.parametrize("mode", ["gravfm", "gravf"])
def test_bfs(graphs, gname, mode):
    g = graphs[gname]
    pg = PT.partition_graph(g, 4, method="round_robin", pad_multiple=16)
    res = Engine(ALG.bfs(0), pg, mode=mode,
                 backend="pallas" if mode == "gravfm" else "ref",
                 tile_e=64, tile_r=32).run()
    par, lvl = _bfs_oracle(g, 0)
    assert np.array_equal(res.state["parent"], par)
    # paper §6.2: BFS sends exactly one message per reachable-source edge
    reachable = lvl[g.src] < 10 ** 9
    assert res.messages == int(reachable.sum())


@pytest.mark.parametrize("mode", ["gravfm", "gravf"])
def test_pagerank(graphs, mode):
    g = graphs["uniform"]
    pg = PT.partition_graph(g, 4, method="greedy", pad_multiple=16)
    res = Engine(ALG.pagerank(30), pg, mode=mode,
                 backend="pallas" if mode == "gravfm" else "ref",
                 tile_e=64, tile_r=32).run()
    assert np.abs(res.state["score"] - _pr_oracle(g)).max() < 1e-5
    assert res.supersteps == 30
    assert res.messages == 30 * g.num_edges  # every edge, every superstep


@pytest.mark.parametrize("mode", ["gravfm", "gravf"])
def test_sssp(mode):
    g = G.uniform(200, 4.0, seed=9, weighted=True).symmetrized()
    pg = PT.partition_graph(g, 4, method="greedy", pad_multiple=16)
    res = Engine(ALG.sssp(0), pg, mode=mode,
                 backend="pallas" if mode == "gravfm" else "ref",
                 tile_e=64, tile_r=32).run()
    oracle = _sssp_oracle(g)
    got = res.state["dist"]
    m = np.isfinite(oracle)
    assert np.allclose(got[m], oracle[m], atol=1e-4)
    assert np.all(np.isinf(got[~m]))


def test_mode_equivalence(graphs):
    """gravf and gravfm must produce bit-identical results (the §4.1
    optimization is semantics-preserving)."""
    g = graphs["rmat"]
    pg = PT.partition_graph(g, 8, method="greedy", pad_multiple=16)
    for kfn in (ALG.wcc, lambda: ALG.bfs(1)):
        a = Engine(kfn(), pg, mode="gravfm", backend="ref").run()
        b = Engine(kfn(), pg, mode="gravf", backend="ref").run()
        for k in a.state:
            assert np.array_equal(a.state[k], b.state[k])
        assert a.messages == b.messages


def test_broadcast_traffic_reduction():
    """Paper §4.1/§5.5: for avg degree >> n_shards, broadcast updates move
    less data than unicast messages; the filter never does worse than
    naive broadcast."""
    g = G.uniform(400, 24.0, seed=1).symmetrized()  # deg ~ 40 >> P-1
    pg = PT.partition_graph(g, 4, method="greedy", pad_multiple=16)
    res = Engine(ALG.wcc(), pg, mode="gravfm", backend="ref").run()
    c = res.comm
    assert c["bcast_filtered_words"] <= c["bcast_naive_words"]
    assert c["bcast_filtered_words"] < c["unicast_words"]
    # measured reduction should be within 2x of the eq.5 model prediction
    speedup = c["unicast_words"] / max(c["bcast_filtered_words"], 1)
    eq5 = g.avg_degree / pg.num_parts
    assert speedup > eq5 / 2


def test_termination_and_inactive_graph():
    """Empty-frontier termination: a graph with no edges finishes after
    superstep 0 (the §4.3 distributed termination bit)."""
    g = G.Graph(num_vertices=32, src=np.zeros(0, np.int32),
                dst=np.zeros(0, np.int32))
    pg = PT.partition_graph(g, 4, pad_multiple=8)
    res = Engine(ALG.bfs(0), pg, mode="gravfm", backend="ref").run()
    assert res.supersteps <= 1
    assert res.messages == 0


def test_ladder_latency_graph():
    """Fig. 10/11 synthetic: w=1 line graph has one active vertex per
    superstep for depth supersteps."""
    g = G.line(64)
    pg = PT.partition_graph(g, 4, pad_multiple=8)
    res = Engine(ALG.bfs(0), pg, mode="gravfm", backend="ref").run()
    assert res.supersteps == 65  # d+1 supersteps
    assert res.messages == g.num_edges
