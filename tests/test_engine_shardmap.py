"""Multi-device shard_map engine: all five exchange schedules must be
bit-identical to the global-array engine.

Needs >1 device, so the check runs in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest
process keeps the default 1 CPU device per the assignment rules)."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {src!r})
import jax, numpy as np
from repro.core import graph as G, partition as PT, algorithms as ALG
from repro.core.engine import Engine
from repro.core.engine_shardmap import ShardEngine

from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((8,), ("graph",))
g = G.uniform(300, 6.0, seed=3).symmetrized()
pg = PT.partition_graph(g, 8, method="greedy", pad_multiple=16)

ref = Engine(ALG.wcc(), pg, mode="gravfm", backend="ref").run()
for exch in ("allgather", "ring", "frontier", "unicast", "combined"):
    out = ShardEngine(ALG.wcc(), pg, mesh=mesh, exchange=exch,
                      backend="ref").run()
    assert np.array_equal(out["state"]["label"], ref.state["label"]), exch
    assert out["messages"] == ref.messages, exch

# pallas kernel inside shard_map
out = ShardEngine(ALG.wcc(), pg, mesh=mesh, exchange="allgather",
                  backend="pallas", tile_e=64, tile_r=32).run()
assert np.array_equal(out["state"]["label"], ref.state["label"])

# pallas segment-combine driving BOTH levels of the combined exchange
# (source-side per-destination fold + receiver-side merge)
out = ShardEngine(ALG.wcc(), pg, mesh=mesh, exchange="combined",
                  backend="pallas", tile_e=64, tile_r=32).run()
assert np.array_equal(out["state"]["label"], ref.state["label"])

# combine-at-source must move fewer words than per-edge unicast once
# many cut edges share a destination: dense power-law R-MAT (avg degree
# 64 over 8 shards -> ~8 edges per (pair, destination) bucket slot)
gd = G.rmat(8, 64, seed=1)
pgd = PT.partition_graph(gd, 8, method="greedy", pad_multiple=16)
uni = ShardEngine(ALG.wcc(), pgd, mesh=mesh, exchange="unicast",
                  backend="ref").run()
comb = ShardEngine(ALG.wcc(), pgd, mesh=mesh, exchange="combined",
                   backend="ref").run()
assert np.array_equal(comb["state"]["label"], uni["state"]["label"])
assert comb["exchange_words"] < uni["exchange_words"], (
    comb["exchange_words"], uni["exchange_words"])

# SSSP carry through the ring schedule
gw = G.uniform(200, 5.0, seed=4, weighted=True).symmetrized()
pgw = PT.partition_graph(gw, 8, method="round_robin", pad_multiple=16)
refs = Engine(ALG.sssp(0), pgw, mode="gravfm", backend="ref").run()
for exch in ("allgather", "ring", "unicast", "combined"):
    out = ShardEngine(ALG.sssp(0), pgw, mesh=mesh, exchange=exch,
                      backend="ref").run()
    assert np.allclose(out["state"]["dist"], refs.state["dist"],
                       equal_nan=True), exch
    assert np.array_equal(out["state"]["parent"], refs.state["parent"]), exch

# frontier compression must move fewer words than dense broadcast on a
# sparse-frontier workload (BFS on a ladder: <=33 active/superstep while
# the dense array is v_max=400+ words/superstep; capacity floor is 64)
gl = G.ladder(32, 100, 1, seed=0)
pgl = PT.partition_graph(gl, 8, pad_multiple=16)
dense = ShardEngine(ALG.bfs(0), pgl, mesh=mesh, exchange="allgather",
                    backend="ref").run()
compact = ShardEngine(ALG.bfs(0), pgl, mesh=mesh, exchange="frontier",
                      backend="ref").run()
assert np.array_equal(dense["state"]["parent"], compact["state"]["parent"])
assert compact["exchange_words"] < dense["exchange_words"], (
    compact["exchange_words"], dense["exchange_words"])

# batched multi-query execution through the explicit collectives: every
# exchange must match per-root single-query Engine runs exactly
roots = np.array([0, 5, 17, 100, 250, 7, 99, 3], np.int32)
for exch in ("allgather", "ring", "frontier", "unicast", "combined"):
    se = ShardEngine(ALG.bfs(), pg, mesh=mesh, exchange=exch, backend="ref")
    outs = se.run_batch(root=roots)
    for i, r in enumerate(roots):
        rr = Engine(ALG.bfs(int(r)), pg, mode="gravfm", backend="ref").run()
        assert np.array_equal(outs[i]["state"]["parent"],
                              rr.state["parent"]), (exch, r)
        assert outs[i]["supersteps"] == rr.supersteps, (exch, r)
        assert outs[i]["messages"] == rr.messages, (exch, r)

# continuous stepping through the explicit collectives: a query spliced
# into the in-flight slot array at superstep t must match a solo run
# exactly, for every exchange schedule; slot recycling re-traces nothing
for exch in ("allgather", "ring", "frontier", "unicast", "combined"):
    se = ShardEngine(ALG.bfs(), pg, mesh=mesh, exchange=exch, backend="ref")
    st = se.make_stepper(4)
    qkw = {{"root": np.zeros(4, np.int32)}}
    carry, act, steps = st.init(qkw)
    occ = np.zeros(4, bool); occ[0] = True        # lane 0: root 0
    for _ in range(2):
        carry, act, steps = st.step(carry, occ)
    qkw["root"][1] = 100                          # joins at superstep 2
    fresh = np.zeros(4, bool); fresh[1] = True
    carry, act, steps = st.admit(carry, qkw, fresh)
    occ[1] = True
    traces_steady = se.traces
    for _ in range(1000):
        occ &= act
        if not occ.any():
            break
        carry, act, steps = st.step(carry, occ)
    else:
        raise AssertionError(exch + " did not quiesce")
    host = st.fetch(carry)
    for lane, root in ((0, 0), (1, 100)):
        res = se.lane_result(host, lane)
        rr = Engine(ALG.bfs(int(root)), pg, mode="gravfm",
                    backend="ref").run()
        assert np.array_equal(res["state"]["parent"],
                              rr.state["parent"]), (exch, lane)
        assert res["supersteps"] == rr.supersteps, (exch, lane)
        assert res["messages"] == rr.messages, (exch, lane)
    assert se.traces == traces_steady, exch      # zero steady-state traces
print("SHARDMAP-SUBPROCESS-OK")
"""


@pytest.mark.slow
def test_shardmap_engine_multidevice():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SCRIPT.format(src=os.path.abspath(src))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDMAP-SUBPROCESS-OK" in proc.stdout


_OVERLAP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {src!r})
import numpy as np
from repro.core import graph as G, partition as PT, algorithms as ALG
from repro.core.engine import Engine
from repro.core.engine_shardmap import ShardEngine
from repro.launch.mesh import compat_make_mesh

mesh = compat_make_mesh((8,), ("graph",))
# weighted so SSSP exercises the lexicographic (dist, parent) carry
# through the windowed pipeline's per-window merge
gw = G.uniform(300, 6.0, seed=3, weighted=True).symmetrized()
pg = PT.partition_graph(gw, 8, method="greedy", pad_multiple=16)

for name, kern in (("bfs", ALG.bfs(0)), ("sssp", ALG.sssp(0))):
    ref = Engine(kern, pg, mode="gravfm", backend="ref").run()
    for exch in ("allgather", "ring", "frontier", "unicast", "combined"):
        se = ShardEngine(kern, pg, mesh=mesh, exchange=exch,
                         backend="ref")
        sync = se.run()
        ov = se.run(overlap=True)
        warm = se.traces
        # steady state AND per-run toggling re-trace nothing: both
        # programs share the engine's device graph
        se.run(overlap=True); se.run(); se.run(overlap=True)
        assert se.traces == warm, (name, exch, "re-traced")
        for s in sync["state"]:
            a, b = np.asarray(sync["state"][s]), np.asarray(ov["state"][s])
            assert np.array_equal(a, b, equal_nan=True), (name, exch, s)
            assert np.array_equal(
                b, np.asarray(ref.state[s]), equal_nan=True), (name, exch, s)
        assert ov["supersteps"] == sync["supersteps"] == ref.supersteps, (
            name, exch)
        assert ov["messages"] == sync["messages"] == ref.messages, (
            name, exch)

# service level: per-request overlap toggling at steady state re-traces
# nothing once both plans are warm
from repro.service import GraphQueryService, QueryRequest
svc = GraphQueryService(num_shards=4, exchange="combined",
                        scheduling="continuous", slots=4)
svc.add_graph("g", gw)
svc.warm("g", "bfs")
svc.warm("g", "bfs", overlap=True)
t0 = svc.stats_snapshot()["plan_traces"]
base = None
for i in range(8):
    req = QueryRequest("g", "bfs", {{"root": (i // 2) % 3}},
                       deadline_ms=1e9, overlap=(i % 2 == 1))
    fut = svc.submit(req)
    svc.flush()
    res = fut.result()
    if i % 2 == 0:
        base = res
    else:
        assert np.array_equal(res.state["parent"], base.state["parent"])
        assert res.supersteps == base.supersteps
assert svc.stats_snapshot()["plan_traces"] == t0, "service re-traced"
print("SHARDMAP-OVERLAP-OK")
"""


@pytest.mark.slow
def test_shardmap_overlap_multidevice():
    """Pipelined (overlapped) exchange schedules: bit-identical to the
    synchronous schedules and the global-array engine for all five
    exchanges x {BFS, SSSP}, with zero re-traces when toggling overlap
    per run — and per request through the serving stack."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _OVERLAP_SCRIPT.format(src=os.path.abspath(src))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDMAP-OVERLAP-OK" in proc.stdout
