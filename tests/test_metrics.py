"""Metrics registry, superstep phase profiler, and SLO watchdog.

MetricsRegistry mechanics (counter/gauge/histogram recording, the
per-family series cap, disabled no-op), the Prometheus text exposition
(line grammar, counter monotonicity across scrapes, cumulative
histogram buckets), the service's metrics endpoint fed by the stats
snapshot (including tiny-capacity TraceBus drop counts and the
per-tenant latency window fix), perfmodel's per-phase projection hook,
profiled-mode phase attribution (bit-identical results, phase sums
accounting for the superstep wall), and the watchdog's firing/resolved
alert state machines under an injected stall and an injected perfmodel
drift."""
import re
import time

import numpy as np
import pytest

from repro.core import graph as G
from repro.core import perfmodel
from repro.service import (GraphQueryService, MetricsRegistry,
                           QueryRequest, ServiceStats, Watchdog,
                           WatchdogConfig, class_key)
from repro.service.metrics import DEFAULT_BUCKETS, Histogram


@pytest.fixture(scope="module")
def small_graph():
    return G.uniform(64, 4.0, seed=0).symmetrized()


def _service(small_graph, **kw):
    kw.setdefault("num_shards", 2)
    kw.setdefault("max_batch", 8)
    svc = GraphQueryService(**kw)
    svc.add_graph("g", small_graph)
    return svc


# ---------------------------------------------------------------------------
# MetricsRegistry mechanics
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_roundtrip():
    reg = MetricsRegistry()
    reg.inc("gravfm_things_total", 2)
    reg.inc("gravfm_things_total", 3)
    reg.set_gauge("gravfm_level", 1.5, tenant="a")
    reg.set_gauge("gravfm_level", 2.5, tenant="b")
    for v in (1e-7, 0.004, 0.004, 2.0):
        reg.observe("gravfm_lat_seconds", v)
    snap = reg.snapshot()
    assert snap["gravfm_things_total"]["kind"] == "counter"
    assert snap["gravfm_things_total"]["series"][0]["value"] == 5.0
    levels = {tuple(s["labels"].items()): s["value"]
              for s in snap["gravfm_level"]["series"]}
    assert levels == {(("tenant", "a"),): 1.5, (("tenant", "b"),): 2.5}
    h = snap["gravfm_lat_seconds"]["series"][0]["histogram"]
    assert h["count"] == 4
    assert h["sum"] == pytest.approx(2.0080001, abs=1e-6)
    # non-cumulative internal counts sum to count (incl. overflow slot)
    assert sum(h["counts"]) == 4
    assert len(h["counts"]) == len(DEFAULT_BUCKETS) + 1


def test_set_counter_is_monotone_clamped():
    reg = MetricsRegistry()
    reg.set_counter("gravfm_total", 10)
    reg.set_counter("gravfm_total", 7)   # a racing stale snapshot
    assert reg.snapshot()["gravfm_total"]["series"][0]["value"] == 10.0
    reg.set_counter("gravfm_total", 12)
    assert reg.snapshot()["gravfm_total"]["series"][0]["value"] == 12.0


def test_series_cap_bounds_memory_and_counts_drops():
    reg = MetricsRegistry(max_series=4)
    for i in range(10):
        reg.inc("gravfm_fanout_total", tenant=f"t{i}")
    snap = reg.snapshot()
    assert len(snap["gravfm_fanout_total"]["series"]) == 4
    assert reg.series_dropped == 6
    dropped = snap["gravfm_metrics_series_dropped_total"]["series"][0]
    assert dropped["value"] == 6.0
    # existing series keep recording after the cap is hit
    reg.inc("gravfm_fanout_total", tenant="t0")
    snap = reg.snapshot()
    t0 = [s for s in snap["gravfm_fanout_total"]["series"]
          if s["labels"] == {"tenant": "t0"}][0]
    assert t0["value"] == 2.0


def test_disabled_registry_is_a_noop():
    reg = MetricsRegistry(enabled=False)
    reg.inc("gravfm_x_total")
    reg.set_gauge("gravfm_g", 1.0)
    reg.observe("gravfm_h_seconds", 0.5)
    reg.add_collector(lambda r: r.inc("gravfm_from_collector_total"))
    assert reg.snapshot() == {}
    assert reg.expose_text() == ""


def test_histogram_buckets_are_log_spaced():
    h = Histogram()
    assert list(h.bounds) == sorted(h.bounds)
    ratios = [b / a for a, b in zip(h.bounds, h.bounds[1:])]
    assert all(r == pytest.approx(10 ** 0.5, rel=1e-9) for r in ratios)


# ---------------------------------------------------------------------------
# Prometheus exposition grammar
# ---------------------------------------------------------------------------

_HELP_RE = re.compile(r"^# HELP [a-zA-Z_:][a-zA-Z0-9_:]* .*$")
_TYPE_RE = re.compile(
    r"^# TYPE [a-zA-Z_:][a-zA-Z0-9_:]* (counter|gauge|histogram)$")
_SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*"                 # metric name
    r"(\{[a-zA-Z_][a-zA-Z0-9_]*=\"(\\.|[^\"\\])*\""  # first label
    r"(,[a-zA-Z_][a-zA-Z0-9_]*=\"(\\.|[^\"\\])*\")*\})?"  # more labels
    r" -?[0-9.e+-]+(e[+-]?[0-9]+)?$|"
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{.*\})? (\+|-)?Inf$|"
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{.*\})? NaN$")


def _parse_exposition(text):
    """Line-by-line grammar check; returns {sample_line_name: value}."""
    samples = {}
    for line in text.splitlines():
        assert line.strip() == line and line, f"blank/padded line: {line!r}"
        if line.startswith("# HELP"):
            assert _HELP_RE.match(line), line
        elif line.startswith("# TYPE"):
            assert _TYPE_RE.match(line), line
        else:
            assert _SAMPLE_RE.match(line), line
            key, val = line.rsplit(" ", 1)
            samples[key] = float(val)
    return samples


def test_exposition_grammar_and_escaping():
    reg = MetricsRegistry()
    reg.inc("gravfm_q_total", 3, help="queries")
    reg.set_gauge("gravfm_g", -1.25, tenant='we"ird\\name', cls="a\nb")
    reg.observe("gravfm_h_seconds", 0.02)
    samples = _parse_exposition(reg.expose_text())
    assert samples["gravfm_q_total"] == 3.0
    esc = [k for k in samples if k.startswith("gravfm_g")]
    assert len(esc) == 1 and '\\"' in esc[0] and "\\n" in esc[0]


def test_histogram_buckets_cumulative_and_sum_to_count():
    reg = MetricsRegistry()
    vals = [1e-7, 3e-4, 3e-4, 0.02, 5.0, 1e4]   # incl. +Inf overflow
    for v in vals:
        reg.observe("gravfm_h_seconds", v)
    samples = _parse_exposition(reg.expose_text())
    buckets = [(k, v) for k, v in samples.items()
               if k.startswith("gravfm_h_seconds_bucket")]
    counts = [v for _, v in buckets]
    assert counts == sorted(counts), "buckets must be cumulative"
    assert buckets[-1][0].endswith('le="+Inf"}')
    assert buckets[-1][1] == samples["gravfm_h_seconds_count"] == 6
    assert samples["gravfm_h_seconds_sum"] == pytest.approx(sum(vals))


def test_service_exposition_counters_monotone_across_scrapes(small_graph):
    svc = _service(small_graph)
    svc.query("g", "bfs", root=1)
    first = _parse_exposition(svc.metrics_text())
    svc.query("g", "bfs", root=2)
    svc.query("g", "bfs", root=3)
    second = _parse_exposition(svc.metrics_text())
    counter_names = {k for k, v in svc.metrics_snapshot().items()
                     if v["kind"] == "counter"}
    checked = 0
    for key, val in first.items():
        name = key.split("{")[0]
        if name in counter_names and key in second:
            assert second[key] >= val, key
            checked += 1
    assert checked >= 10
    assert (second["gravfm_queries_completed_total"]
            > first["gravfm_queries_completed_total"])


# ---------------------------------------------------------------------------
# service feed: stats / store / trace / tenants
# ---------------------------------------------------------------------------

def test_tiny_capacity_bus_reports_drops(small_graph):
    svc = _service(small_graph, trace_capacity=8)
    for r in range(6):
        svc.query("g", "bfs", root=r)
    snap = svc.stats_snapshot()
    assert snap["trace_events"] > 8
    assert snap["trace_dropped"] == snap["trace_events"] - 8
    samples = _parse_exposition(svc.metrics_text())
    assert samples["gravfm_trace_dropped_total"] == snap["trace_dropped"]
    assert samples["gravfm_trace_events_total"] == snap["trace_events"]


def test_store_and_tenant_series_present(small_graph):
    svc = _service(small_graph)
    svc.query("g", "bfs", root=1, tenant="acme")
    samples = _parse_exposition(svc.metrics_text())
    assert samples["gravfm_store_publishes_total"] >= 1
    assert "gravfm_store_resident_bytes" in samples
    assert samples['gravfm_tenant_completed_total{tenant="acme"}'] == 1
    ck = [k for k in samples
          if k.startswith("gravfm_roofline_efficiency")]
    assert ck, "per-class roofline gauges missing"


def test_model_limit_terms_exposed_per_class(small_graph):
    svc = _service(small_graph)
    svc.query("g", "bfs", root=1)
    samples = _parse_exposition(svc.metrics_text())
    terms = {k: v for k, v in samples.items()
             if k.startswith("gravfm_model_limit_teps")}
    for term in ("L_PE", "L_mem", "L_if", "L_net", "T_sys"):
        assert any(f'term="{term}"' in k for k in terms), term
    # T_sys is the min of the four limits (eq. 9)
    ck = class_key(next(iter(svc._class_meta.values())))
    lim = svc.projected_limits(ck)
    assert lim["T_sys"] == min(lim["L_PE"], lim["L_mem"],
                               lim["L_if"], lim["L_net"])


def test_metrics_off_knob(small_graph):
    svc = _service(small_graph, metrics=False)
    svc.query("g", "bfs", root=1)
    assert svc.metrics_text() == ""
    assert svc.metrics_snapshot() == {}


def test_tenant_latency_window_honors_config():
    stats = ServiceStats(latency_window=4)
    for i in range(100):
        stats.record_tenant("t", completed=1, latency_ms=float(i))
    snap = stats.tenant_snapshot()["t"]
    # only the last 4 samples (96..99) are in the window
    assert snap["latency_p50_ms"] >= 96.0


def test_queue_wait_percentiles_in_snapshot(small_graph):
    svc = _service(small_graph, scheduling="continuous", slots=2)
    for r in range(4):
        svc.query("g", "bfs", root=r)
    snap = svc.stats_snapshot()
    assert snap["queue_wait_p95_ms"] >= snap["queue_wait_p50_ms"] >= 0.0


# ---------------------------------------------------------------------------
# perfmodel per-phase projection hook
# ---------------------------------------------------------------------------

def test_phase_projection_maps_terms():
    wl = perfmodel.Workload(num_vertices=10000, num_edges=80000)
    lim = perfmodel.limits(perfmodel.PAPER_PLATFORM,
                           perfmodel.PAPER_ALGOS["bfs"], wl, n_nodes=4)
    proj = perfmodel.phase_projection(lim)
    assert set(proj) == set(perfmodel.PHASE_TERMS)
    assert proj["scatter"] == lim["L_mem"]
    assert proj["combine"] == proj["apply"] == lim["L_PE"]
    assert proj["exchange"] == lim["L_if"]
    assert proj["probe"] is None


# ---------------------------------------------------------------------------
# superstep phase profiler
# ---------------------------------------------------------------------------

def _profiled_pair(small_graph, **kw):
    out = {}
    for profile in (False, True):
        svc = _service(small_graph, scheduling="continuous", slots=4,
                       result_cache_size=0, profile_phases=profile, **kw)
        res = [svc.query("g", "bfs", root=r) for r in range(4)]
        out[profile] = (svc, res)
    return out


def test_profiled_results_bit_identical(small_graph):
    pair = _profiled_pair(small_graph)
    for a, b in zip(pair[False][1], pair[True][1]):
        assert a.supersteps == b.supersteps
        assert a.messages == b.messages
        for k in a.state:
            assert np.array_equal(np.asarray(a.state[k]),
                                  np.asarray(b.state[k])), k


def test_profiled_superstep_events_carry_phase_split(small_graph):
    svc, _ = _profiled_pair(small_graph)[True]
    ev = [e for e in svc.trace.snapshot() if e.kind == "superstep"]
    assert ev
    for e in ev:
        phases = e.attrs["phase"]
        assert set(phases) == {"scatter", "combine", "apply", "probe"}
        assert all(v >= 0.0 for v in phases.values())
    # and the per-class histograms saw every phase
    snap = svc.metrics_snapshot()
    series = snap["gravfm_superstep_phase_seconds"]["series"]
    assert {s["labels"]["phase"] for s in series} == \
        {"scatter", "combine", "apply", "probe"}
    # compile-tainted supersteps are excluded from the histograms (they
    # still carry phase attrs on the trace), so count <= events — but
    # every phase sees the same execution supersteps
    counts = {s["histogram"]["count"] for s in series}
    assert len(counts) == 1
    assert 1 <= counts.pop() <= len(ev)


def test_unprofiled_superstep_events_have_no_phase(small_graph):
    svc, _ = _profiled_pair(small_graph)[False]
    ev = [e for e in svc.trace.snapshot() if e.kind == "superstep"]
    assert ev and all("phase" not in e.attrs for e in ev)


def test_phase_times_account_for_superstep_wall():
    """The phase split must explain the profiled superstep wall: the
    sum of phase times lands within 10% of the dispatch wall the trace
    event measured around the same superstep (the residue is host glue
    between phase dispatches). Compared against the *profiled* wall —
    on CPU the split dispatch loses XLA fusion across phase boundaries,
    so profiled absolute walls sit above the fused path's (the known
    cost of profiled mode, see README); a loose 2.5x cross-check
    bounds that distortion. A sizeable graph so compute dominates
    dispatch overhead; 3 attempts ride out scheduler jitter."""
    g = G.uniform(20000, 8.0, seed=1).symmetrized()
    last = None
    for _ in range(3):
        svcs = {}
        for profile in (False, True):
            svc = GraphQueryService(num_shards=2, scheduling="continuous",
                                    slots=4, result_cache_size=0,
                                    profile_phases=profile)
            svc.add_graph("g", g)
            svc.warm("g", "bfs")
            for r in range(4):
                svc.query("g", "bfs", root=r)
            svcs[profile] = svc
        prof = [e for e in svcs[True].trace.snapshot()
                if e.kind == "superstep"]
        fused = [e for e in svcs[False].trace.snapshot()
                 if e.kind == "superstep"]
        phase_sum = sum(sum(e.attrs["phase"].values()) for e in prof)
        prof_wall = sum(e.dur_s for e in prof)
        fused_wall = sum(e.dur_s for e in fused)
        ratio = phase_sum / prof_wall
        last = (ratio, phase_sum, fused_wall)
        if 0.9 <= ratio <= 1.1 and phase_sum < 2.5 * fused_wall:
            return
    ratio, phase_sum, fused_wall = last
    raise AssertionError(
        f"phase sum explains {ratio:.1%} of the profiled superstep wall "
        f"(want 90-110%); phase_sum={phase_sum:.4f}s "
        f"fused_wall={fused_wall:.4f}s")


# ---------------------------------------------------------------------------
# SLO watchdog
# ---------------------------------------------------------------------------

def _alert_events(svc, rule=None):
    ev = [e for e in svc.trace.snapshot() if e.kind == "alert"]
    if rule is not None:
        ev = [e for e in ev if e.attrs["rule"] == rule]
    return ev


def test_watchdog_stall_fires_once_and_resolves(small_graph):
    svc = _service(small_graph, scheduling="continuous", slots=2)
    wd = Watchdog(svc, stall_after_s=5.0)
    t0 = time.perf_counter()
    # queued work, pump never runs (service not started, no flush)
    fut = svc.submit(QueryRequest(graph_id="g", kernel="bfs",
                                  query_kwargs={"root": 1}))
    assert wd.evaluate_once(now=t0) == []
    # several in-window evaluations: still one alert, fired once
    active = wd.evaluate_once(now=t0 + 10.0)
    wd.evaluate_once(now=t0 + 11.0)
    assert [a.rule for a in active] == ["stall"]
    firing = _alert_events(svc, "stall")
    assert len(firing) == 1 and firing[0].attrs["state"] == "firing"
    assert firing[0].attrs["alert_kind"] == "liveness"
    # clear the stall: drain the backlog, then evaluate again
    svc.flush()
    fut.result()
    assert wd.evaluate_once(now=t0 + 12.0) == []
    ev = _alert_events(svc, "stall")
    assert [e.attrs["state"] for e in ev] == ["firing", "resolved"]
    samples = _parse_exposition(svc.metrics_text())
    assert samples['gravfm_alerts_fired_total{rule="stall"}'] == 1
    assert samples['gravfm_alerts_resolved_total{rule="stall"}'] == 1
    assert samples["gravfm_alerts_active"] == 0


def test_watchdog_perfmodel_drift_fires_once_and_resolves(small_graph):
    svc = _service(small_graph, scheduling="continuous", slots=4,
                   result_cache_size=0)
    for r in range(8):
        svc.query("g", "bfs", root=r)
    ck = class_key(next(iter(svc._class_meta.values())))
    measured = svc.stats.roofline_snapshot()[ck]["teps"]
    wd = Watchdog(svc, drift_tol=1.0, min_completed=4)
    t0 = time.perf_counter()
    # projection == measurement: inside tolerance, nothing fires
    svc.stats.set_roofline_projector(lambda _ck: measured)
    assert wd.evaluate_once(now=t0) == []
    # inject drift: the model now projects 1000x the measurement
    svc.stats.set_roofline_projector(lambda _ck: measured * 1000.0)
    active = wd.evaluate_once(now=t0 + 1.0)
    wd.evaluate_once(now=t0 + 2.0)
    assert [(a.rule, a.subject) for a in active] == \
        [("perfmodel_drift", ck)]
    assert len(_alert_events(svc, "perfmodel_drift")) == 1
    # model corrected: the alert resolves
    svc.stats.set_roofline_projector(lambda _ck: measured)
    assert wd.evaluate_once(now=t0 + 3.0) == []
    ev = _alert_events(svc, "perfmodel_drift")
    assert [e.attrs["state"] for e in ev] == ["firing", "resolved"]
    assert ev[0].klass == ck
    assert ev[0].attrs["alert_kind"] == "model"


def test_watchdog_deadline_miss_rate_rule(small_graph):
    svc = _service(small_graph, scheduling="continuous", slots=2,
                   result_cache_size=0)
    wd = Watchdog(svc, miss_rate_max=0.5, min_window_events=4)
    t0 = time.perf_counter()
    wd.evaluate_once(now=t0)
    # every query's deadline is already blown at submission
    for r in range(6):
        svc.query("g", "bfs", root=r, deadline_ms=-1.0)
    active = wd.evaluate_once(now=t0 + 1.0)
    assert [a.rule for a in active] == ["deadline_miss_rate"]
    assert active[0].value == 1.0
    # a window of on-time queries brings the rate back down
    for r in range(20, 40):
        svc.query("g", "bfs", root=r, deadline_ms=1e6)
    assert wd.evaluate_once(now=t0 + 2.0) == []


def test_watchdog_insufficient_window_keeps_state(small_graph):
    svc = _service(small_graph, scheduling="continuous", slots=2)
    wd = Watchdog(svc, miss_rate_max=0.5, min_window_events=8)
    t0 = time.perf_counter()
    wd.evaluate_once(now=t0)
    # 2 missed queries < min_window_events: rule not evaluable, no alert
    for r in range(2):
        svc.query("g", "bfs", root=r, deadline_ms=-1.0)
    assert wd.evaluate_once(now=t0 + 1.0) == []
    assert _alert_events(svc) == []


def test_watchdog_thread_lifecycle(small_graph):
    svc = _service(small_graph, watchdog=True,
                   watchdog_config=WatchdogConfig(interval_s=0.02))
    svc.start()
    try:
        assert svc.watchdog is not None
        deadline = time.time() + 5.0
        while svc.watchdog.evaluations == 0 and time.time() < deadline:
            time.sleep(0.02)
        assert svc.watchdog.evaluations > 0
    finally:
        svc.stop()
    assert svc.watchdog is None
