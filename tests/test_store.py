"""Multi-tenant GraphStore: versioned residency under a memory budget.

Covers the store's contract (LRU eviction, query pins, transparent
refault, atomic version publish), the host-spill residency tier
(device -> host spill -> discard; refault = re-upload, bit-identical,
zero re-traces; spill_budget overflow degrades to discard), the
out-of-lock fault path (double-faulting threads share one
materialization; a fault in progress blocks neither other entries'
store operations nor other tenants' submits), the tenancy policy layer
(token buckets, fair-share weights), and the service-level integration:
re-register-as-publish semantics, eviction/pin races (a query in flight
on a graph chosen for eviction completes bit-identically), version-swap
isolation (old-version results unaffected by publish), stale-plan
invalidation scoped to the discarded version, and weighted fair share.
A shard_map-backend variant runs in a subprocess (multi-device rules).
"""
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import algorithms as ALG
from repro.core import graph as G
from repro.core import partition as PT
from repro.core.engine import Engine
from repro.service import (AdmissionError, GraphQueryService, PlanCache,
                           QueryRequest)
from repro.store import (GraphStore, StoreError, TenantRegistry,
                         TokenBucket)


@pytest.fixture(scope="module")
def g_a():
    return G.uniform(300, 6.0, seed=1).symmetrized()


@pytest.fixture(scope="module")
def g_b():
    return G.uniform(300, 6.0, seed=2).symmetrized()


@pytest.fixture(scope="module")
def g_c():
    return G.uniform(300, 6.0, seed=3).symmetrized()


@pytest.fixture(scope="module")
def deep_graph():
    # ladder: BFS from rank-0 takes ~30 supersteps, so a query is still
    # in flight while we evict/publish around it
    return G.ladder(2, 30, 1, seed=0)


def _budget_for(graph, k: float, pad_multiple=16, num_shards=4) -> float:
    """A budget that fits ``k`` layouts the size of ``graph``'s."""
    pg = PT.partition_graph(graph, num_shards, pad_multiple=pad_multiple)
    return k * pg.device_nbytes


# ---------------------------------------------------------------------------
# store unit behavior
# ---------------------------------------------------------------------------

def test_publish_acquire_idempotent(g_a):
    store = GraphStore(num_shards=4, pad_multiple=16)
    v = store.publish("a", g_a)
    assert v == 1
    assert store.publish("a", g_a) == 1          # identical -> no-op
    assert store.latest_version("a") == 1
    with store.acquire("a") as lease:
        assert lease.pg.num_vertices == g_a.num_vertices
    assert store.snapshot()["resident_graphs"] == 1
    assert store.faults == 0


def test_partitioned_graph_byte_accounting(g_a):
    pg = PT.partition_graph(g_a, 4, pad_multiple=16)
    assert pg.device_nbytes > 0
    assert pg.nbytes > pg.device_nbytes          # + the stats edge list
    expected = sum(getattr(pg, f).nbytes for f in (
        "part_of", "local_of", "vert_gid", "vert_valid", "out_deg",
        "in_src_slot", "in_src_gid", "in_src_outdeg", "in_dst_local",
        "in_w", "in_valid", "pair_src_local", "pair_src_gid",
        "pair_src_outdeg", "pair_dst_local", "pair_w", "pair_valid",
        "nbr_filter"))
    assert pg.device_nbytes == expected


def test_lru_eviction_order(g_a, g_b, g_c):
    budget = _budget_for(g_a, 2.5)
    store = GraphStore(budget_bytes=budget, num_shards=4, pad_multiple=16)
    store.publish("a", g_a)
    store.publish("b", g_b)
    # touch "a" so "b" is the LRU victim when "c" arrives
    store.acquire("a").release()
    store.publish("c", g_c)
    snap = store.snapshot()
    assert snap["evictions"] == 1
    desc = {e["graph_id"]: e for e in store.describe()}
    assert desc["b"]["resident"] is False
    assert desc["a"]["resident"] and desc["c"]["resident"]


def test_fault_rematerializes_bit_identical(g_a, g_b):
    budget = _budget_for(g_a, 1.5)
    store = GraphStore(budget_bytes=budget, num_shards=4, pad_multiple=16)
    store.publish("a", g_a)
    with store.acquire("a") as lease:
        before = {f: np.array(getattr(lease.pg, f))
                  for f in ("part_of", "in_src_slot", "in_dst_local",
                            "vert_gid", "in_w")}
    store.publish("b", g_b)                       # evicts idle "a"
    assert not {e["graph_id"]: e for e in store.describe()}["a"]["resident"]
    with store.acquire("a") as lease:             # transparent refault
        for f, arr in before.items():
            assert np.array_equal(np.asarray(getattr(lease.pg, f)), arr), f
    assert store.faults == 1


def test_pinned_graph_never_evicted(g_a, g_b):
    budget = _budget_for(g_a, 1.5)
    store = GraphStore(budget_bytes=budget, num_shards=4, pad_multiple=16)
    store.publish("a", g_a)
    lease_a = store.acquire("a")                  # pin
    store.publish("b", g_b)       # over budget; "a" pinned -> "b" evicted
    desc = {e["graph_id"]: e for e in store.describe()}
    assert desc["a"]["resident"]                  # pin held
    lease_b = store.acquire("b")  # fault "b" back; BOTH pinned now
    desc = {e["graph_id"]: e for e in store.describe()}
    assert desc["a"]["resident"] and desc["b"]["resident"]
    assert store.snapshot()["budget_overcommits"] >= 1
    assert store.evict("a") is False              # explicit evict refused
    lease_a.release()                             # now evictable
    lease_b.release()             # sweep: LRU "a" goes, "b" stays
    desc = {e["graph_id"]: e for e in store.describe()}
    assert not desc["a"]["resident"]
    assert desc["b"]["resident"]


def test_version_publish_supersedes_and_drains(g_a, g_b):
    store = GraphStore(num_shards=4, pad_multiple=16)
    assert store.publish("a", g_a) == 1
    lease_v1 = store.acquire("a", 1)              # in-flight query on v1
    assert store.publish("a", g_b) == 2
    assert store.latest_version("a") == 2
    # v1 stays resident for its drain ...
    desc = {e["version"]: e for e in store.describe()
            if e["graph_id"] == "a"}
    assert desc[1]["resident"] and desc[1]["superseded"]
    assert np.array_equal(lease_v1.pg.part_of,
                          PT.partition_graph(g_a, 4,
                                             pad_multiple=16).part_of)
    # ... and is evicted the moment the last pin drops
    lease_v1.release()
    desc = {e["version"]: e for e in store.describe()
            if e["graph_id"] == "a"}
    assert not desc[1]["resident"]
    assert desc[2]["resident"]


def test_unversioned_store_rejects_republish(g_a, g_b):
    store = GraphStore(versioned=False, num_shards=4, pad_multiple=16)
    store.publish("a", g_a)
    store.publish("a", g_a)                       # identical: fine
    with pytest.raises(StoreError):
        store.publish("a", g_b)


def test_peek_requires_residency_and_remove_refuses_pins(g_a, g_b):
    budget = _budget_for(g_a, 1.5)
    store = GraphStore(budget_bytes=budget, num_shards=4, pad_multiple=16)
    store.publish("a", g_a)
    store.publish("b", g_b)                       # "a" evicted
    with pytest.raises(StoreError):
        store.peek("a")
    lease = store.acquire("b")
    with pytest.raises(StoreError):
        store.remove("b")
    lease.release()
    store.remove("b")
    with pytest.raises(KeyError):
        store.latest_version("b")


# ---------------------------------------------------------------------------
# host-spill residency tier
# ---------------------------------------------------------------------------

def test_eviction_spills_to_host_and_refaults_cheaply(g_a, g_b):
    """A budget eviction demotes to the host tier; the next acquire is a
    spilled refault (no partitioner re-run) that is array-for-array the
    original layout."""
    budget = _budget_for(g_a, 1.5)
    store = GraphStore(budget_bytes=budget, num_shards=4, pad_multiple=16)
    store.publish("a", g_a)
    with store.acquire("a") as lease:
        before = lease.pg
    store.publish("b", g_b)                       # evicts idle "a" -> spill
    desc = {e["graph_id"]: e for e in store.describe()}
    assert not desc["a"]["resident"] and desc["a"]["spilled"]
    snap = store.snapshot()
    assert snap["spills"] == 1 and snap["discards"] == 0
    assert snap["spilled_graphs"] == 1 and snap["spilled_bytes"] > 0
    with store.acquire("a") as lease:             # refault from host tier
        assert lease.pg is before     # the spilled arrays survive verbatim
    snap = store.snapshot()
    assert snap["faults"] == 1
    assert snap["refault_upload_ms"] >= 0.0


def test_spill_budget_overflow_discards_lru(g_a, g_b, g_c):
    """Host-tier overflow degrades to the pre-spill behavior: the LRU
    spilled layout is discarded and its next fault is cold."""
    budget = _budget_for(g_a, 1.5)
    store = GraphStore(budget_bytes=budget, num_shards=4, pad_multiple=16,
                       spill_budget_bytes=budget)   # host tier fits one
    store.publish("a", g_a)
    store.publish("b", g_b)                       # "a" spilled
    store.publish("c", g_c)                       # "b" spilled -> "a" out
    snap = store.snapshot()
    assert snap["spills"] == 2
    assert snap["discards"] == 1
    desc = {e["graph_id"]: e for e in store.describe()}
    assert not desc["a"]["resident"] and not desc["a"]["spilled"]
    assert desc["b"]["spilled"]
    with store.acquire("a") as lease:             # cold fault re-partitions
        assert lease.pg.num_vertices == g_a.num_vertices
    assert store.faults == 1


def test_spill_disabled_restores_discard_on_evict(g_a, g_b):
    """spill_budget_bytes=0 turns the host tier off entirely."""
    budget = _budget_for(g_a, 1.5)
    store = GraphStore(budget_bytes=budget, num_shards=4, pad_multiple=16,
                       spill_budget_bytes=0)
    store.publish("a", g_a)
    store.publish("b", g_b)
    snap = store.snapshot()
    assert snap["evictions"] == 1
    assert snap["spills"] == 0 and snap["discards"] == 1
    assert snap["spilled_graphs"] == 0


def test_spill_refault_keeps_plans_zero_retrace(g_a, g_b):
    """The acceptance invariant: spill -> refault round-trips
    bit-identically AND re-traces nothing — the plan cache keeps the
    spilled version's engines/plans and only re-uploads their arrays."""
    budget = _budget_for(g_a, 1.5)
    svc = GraphQueryService(num_shards=4, max_batch=4, slots=4,
                            scheduling="continuous",
                            memory_budget=budget, result_cache_size=0)
    svc.add_graph("a", g_a, pad_multiple=16)
    svc.add_graph("b", g_b, pad_multiple=16)
    res_a0 = svc.query("a", "bfs", root=0, deadline_ms=60_000)
    svc.query("b", "bfs", root=0, deadline_ms=60_000)   # spills "a"
    snap0 = svc.stats_snapshot()
    assert snap0["plan_traces"] > 0
    assert snap0["store_spills"] >= 1
    assert {e["graph_id"]: e for e in svc.store.describe()}["a"]["spilled"]
    res_a1 = svc.query("a", "bfs", root=0, deadline_ms=60_000)  # refault
    snap1 = svc.stats_snapshot()
    assert snap1["plan_traces"] == snap0["plan_traces"]   # ZERO re-traces
    assert snap1["store_faults"] >= snap0["store_faults"] + 1
    assert snap1["store_discards"] == 0
    pg_a = PT.partition_graph(g_a, 4, pad_multiple=16)
    ref = Engine(ALG.bfs(0), pg_a, mode="gravfm", backend="ref").run()
    for res in (res_a0, res_a1):
        assert np.array_equal(res.state["parent"], ref.state["parent"])
        assert res.supersteps == ref.supersteps
        assert res.messages == ref.messages


def test_engine_tier_bytes_replace_layout_proxy(g_a):
    """The store charges each version's TRUE engine-tier device bytes
    (Engine.device_nbytes — what offload() actually demotes) once
    engines exist, replacing the partition-layout proxy estimate; a
    version serving several engines is charged all of them. Budget
    conservation: resident_bytes equals the sum of the live engines'
    bytes."""
    svc = GraphQueryService(num_shards=4, max_batch=4)
    svc.add_graph("a", g_a, pad_multiple=16)
    store = svc.store
    proxy = PT.partition_graph(g_a, 4, pad_multiple=16).device_nbytes
    assert store.resident_bytes == proxy        # no engines yet: proxy
    svc.query("a", "bfs", root=0)               # builds the bfs engine
    true1 = sum(e.device_nbytes for e in svc.plans._engines.values())
    assert true1 > 0
    assert store.resident_bytes == true1
    assert store.resident_bytes != proxy
    # conservation check against what offload() would actually free
    eng = next(iter(svc.plans._engines.values()))
    assert eng.device_nbytes == eng.offload()
    eng.upload()
    # a second engine (other mode) against the same version adds ON TOP
    svc.query("a", "bfs", root=0, mode="gravf")
    true2 = sum(e.device_nbytes for e in svc.plans._engines.values())
    assert true2 > true1
    assert store.resident_bytes == true2
    assert store.snapshot()["resident_bytes"] == float(true2)


def test_engine_tier_budget_conservation_with_eviction(g_a, g_b):
    """With the true engine-tier charge, a budget sized for ~1.5 engine
    footprints forces an eviction when the second graph's engine lands,
    and the final (unpinned) resident bytes respect the budget. The
    evicted graph still answers bit-identically after its refault."""
    pg = PT.partition_graph(g_a, 4, pad_multiple=16)
    eb = Engine(ALG.bfs(), pg, mode="gravfm", backend="ref").device_nbytes
    budget = 1.5 * eb
    svc = GraphQueryService(num_shards=4, max_batch=4,
                            memory_budget=budget)
    svc.add_graph("a", g_a, pad_multiple=16)
    svc.add_graph("b", g_b, pad_multiple=16)
    svc.query("a", "bfs", root=0)
    svc.query("b", "bfs", root=0)               # pushes over budget
    store = svc.store
    assert store.snapshot()["evictions"] >= 1
    assert store.resident_bytes <= budget       # conservation, unpinned
    res = svc.query("a", "bfs", root=1)         # fault back in
    assert store.resident_bytes <= budget
    ref = Engine(ALG.bfs(1), pg, mode="gravfm", backend="ref").run()
    assert np.array_equal(res.state["parent"], ref.state["parent"])


def test_engine_offload_upload_roundtrip_zero_retrace(g_a):
    """The engine tier of the spill: offload demotes the graph arrays to
    host copies, upload promotes them back, and neither move re-traces
    or changes results."""
    pg = PT.partition_graph(g_a, 4, pad_multiple=16)
    eng = Engine(ALG.bfs(), pg, mode="gravfm", backend="ref")
    before = eng.run(root=0)
    traces0 = eng.traces
    freed = eng.offload()
    assert freed > 0 and not eng.device_resident
    assert eng.offload() == 0                     # idempotent
    mid = eng.run(root=0)                         # offloaded still works
    assert eng.upload() >= 0.0 and eng.device_resident
    assert eng.upload() == 0.0                    # idempotent
    after = eng.run(root=0)
    assert eng.traces == traces0                  # no re-trace either way
    for res in (mid, after):
        assert np.array_equal(res.state["parent"], before.state["parent"])


# ---------------------------------------------------------------------------
# out-of-lock faulting
# ---------------------------------------------------------------------------

def test_concurrent_faults_share_one_materialization(g_a, g_b, monkeypatch):
    """Two threads faulting the same discarded entry: the first claims
    the build, the second waits on the ENTRY's condvar, and exactly one
    partitioner run happens."""
    from repro.store import registry as reg
    budget = _budget_for(g_a, 1.5)
    store = GraphStore(budget_bytes=budget, num_shards=4, pad_multiple=16,
                       spill_budget_bytes=0)      # force a cold fault
    store.publish("a", g_a)
    store.publish("b", g_b)                       # "a" discarded
    real = reg.partition_graph
    calls = []

    def counting(graph, *args, **kwargs):
        calls.append(graph)
        time.sleep(0.05)                          # widen the race window
        return real(graph, *args, **kwargs)

    monkeypatch.setattr(reg, "partition_graph", counting)
    leases = [None, None]

    def fault(i):
        leases[i] = store.acquire("a")

    threads = [threading.Thread(target=fault, args=(i,)) for i in (0, 1)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30)
    assert len(calls) == 1                        # one build, shared
    assert leases[0].pg is leases[1].pg
    assert store.faults == 1
    desc = {e["graph_id"]: e for e in store.describe()}
    assert desc["a"]["pins"] == 2
    for lease in leases:
        lease.release()


def test_fault_in_progress_does_not_block_other_entries(g_a, g_b, g_c,
                                                        monkeypatch):
    """While tenant A's cold fault materializes (store lock RELEASED),
    tenant B can acquire its resident graph and a third tenant can
    publish — no head-of-line blocking on the registry."""
    from repro.store import registry as reg
    budget = _budget_for(g_a, 1.5)
    store = GraphStore(budget_bytes=budget, num_shards=4, pad_multiple=16,
                       spill_budget_bytes=0)
    store.publish("a", g_a)
    store.publish("b", g_b)                       # "a" discarded
    real = reg.partition_graph
    entered, gate = threading.Event(), threading.Event()

    def gated(graph, *args, **kwargs):
        if graph is g_a:                          # block only A's build
            entered.set()
            assert gate.wait(30)
        return real(graph, *args, **kwargs)

    monkeypatch.setattr(reg, "partition_graph", gated)
    done = {}

    def fault_a():
        done["a"] = store.acquire("a")

    t = threading.Thread(target=fault_a)
    t.start()
    try:
        assert entered.wait(30)                   # A's build is in flight
        lease_b = store.acquire("b")              # resident: returns at once
        assert lease_b.pg is not None
        assert store.publish("c", g_c) == 1       # full publish+materialize
        assert store.snapshot()["graphs"] == 3
        assert "a" not in done                    # A genuinely still faulting
        lease_b.release()
    finally:
        gate.set()
        t.join(30)
    assert done["a"].pg.num_vertices == g_a.num_vertices
    done["a"].release()


def test_tenant_fault_does_not_block_other_tenant_queries(g_a, g_b,
                                                          monkeypatch):
    """Service-level head-of-line check: a tenant-A fault in progress
    must not block a tenant-B submit/flush round-trip."""
    from repro.store import registry as reg
    budget = _budget_for(g_a, 1.5)
    svc = GraphQueryService(num_shards=4, max_batch=4, slots=4,
                            scheduling="continuous", memory_budget=budget,
                            spill_budget=0, result_cache_size=0)
    svc.add_graph("a", g_a, pad_multiple=16)
    svc.add_graph("b", g_b, pad_multiple=16)      # "a" discarded
    svc.query("b", "bfs", root=0, deadline_ms=60_000)   # warm B's plans
    real = reg.partition_graph
    entered, gate = threading.Event(), threading.Event()

    def gated(graph, *args, **kwargs):
        if graph is g_a:
            entered.set()
            assert gate.wait(60)
        return real(graph, *args, **kwargs)

    monkeypatch.setattr(reg, "partition_graph", gated)
    res_holder = {}

    def tenant_a():
        res_holder["a"] = svc.query("a", "bfs", root=0, tenant="A",
                                    deadline_ms=600_000)

    t = threading.Thread(target=tenant_a)
    t.start()
    try:
        assert entered.wait(30)                   # A blocked mid-fault
        res_b = svc.query("b", "bfs", root=1, tenant="B",
                          deadline_ms=60_000)     # full submit->result
        assert res_b.supersteps > 0
        assert "a" not in res_holder
    finally:
        gate.set()
        t.join(60)
    pg_a = PT.partition_graph(g_a, 4, pad_multiple=16)
    ref = Engine(ALG.bfs(0), pg_a, mode="gravfm", backend="ref").run()
    assert np.array_equal(res_holder["a"].state["parent"],
                          ref.state["parent"])


def test_publish_during_fault_does_not_resurrect_retired_version(
        g_a, g_b, g_c, monkeypatch):
    """A publish landing while an unpinned version's fault materializes
    retires that version (pins==0); the builder must then DROP its
    build — not install into the tombstone and lease a superseded
    version."""
    from repro.store import registry as reg
    budget = _budget_for(g_a, 1.5)
    store = GraphStore(budget_bytes=budget, num_shards=4, pad_multiple=16,
                       spill_budget_bytes=0)
    store.publish("a", g_a)
    store.publish("b", g_b)                       # "a" v1 discarded
    real = reg.partition_graph
    entered, gate = threading.Event(), threading.Event()

    def gated(graph, *args, **kwargs):
        if graph is g_a:
            entered.set()
            assert gate.wait(30)
        return real(graph, *args, **kwargs)

    monkeypatch.setattr(reg, "partition_graph", gated)
    result = {}

    def fault_v1():
        try:
            result["lease"] = store.acquire("a", 1)
        except StoreError as exc:
            result["err"] = exc

    t = threading.Thread(target=fault_v1)
    t.start()
    try:
        assert entered.wait(30)                   # v1's build in flight
        assert store.publish("a", g_c) == 2       # v1 (pins==0) retires
    finally:
        gate.set()
        t.join(30)
    assert "lease" not in result
    assert "superseded" in str(result["err"])
    desc = {e["version"]: e for e in store.describe()
            if e["graph_id"] == "a"}
    assert not desc[1]["resident"]                # tombstone stayed dead
    with store.acquire("a") as lease:
        assert lease.version == 2


def test_explicit_discard_refused_while_refault_in_flight(g_a, g_b):
    """evict(spill=False) during an in-progress refault must refuse (the
    build is reading the spilled layout; discarding would also drop the
    version's plans mid-refault)."""
    budget = _budget_for(g_a, 1.5)
    store = GraphStore(budget_bytes=budget, num_shards=4, pad_multiple=16)
    store.publish("a", g_a)
    store.publish("b", g_b)                       # "a" spilled
    entered, gate = threading.Event(), threading.Event()

    def gated_refault(graph_id, version):
        entered.set()
        assert gate.wait(30)

    store.add_refault_listener(gated_refault)
    result = {}

    def fault():
        result["lease"] = store.acquire("a")

    t = threading.Thread(target=fault)
    t.start()
    try:
        assert entered.wait(30)                   # refault mid-build
        assert store.evict("a", spill=False) is False
        assert store.snapshot()["discards"] == 0
    finally:
        gate.set()
        t.join(30)
    assert result["lease"].pg.num_vertices == g_a.num_vertices
    result["lease"].release()


# ---------------------------------------------------------------------------
# publish validation + superseded-acquire guard (bugfix regressions)
# ---------------------------------------------------------------------------

def test_publish_rejects_nonpositive_spec(g_a):
    """Explicit zeros must raise, not silently take the defaults."""
    store = GraphStore(num_shards=4, pad_multiple=16)
    with pytest.raises(StoreError, match="num_shards"):
        store.publish("g", g_a, num_shards=0)
    with pytest.raises(StoreError, match="num_shards"):
        store.publish("g", g_a, num_shards=-2)
    with pytest.raises(StoreError, match="pad_multiple"):
        store.publish("g", g_a, pad_multiple=0)
    with pytest.raises(StoreError, match="method"):
        store.publish("g", g_a, method="nope")
    assert store.known_version("g") == 0          # nothing registered


def test_acquire_superseded_nonresident_raises(g_a, g_b):
    """A superseded version whose retirement is pending must not be
    re-materialized by a late acquire — only re-pinning the
    still-resident drain is legal."""
    store = GraphStore(num_shards=4, pad_multiple=16)
    store.publish("g", g_a)
    lease = store.acquire("g", 1)
    store.publish("g", g_b)                       # v1 superseded, draining
    # re-pinning the resident draining version is the dispatch path
    store.acquire("g", 1).release()
    # the un-drained window: v1 loses device residency while registered
    store._versions[("g", 1)].pg = None
    with pytest.raises(StoreError, match="superseded"):
        store.acquire("g", 1)
    lease.release()                               # drain completes
    assert store.latest_version("g") == 2
    with store.acquire("g") as lease2:
        assert lease2.version == 2


# ---------------------------------------------------------------------------
# tenancy policy
# ---------------------------------------------------------------------------

def test_token_bucket_injected_time():
    b = TokenBucket(rate=2.0, burst=2, now=0.0)
    assert b.try_take(now=0.0) and b.try_take(now=0.0)
    assert not b.try_take(now=0.0)                # burst exhausted
    assert b.try_take(now=0.5)                    # 0.5s * 2/s = 1 token
    assert not b.try_take(now=0.5)
    assert b.try_take(now=10.0)                   # refill caps at burst
    assert b.try_take(now=10.0)
    assert not b.try_take(now=10.0)


def test_tenant_registry_defaults_and_quota():
    reg = TenantRegistry()
    assert reg.weight("anon") == 1.0
    assert reg.admit("anon")                      # unlimited by default
    reg.configure("paid", weight=4.0, rate_qps=2.0, burst=2, now=0.0)
    assert reg.weight("paid") == 4.0
    assert reg.admit("paid", now=0.0) and reg.admit("paid", now=0.0)
    assert not reg.admit("paid", now=0.0)
    assert reg.admit("paid", now=1.0)


# ---------------------------------------------------------------------------
# service integration
# ---------------------------------------------------------------------------

def test_add_graph_republish_is_version_publish(g_a, g_b):
    svc = GraphQueryService(num_shards=4, max_batch=4)
    svc.add_graph("g", g_a, pad_multiple=16)
    svc.add_graph("g", g_a, pad_multiple=16)      # idempotent
    assert svc.store.latest_version("g") == 1
    res_v1 = svc.query("g", "bfs", root=0)
    assert svc.publish("g", g_b, pad_multiple=16) == 2
    res_v2 = svc.query("g", "bfs", root=0)
    pg_b = PT.partition_graph(g_b, 4, pad_multiple=16)
    ref = Engine(ALG.bfs(0), pg_b, mode="gravfm", backend="ref").run()
    assert np.array_equal(res_v2.state["parent"], ref.state["parent"])
    # the two versions genuinely differ
    assert not np.array_equal(res_v1.state["parent"],
                              res_v2.state["parent"])


def test_add_graph_unversioned_service_raises(g_a, g_b):
    svc = GraphQueryService(num_shards=4, max_batch=4, versioned=False)
    svc.add_graph("g", g_a, pad_multiple=16)
    with pytest.raises(StoreError):
        svc.add_graph("g", g_b, pad_multiple=16)


def test_result_cache_is_version_scoped(g_a, g_b):
    svc = GraphQueryService(num_shards=4, max_batch=4)
    svc.add_graph("g", g_a, pad_multiple=16)
    svc.query("g", "bfs", root=0)
    svc.publish("g", g_b, pad_multiple=16)
    res = svc.query("g", "bfs", root=0)           # must NOT hit v1's cache
    assert svc.stats_snapshot()["result_cache_hits"] == 0
    pg_b = PT.partition_graph(g_b, 4, pad_multiple=16)
    ref = Engine(ALG.bfs(0), pg_b, mode="gravfm", backend="ref").run()
    assert np.array_equal(res.state["parent"], ref.state["parent"])
    svc.query("g", "bfs", root=0)                 # same version: hit
    assert svc.stats_snapshot()["result_cache_hits"] == 1
    # v1's entries were purged when its drained version retired — dead
    # keys must not squeeze live ones out of the bounded LRU
    assert all(k[1] != 1 for k in svc._result_cache)


def test_eviction_pin_race_query_completes_bit_identical(deep_graph, g_b):
    """A graph chosen for eviction while a query is in flight must stay
    pinned until the query retires, and the result must be bit-identical
    to a solo run."""
    budget = _budget_for(deep_graph, 1.2)
    svc = GraphQueryService(num_shards=4, max_batch=4, slots=4,
                            scheduling="continuous",
                            memory_budget=budget, result_cache_size=0)
    svc.add_graph("deep", deep_graph, pad_multiple=16)
    svc.add_graph("other", g_b, pad_multiple=16)  # evicts idle "deep"
    assert svc.store.evictions >= 1
    fut = svc.submit(QueryRequest("deep", "bfs", {"root": 0},
                                  deadline_ms=60_000))   # faults it back
    for _ in range(3):
        svc.poll()                                # in flight, pinned
    assert not fut.done()
    # pressure from the other tenant while "deep" is pinned
    f2 = svc.submit(QueryRequest("other", "bfs", {"root": 0},
                                 deadline_ms=60_000))
    svc.flush()
    assert svc.store.snapshot()["budget_overcommits"] >= 1
    pg_deep = PT.partition_graph(deep_graph, 4, pad_multiple=16)
    ref = Engine(ALG.bfs(0), pg_deep, mode="gravfm", backend="ref").run()
    res = fut.result()
    assert np.array_equal(res.state["parent"], ref.state["parent"])
    assert res.supersteps == ref.supersteps
    assert res.messages == ref.messages
    assert f2.result() is not None
    assert svc.store.faults >= 1


def test_version_swap_isolation_inflight_drains_on_old(deep_graph, g_a,
                                                       g_b):
    """publish() while queries are in flight: they drain on version N
    bit-identically; new arrivals bind N+1; N's plans are dropped after
    the drain without touching other graphs' cache entries."""
    svc = GraphQueryService(num_shards=4, max_batch=4, slots=4,
                            scheduling="continuous", result_cache_size=0)
    svc.add_graph("g", deep_graph, pad_multiple=16)
    svc.add_graph("bystander", g_b, pad_multiple=16)
    f_by = svc.submit(QueryRequest("bystander", "bfs", {"root": 0},
                                   deadline_ms=60_000))
    f_old = svc.submit(QueryRequest("g", "bfs", {"root": 0},
                                    deadline_ms=60_000))
    for _ in range(3):
        svc.poll()
    assert not f_old.done()                       # mid-flight on v1
    assert svc.publish("g", g_a, pad_multiple=16) == 2
    f_new = svc.submit(QueryRequest("g", "bfs", {"root": 0},
                                    deadline_ms=60_000))
    svc.flush()
    pg_v1 = PT.partition_graph(deep_graph, 4, pad_multiple=16)
    ref_v1 = Engine(ALG.bfs(0), pg_v1, mode="gravfm", backend="ref").run()
    res_old = f_old.result()
    assert np.array_equal(res_old.state["parent"], ref_v1.state["parent"])
    assert res_old.supersteps == ref_v1.supersteps
    assert res_old.messages == ref_v1.messages
    pg_v2 = PT.partition_graph(g_a, 4, pad_multiple=16)
    ref_v2 = Engine(ALG.bfs(0), pg_v2, mode="gravfm", backend="ref").run()
    assert np.array_equal(f_new.result().state["parent"],
                          ref_v2.state["parent"])
    assert f_by.result() is not None
    # stale-plan invalidation: v1's stepper plans are gone (its drain
    # released the last pin -> superseded version evicted), v2's and the
    # bystander's survive
    versions = {(k.graph_id, k.version) for k in svc.plans._steppers}
    assert ("g", 1) not in versions
    assert ("g", 2) in versions
    assert ("bystander", 1) in versions
    desc = {(e["graph_id"], e["version"]): e for e in svc.store.describe()}
    assert not desc[("g", 1)]["resident"]


def test_fair_share_weighted_slots(g_a):
    """Two flooding tenants at weights 2:1 on one class retire queries
    in ~2:1 ratio while contended."""
    svc = GraphQueryService(num_shards=4, max_batch=6, slots=6,
                            scheduling="continuous", result_cache_size=0)
    svc.add_graph("g", g_a, pad_multiple=16)
    svc.set_tenant("heavy", weight=2.0)
    svc.set_tenant("light", weight=1.0)
    n_each = 24
    rng = np.random.default_rng(0)
    roots = iter(int(r) for r in
                 rng.integers(0, g_a.num_vertices, size=2 * n_each))
    futs = {"heavy": [], "light": []}
    for _ in range(n_each):
        for t in ("heavy", "light"):
            futs[t].append(svc.submit(QueryRequest(
                "g", "bfs", {"root": next(roots)},
                tenant=t, deadline_ms=600_000)))
    # pump while contended: stop as soon as either side's queue could
    # run dry (half the work done), then compare completion counts
    for _ in range(200):
        svc.poll()
        done_h = sum(f.done() for f in futs["heavy"])
        done_l = sum(f.done() for f in futs["light"])
        if done_h + done_l >= n_each:
            break
    assert done_h + done_l >= n_each
    ratio = done_h / max(done_l, 1)
    assert 2.0 * 0.8 <= ratio <= 2.0 * 1.25, (done_h, done_l)
    svc.flush()
    for fs in futs.values():
        for f in fs:
            assert f.result() is not None
    snap = svc.stats_snapshot()
    assert snap["tenants"]["heavy"]["completed"] == n_each
    assert snap["tenants"]["light"]["completed"] == n_each


def test_tenant_rate_quota_sheds(g_a):
    svc = GraphQueryService(num_shards=4, max_batch=4)
    svc.add_graph("g", g_a, pad_multiple=16)
    svc.set_tenant("capped", rate_qps=0.001, burst=2)
    f1 = svc.submit(QueryRequest("g", "bfs", {"root": 0}, tenant="capped"))
    f2 = svc.submit(QueryRequest("g", "bfs", {"root": 1}, tenant="capped"))
    f3 = svc.submit(QueryRequest("g", "bfs", {"root": 2}, tenant="capped"))
    with pytest.raises(AdmissionError, match="rate quota"):
        f3.result(timeout=0)
    svc.flush()
    assert f1.result() is not None and f2.result() is not None
    snap = svc.stats_snapshot()
    assert snap["tenants"]["capped"]["shed"] == 1
    assert snap["queries_shed"] == 1
    # other tenants are unaffected by the capped tenant's dry bucket
    f4 = svc.submit(QueryRequest("g", "bfs", {"root": 3}))
    svc.flush()
    assert f4.result() is not None


def test_publish_while_bucketed_queries_queued_drains_on_old(g_a, g_b):
    """A queued-but-undispatched bucketed request pins its version from
    submit, so a publish() in the queue-wait window cannot retire the
    version out from under the waiting batch."""
    svc = GraphQueryService(num_shards=4, max_batch=8)   # bucketed
    svc.add_graph("g", g_a, pad_multiple=16)
    f_old = svc.submit(QueryRequest("g", "bfs", {"root": 0},
                                    deadline_ms=60_000))
    assert not f_old.done()                    # waiting in the batcher
    assert svc.publish("g", g_b, pad_multiple=16) == 2
    f_new = svc.submit(QueryRequest("g", "bfs", {"root": 0},
                                    deadline_ms=60_000))
    svc.flush()
    pg_a = PT.partition_graph(g_a, 4, pad_multiple=16)
    ref_a = Engine(ALG.bfs(0), pg_a, mode="gravfm", backend="ref").run()
    assert np.array_equal(f_old.result().state["parent"],
                          ref_a.state["parent"])
    pg_b = PT.partition_graph(g_b, 4, pad_multiple=16)
    ref_b = Engine(ALG.bfs(0), pg_b, mode="gravfm", backend="ref").run()
    assert np.array_equal(f_new.result().state["parent"],
                          ref_b.state["parent"])
    # v1 drained -> retired: host payloads released, tombstone remains
    desc = {(e["graph_id"], e["version"]): e for e in svc.store.describe()}
    assert not desc[("g", 1)]["resident"]


def test_plan_cache_conflicts_with_budget_args(g_a):
    cache = PlanCache()
    with pytest.raises(ValueError, match="mutually exclusive"):
        GraphQueryService(plan_cache=cache, memory_budget=1e9)
    with pytest.raises(ValueError, match="mutually exclusive"):
        GraphQueryService(plan_cache=cache, versioned=False)


def test_plan_cache_version_zero_resolves_latest(g_a, g_b):
    """PlanKey(version=0) — the pre-store API — binds the store's latest
    published version at lookup time."""
    from repro.service import PlanKey
    cache = PlanCache()
    cache.register_graph("g", g_a, num_shards=4, pad_multiple=16)
    key = PlanKey(graph_id="g", kernel="bfs", mode="gravfm",
                  num_shards=4, batch_size=2, backend="ref")
    plan1 = cache.get_plan(key)
    assert plan1.key.version == 1
    cache.register_graph("g", g_b, num_shards=4, pad_multiple=16)
    plan2 = cache.get_plan(key)
    assert plan2.key.version == 2
    assert plan2 is not plan1


def test_store_counters_in_stats_endpoint(g_a):
    svc = GraphQueryService(num_shards=4, max_batch=4)
    svc.add_graph("g", g_a, pad_multiple=16)
    snap = svc.stats_snapshot()
    assert snap["store_resident_graphs"] == 1
    assert snap["store_resident_bytes"] > 0
    assert snap["store_evictions"] == 0
    assert "tenants" in snap


# ---------------------------------------------------------------------------
# shard_map-backend variant (subprocess: needs >1 device)
# ---------------------------------------------------------------------------

_SHARDMAP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {src!r})
import numpy as np
from repro.core import graph as G, partition as PT, algorithms as ALG
from repro.core.engine import Engine
from repro.core.engine_shardmap import ShardEngine
from repro.launch.mesh import compat_make_mesh
from repro.store import GraphStore

mesh = compat_make_mesh((8,), ("graph",))
deep = G.ladder(2, 30, 1, seed=0)
other = G.uniform(300, 6.0, seed=2).symmetrized()
budget = 1.2 * PT.partition_graph(deep, 8, pad_multiple=16).device_nbytes
store = GraphStore(budget_bytes=budget, num_shards=8, pad_multiple=16)
store.publish("deep", deep)
store.publish("other", other)        # idle "deep" evicted

# fault "deep" back and start an in-flight shard_map continuous query
lease = store.acquire("deep")
assert store.faults == 1
se = ShardEngine(ALG.bfs(), lease.pg, mesh=mesh, exchange="allgather",
                 backend="ref")
st = se.make_stepper(2)
qkw = {{"root": np.zeros(2, np.int32)}}
carry, act, steps = st.init(qkw)
occ = np.zeros(2, bool); occ[0] = True
for _ in range(3):
    carry, act, steps = st.step(carry, occ)

# eviction pressure while pinned: "deep" must survive (overcommit)
lease2 = store.acquire("other")
assert {{e["graph_id"]: e for e in store.describe()}}["deep"]["resident"]
assert store.snapshot()["budget_overcommits"] >= 1

# version publish mid-flight: v1 pinned for its drain, v2 is latest
store.publish("deep", other)
assert store.latest_version("deep") == 2
assert {{(e["graph_id"], e["version"]): e["resident"]
        for e in store.describe()}}[("deep", 1)]

# finish the in-flight query on v1 — bit-identical to a solo run
for _ in range(1000):
    occ &= act
    if not occ.any():
        break
    carry, act, steps = st.step(carry, occ)
res = se.lane_result(st.fetch(carry), 0)
ref = Engine(ALG.bfs(0), PT.partition_graph(deep, 8, pad_multiple=16),
             mode="gravfm", backend="ref").run()
assert np.array_equal(res["state"]["parent"], ref.state["parent"])
assert res["supersteps"] == ref.supersteps
assert res["messages"] == ref.messages

# drain: releasing the last pin evicts the superseded v1
lease.release()
assert not {{(e["graph_id"], e["version"]): e["resident"]
            for e in store.describe()}}[("deep", 1)]
lease2.release()
print("STORE-SHARDMAP-OK")
"""


@pytest.mark.slow
def test_store_shardmap_eviction_pin_and_version_swap():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SHARDMAP_SCRIPT.format(src=os.path.abspath(src))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "STORE-SHARDMAP-OK" in proc.stdout
