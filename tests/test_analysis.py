"""Static-analysis suite tests (repro.analysis).

Three layers:

1. Per-rule fixtures — a known-bad snippet makes the rule fire, a
   known-good variant stays silent (including ``# analysis: allow``).
2. Infrastructure — fingerprint stability, baseline round-trip, the
   CLI exit-code contract.
3. The real tree — ``run_check`` over this repository is clean with an
   empty baseline, and seeded violations in a scratch copy of the tree
   are caught (the checker demonstrably protects the invariants it
   claims to).
"""
import json
import os
import shutil
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (Baseline, DeadCodePass, LockPass, RetracePass,
                            TaxonomyPass, run_check)
from repro.analysis.cli import main as cli_main
from repro.analysis.findings import SourceFile, fingerprint_of

REPO = Path(__file__).resolve().parents[1]


def src(tmp_path, text, rel="mod.py"):
    text = textwrap.dedent(text)
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text, encoding="utf-8")
    return SourceFile(p, rel, text)


def rules(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# lock-discipline fixtures
# ---------------------------------------------------------------------------

LOCK_PREAMBLE = """
import threading

class Store:
    def __init__(self):
        self._lock = threading.RLock()  # lock: store

class Stats:
    def __init__(self):
        self._lock = threading.Lock()  # lock: stats
"""


class TestLockRules:
    def test_order_inversion_fires(self, tmp_path):
        sf = src(tmp_path, LOCK_PREAMBLE + """
    def bad(self, store):
        with self._lock:
            with store._lock:
                pass
""")
        fs = LockPass().run([sf])
        assert "LCK001" in rules(fs)
        assert any("stats" in f.message and "store" in f.message
                   for f in fs if f.rule == "LCK001")

    def test_correct_order_is_clean(self, tmp_path):
        sf = src(tmp_path, LOCK_PREAMBLE + """
    def good(self, store):
        pass

class Service:
    def __init__(self):
        self._lock = threading.RLock()  # lock: server

    def ok(self, store):
        with self._lock:
            with store._lock:
                pass
""")
        assert LockPass().run([sf]) == []

    def test_self_deadlock_nonreentrant(self, tmp_path):
        sf = src(tmp_path, LOCK_PREAMBLE + """
    def bad(self):
        with self._lock:
            with self._lock:
                pass
""")
        fs = LockPass().run([sf])
        assert any(f.rule == "LCK001" and "non-reentrant" in f.message
                   for f in fs)

    def test_leaf_lock_across_outbound_call(self, tmp_path):
        sf = src(tmp_path, LOCK_PREAMBLE + """
    def bad(self):
        with self._lock:
            open("/tmp/x")
""")
        fs = LockPass().run([sf])
        assert rules(fs) == ["LCK002"]

    def test_leaf_outcall_allow_annotation(self, tmp_path):
        sf = src(tmp_path, LOCK_PREAMBLE + """
    def fine(self):
        with self._lock:
            open("/tmp/x")  # analysis: allow(LCK002)
""")
        assert LockPass().run([sf]) == []

    def test_blocking_under_forbidding_lock(self, tmp_path):
        sf = src(tmp_path, LOCK_PREAMBLE + """
    def bad(self, fut):
        with self._lock:
            fut.result()
""")
        fs = LockPass().run([sf])
        assert "LCK003" in rules(fs)

    def test_condition_wait_on_own_lock_exempt(self, tmp_path):
        sf = src(tmp_path, """
import threading

class Server:
    def __init__(self):
        self._lock = threading.RLock()  # lock: server
        self._wake = threading.Condition(self._lock)  # lock: server

    def waits(self):
        with self._wake:
            self._wake.wait(0.1)
""")
        assert LockPass().run([sf]) == []

    def test_callback_under_store_lock(self, tmp_path):
        sf = src(tmp_path, """
import threading

class Store:
    def __init__(self):
        self._lock = threading.RLock()  # lock: store
        self._evict_listeners = []

    def bad(self):
        with self._lock:
            for fn in self._evict_listeners:
                fn(1, 2)
""")
        fs = LockPass().run([sf])
        assert "LCK004" in rules(fs)

    def test_unregistered_lock_construction(self, tmp_path):
        sf = src(tmp_path, """
import threading

class Thing:
    def __init__(self):
        self._lock = threading.Lock()
""")
        fs = LockPass().run([sf])
        assert rules(fs) == ["LCK005"]

    def test_unknown_domain_annotation(self, tmp_path):
        sf = src(tmp_path, """
import threading

class Thing:
    def __init__(self):
        self._lock = threading.Lock()  # lock: nosuchdomain
""")
        fs = LockPass().run([sf])
        assert rules(fs) == ["LCK005"]
        assert "undeclared" in fs[0].message

    def test_transitive_effect_anchored_at_site(self, tmp_path):
        """A violation inside a helper reached from under a lock is
        reported at the helper's line (one allow covers all callers)."""
        sf = src(tmp_path, LOCK_PREAMBLE + """
    def helper(self, fut):
        fut.result()

    def caller_a(self, fut):
        with self._lock:
            self.helper(fut)

    def caller_b(self, fut):
        with self._lock:
            self.helper(fut)
""")
        fs = LockPass().run([sf])
        lck3 = [f for f in fs if f.rule == "LCK003"]
        assert lck3 and len({f.line for f in lck3}) == 1


# ---------------------------------------------------------------------------
# retrace-hazard fixtures
# ---------------------------------------------------------------------------


class TestRetraceRules:
    def test_tracer_branch_fires(self, tmp_path):
        sf = src(tmp_path, """
import jax

def step(x):
    if x > 0:
        return x
    return -x

run = jax.jit(step)
""")
        fs = RetracePass().run([sf])
        assert "RTR001" in rules(fs)

    def test_static_shape_branch_is_clean(self, tmp_path):
        sf = src(tmp_path, """
import jax

def step(x):
    if x.shape[0] > 4:
        return x
    return -x

run = jax.jit(step)
""")
        assert [f for f in RetracePass().run([sf])
                if f.rule == "RTR001"] == []

    def test_none_check_is_clean(self, tmp_path):
        sf = src(tmp_path, """
import jax

def step(x, y=None):
    if y is None:
        return x
    return x + y

run = jax.jit(step)
""")
        assert [f for f in RetracePass().run([sf])
                if f.rule == "RTR001"] == []

    def test_host_marker_suppresses(self, tmp_path):
        sf = src(tmp_path, """
import jax

def step(x):  # analysis: host
    if x > 0:
        return x
    return -x

run = jax.jit(step)
""")
        assert [f for f in RetracePass().run([sf])
                if f.rule == "RTR001"] == []

    def test_traced_marker_forces_check(self, tmp_path):
        sf = src(tmp_path, """
def deliver(x):  # analysis: traced
    while x < 3:
        x = x + 1
    return x
""")
        fs = RetracePass().run([sf])
        assert "RTR001" in rules(fs)

    def test_jit_in_hot_path_fires(self, tmp_path):
        sf = src(tmp_path, """
import jax

class Stepper:
    def step(self, fn, x):
        return jax.jit(fn)(x)
""")
        fs = RetracePass().run([sf])
        assert "RTR002" in rules(fs)

    def test_jit_in_factory_is_clean(self, tmp_path):
        sf = src(tmp_path, """
import jax

class Stepper:
    def __init__(self, fn):
        self._run = jax.jit(fn)

    def _build(self, fn):
        return jax.jit(fn)

    def make_run(self, fn):
        return jax.jit(fn)
""")
        assert [f for f in RetracePass().run([sf])
                if f.rule == "RTR002"] == []

    def test_array_valued_static_arg(self, tmp_path):
        sf = src(tmp_path, """
import jax
import jax.numpy as jnp

def f(x, cfg):
    return x

run = jax.jit(f, static_argnums=(1,))

def call(x):
    return run(x, jnp.zeros(4))
""")
        fs = RetracePass().run([sf])
        assert "RTR003" in rules(fs)

    def test_nonliteral_static_spec(self, tmp_path):
        sf = src(tmp_path, """
import jax

def f(x):
    return x

run = jax.jit(f, static_argnums=[[1]])
""")
        fs = RetracePass().run([sf])
        assert "RTR003" in rules(fs)

    def test_closure_captured_device_array(self, tmp_path):
        sf = src(tmp_path, """
import jax
import jax.numpy as jnp

def build():
    table = jnp.arange(8)

    def step(x):
        return x + table

    return jax.jit(step)
""")
        fs = RetracePass().run([sf])
        assert "RTR004" in rules(fs)

    def test_numpy_host_constant_closure_clean(self, tmp_path):
        sf = src(tmp_path, """
import jax
import numpy as np

def build():
    table = np.arange(8)

    def step(x):
        return x + table

    return jax.jit(step)
""")
        assert [f for f in RetracePass().run([sf])
                if f.rule == "RTR004"] == []

    def test_unrolled_collective_pipeline_fires(self, tmp_path):
        # the double-buffer window index as a Python int: the ppermute
        # pipeline unrolls at trace time
        sf = src(tmp_path, """
import jax

def deliver(buf, perm):  # analysis: traced
    acc = buf
    for k in range(4):
        buf = jax.lax.ppermute(buf, "graph", perm)
        acc = acc + buf
    return acc
""")
        fs = RetracePass().run([sf])
        assert "RTR005" in rules(fs)

    def test_fori_loop_pipeline_is_clean(self, tmp_path):
        # the fixed pattern: window index in the fori_loop carry, the
        # permutation *table* built with a comprehension
        sf = src(tmp_path, """
import jax

def deliver(buf, P):  # analysis: traced
    perm = [(i, (i + 1) % P) for i in range(P)]

    def body(k, st):
        acc, cur = st
        nxt = jax.lax.ppermute(cur, "graph", perm)
        return (acc + nxt, nxt)

    return jax.lax.fori_loop(0, P, body, (buf, buf))
""")
        assert [f for f in RetracePass().run([sf])
                if f.rule == "RTR005"] == []

    def test_host_loop_collective_is_clean(self, tmp_path):
        # a host function looping over jitted collective programs is
        # not a traced scope — dispatch loops are fine
        sf = src(tmp_path, """
import jax

def pump(progs, buf):
    for p in progs:
        buf = p(buf)
    return buf
""")
        assert [f for f in RetracePass().run([sf])
                if f.rule == "RTR005"] == []

    def test_unrolled_collective_allow_comment(self, tmp_path):
        sf = src(tmp_path, """
import jax

def deliver(buf, perm):  # analysis: traced
    for k in range(2):  # analysis: allow(RTR005)
        buf = jax.lax.ppermute(buf, "graph", perm)
    return buf
""")
        assert [f for f in RetracePass().run([sf])
                if f.rule == "RTR005"] == []


# ---------------------------------------------------------------------------
# taxonomy fixtures
# ---------------------------------------------------------------------------

README_FIXTURE = """
## Observability

Event taxonomy:

| kind | emitted by | meaning |
|---|---|---|
| `submit` | server | arrived |
| `retire` | scheduler | resolved |

## Metrics

Metric-name taxonomy:

| family | type | labels | source |
|---|---|---|---|
| `gravfm_queries_{submitted,completed}_total` | counter | — | stats |
| `gravfm_qps` | gauge | — | stats |
| `gravfm_store_<k>_total` | counter | — | store |

## Next
"""

KINDS = {"submit", "retire"}


class TestTaxonomyRules:
    def make(self, tmp_path, body):
        return src(tmp_path, body)

    def test_unknown_trace_kind(self, tmp_path):
        sf = self.make(tmp_path, """
def f(bus):
    bus.emit("gone", q=1)
""")
        fs = TaxonomyPass(event_kinds=KINDS,
                          readme_text=README_FIXTURE).run([sf])
        assert "TAX001" in rules(fs)

    def test_known_kind_clean(self, tmp_path):
        sf = self.make(tmp_path, """
def f(bus):
    bus.emit("submit", q=1)
""")
        fs = TaxonomyPass(event_kinds=KINDS,
                          readme_text=README_FIXTURE).run([sf])
        assert "TAX001" not in rules(fs)

    def test_malformed_metric_name(self, tmp_path):
        sf = self.make(tmp_path, """
def f(reg):
    reg.inc("gravfm_Bad-Name_total")
""")
        fs = TaxonomyPass(event_kinds=KINDS,
                          readme_text=README_FIXTURE).run([sf])
        assert "TAX002" in rules(fs)

    def test_counter_without_total_suffix(self, tmp_path):
        sf = self.make(tmp_path, """
def f(reg):
    reg.inc("gravfm_queries_submitted")
""")
        fs = TaxonomyPass(event_kinds=KINDS,
                          readme_text=README_FIXTURE).run([sf])
        assert "TAX003" in rules(fs)

    def test_kind_conflict(self, tmp_path):
        sf = self.make(tmp_path, """
def f(reg):
    reg.inc("gravfm_qps_x_total")
    reg.set_gauge("gravfm_qps_x_total")
""")
        fs = TaxonomyPass(event_kinds=KINDS,
                          readme_text=README_FIXTURE).run([sf])
        assert "TAX004" in rules(fs)

    def test_undocumented_family(self, tmp_path):
        sf = self.make(tmp_path, """
def f(reg):
    reg.set_gauge("gravfm_mystery_depth")
""")
        fs = TaxonomyPass(event_kinds=KINDS,
                          readme_text=README_FIXTURE).run([sf])
        assert "TAX005" in rules(fs)

    def test_fstring_family_resolves_against_wildcard_row(self, tmp_path):
        sf = self.make(tmp_path, """
def f(reg, snap):
    for key, val in snap.items():
        reg.set_counter(f"gravfm_store_{key}_total", val)
""")
        fs = TaxonomyPass(event_kinds=KINDS,
                          readme_text=README_FIXTURE).run([sf])
        assert fs == []

    def test_loop_literal_fstring_expands(self, tmp_path):
        sf = self.make(tmp_path, """
def f(reg, t):
    for field in ("submitted", "completed"):
        reg.set_counter(f"gravfm_queries_{field}_total", t[field])
""")
        fs = TaxonomyPass(event_kinds=KINDS,
                          readme_text=README_FIXTURE).run([sf])
        assert fs == []

    def test_undocumented_event_kind(self, tmp_path):
        sf = src(tmp_path, """
EVENT_KINDS = frozenset({"submit", "retire", "newkind"})
""", rel="service/trace.py")
        fs = TaxonomyPass(readme_text=README_FIXTURE).run([sf])
        assert "TAX006" in rules(fs)
        assert any("newkind" in f.message for f in fs)


# ---------------------------------------------------------------------------
# dead-code fixtures
# ---------------------------------------------------------------------------


class TestDeadCode:
    def test_unused_import_and_def(self, tmp_path):
        sf = src(tmp_path, """
import os
import json

def _helper():
    return 1

def used():
    return json.dumps({})
""")
        fs = DeadCodePass().run([sf])
        assert rules(fs) == ["DC001", "DC002"]
        assert all(f.severity == "info" for f in fs)

    def test_quoted_annotation_counts_as_use(self, tmp_path):
        sf = src(tmp_path, """
from typing import Dict

def f(x) -> "Dict[str, int]":
    return {}
""")
        assert DeadCodePass().run([sf]) == []

    def test_all_export_counts_as_use(self, tmp_path):
        sf = src(tmp_path, """
import os

__all__ = ["os"]
""")
        assert DeadCodePass().run([sf]) == []


# ---------------------------------------------------------------------------
# fingerprints, baseline, CLI
# ---------------------------------------------------------------------------


class TestInfra:
    def test_fingerprint_ignores_line_number(self):
        a = fingerprint_of("LCK001", "m.py", "f", "with self._lock:")
        b = fingerprint_of("LCK001", "m.py", "f", "  with self._lock:  ")
        assert a == b and len(a) == 16

    def test_baseline_round_trip(self, tmp_path):
        sf = src(tmp_path, LOCK_PREAMBLE + """
    def bad(self):
        with self._lock:
            open("/tmp/x")
""")
        fs = LockPass().run([sf])
        assert fs
        path = tmp_path / "baseline.json"
        Baseline().save(path, fs)
        loaded = Baseline.load(path)
        assert all(f in loaded for f in fs)
        data = json.loads(path.read_text())
        assert set(data) == {"fingerprints"}

    def test_cli_gates_on_new_findings(self, tmp_path):
        root = tmp_path / "proj"
        (root / "src" / "repro").mkdir(parents=True)
        (root / "src" / "repro" / "service").mkdir()
        bad = textwrap.dedent("""
            import threading

            class S:
                def __init__(self):
                    self._lock = threading.Lock()  # lock: stats

                def bad(self):
                    with self._lock:
                        open("/tmp/x")
        """)
        (root / "src" / "repro" / "service" / "stats.py").write_text(bad)
        rc = cli_main(["check", "--root", str(root)])
        assert rc == 1
        # baselining the findings makes the same tree pass
        rc = cli_main(["check", "--root", str(root),
                       "--write-baseline", str(tmp_path / "b.json")])
        assert rc == 0
        rc = cli_main(["check", "--root", str(root),
                       "--baseline", str(tmp_path / "b.json")])
        assert rc == 0

    def test_cli_json_report(self, tmp_path, capsys):
        root = tmp_path / "proj"
        (root / "src" / "repro").mkdir(parents=True)
        out = tmp_path / "report.json"
        rc = cli_main(["check", "--root", str(root), "--json",
                       "--json-out", str(out)])
        assert rc == 0
        payload = json.loads(out.read_text())
        assert payload["ok"] is True
        assert set(payload) == {"ok", "new", "baselined", "info",
                                "passes"}
        assert json.loads(capsys.readouterr().out)["ok"] is True


# ---------------------------------------------------------------------------
# the real tree
# ---------------------------------------------------------------------------


class TestRepoTree:
    def test_repo_is_clean_with_empty_baseline(self):
        report = run_check(REPO)
        msgs = [f.render() for f in report["new"]]
        assert report["ok"], "\n".join(msgs)
        assert report["info"] == [], "\n".join(
            f.render() for f in report["info"])

    def test_module_invocation_exits_zero(self):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "check", "--root",
             str(REPO), "--baseline",
             str(REPO / "analysis-baseline.json")],
            cwd=REPO, capture_output=True, text=True,
            env={**os.environ, "PYTHONPATH": str(REPO / "src")})
        assert proc.returncode == 0, proc.stdout + proc.stderr

    @pytest.fixture()
    def scratch(self, tmp_path):
        """A scratch copy of the real tree the tests can vandalise."""
        root = tmp_path / "scratch"
        (root / "src").mkdir(parents=True)
        shutil.copytree(REPO / "src" / "repro", root / "src" / "repro")
        shutil.copy(REPO / "README.md", root / "README.md")
        return root

    def test_scratch_copy_is_clean(self, scratch):
        assert run_check(scratch)["ok"]

    def test_seeded_lock_inversion_is_caught(self, scratch):
        server = scratch / "src" / "repro" / "service" / "server.py"
        text = server.read_text()
        # a method that takes the store lock and then the server lock —
        # a textbook inversion of the declared hierarchy
        text += textwrap.dedent("""

        def _seeded_inversion(svc):
            with svc.store._lock:
                with svc._lock:
                    pass
        """)
        server.write_text(text)
        report = run_check(scratch)
        assert not report["ok"]
        assert any(f.rule == "LCK001" and "server" in f.message
                   for f in report["new"])

    def test_seeded_tracer_branch_is_caught(self, scratch):
        stepper = scratch / "src" / "repro" / "core" / "stepper.py"
        text = stepper.read_text()
        text += textwrap.dedent("""

        def _seeded_hazard(x):  # analysis: traced
            if x > 0:
                return x
            return -x
        """)
        stepper.write_text(text)
        report = run_check(scratch)
        assert not report["ok"]
        assert any(f.rule == "RTR001" for f in report["new"])

    def test_seeded_unrolled_collective_is_caught(self, scratch):
        engine = (scratch / "src" / "repro" / "core"
                  / "engine_shardmap.py")
        text = engine.read_text()
        # a pipelined deliver whose double-buffer window index is a
        # Python int — the exact hazard the overlapped schedules must
        # avoid (their window index lives in the fori_loop carry)
        text += textwrap.dedent("""

        def _seeded_pipeline(buf, perm):  # analysis: traced
            for win in range(4):
                buf = jax.lax.ppermute(buf, "graph", perm)
            return buf
        """)
        engine.write_text(text)
        report = run_check(scratch)
        assert not report["ok"]
        assert any(f.rule == "RTR005" for f in report["new"])

    def test_seeded_unknown_kind_is_caught(self, scratch):
        registry = scratch / "src" / "repro" / "store" / "registry.py"
        text = registry.read_text()
        text += textwrap.dedent("""

        def _seeded_emit(bus):
            bus.emit("not_a_kind", graph_id=0)
        """)
        registry.write_text(text)
        report = run_check(scratch)
        assert not report["ok"]
        assert any(f.rule == "TAX001" and "not_a_kind" in f.message
                   for f in report["new"])

    def test_seeded_undocumented_metric_is_caught(self, scratch):
        metrics = scratch / "src" / "repro" / "service" / "metrics.py"
        text = metrics.read_text()
        text += textwrap.dedent("""

        def _seeded_metric(reg):
            reg.set_gauge("gravfm_totally_new_gauge", 1.0)
        """)
        metrics.write_text(text)
        report = run_check(scratch)
        assert not report["ok"]
        assert any(f.rule == "TAX005" for f in report["new"])
