"""Query service subsystem: batched execution must be bit-identical to
sequential single-query runs; the plan cache must serve steady state with
zero re-traces; the scheduler must respect batch-size and deadline
triggers for mixed-deadline request streams."""
import time

import numpy as np
import pytest

from repro.core import algorithms as ALG
from repro.core import graph as G
from repro.core import partition as PT
from repro.core.engine import Engine
from repro.service import (Batcher, GraphQueryService, PlanCache, PlanKey,
                           QueryClass, QueryRequest, bucket_for)


@pytest.fixture(scope="module")
def graph():
    return G.uniform(600, 8.0, seed=11, weighted=True).symmetrized()


@pytest.fixture(scope="module")
def pg(graph):
    return PT.partition_graph(graph, 4, method="greedy", pad_multiple=16)


# ---------------------------------------------------------------------------
# batched engine execution == sequential single-query runs
# ---------------------------------------------------------------------------

def test_batched_bfs_matches_sequential(graph, pg):
    roots = (np.arange(32, dtype=np.int32) * 13) % graph.num_vertices
    eng = Engine(ALG.bfs(), pg, mode="gravfm", backend="ref")
    batch = eng.run_batch(root=roots)
    assert len(batch) == 32
    for i, r in enumerate(roots):
        single = Engine(ALG.bfs(int(r)), pg, mode="gravfm",
                        backend="ref").run()
        assert np.array_equal(batch[i].state["parent"],
                              single.state["parent"])
        assert batch[i].supersteps == single.supersteps
        assert batch[i].messages == single.messages


def test_batched_bfs_matches_sequential_pallas(graph, pg):
    roots = np.array([0, 3, 77, 401], np.int32)
    eng = Engine(ALG.bfs(), pg, mode="gravfm", backend="pallas",
                 tile_e=64, tile_r=32)
    batch = eng.run_batch(root=roots)
    for i, r in enumerate(roots):
        single = Engine(ALG.bfs(int(r)), pg, mode="gravfm",
                        backend="pallas", tile_e=64, tile_r=32).run()
        assert np.array_equal(batch[i].state["parent"],
                              single.state["parent"])


def test_batched_sssp_matches_sequential(graph, pg):
    roots = (np.arange(8, dtype=np.int32) * 71) % graph.num_vertices
    eng = Engine(ALG.sssp(), pg, mode="gravfm", backend="ref")
    batch = eng.run_batch(root=roots)
    for i, r in enumerate(roots):
        single = Engine(ALG.sssp(int(r)), pg, mode="gravfm",
                        backend="ref").run()
        # bit-identical incl. inf for unreachable
        assert np.array_equal(
            batch[i].state["dist"].view(np.int32),
            single.state["dist"].view(np.int32))
        assert np.array_equal(batch[i].state["parent"],
                              single.state["parent"])


def test_run_query_kwarg_overrides_closure(pg):
    eng = Engine(ALG.bfs(0), pg, mode="gravfm", backend="ref")
    res = eng.run(root=42)
    ref = Engine(ALG.bfs(42), pg, mode="gravfm", backend="ref").run()
    assert np.array_equal(res.state["parent"], ref.state["parent"])


def test_run_batch_requires_query_arrays(pg):
    eng = Engine(ALG.bfs(), pg, mode="gravfm", backend="ref")
    with pytest.raises(ValueError):
        eng.run_batch()


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def test_plan_cache_hit_miss_and_zero_retrace(graph):
    cache = PlanCache()
    cache.register_graph("g", graph, num_shards=4, pad_multiple=16)
    key = PlanKey(graph_id="g", kernel="bfs", mode="gravfm",
                  num_shards=4, batch_size=8, backend="ref")

    plan = cache.get_plan(key, warm=True)
    assert cache.stats.plan_cache_misses == 1
    traces_after_warm = cache.sync_trace_counters()
    assert traces_after_warm >= 1

    roots = np.arange(8, dtype=np.int32)
    plan2 = cache.get_plan(key)
    assert plan2 is plan
    assert cache.stats.plan_cache_hits == 1
    plan2.execute(root=roots)
    plan2.execute(root=roots + 8)
    # steady state: zero re-traces after the warmup compile
    assert cache.sync_trace_counters() == traces_after_warm

    # different batch size = different plan (miss), same engine (1 trace)
    key16 = PlanKey(graph_id="g", kernel="bfs", mode="gravfm",
                    num_shards=4, batch_size=16, backend="ref")
    cache.get_plan(key16, warm=True)
    assert cache.stats.plan_cache_misses == 2


def test_plan_cache_rejects_unbatchable_kernel(graph):
    cache = PlanCache()
    cache.register_graph("g", graph, num_shards=4, pad_multiple=16)
    with pytest.raises(ValueError):
        cache.get_plan(PlanKey(graph_id="g", kernel="wcc", mode="gravfm",
                               num_shards=4, batch_size=8, backend="ref"))


def test_plan_cache_requires_registered_graph():
    cache = PlanCache()
    with pytest.raises(KeyError):
        cache.get_plan(PlanKey(graph_id="nope", kernel="bfs",
                               mode="gravfm", num_shards=4, batch_size=1,
                               backend="ref"))


# ---------------------------------------------------------------------------
# batcher / scheduler
# ---------------------------------------------------------------------------

def test_bucket_for():
    assert [bucket_for(n, 32) for n in (1, 2, 3, 5, 8, 9, 31, 32, 33)] == \
        [1, 2, 4, 8, 8, 16, 32, 32, 32]


def test_batcher_groups_by_class_and_fills():
    b = Batcher(max_batch=4, slack_ms=0.0)
    qa = QueryClass("g1", "bfs", "gravfm", 4, "ref")
    qb = QueryClass("g2", "bfs", "gravfm", 4, "ref")
    out = []
    for i in range(7):
        r = QueryRequest("g1" if i % 2 == 0 else "g2", "bfs",
                         {"root": i})
        ready = b.add(qa if i % 2 == 0 else qb, (r, None), True)
        if ready is not None:
            out.append(ready)
    # g1 saw 4 requests (i = 0,2,4,6) -> one full batch; g2 still pending
    assert len(out) == 1 and out[0][0] == qa and len(out[0][1]) == 4
    assert len(b) == 3


def test_batcher_mixed_deadlines_flush_order():
    """A class's flush time is the TIGHTEST member deadline; an urgent
    request joining a lazy batch pulls the whole batch forward."""
    b = Batcher(max_batch=32, slack_ms=0.0)
    qc = QueryClass("g", "bfs", "gravfm", 4, "ref")
    now = time.perf_counter()
    lazy = QueryRequest("g", "bfs", {"root": 1}, deadline_ms=10_000)
    b.add(qc, (lazy, None), True)
    assert b.due(now) == []           # nothing due yet
    nxt = b.next_flush_s()
    assert nxt is not None and nxt > now + 5

    urgent = QueryRequest("g", "bfs", {"root": 2}, deadline_ms=1.0)
    b.add(qc, (urgent, None), True)
    assert b.next_flush_s() < now + 1
    due = b.due(urgent.deadline_s + 1e-3)
    assert len(due) == 1 and len(due[0][1]) == 2  # both ride the batch
    assert len(b) == 0


def test_service_end_to_end_batched_correctness(graph, pg):
    svc = GraphQueryService(num_shards=4, max_batch=8)
    svc.add_graph("g", graph, pad_multiple=16)
    futs = [svc.submit(QueryRequest("g", "bfs", {"root": int(r)}))
            for r in range(8)]
    assert all(f.done() for f in futs)  # full batch auto-dispatched
    for r, f in enumerate(futs):
        ref = Engine(ALG.bfs(r), pg, mode="gravfm", backend="ref").run()
        assert np.array_equal(f.result().state["parent"],
                              ref.state["parent"])
    snap = svc.stats_snapshot()
    assert snap["queries_completed"] == 8
    assert snap["batches_dispatched"] == 1
    assert snap["avg_batch_size"] == 8


def test_service_steady_state_zero_retrace(graph):
    svc = GraphQueryService(num_shards=4, max_batch=8)
    svc.add_graph("g", graph, pad_multiple=16)
    for wave in range(3):
        for r in range(8):
            svc.submit(QueryRequest("g", "bfs",
                                    {"root": wave * 8 + r}))
        if wave == 0:
            traces0 = svc.stats_snapshot()["plan_traces"]
    snap = svc.stats_snapshot()
    assert snap["plan_traces"] == traces0    # acceptance: zero re-traces
    assert snap["plan_cache_hits"] >= 2
    assert snap["plan_cache_misses"] == 1


def test_service_partial_batch_padding_and_poll(graph, pg):
    svc = GraphQueryService(num_shards=4, max_batch=32)
    svc.add_graph("g", graph, pad_multiple=16)
    # 3 queries -> bucket 4, one pad lane, dispatched via deadline poll
    futs = [svc.submit(QueryRequest("g", "bfs", {"root": r},
                                    deadline_ms=5.0)) for r in range(3)]
    assert not any(f.done() for f in futs)
    deadline = time.perf_counter() + 5
    while svc.pending() and time.perf_counter() < deadline:
        svc.poll()
        time.sleep(0.002)
    assert all(f.done() for f in futs)
    for r, f in enumerate(futs):
        ref = Engine(ALG.bfs(r), pg, mode="gravfm", backend="ref").run()
        assert np.array_equal(f.result().state["parent"],
                              ref.state["parent"])
    assert svc.stats_snapshot()["batch_pad_queries"] == 1


def test_service_mixed_deadline_async_scheduler(graph, pg):
    svc = GraphQueryService(num_shards=4, max_batch=32).start()
    svc.add_graph("g", graph, pad_multiple=16)
    try:
        slow_f = svc.submit(QueryRequest("g", "bfs", {"root": 0},
                                         deadline_ms=5_000))
        fast_f = svc.submit(QueryRequest("g", "bfs", {"root": 1},
                                         deadline_ms=30))
        # the urgent request drags the lazy one along in the same batch
        res_fast = fast_f.result(timeout=10)
        res_slow = slow_f.result(timeout=10)
    finally:
        svc.stop()
    for r, res in ((0, res_slow), (1, res_fast)):
        ref = Engine(ALG.bfs(r), pg, mode="gravfm", backend="ref").run()
        assert np.array_equal(res.state["parent"], ref.state["parent"])
    assert svc.stats_snapshot()["batches_dispatched"] == 1


def test_service_unbatchable_and_sync_query(graph, pg):
    svc = GraphQueryService(num_shards=4, max_batch=8)
    svc.add_graph("g", graph, pad_multiple=16)
    res = svc.query("g", "wcc")
    ref = Engine(ALG.wcc(), pg, mode="gravfm", backend="ref").run()
    assert np.array_equal(res.state["label"], ref.state["label"])
    res = svc.query("g", "sssp", root=5)
    ref = Engine(ALG.sssp(5), pg, mode="gravfm", backend="ref").run()
    assert np.array_equal(res.state["dist"].view(np.int32),
                          ref.state["dist"].view(np.int32))


def test_service_rejects_bad_requests(graph):
    svc = GraphQueryService(num_shards=4, max_batch=8)
    svc.add_graph("g", graph, pad_multiple=16)
    with pytest.raises(KeyError):
        svc.submit(QueryRequest("g", "nope", {"root": 0}))
    with pytest.raises(ValueError):
        svc.submit(QueryRequest("g", "bfs", {"root": 0, "bogus": 1}))
    # missing a declared param must fail at submit, not co-batch-dependent
    with pytest.raises(ValueError, match="missing"):
        svc.submit(QueryRequest("g", "bfs"))


def test_sync_query_flushes_only_its_class(graph, pg):
    svc = GraphQueryService(num_shards=4, max_batch=32)
    svc.add_graph("g", graph, pad_multiple=16)
    pend = svc.submit(QueryRequest("g", "sssp", {"root": 2},
                                   deadline_ms=60_000))
    res = svc.query("g", "bfs", root=1)
    ref = Engine(ALG.bfs(1), pg, mode="gravfm", backend="ref").run()
    assert np.array_equal(res.state["parent"], ref.state["parent"])
    # the sssp request's half-filled batch kept accumulating
    assert not pend.done() and svc.pending() == 1
    svc.flush()
    assert pend.done()


def test_engine_rejects_misspelled_query_param(pg):
    """A typo'd kwarg must not be silently swallowed by init_state's
    catch-all (which would run every lane from the default root)."""
    eng = Engine(ALG.bfs(), pg, mode="gravfm", backend="ref")
    with pytest.raises(ValueError, match="roots"):
        eng.run_batch(roots=np.arange(4, dtype=np.int32))
    with pytest.raises(ValueError, match="rot"):
        eng.run(rot=3)


def test_service_cancelled_future_does_not_poison_batch(graph, pg):
    svc = GraphQueryService(num_shards=4, max_batch=32)
    svc.add_graph("g", graph, pad_multiple=16)
    f_cancel = svc.submit(QueryRequest("g", "bfs", {"root": 0}))
    f_keep = svc.submit(QueryRequest("g", "bfs", {"root": 1}))
    assert f_cancel.cancel()
    svc.flush()
    assert f_keep.done() and not f_keep.cancelled()
    ref = Engine(ALG.bfs(1), pg, mode="gravfm", backend="ref").run()
    assert np.array_equal(f_keep.result().state["parent"],
                          ref.state["parent"])
    # only the surviving query is accounted
    assert svc.stats_snapshot()["queries_completed"] == 1


def test_service_shares_one_stats_object(graph):
    """Passing both plan_cache and stats must not split the counters
    across two ServiceStats objects (cache hits would vanish from the
    endpoint)."""
    from repro.service import PlanCache, ServiceStats
    cache = PlanCache()
    stats = ServiceStats()
    svc = GraphQueryService(num_shards=4, max_batch=4, plan_cache=cache,
                            stats=stats)
    svc.add_graph("g", graph, pad_multiple=16)
    for wave in range(2):
        for r in range(4):
            svc.submit(QueryRequest("g", "bfs", {"root": wave * 4 + r}))
    snap = svc.stats_snapshot()
    assert snap["plan_cache_misses"] == 1
    assert snap["plan_cache_hits"] == 1
    assert snap["plan_traces"] >= 1
