"""Partitioner + layout invariants (hypothesis property tests).

These are the paper-§4.4 guarantees the engine relies on: exact edge
conservation across both Fig. 4 layouts, ownership bijection, neighbor
filter correctness, and the balance claims of Fig. 12.
"""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't abort collection
from hypothesis import given, settings, strategies as st

from repro.core import graph as G
from repro.core import partition as PT


@st.composite
def small_graphs(draw):
    v = draw(st.integers(2, 120))
    e = draw(st.integers(0, 500))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, v, size=e).astype(np.int32)
    dst = rng.integers(0, v, size=e).astype(np.int32)
    w = rng.uniform(0.1, 1.0, size=e).astype(np.float32)
    return G.Graph(v, src, dst, w)


@settings(max_examples=40, deadline=None)
@given(g=small_graphs(), p=st.sampled_from([1, 2, 4, 7]),
       method=st.sampled_from(["round_robin", "greedy", "snake_lpt",
                               "ldg"]))
def test_partition_invariants(g, p, method):
    pg = PT.partition_graph(g, p, method=method, pad_multiple=8)
    # ownership bijection
    assert pg.part_of.shape == (g.num_vertices,)
    assert (pg.part_of >= 0).all() and (pg.part_of < p).all()
    assert pg.vert_valid.sum() == g.num_vertices
    gids = pg.vert_gid[pg.vert_valid]
    assert sorted(gids.tolist()) == list(range(g.num_vertices))
    # edge conservation in BOTH layouts (Fig. 4)
    assert int(pg.in_valid.sum()) == g.num_edges
    assert int(pg.pair_valid.sum()) == g.num_edges
    # GraVF-M CSC: every in-edge lands on its destination's shard
    for shard in range(p):
        v = pg.in_valid[shard]
        dl = pg.in_dst_local[shard][v]
        assert (dl < pg.v_max).all()
        owners = pg.vert_gid[shard][dl]
        dpart = pg.part_of[owners]
        assert (dpart == shard).all()
    # out-degrees preserved
    od = np.zeros(g.num_vertices, np.int64)
    od[pg.vert_gid[pg.vert_valid]] = pg.out_deg[pg.vert_valid]
    assert (od == g.out_degrees()).all()


@settings(max_examples=30, deadline=None)
@given(g=small_graphs(), p=st.sampled_from([2, 4]))
def test_neighbor_filter(g, p):
    """§4.3 filter bitmap: filter[v, q] iff v has an out-neighbor on q."""
    pg = PT.partition_graph(g, p, pad_multiple=8)
    expect = np.zeros((g.num_vertices, p), bool)
    for s, d in zip(g.src, g.dst):
        expect[s, pg.part_of[d]] = True
    assert (pg.nbr_filter == expect).all()


def test_greedy_balance_quality():
    """Paper §4.4: greedy edge balance is near-perfect even unsorted; on a
    skewed RMAT graph it beats round-robin. Hub vertices bound what any
    partitioner can do: greedy satisfies max_load <= mean + max_degree."""
    g = G.rmat(10, 8, seed=5)
    deg = g.out_degrees()

    def loads(method):
        part = PT.PARTITIONERS[method](g, 8)
        return np.bincount(part, weights=deg, minlength=8)

    gr = loads("greedy")
    rr = loads("round_robin")
    assert gr.max() <= gr.mean() + deg.max()       # classic greedy bound
    assert gr.max() <= rr.max() + 1e-9             # beats round robin
    # and on a hub-free uniform graph, greedy IS near-perfect
    gu = G.uniform(1000, 8.0, seed=5)
    part = PT.PARTITIONERS["greedy"](gu, 8)
    lu = np.bincount(part, weights=gu.out_degrees(), minlength=8)
    assert lu.max() / lu.mean() <= 1.01


def test_ldg_reduces_cross_edges():
    """LDG (METIS stand-in) should cut cross-shard edges vs round-robin on
    a community-structured graph."""
    # two dense communities + a few bridges
    rng = np.random.default_rng(0)
    n = 200
    a = rng.integers(0, n // 2, size=(2000, 2))
    b = rng.integers(n // 2, n, size=(2000, 2))
    bridges = np.stack([rng.integers(0, n // 2, 20),
                        rng.integers(n // 2, n, 20)], axis=1)
    e = np.concatenate([a, b, bridges])
    g = G.Graph(n, e[:, 0].astype(np.int32), e[:, 1].astype(np.int32))

    def cross(method):
        pg = PT.partition_graph(g, 2, method=method, pad_multiple=8)
        return PT.edge_balance(pg)["cross_frac"]

    assert cross("ldg") < cross("round_robin")


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_generators_well_formed(seed):
    for g in (G.uniform(100, 3.0, seed=seed), G.rmat(6, 4, seed=seed),
              G.ladder(4, 5, 2, seed=seed), G.road(8, seed=seed)):
        assert (g.src >= 0).all() and (g.src < g.num_vertices).all()
        assert (g.dst >= 0).all() and (g.dst < g.num_vertices).all()
        assert (g.src != g.dst).all()  # no self loops after cleanup
        # dedup: no repeated (src, dst)
        key = g.src.astype(np.int64) * g.num_vertices + g.dst
        assert len(np.unique(key)) == g.num_edges
