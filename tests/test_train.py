"""Training substrate: loss-decrease, checkpoint/restart fault tolerance,
microbatch equivalence, optimizer math, gradient compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't abort collection
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import layers as L
from repro.models import lm as LM
from repro.train import checkpoint as CKPT
from repro.train import compress as CMP
from repro.train.loop import TrainConfig, Trainer, make_train_step
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   clip_by_global_norm, warmup_cosine)


def _mini():
    cfg = configs.get("qwen3-4b", reduced=True)
    dc = DataConfig(vocab=cfg.vocab, global_batch=8, seq_len=32)
    oc = AdamWConfig(lr_peak=1e-3, warmup_steps=3, total_steps=30)
    return cfg, dc, oc


def test_loss_decreases():
    cfg, dc, oc = _mini()
    out = Trainer(cfg, dc, oc, TrainConfig(steps=25, log_every=4)).run()
    assert out["losses"][0][1] > out["losses"][-1][1]


def test_crash_resume_reaches_end():
    cfg, dc, oc = _mini()
    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(steps=20, ckpt_every=5, ckpt_dir=d, log_every=5)
        with pytest.raises(RuntimeError):
            Trainer(cfg, dc, oc, tc).run(fail_at_step=12)
        out = Trainer(cfg, dc, oc, tc).run()  # resumes from step 10
        assert out["final_step"] == 19
        # checkpoint directory only keeps the retention window
        kept = [x for x in os.listdir(d) if x.startswith("step_")]
        assert 0 < len(kept) <= 3


def test_checkpoint_roundtrip_preserves_dtypes():
    cfg, _, _ = _mini()
    params = L.init_params(jax.random.PRNGKey(0), LM.lm_spec(cfg))
    opt = adamw_init(params)
    with tempfile.TemporaryDirectory() as d:
        CKPT.save(d, 7, {"params": params, "opt": opt})
        restored, meta = CKPT.restore_latest(
            d, {"params": params, "opt": opt})
        assert meta["step"] == 7
        for a, b in zip(jax.tree.leaves(restored),
                        jax.tree.leaves({"params": params, "opt": opt})):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_resume_with_reshard_template():
    """A checkpoint restores into a template regardless of how it will be
    re-sharded (elastic resume): restore is by logical name + shape."""
    cfg, _, _ = _mini()
    params = L.init_params(jax.random.PRNGKey(0), LM.lm_spec(cfg))
    with tempfile.TemporaryDirectory() as d:
        CKPT.save(d, 1, {"params": params})
        template = {"params": jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)}
        restored, _ = CKPT.restore_latest(d, template)
        assert restored is not None


def test_microbatch_equivalence():
    """Gradient accumulation (mb=4) must match the single-batch step."""
    cfg, dc, oc = _mini()
    params = L.init_params(jax.random.PRNGKey(1), LM.lm_spec(cfg))
    opt = adamw_init(params)
    data = SyntheticTokens(dc).batch(0)
    s1 = jax.jit(make_train_step(cfg, oc))
    s4 = jax.jit(make_train_step(cfg, oc, microbatch=4))
    p1, _, m1 = s1(params, opt, data, jnp.int32(0))
    p4, _, m4 = s4(params, opt, data, jnp.int32(0))
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 0.05
    diffs = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()), p1, p4))
    assert max(diffs) < 0.05  # bf16 params: one-ulp-scale differences ok


def test_warmup_cosine_schedule():
    oc = AdamWConfig(lr_peak=1e-3, lr_min=1e-5, warmup_steps=10,
                     total_steps=100)
    assert float(warmup_cosine(oc, jnp.int32(0))) == 0.0
    assert abs(float(warmup_cosine(oc, jnp.int32(10))) - 1e-3) < 1e-9
    assert float(warmup_cosine(oc, jnp.int32(100))) <= 1e-5 + 1e-9
    # monotone decay after warmup
    lrs = [float(warmup_cosine(oc, jnp.int32(s))) for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(lrs, lrs[1:]))


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 10.0) < 1e-5
    total = np.sqrt(sum(float(jnp.sum(x ** 2))
                        for x in jax.tree.leaves(clipped)))
    assert abs(total - 1.0) < 1e-5


def test_adamw_decoupled_decay():
    """Weight decay applies to matrices, not vectors/norms."""
    oc = AdamWConfig(lr_peak=1e-2, warmup_steps=0, total_steps=10,
                     weight_decay=0.5)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    grads = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    st = adamw_init(params)
    p2, _ = adamw_update(grads, st, params, oc, jnp.int32(5))
    assert float(p2["w"][0, 0]) < 1.0   # decayed
    assert float(p2["b"][0]) == 1.0     # untouched


# --- gradient compression -------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(1, 5000))
def test_int8_quantizer_error_bound(seed, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32)) * 10
    q, s, cnt = CMP.quantize_int8(x, jax.random.PRNGKey(seed))
    back = CMP.dequantize_int8(q, s, cnt, x.shape, jnp.float32)
    # per-block absmax scaling: error <= scale (1/127 of block max)
    blocks = np.asarray(x).reshape(-1)
    err = np.abs(np.asarray(back) - blocks[:n] if False else
                 np.abs(np.asarray(back) - np.asarray(x)))
    assert float(err.max()) <= float(jnp.abs(x).max()) / 127.0 + 1e-6


def test_int8_quantizer_unbiased():
    """Stochastic rounding: mean dequantized value converges to x."""
    x = jnp.full((CMP.BLOCK,), 0.31337, jnp.float32)
    acc = np.zeros(CMP.BLOCK)
    K = 200
    for i in range(K):
        q, s, n = CMP.quantize_int8(x, jax.random.PRNGKey(i))
        acc += np.asarray(CMP.dequantize_int8(q, s, n, x.shape,
                                              jnp.float32))
    assert abs(acc.mean() / K - 0.31337) < 1e-3


def test_wire_bytes_model():
    wb = CMP.wire_bytes(1_000_000)
    assert wb["ratio"] > 3.5  # ~4x reduction vs f32
