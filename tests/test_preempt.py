"""Preemptible lane lifecycle: a preempted (parked) query restored into
a freed slot must be bit-identical to an uninterrupted run — state,
superstep count and message count — across gravfm and gravf modes,
single- and multi-shard (the shard_map variant runs in a subprocess);
park/restore cycles must re-trace nothing after warm; deadline-priority
preemption must let a tight-deadline arrival jump a fully occupied slot
array; deadline aging must prevent starvation under a continuous stream
of higher-priority arrivals (hypothesis property); and the parked-carry
bytes must be charged against the store's spill budget."""
import os
import subprocess
import sys
import threading
from concurrent.futures import Future

import numpy as np
import pytest

from repro.core import algorithms as ALG
from repro.core import graph as G
from repro.core import partition as PT
from repro.core.engine import Engine
from repro.core.stepper import LaneMeta, LaneTable
from repro.service import (GraphQueryService, QueryRequest, ServiceStats)
from repro.store import GraphStore


from benchmarks.continuous import _mixed_graph  # noqa: E402 — the CI
# benchmark and this suite must exercise the SAME mixed-depth workload


@pytest.fixture(scope="module")
def deep_graph():
    # ladder: BFS depth varies strongly with the root, so parked lanes
    # genuinely have work left when restored
    return G.ladder(2, 30, 1, seed=0)


# ---------------------------------------------------------------------------
# LaneTable park/restore == uninterrupted run (engine level)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["gravfm", "gravf"])
def test_park_restore_bit_identity(deep_graph, mode):
    """checkpoint -> run other work -> restore must resume the lane
    bit-identically (same state, superstep count, messages, comm stats)
    to never having been parked."""
    pg = PT.partition_graph(deep_graph, 4, method="greedy", pad_multiple=16)
    eng = Engine(ALG.bfs(), pg, mode=mode, backend="ref")
    n = deep_graph.num_vertices
    tab = LaneTable(eng.make_stepper(2), 2, ("root",))
    tab.admit({0: LaneMeta(payload="A", qkw={"root": 0}),
               1: LaneMeta(payload="B", qkw={"root": n - 1})})
    for _ in range(3):
        tab.step(tab.alive_mask(10_000))
    ck = tab.checkpoint(0)          # park A at superstep 3
    assert ck.superstep == 3 and ck.nbytes > 0
    # admit C into A's old slot; run B and C to completion
    tab.admit({0: LaneMeta(payload="C", qkw={"root": n // 2})})
    while tab.alive_mask(10_000).any():
        tab.step(tab.alive_mask(10_000))
    host = tab.fetch()
    results = {"C": eng.lane_result(host, 0), "B": eng.lane_result(host, 1)}
    tab.release(0), tab.release(1)
    tab.restore(0, ck)              # un-park A
    while tab.alive_mask(10_000).any():
        tab.step(tab.alive_mask(10_000))
    results["A"] = eng.lane_result(tab.fetch(), 0)
    traces0 = eng.traces
    for name, root in (("A", 0), ("B", n - 1), ("C", n // 2)):
        ref = Engine(ALG.bfs(root), pg, mode=mode, backend="ref").run()
        res = results[name]
        assert np.array_equal(res.state["parent"], ref.state["parent"]), name
        assert res.supersteps == ref.supersteps, name
        assert res.messages == ref.messages, name
        assert res.comm["messages"] == ref.comm["messages"], name
    # a second park/restore cycle re-traces nothing
    tab.release(0)
    tab.admit({1: LaneMeta(payload="D", qkw={"root": 7})})
    tab.step(tab.alive_mask(10_000))
    ck2 = tab.checkpoint(1)
    tab.restore(1, ck2)
    while tab.alive_mask(10_000).any():
        tab.step(tab.alive_mask(10_000))
    resD = eng.lane_result(tab.fetch(), 1)
    refD = Engine(ALG.bfs(7), pg, mode=mode, backend="ref").run()
    assert np.array_equal(resD.state["parent"], refD.state["parent"])
    assert resD.supersteps == refD.supersteps
    assert eng.traces == traces0


def test_park_restore_sssp_carry(deep_graph):
    """The argmin-carry (SSSP parent pointer) state survives a park."""
    g = G.uniform(200, 6.0, seed=5, weighted=True).symmetrized()
    pg = PT.partition_graph(g, 4, method="greedy", pad_multiple=16)
    eng = Engine(ALG.sssp(), pg, mode="gravfm", backend="ref")
    tab = LaneTable(eng.make_stepper(2), 2, ("root",))
    tab.admit({0: LaneMeta(payload=0, qkw={"root": 0}),
               1: LaneMeta(payload=1, qkw={"root": 99})})
    tab.step(tab.alive_mask(10_000))
    tab.step(tab.alive_mask(10_000))
    ck = tab.checkpoint(0)
    while tab.alive_mask(10_000).any():
        tab.step(tab.alive_mask(10_000))
    tab.release(1)
    tab.restore(1, ck)          # restore into a DIFFERENT slot
    while tab.alive_mask(10_000).any():
        tab.step(tab.alive_mask(10_000))
    res = eng.lane_result(tab.fetch(), 1)
    ref = Engine(ALG.sssp(0), pg, mode="gravfm", backend="ref").run()
    assert np.array_equal(res.state["dist"].view(np.int32),
                          ref.state["dist"].view(np.int32))
    assert np.array_equal(res.state["parent"], ref.state["parent"])


# ---------------------------------------------------------------------------
# service-level deadline-priority preemption
# ---------------------------------------------------------------------------

def test_service_preemption_end_to_end():
    """A tight-deadline, high-priority arrival finding every slot busy
    parks the laxest deep lane, completes fast, and the parked query is
    restored and finishes bit-identically — with zero re-traces across
    the whole park/restore cycle (the acceptance criterion)."""
    g = _mixed_graph(300, 6.0, 40)
    pg = PT.partition_graph(g, 4, method="greedy", pad_multiple=16)
    svc = GraphQueryService(num_shards=4, max_batch=8,
                            scheduling="continuous", slots=2,
                            result_cache_size=0)
    svc.add_graph("g", g, pad_multiple=16)
    svc.warm("g", "bfs")        # pre-traces admit/step AND park/restore
    traces0 = svc.stats_snapshot()["plan_traces"]
    deep = [svc.submit(QueryRequest("g", "bfs", {"root": 300},
                                    deadline_ms=60_000)),
            svc.submit(QueryRequest("g", "bfs", {"root": 339},
                                    deadline_ms=60_000))]
    for _ in range(3):
        svc.poll()
    assert not any(f.done() for f in deep)       # slots full, mid-flight
    fg = svc.submit(QueryRequest("g", "bfs", {"root": 5},
                                 deadline_ms=25, priority=1))
    for _ in range(12):
        svc.poll()
        if fg.done():
            break
    assert fg.done(), "foreground never preempted a lane"
    snap = svc.stats_snapshot()
    assert snap["preemptions"] >= 1
    assert not all(f.done() for f in deep)
    svc.flush()
    snap = svc.stats_snapshot()
    assert snap["lane_restores"] >= 1
    assert snap["parked_lanes"] == 0
    assert snap["park_restore_ms"] > 0.0
    # bit-identity for everyone, preempted or not
    for root, fut in ((300, deep[0]), (339, deep[1]), (5, fg)):
        ref = Engine(ALG.bfs(root), pg, mode="gravfm", backend="ref").run()
        res = fut.result(timeout=0)
        assert np.array_equal(res.state["parent"], ref.state["parent"])
        assert res.supersteps == ref.supersteps
        assert res.messages == ref.messages
    # the whole preempt->park->restore cycle re-traced NOTHING
    assert snap["plan_traces"] == traces0


def test_preemption_off_runs_to_retire():
    """preemption=False restores the old behavior: the tight arrival
    waits for a natural retire."""
    g = _mixed_graph(200, 6.0, 30)
    svc = GraphQueryService(num_shards=4, max_batch=8,
                            scheduling="continuous", slots=1,
                            result_cache_size=0, preemption=False)
    svc.add_graph("g", g, pad_multiple=16)
    deep = svc.submit(QueryRequest("g", "bfs", {"root": 200},
                                   deadline_ms=60_000))
    svc.poll()
    fg = svc.submit(QueryRequest("g", "bfs", {"root": 3},
                                 deadline_ms=5, priority=1))
    for _ in range(5):
        svc.poll()
    assert not fg.done()                 # no slot ever freed early
    assert svc.stats_snapshot()["preemptions"] == 0
    svc.flush()
    assert fg.result() is not None and deep.result() is not None


def test_parked_bytes_charged_against_spill_budget():
    """Parks reserve host bytes in the store's spill budget; a zero
    budget (host tier disabled) refuses every park, so preemption
    silently degrades to run-to-retire."""
    g = _mixed_graph(200, 6.0, 30)
    svc = GraphQueryService(num_shards=4, max_batch=8,
                            scheduling="continuous", slots=1,
                            result_cache_size=0, spill_budget=0)
    svc.add_graph("g", g, pad_multiple=16)
    deep = svc.submit(QueryRequest("g", "bfs", {"root": 200},
                                   deadline_ms=60_000))
    svc.poll()
    fg = svc.submit(QueryRequest("g", "bfs", {"root": 3},
                                 deadline_ms=5, priority=1))
    for _ in range(5):
        svc.poll()
    assert svc.stats_snapshot()["preemptions"] == 0   # budget refused
    svc.flush()
    assert fg.result() is not None and deep.result() is not None
    # and with an unbounded budget the charge round-trips to zero
    store = GraphStore()
    assert store.reserve_parked(1024) is True
    assert store.snapshot()["parked_bytes"] == 1024.0
    store.release_parked(1024)
    assert store.snapshot()["parked_bytes"] == 0.0
    # a bounded budget admits until full, then refuses an infeasible
    # park up front (without discarding anything to make room it can
    # never have)
    store2 = GraphStore(spill_budget_bytes=100)
    assert store2.reserve_parked(60) is True
    assert store2.reserve_parked(60) is False
    assert store2.snapshot()["parked_bytes"] == 60.0
    assert store2.snapshot()["discards"] == 0


# ---------------------------------------------------------------------------
# fake-stepper harness (threaded race + starvation property) — shared
# with tests/test_continuous.py
# ---------------------------------------------------------------------------

from _fake_stepper import fake_scheduler, submit_fake  # noqa: E402


def _fake_scheduler(slots=1, **kw):
    return fake_scheduler(slots=slots, **kw)


_submit_fake = submit_fake


def test_threaded_preempt_while_retiring():
    """A tight-priority submit racing an in-flight drain must preempt at
    the next admission window; the preempted lane resumes (not
    restarts) and everyone resolves. The urgent query finishes first."""
    stats = ServiceStats()
    gate = threading.Semaphore(0)
    in_step = threading.Event()

    def hook():
        in_step.set()
        gate.acquire()

    sched, qclass = _fake_scheduler(slots=1, stats=stats, step_hook=hook)
    futA = _submit_fake(sched, qclass, depth=10)
    order = []
    futA.add_done_callback(lambda f: order.append("A"))

    t = threading.Thread(target=sched.drain)
    t.start()
    assert in_step.wait(10)          # A's superstep 1 in flight
    got = {}

    def submitter():
        got["B"] = _submit_fake(sched, qclass, depth=2, deadline_ms=10,
                                priority=1)
        got["B"].add_done_callback(lambda f: order.append("B"))

    s = threading.Thread(target=submitter)
    s.start()
    for _ in range(500):
        if not t.is_alive():
            break
        gate.release()
        t.join(0.02)
    t.join(10)
    assert not t.is_alive(), "drain never finished"
    s.join(10)
    futB = got["B"]
    assert futB.result(timeout=0).supersteps == 2
    # A RESUMED from its parked superstep: total superstep count intact
    assert futA.result(timeout=0).supersteps == 10
    assert order == ["B", "A"]
    assert stats.preemptions >= 1 and stats.lane_restores >= 1
    assert sched.parked() == 0 and sched.pending() == 0


def test_starvation_aging_deterministic():
    """Fixed adversarial stream (runs even without hypothesis): a
    priority-0 deep query keeps completing with its exact superstep
    count despite repeated preemption by priority-3 arrivals, because
    aggressive aging credit outranks the priority boost."""
    stats = ServiceStats()
    sched, qclass = _fake_scheduler(slots=1, stats=stats, aging_rate=1e7)
    bg = _submit_fake(sched, qclass, depth=12)
    sched.pump()
    fgs = []
    for d in (2, 1, 3, 2, 1):
        fgs.append(_submit_fake(sched, qclass, depth=d, deadline_ms=1,
                                priority=3))
        sched.pump()
    sched.drain(max_pumps=10_000)
    for d, f in zip((2, 1, 3, 2, 1), fgs):
        assert f.result(timeout=0).supersteps == d
    assert bg.result(timeout=0).supersteps == 12
    assert stats.preemptions >= 1
    assert sched.parked() == 0 and sched.pending() == 0


def test_starvation_aging_property():
    """Under ANY stream of higher-priority tight-deadline arrivals, a
    preempted query still completes — with its full superstep count
    (bit-identical resume across arbitrarily many park/restore cycles).
    With aggressive aging its credit outranks the priority boost, so it
    is restored ahead of queued urgent work and not re-parked."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st_

    @settings(max_examples=20, deadline=None)
    @given(st_.integers(5, 20),
           st_.lists(st_.integers(1, 4), min_size=1, max_size=6))
    def check(bg_depth, fg_depths):
        stats = ServiceStats()
        sched, qclass = _fake_scheduler(slots=1, stats=stats,
                                        aging_rate=1e7)
        bg = _submit_fake(sched, qclass, depth=bg_depth)
        sched.pump()                 # bg occupies the only lane
        fgs = []
        for d in fg_depths:
            fgs.append(_submit_fake(sched, qclass, depth=d,
                                    deadline_ms=1, priority=3))
            sched.pump()             # admission window: may preempt bg
        sched.drain(max_pumps=10_000)
        for d, f in zip(fg_depths, fgs):
            assert f.result(timeout=0).supersteps == d
        # the background query was parked (at least once for the first
        # urgent arrival) yet completed with its exact depth
        assert bg.result(timeout=0).supersteps == bg_depth
        assert sched.parked() == 0 and sched.pending() == 0

    check()


def test_missing_param_fails_future_not_strands():
    """A request missing a declared query param must fail ITS future
    (and the class) loudly — the meta is installed in the table before
    the kwarg write that raises, so the failure path can see it."""
    sched, qclass = _fake_scheduler(slots=2)
    fut = Future()
    sched.submit(qclass, QueryRequest("g", "fake", {},  # no "depth"
                                      deadline_ms=600_000), fut)
    sched.pump()
    with pytest.raises(KeyError):
        fut.result(timeout=0)
    assert sched.pending() == 0
    # the class recovers on the next (well-formed) submit
    ok = _submit_fake(sched, qclass, depth=2)
    sched.drain()
    assert ok.result(timeout=0).supersteps == 2


def test_depth_packing_orders_refill_by_predicted_depth():
    """With equal deadlines (same depth bucket), the refill pops queued
    work in predicted-depth order — the two shallow-predicted queries
    are co-scheduled and retire on the SAME pump, cutting retire-fetch
    churn; the deep-predicted one waits despite arriving first."""
    stats = ServiceStats()
    sched, qclass = _fake_scheduler(slots=2, stats=stats)
    from repro.service.continuous import class_key
    ck = class_key(qclass)
    # evolve the class depth EWMA between submits so each queued item
    # snapshots a different prediction (deep arrives FIRST)
    stats.record_query_depth(ck, 9.0)
    f_deep = _submit_fake(sched, qclass, depth=8)    # predicted 9.0
    stats.record_query_depth(ck, 1.0)
    f_s1 = _submit_fake(sched, qclass, depth=2)      # predicted ~7.4
    stats.record_query_depth(ck, 1.0)
    f_s2 = _submit_fake(sched, qclass, depth=2)      # predicted ~6.1
    done_at = {}
    pump = 0
    while sched.has_work() and pump < 100:
        sched.pump()
        pump += 1
        for name, f in (("deep", f_deep), ("s1", f_s1), ("s2", f_s2)):
            if f.done() and name not in done_at:
                done_at[name] = pump
    assert f_deep.done() and f_s1.done() and f_s2.done()
    assert done_at["s1"] == done_at["s2"]   # packed, retired together
    assert done_at["deep"] > done_at["s1"]  # FIFO would have run first


# ---------------------------------------------------------------------------
# shard_map checkpoint/restore across all four exchanges (subprocess)
# ---------------------------------------------------------------------------

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, {src!r})
import jax, numpy as np
from repro.core import graph as G, partition as PT, algorithms as ALG
from repro.core.engine import Engine
from repro.core.engine_shardmap import ShardEngine
from repro.launch.mesh import compat_make_mesh

mesh = compat_make_mesh((4,), ("graph",))
g = G.uniform(200, 5.0, seed=3).symmetrized()
pg = PT.partition_graph(g, 4, method="greedy", pad_multiple=16)

for exch in ("allgather", "ring", "frontier", "unicast"):
    se = ShardEngine(ALG.bfs(), pg, mesh=mesh, exchange=exch,
                     backend="ref")
    st = se.make_stepper(3)
    qkw = {{"root": np.zeros(3, np.int32)}}
    qkw["root"][0] = 0
    qkw["root"][1] = 100
    carry, act, steps = st.init(qkw)
    occ = np.array([True, True, False])
    for _ in range(2):
        carry, act, steps = st.step(carry, occ & act)
    # park lane 0 at superstep 2: fetch ONLY its per-shard slices
    ck = st.fetch_lane(carry, 0)
    for leaf in jax.tree.leaves(ck):
        assert np.asarray(leaf).shape[:1] == (4,) or np.ndim(leaf) <= 1
    occ[0] = False
    # run lane 1 to completion, then warm park/restore trace counters
    while (occ & act).any():
        carry, act, steps = st.step(carry, occ & act)
    fresh = np.zeros(3, bool)
    fresh[0] = True
    carry, act, steps = st.restore(carry, ck, fresh)
    occ[0] = True
    traces_steady = se.traces
    while (occ & act).any():
        carry, act, steps = st.step(carry, occ & act)
    # a SECOND park/restore cycle must re-trace nothing
    carry, act, steps = st.restore(carry, st.fetch_lane(carry, 2),
                                   np.zeros(3, bool))
    assert se.traces == traces_steady, exch
    host = st.fetch(carry)
    for lane, root in ((0, 0), (1, 100)):
        res = se.lane_result(host, lane)
        ref = Engine(ALG.bfs(root), pg, mode="gravfm",
                     backend="ref").run()
        assert np.array_equal(res["state"]["parent"],
                              ref.state["parent"]), (exch, lane)
        assert res["supersteps"] == ref.supersteps, (exch, lane)
        assert res["messages"] == ref.messages, (exch, lane)
print("PREEMPT-SHARDMAP-OK")
"""


@pytest.mark.slow
def test_shardmap_checkpoint_multidevice():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SCRIPT.format(src=os.path.abspath(src))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "PREEMPT-SHARDMAP-OK" in proc.stdout
