"""Query-lifecycle tracing + roofline telemetry.

TraceBus mechanics (bounded ring, disabled no-op), span assembly from
synthetic and real event streams, Chrome-trace export validity, the
superstep events' lane→query attribution, parked intervals under
preemption, roofline_efficiency validated against perfmodel.limits(),
the busy-denominator clamp, the park/restore counter split, per-tenant
deadline-miss accounting, store residency events, and counter
conservation (submitted == completed + shed + in-flight) across the
bucketed, continuous, and preemption paths."""
import json

import numpy as np
import pytest

from benchmarks.continuous import _mixed_graph
from repro.core import graph as G
from repro.core import perfmodel
from repro.service import (GraphQueryService, QueryRequest, ServiceStats,
                           TraceBus, TraceEvent, assemble_spans,
                           chrome_trace, class_key)
from repro.store import GraphStore


@pytest.fixture(scope="module")
def small_graph():
    return G.uniform(64, 4.0, seed=0).symmetrized()


def _service(small_graph, **kw):
    kw.setdefault("num_shards", 2)
    kw.setdefault("max_batch", 8)
    svc = GraphQueryService(**kw)
    svc.add_graph("g", small_graph)
    return svc


def _run(svc, reqs):
    futs = [svc.submit(r) for r in reqs]
    svc.flush()
    return futs


# ---------------------------------------------------------------------------
# TraceBus mechanics
# ---------------------------------------------------------------------------

def test_bus_is_a_bounded_ring():
    bus = TraceBus(capacity=8)
    for i in range(20):
        bus.emit("submit", qid=i)
    assert len(bus) == 8
    assert bus.emitted == 20
    assert bus.dropped == 12
    # the ring keeps the MOST RECENT events
    assert [e.qid for e in bus.snapshot()] == list(range(12, 20))
    bus.clear()
    assert len(bus) == 0 and bus.emitted == 0


def test_disabled_bus_is_a_noop():
    bus = TraceBus(enabled=False)
    bus.emit("submit", qid=1)
    assert len(bus) == 0 and bus.emitted == 0
    assert bus.chrome_trace()["traceEvents"] == []


def test_unknown_event_kind_rejected():
    bus = TraceBus()
    with pytest.raises(AssertionError):
        bus.emit("frobnicate", qid=1)


# ---------------------------------------------------------------------------
# span assembly (synthetic streams)
# ---------------------------------------------------------------------------

def test_span_assembly_full_lifecycle():
    evs = [
        TraceEvent("submit", 1.0, qid=7, tenant="t", klass="k"),
        TraceEvent("admit", 2.0, qid=7),
        TraceEvent("park", 3.0, qid=7),
        TraceEvent("restore", 5.0, qid=7),
        TraceEvent("retire", 6.0, qid=7,
                   attrs={"reason": "retired", "supersteps": 9,
                          "messages": 123, "deadline_slack_s": 0.25}),
    ]
    sp = assemble_spans(evs)[7]
    assert sp.tenant == "t" and sp.klass == "k"
    assert sp.queued == (1.0, 2.0) and sp.queued_s() == 1.0
    assert sp.active == [(2.0, 3.0), (5.0, 6.0)]
    assert sp.parked == [(3.0, 5.0)] and sp.parks == 1
    assert sp.active_s() == 2.0 and sp.parked_s() == 2.0
    assert sp.outcome == "retired" and sp.retired_s == 6.0
    assert sp.supersteps == 9 and sp.messages == 123
    assert sp.deadline_slack_s == 0.25


def test_span_assembly_outcomes_and_open_intervals():
    evs = [
        TraceEvent("submit", 1.0, qid=1),
        TraceEvent("retire", 1.5, qid=1, attrs={"reason": "cache"}),
        TraceEvent("submit", 2.0, qid=2),
        TraceEvent("shed", 2.5, qid=2, attrs={"reason": "quota"}),
        TraceEvent("submit", 3.0, qid=3),
        TraceEvent("admit", 4.0, qid=3),      # still running at snapshot
    ]
    spans = assemble_spans(evs)
    assert spans[1].outcome == "cache_hit"
    assert spans[1].queued == (1.0, 1.5)      # resolved out of the queue
    assert spans[2].outcome == "shed"
    assert spans[3].outcome is None
    assert spans[3].active == [(4.0, None)]   # open interval


def test_span_assembly_survives_ring_truncation():
    # submit fell off the ring; the admit must still open a span
    evs = [TraceEvent("admit", 5.0, qid=4),
           TraceEvent("retire", 6.0, qid=4, attrs={"reason": "retired"})]
    sp = assemble_spans(evs)[4]
    assert sp.queued == (5.0, 5.0)            # zero-width placeholder
    assert sp.active == [(5.0, 6.0)]
    assert sp.outcome == "retired"


# ---------------------------------------------------------------------------
# end-to-end: continuous scheduling
# ---------------------------------------------------------------------------

def test_continuous_spans_reconstruct_lifecycle(small_graph):
    svc = _service(small_graph, scheduling="continuous", slots=4,
                   result_cache_size=0)
    reqs = [QueryRequest("g", "bfs", {"root": int(i)}, deadline_ms=60_000)
            for i in range(6)]
    futs = _run(svc, reqs)
    results = {r.qid: f.result(timeout=30) for r, f in zip(reqs, futs)}
    spans = svc.trace.spans()
    for r in reqs:
        sp = spans[r.qid]
        assert sp.outcome == "retired"
        assert sp.klass is not None and "bfs" in sp.klass
        # queue -> active -> retire, all intervals closed and ordered
        assert sp.queued is not None and sp.queued[1] is not None
        assert sp.active and all(b is not None for _, b in sp.active)
        assert sp.queued[0] <= sp.queued[1] <= sp.active[0][0]
        assert sp.retired_s >= sp.active[-1][1] - 1e-9
        # the retire event carries the query's own result attribution
        assert sp.supersteps == results[r.qid].supersteps
        assert sp.messages == results[r.qid].messages
        assert sp.deadline_slack_s is not None


def test_superstep_events_attribute_lanes_to_queries(small_graph):
    svc = _service(small_graph, scheduling="continuous", slots=4,
                   result_cache_size=0)
    reqs = [QueryRequest("g", "bfs", {"root": int(i)}, deadline_ms=60_000)
            for i in range(4)]
    for f in _run(svc, reqs):
        f.result(timeout=30)
    steps = [e for e in svc.trace.snapshot() if e.kind == "superstep"]
    assert steps, "no superstep events emitted"
    qids = {r.qid for r in reqs}
    seen = set()
    for ev in steps:
        assert ev.dur_s > 0.0
        assert ev.klass is not None
        lanes = ev.attrs["lanes"]
        assert ev.attrs["n_alive"] == len(lanes)
        assert set(lanes.values()) <= qids
        seen |= set(lanes.values())
    # every query was attributed to at least one dispatch
    assert seen == qids


def test_chrome_trace_export_is_loadable(tmp_path, small_graph):
    svc = _service(small_graph, scheduling="continuous", slots=4,
                   result_cache_size=0)
    for f in _run(svc, [QueryRequest("g", "bfs", {"root": int(i)},
                                     deadline_ms=60_000)
                        for i in range(4)]):
        f.result(timeout=30)
    path = svc.dump_trace(str(tmp_path / "trace.json"))
    doc = json.loads((tmp_path / "trace.json").read_text())
    assert path.endswith("trace.json")
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and evs
    # every event is a JSON-clean dict with the required trace fields
    for e in evs:
        assert e["ph"] in ("X", "i", "M")
        if e["ph"] == "X":
            assert e["ts"] >= 0 and e["dur"] >= 0
    names = {e["name"] for e in evs if e["ph"] == "X"}
    assert {"queued", "active", "superstep"} <= names
    pids = {e["pid"] for e in evs}
    assert {1, 2, 3} <= pids    # queries, scheduler, graph-store


# ---------------------------------------------------------------------------
# preemption: parked intervals
# ---------------------------------------------------------------------------

def test_preempted_query_span_shows_parked_interval():
    g = _mixed_graph(300, 6.0, 40)
    svc = GraphQueryService(num_shards=4, max_batch=8,
                            scheduling="continuous", slots=2,
                            result_cache_size=0)
    svc.add_graph("g", g, pad_multiple=16)
    svc.warm("g", "bfs")
    deep = [QueryRequest("g", "bfs", {"root": 300}, deadline_ms=60_000),
            QueryRequest("g", "bfs", {"root": 339}, deadline_ms=60_000)]
    deep_futs = [svc.submit(r) for r in deep]
    for _ in range(3):
        svc.poll()
    fg = QueryRequest("g", "bfs", {"root": 5}, deadline_ms=25, priority=1)
    fg_fut = svc.submit(fg)
    for _ in range(12):
        svc.poll()
        if fg_fut.done():
            break
    svc.flush()
    for f in deep_futs + [fg_fut]:
        assert f.result(timeout=30) is not None
    assert svc.stats_snapshot()["preemptions"] >= 1
    spans = svc.trace.spans()
    victims = [sp for sp in spans.values() if sp.parks > 0]
    assert victims, "no span recorded a park"
    v = victims[0]
    assert v.qid in {r.qid for r in deep}
    # active -> parked -> active again, every interval closed
    assert v.parked and all(b is not None for _, b in v.parked)
    assert len(v.active) >= 2
    assert v.parked_s() > 0.0
    assert v.outcome == "retired"
    # the park event names its preemptor
    park = next(e for e in svc.trace.snapshot() if e.kind == "park")
    assert park.attrs["by"] == fg.qid
    # the foreground's admit says it preempted
    admits = [e for e in svc.trace.snapshot()
              if e.kind == "admit" and e.qid == fg.qid]
    assert any(e.attrs.get("reason") == "preempt" for e in admits)
    # parked phase survives the Chrome export
    slices = [e for e in chrome_trace(svc.trace.snapshot())["traceEvents"]
              if e.get("ph") == "X" and e["name"] == "parked"]
    assert slices and all(s["dur"] > 0 for s in slices)


# ---------------------------------------------------------------------------
# roofline telemetry
# ---------------------------------------------------------------------------

def test_roofline_efficiency_matches_perfmodel(small_graph):
    svc = _service(small_graph, scheduling="continuous", slots=4,
                   result_cache_size=0)
    reqs = [QueryRequest("g", "bfs", {"root": int(i)}, deadline_ms=60_000)
            for i in range(4)]
    for f in _run(svc, reqs):
        f.result(timeout=30)
    snap = svc.stats_snapshot()
    ck = f"g@v1/bfs/gravfm"
    assert ck in snap["roofline"]
    r = snap["roofline"][ck]
    # the projection IS the §5 model's system limit on this workload
    wl = perfmodel.Workload(num_vertices=small_graph.num_vertices,
                            num_edges=small_graph.num_edges)
    want = perfmodel.limits(perfmodel.PAPER_PLATFORM,
                            perfmodel.PAPER_ALGOS["bfs"], wl,
                            n_nodes=2, mode="gravfm")["T_sys"]
    assert r["projected_teps"] == pytest.approx(want)
    # measured TEPS = per-class messages over per-class execution busy
    assert r["busy_s"] > 0.0 and r["completed"] == len(reqs)
    assert r["teps"] == pytest.approx(r["messages"] / r["busy_s"])
    assert r["efficiency"] == pytest.approx(r["teps"] / want)
    assert snap["roofline_efficiency"][ck] == r["efficiency"]
    # an interpreted-CPU run is far below the paper platform's roofline
    assert 0.0 < r["efficiency"] < 1.0


def test_roofline_accounted_on_bucketed_path_too(small_graph):
    svc = _service(small_graph, scheduling="bucketed",
                   result_cache_size=0)
    for f in _run(svc, [QueryRequest("g", "bfs", {"root": int(i)},
                                     deadline_ms=60_000)
                        for i in range(3)]):
        f.result(timeout=30)
    # dispatch once more so a warm (non-compile) wall lands in busy
    for f in _run(svc, [QueryRequest("g", "bfs", {"root": int(i + 8)},
                                     deadline_ms=60_000)
                        for i in range(3)]):
        f.result(timeout=30)
    r = svc.stats_snapshot()["roofline"]["g@v1/bfs/gravfm"]
    assert r["completed"] == 6 and r["busy_s"] > 0.0
    assert r["projected_teps"] > 0.0 and r["efficiency"] > 0.0


def test_roofline_unknown_class_reports_zero_not_garbage():
    stats = ServiceStats()
    stats.record_busy(0.1, class_key="nobody@v1/bfs/gravfm")
    stats.record_retire(100, 1.0, class_key="nobody@v1/bfs/gravfm")
    # no projector installed -> efficiency 0.0, never a bogus ratio
    r = stats.snapshot()["roofline"]["nobody@v1/bfs/gravfm"]
    assert r["projected_teps"] == 0.0 and r["efficiency"] == 0.0
    assert r["teps"] > 0.0


# ---------------------------------------------------------------------------
# satellite: busy clamp + park/restore split
# ---------------------------------------------------------------------------

def test_qps_busy_and_teps_zero_before_any_dispatch():
    stats = ServiceStats()
    snap = stats.snapshot()
    assert snap["qps_busy"] == 0.0 and snap["teps"] == 0.0
    # completions with NO busy time (pure result-cache hits) must not
    # divide by the epsilon clamp either
    stats.record_result_hit(0.1)
    snap = stats.snapshot()
    assert snap["queries_completed"] == 1
    assert snap["qps_busy"] == 0.0 and snap["teps"] == 0.0
    stats.record_busy(0.5)
    assert stats.snapshot()["qps_busy"] == pytest.approx(2.0)


def test_park_and_restore_counters_split():
    stats = ServiceStats()
    stats.record_preempt(0.004)
    stats.record_restore(0.001)
    snap = stats.snapshot()
    assert snap["park_ms"] == pytest.approx(4.0)
    assert snap["restore_ms"] == pytest.approx(1.0)
    # back-compat: the pre-split sum is still published
    assert snap["park_restore_ms"] == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# deadline misses
# ---------------------------------------------------------------------------

def test_deadline_miss_counters_aggregate_and_per_tenant(small_graph):
    svc = _service(small_graph, scheduling="bucketed",
                   result_cache_size=0)
    # an already-expired deadline must retire as a miss, not a shed
    fut = svc.submit(QueryRequest("g", "bfs", {"root": 0},
                                  deadline_ms=0.0, tenant="late"))
    svc.flush()
    fut.result(timeout=30)
    snap = svc.stats_snapshot()
    assert snap["deadline_misses"] == 1
    assert snap["queries_shed"] == 0
    assert snap["tenants"]["late"]["deadline_misses"] == 1
    # the retire event records the (negative) slack
    retired = [sp for sp in svc.trace.spans().values()
               if sp.outcome == "retired"]
    assert retired and retired[0].deadline_slack_s is not None
    assert retired[0].deadline_slack_s <= 0.0


def test_deadline_miss_continuous(small_graph):
    svc = _service(small_graph, scheduling="continuous", slots=2,
                   result_cache_size=0)
    fut = svc.submit(QueryRequest("g", "bfs", {"root": 1},
                                  deadline_ms=0.0, tenant="late"))
    svc.flush()
    fut.result(timeout=30)
    snap = svc.stats_snapshot()
    assert snap["deadline_misses"] >= 1
    assert snap["tenants"]["late"]["deadline_misses"] >= 1


# ---------------------------------------------------------------------------
# counter conservation
# ---------------------------------------------------------------------------

def _check_conservation(snap, *, in_flight_ok=False):
    in_flight = snap["pending"]
    if not in_flight_ok:
        assert in_flight == 0
    assert (snap["queries_submitted"]
            == snap["queries_completed"] + snap["queries_shed"]
            + in_flight), snap
    # tenant breakdowns sum to the aggregates (in-flight queries are
    # submitted but not yet completed/shed, hence the slack term above;
    # the per-tenant sums have no such slack — tenants are recorded at
    # the same points as the aggregates)
    tenants = snap["tenants"]
    assert sum(t["submitted"] for t in tenants.values()) \
        == snap["queries_submitted"]
    assert sum(t["shed"] for t in tenants.values()) \
        == snap["queries_shed"]
    assert sum(t["completed"] for t in tenants.values()) \
        == snap["queries_completed"]
    assert sum(t["result_cache_hits"] for t in tenants.values()) \
        == snap["result_cache_hits"]
    assert sum(t["deadline_misses"] for t in tenants.values()) \
        == snap["deadline_misses"]


@pytest.mark.parametrize("scheduling", ["bucketed", "continuous"])
def test_counter_conservation_with_hits_and_sheds(small_graph, scheduling):
    svc = _service(small_graph, scheduling=scheduling, slots=4)
    # quota: tenant "q" admits exactly one query, sheds the rest
    svc.set_tenant("q", rate_qps=0.001, burst=1)
    reqs = ([QueryRequest("g", "bfs", {"root": int(i)},
                          deadline_ms=60_000, tenant="a")
             for i in range(4)]
            + [QueryRequest("g", "bfs", {"root": 9}, deadline_ms=60_000,
                            tenant="q") for _ in range(3)])
    futs = _run(svc, reqs)
    shed = sum(1 for f in futs if f.exception(timeout=30) is not None)
    assert shed == 2                       # quota burst of 1 admitted 1
    # identical resubmits are result-cache hits (completed, no engine)
    for f in _run(svc, [QueryRequest("g", "bfs", {"root": 0},
                                     deadline_ms=60_000, tenant="a")
                        for _ in range(2)]):
        f.result(timeout=30)
    snap = svc.stats_snapshot()
    assert snap["result_cache_hits"] == 2
    assert snap["queries_shed"] == 2
    _check_conservation(snap)


def test_counter_conservation_mid_flight(small_graph):
    svc = _service(small_graph, scheduling="continuous", slots=2,
                   result_cache_size=0)
    futs = [svc.submit(QueryRequest("g", "bfs", {"root": int(i)},
                                    deadline_ms=60_000))
            for i in range(5)]
    snap = svc.stats_snapshot()
    assert snap["pending"] == 5            # nothing pumped yet
    _check_conservation(snap, in_flight_ok=True)
    svc.poll()                             # some admitted, none done yet
    _check_conservation(svc.stats_snapshot(), in_flight_ok=True)
    svc.flush()
    for f in futs:
        f.result(timeout=30)
    _check_conservation(svc.stats_snapshot())


def test_counter_conservation_preemption_path():
    g = _mixed_graph(300, 6.0, 40)
    svc = GraphQueryService(num_shards=4, max_batch=8,
                            scheduling="continuous", slots=2,
                            result_cache_size=0)
    svc.add_graph("g", g, pad_multiple=16)
    svc.warm("g", "bfs")
    futs = [svc.submit(QueryRequest("g", "bfs", {"root": 300},
                                    deadline_ms=60_000, tenant="bg")),
            svc.submit(QueryRequest("g", "bfs", {"root": 339},
                                    deadline_ms=60_000, tenant="bg"))]
    for _ in range(3):
        svc.poll()
    _check_conservation(svc.stats_snapshot(), in_flight_ok=True)
    futs.append(svc.submit(QueryRequest("g", "bfs", {"root": 5},
                                        deadline_ms=25, priority=1,
                                        tenant="fg")))
    svc.flush()
    for f in futs:
        f.result(timeout=30)
    snap = svc.stats_snapshot()
    assert snap["preemptions"] >= 1        # the path under test was taken
    _check_conservation(snap)


# ---------------------------------------------------------------------------
# store residency events
# ---------------------------------------------------------------------------

def test_store_emits_residency_transitions(small_graph):
    bus = TraceBus()
    store = GraphStore(num_shards=2, versioned=True)
    store.set_trace(bus)
    store.publish("a", small_graph)
    kinds = [e.kind for e in bus.snapshot()]
    assert kinds == ["publish"]
    ev = bus.snapshot()[0]
    assert ev.attrs["graph_id"] == "a" and ev.attrs["version"] == 1
    assert ev.attrs["num_edges"] == small_graph.num_edges
    # spill (policy evict), then refault on acquire
    assert store.evict("a")
    kinds = [e.kind for e in bus.snapshot()]
    assert kinds == ["publish", "spill"]
    with store.acquire("a"):
        pass
    kinds = [e.kind for e in bus.snapshot()]
    assert kinds == ["publish", "spill", "refault"]
    refault = bus.snapshot()[-1]
    assert refault.attrs["cold"] is False and refault.dur_s >= 0.0
    # forced discard -> evict event
    assert store.evict("a", spill=False)
    assert [e.kind for e in bus.snapshot()][-1] == "evict"


def test_service_trace_has_store_events(small_graph):
    svc = _service(small_graph, scheduling="bucketed")
    kinds = {e.kind for e in svc.trace.snapshot()}
    assert "publish" in kinds              # add_graph went over the bus


# ---------------------------------------------------------------------------
# tracing can be turned off
# ---------------------------------------------------------------------------

def test_tracing_off_emits_nothing(small_graph):
    svc = _service(small_graph, scheduling="continuous", slots=2,
                   tracing=False, result_cache_size=0)
    for f in _run(svc, [QueryRequest("g", "bfs", {"root": 0},
                                     deadline_ms=60_000)]):
        f.result(timeout=30)
    assert svc.trace.emitted == 0
    snap = svc.stats_snapshot()
    assert snap["trace_events"] == 0 and snap["trace_dropped"] == 0
    # stats are unaffected: the roofline still accounts the class
    assert snap["roofline"]                # non-empty
