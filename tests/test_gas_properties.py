"""Programming-model contract properties (hypothesis).

DESIGN.md §9 assumption 2: hardware delivers messages in arbitrary order,
so gather must be order-insensitive. Our engine pre-aggregates with a
combiner; these tests check the built-in kernels' combiners are genuinely
commutative/associative monoids and that results are delivery-order
independent end-to-end (by permuting edge insertion order)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't abort collection
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import algorithms as ALG
from repro.core import graph as G
from repro.core import partition as PT
from repro.core.engine import Engine
from repro.kernels import ops as kops


@settings(max_examples=50, deadline=None)
@given(vals=st.lists(st.integers(-10 ** 6, 10 ** 6), min_size=1,
                     max_size=20),
       combiner=st.sampled_from(["min", "max", "add"]),
       seed=st.integers(0, 100))
def test_combiner_monoid_laws(vals, combiner, seed):
    rng = np.random.default_rng(seed)
    arr = np.array(vals, np.int64)
    op = {"min": np.minimum, "max": np.maximum, "add": np.add}[combiner]
    ident = kops.identity_for(combiner, jnp.int32)
    # identity
    assert op(arr[0], ident) == arr[0]
    # commutativity under random permutation: fold result is invariant
    perm = rng.permutation(len(arr))
    fold = arr[0]
    for v in arr[1:]:
        fold = op(fold, v)
    fold_p = arr[perm][0]
    for v in arr[perm][1:]:
        fold_p = op(fold_p, v)
    assert fold == fold_p


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_delivery_order_independence(seed):
    """Permuting the edge list (=> different message generation order and
    different lane assignment) must not change any algorithm result."""
    rng = np.random.default_rng(seed)
    g = G.uniform(120, 4.0, seed=seed).symmetrized()
    perm = rng.permutation(g.num_edges)
    g2 = G.Graph(g.num_vertices, g.src[perm], g.dst[perm],
                 None if g.weights is None else g.weights[perm])
    for kfn in (ALG.wcc, lambda: ALG.bfs(0)):
        outs = []
        for gg in (g, g2):
            pg = PT.partition_graph(gg, 4, pad_multiple=16)
            outs.append(Engine(kfn(), pg, mode="gravfm",
                               backend="ref").run().state)
        for k in outs[0]:
            assert np.array_equal(outs[0][k], outs[1][k])


@settings(max_examples=10, deadline=None)
@given(p=st.sampled_from([1, 2, 3, 4, 8]), seed=st.integers(0, 50))
def test_partition_count_independence(p, seed):
    """Results must be independent of the shard count (the generated
    'system size' is a deployment knob, not a semantic one)."""
    g = G.uniform(100, 4.0, seed=seed).symmetrized()
    base = None
    pg = PT.partition_graph(g, p, pad_multiple=8)
    res = Engine(ALG.wcc(), pg, mode="gravfm", backend="ref").run()
    pg1 = PT.partition_graph(g, 1, pad_multiple=8)
    ref = Engine(ALG.wcc(), pg1, mode="gravfm", backend="ref").run()
    assert np.array_equal(res.state["label"], ref.state["label"])
