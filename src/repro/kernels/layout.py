"""Static edge-tile layout for the Pallas edge-traversal kernel.

Built once per partitioned graph (host-side numpy), like the paper's
load-time edge-list preparation. Guarantees:
  * edges sorted by destination segment,
  * rows grouped into windows of ``tile_r`` consecutive segments,
  * per-window edge runs padded to a multiple of ``tile_e`` so no tile
    straddles a window boundary,
  * empty windows own zero tiles (they are masked after the kernel).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["EdgeLayout", "build_layout"]


@dataclasses.dataclass(frozen=True)
class EdgeLayout:
    num_segments: int
    tile_e: int
    tile_r: int
    n_tiles: int
    n_windows: int
    window_id: np.ndarray        # (n_tiles,) int32, non-decreasing
    rel: np.ndarray              # (n_tiles*tile_e,) int32; pads hold tile_r
    lane_of_edge: np.ndarray     # (E,) int32: padded lane of original edge i
    lane_valid: np.ndarray       # (n_tiles*tile_e,) bool
    window_written: np.ndarray   # (n_windows,) bool

    @property
    def num_lanes(self) -> int:
        return self.n_tiles * self.tile_e

    def place(self, arr: np.ndarray, fill) -> np.ndarray:
        """Scatter a per-edge array into padded kernel lanes."""
        out = np.full((self.num_lanes,) + arr.shape[1:], fill, arr.dtype)
        out[self.lane_of_edge] = arr
        return out

    @property
    def pad_overhead(self) -> float:
        e = int(self.lane_valid.sum())
        return self.num_lanes / max(e, 1) - 1.0


def build_layout(seg_ids: np.ndarray, num_segments: int, *,
                 tile_e: int = 512, tile_r: int = 256) -> EdgeLayout:
    """``seg_ids``: (E,) sorted ascending, values in [0, num_segments]
    (``num_segments`` itself = discard bin for pre-padded lanes)."""
    seg_ids = np.asarray(seg_ids, np.int64)
    assert seg_ids.ndim == 1
    if seg_ids.size:
        assert (np.diff(seg_ids) >= 0).all(), "seg_ids must be sorted"
        assert seg_ids.max() <= num_segments
    total_segs = num_segments + 1
    n_windows = -(-total_segs // tile_r)

    window = seg_ids // tile_r
    counts = np.bincount(window, minlength=n_windows).astype(np.int64)
    padded = -(-counts // tile_e) * tile_e  # 0 stays 0
    tiles_per_window = padded // tile_e
    n_tiles = int(tiles_per_window.sum())
    if n_tiles == 0:  # degenerate empty graph: one dummy tile
        n_tiles = 1
        tiles_per_window = tiles_per_window.copy()
        tiles_per_window[0] = 1
        padded = padded.copy()
        padded[0] = tile_e

    src_start = np.concatenate([[0], np.cumsum(counts)])[:-1]
    dst_start = np.concatenate([[0], np.cumsum(padded)])[:-1]

    E = seg_ids.shape[0]
    idx = np.arange(E, dtype=np.int64)
    lane = dst_start[window] + (idx - src_start[window])
    L = n_tiles * tile_e

    rel = np.full(L, tile_r, np.int32)
    rel[lane] = (seg_ids - window * tile_r).astype(np.int32)
    lane_valid = np.zeros(L, bool)
    lane_valid[lane] = True

    window_id = np.repeat(
        np.arange(n_windows, dtype=np.int32), tiles_per_window)
    window_written = counts > 0
    if window_written.sum() == 0:
        window_written = window_written.copy()
        window_written[0] = True

    return EdgeLayout(
        num_segments=num_segments, tile_e=tile_e, tile_r=tile_r,
        n_tiles=n_tiles, n_windows=int(n_windows),
        window_id=window_id.astype(np.int32), rel=rel,
        lane_of_edge=lane.astype(np.int32), lane_valid=lane_valid,
        window_written=window_written)
