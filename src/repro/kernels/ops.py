"""Jitted dispatch wrappers for the kernel layer.

``segment_combine``: runs the Pallas edge-traversal kernel when a static
:class:`EdgeLayout` is supplied (interpret=True on CPU — this container —
compiled on TPU), falling back to the pure-jnp oracle otherwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .edge_gather import segment_combine_pallas, _identity_for
from .layout import EdgeLayout, build_layout

__all__ = ["segment_combine", "segment_combine_layout", "build_layout",
           "EdgeLayout", "identity_for"]

identity_for = _identity_for


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def segment_combine_layout(vals_padded: jnp.ndarray, layout: EdgeLayout,
                           combiner: str, *, interpret: bool | None = None):
    """Kernel path. ``vals_padded`` is (layout.num_lanes,) with identity in
    padding lanes (use ``layout.place`` or mask with ``layout.lane_valid``).
    Returns (num_segments,)."""
    if interpret is None:
        interpret = _interpret_default()
    wid = jnp.asarray(layout.window_id)
    rel = jnp.asarray(layout.rel)
    out = segment_combine_pallas(
        wid, rel, vals_padded, combiner=combiner,
        tile_e=layout.tile_e, tile_r=layout.tile_r,
        n_windows=layout.n_windows, interpret=interpret)
    ident = identity_for(combiner, vals_padded.dtype)
    written = jnp.repeat(jnp.asarray(layout.window_written),
                         layout.tile_r, total_repeat_length=layout.n_windows * layout.tile_r)
    out = jnp.where(written, out, ident)
    return out[: layout.num_segments]


def segment_combine(vals: jnp.ndarray, seg_ids: jnp.ndarray,
                    num_segments: int, combiner: str,
                    layout: EdgeLayout | None = None,
                    interpret: bool | None = None):
    """Aggregate per-destination messages. With a layout → Pallas kernel;
    without → jnp oracle (used for the GraVF baseline path and as the
    reference in tests)."""
    if layout is None:
        return ref.segment_combine(vals, seg_ids, num_segments, combiner)
    ident = identity_for(combiner, vals.dtype)
    lane_valid = jnp.asarray(layout.lane_valid)
    vals_padded = jnp.where(lane_valid, vals, ident)
    return segment_combine_layout(vals_padded, layout, combiner,
                                  interpret=interpret)
