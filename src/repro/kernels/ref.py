"""Pure-jnp oracles for the kernel layer.

``segment_combine``: the fused receiver-side scatter+gather hot loop —
per-destination aggregation of on-demand messages (paper §4.1/§4.2). The
Pallas kernels in this package must match these bit-for-bit (up to
floating-point reduction-order tolerance for "add").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["segment_combine", "segment_combine_carry"]


def segment_combine(vals: jnp.ndarray, seg_ids: jnp.ndarray,
                    num_segments: int, combiner: str) -> jnp.ndarray:
    """Aggregate ``vals`` by ``seg_ids`` with a monoid. ``seg_ids`` may
    contain values >= num_segments for padding lanes (discarded)."""
    n = num_segments + 1
    clipped = jnp.minimum(seg_ids, num_segments)
    if combiner == "add":
        out = jax.ops.segment_sum(vals, clipped, num_segments=n)
    elif combiner == "min":
        out = jax.ops.segment_min(vals, clipped, num_segments=n)
    elif combiner == "max":
        out = jax.ops.segment_max(vals, clipped, num_segments=n)
    else:
        raise ValueError(f"unknown combiner: {combiner}")
    return out[:num_segments]


def segment_combine_carry(key_vals: jnp.ndarray, carry_vals: jnp.ndarray,
                          seg_ids: jnp.ndarray, num_segments: int,
                          combiner: str, carry_identity) -> tuple:
    """min/max-combine on ``key_vals`` with an argmin-style carried value:
    among lanes achieving the winning key, the min carry wins (deterministic
    tie-break; mirrors the paper's arbitrary-order message delivery, where
    any winning message's payload is acceptable)."""
    assert combiner in ("min", "max")
    acc = segment_combine(key_vals, seg_ids, num_segments, combiner)
    clipped = jnp.minimum(seg_ids, num_segments)
    at_edge = jnp.take(jnp.concatenate([acc, acc[-1:]]) if num_segments else acc,
                       jnp.minimum(clipped, max(num_segments - 1, 0)))
    winner = (key_vals == at_edge) & (seg_ids < num_segments)
    masked_carry = jnp.where(winner, carry_vals, carry_identity)
    carry = segment_combine(masked_carry, seg_ids, num_segments, "min")
    return acc, carry
