"""Pallas TPU kernel: fused receiver-side scatter+gather edge traversal.

This is the paper's compute hot-spot (its whole §5 model is in traversed
edges/second), re-architected for the TPU memory hierarchy instead of
ported from the FPGA pipeline:

  * Edges arrive pre-sorted by destination segment (CSC order — a static
    property of the partitioned graph, prepared once at load time like the
    paper's per-PE edge lists).
  * The edge stream is cut into fixed ``TILE_E``-edge tiles. Rows are
    grouped into windows of ``TILE_R`` consecutive segments, and tiles are
    padded so NO tile straddles a window boundary (static layout, see
    ``layout.py``).
  * Each grid step stages one (rel, vals) tile through VMEM (BlockSpec),
    expands it against a broadcasted iota into a ``TILE_R x TILE_E``
    equality mask — the VPU's 8x128 lanes play the role of the paper's
    parallel PEs — and folds it into the window's partial with the
    semiring combiner. Messages are produced and consumed entirely in
    VMEM, never materialized to HBM: the exact TPU analogue of GraVF-M's
    "generate messages on demand, immediately consumed by gather".
  * Consecutive tiles of the same window hit the same output block, which
    therefore stays resident in VMEM (sequential TPU grid); a
    scalar-prefetched ``window_id`` array drives the output index_map —
    this is the floating-barrier-flavoured part: the output block "floats"
    forward only when the window changes, with no global flush.

Semirings: add (PageRank), min (BFS/WCC/SSSP), max. ``interpret=True``
executes the same kernel body on CPU for validation (this container).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["segment_combine_pallas", "segment_combine_windows"]


def _identity_for(combiner: str, dtype):
    """Combiner identity as a PYTHON scalar (weakly typed — safe to bake
    into kernel bodies and jnp.where without forcing a dtype)."""
    dt = jnp.dtype(dtype)
    if combiner == "add":
        return 0.0 if jnp.issubdtype(dt, jnp.floating) else 0
    if combiner == "min":
        return (float("inf") if jnp.issubdtype(dt, jnp.floating)
                else int(jnp.iinfo(dt).max))
    if combiner == "max":
        return (float("-inf") if jnp.issubdtype(dt, jnp.floating)
                else int(jnp.iinfo(dt).min))
    raise ValueError(combiner)


def _make_kernel(combiner: str, tile_e: int, tile_r: int, dtype):
    ident = _identity_for(combiner, dtype)

    def kern(wid_ref, rel_ref, vals_ref, out_ref):
        t = pl.program_id(0)
        wid = wid_ref[t]
        prev = wid_ref[jnp.maximum(t - 1, 0)]
        is_first = (t == 0) | (wid != prev)

        rel = rel_ref[...]          # (tile_e,) int32 row-within-window
        vals = vals_ref[...]        # (tile_e,) message values
        # (tile_r, tile_e) equality mask vs broadcasted iota: each VPU row
        # lane selects the messages destined for its vertex.
        iota = jax.lax.broadcasted_iota(jnp.int32, (tile_r, tile_e), 0)
        mask = iota == rel[None, :]
        expanded = jnp.where(mask, vals[None, :], ident)
        if combiner == "add":
            part = jnp.sum(expanded, axis=1)
        elif combiner == "min":
            part = jnp.min(expanded, axis=1)
        else:
            part = jnp.max(expanded, axis=1)

        @pl.when(is_first)
        def _init():
            out_ref[...] = part

        @pl.when(jnp.logical_not(is_first))
        def _accum():
            if combiner == "add":
                out_ref[...] = out_ref[...] + part
            elif combiner == "min":
                out_ref[...] = jnp.minimum(out_ref[...], part)
            else:
                out_ref[...] = jnp.maximum(out_ref[...], part)

    return kern


@functools.partial(
    jax.jit,
    static_argnames=("combiner", "tile_e", "tile_r", "n_windows", "interpret"))
def segment_combine_pallas(window_id, rel, vals, *, combiner: str,
                           tile_e: int, tile_r: int, n_windows: int,
                           interpret: bool = True):
    """Run the edge-traversal kernel.

    Args:
      window_id: (n_tiles,) int32 — output window per tile (non-decreasing).
      rel:       (n_tiles*tile_e,) int32 — row-within-window per edge lane;
                 padding lanes hold ``tile_r`` (matches no row).
      vals:      (n_tiles*tile_e,) message values (padding lanes hold the
                 combiner identity).
      n_windows: number of output windows; result is (n_windows*tile_r,).
    """
    n_tiles = window_id.shape[0]
    assert rel.shape[0] == n_tiles * tile_e and vals.shape[0] == n_tiles * tile_e
    kern = _make_kernel(combiner, tile_e, tile_r, vals.dtype)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_tiles,),
            in_specs=[
                pl.BlockSpec((tile_e,), lambda t, wid: (t,)),
                pl.BlockSpec((tile_e,), lambda t, wid: (t,)),
            ],
            out_specs=pl.BlockSpec((tile_r,), lambda t, wid: (wid[t],)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_windows * tile_r,), vals.dtype),
        interpret=interpret,
    )(window_id, rel, vals)
    return out


@functools.partial(
    jax.jit,
    static_argnames=("combiner", "tile_e", "tile_r", "n_windows",
                     "num_segments", "interpret"))
def segment_combine_windows(window_id, rel, vals, *, combiner: str,
                            tile_e: int, tile_r: int, n_windows: int,
                            window_written, num_segments: int,
                            interpret: bool = True):
    """Windowed segment-combine with the full post-processing both engine
    paths need: run :func:`segment_combine_pallas`, force never-written
    windows (gaps in the segment range) back to the combiner identity via
    ``window_written`` (an ``(n_windows,)`` bool mask from the layout),
    and slice the ``(n_windows*tile_r,)`` window grid down to the first
    ``num_segments`` true segments."""
    out = segment_combine_pallas(window_id, rel, vals, combiner=combiner,
                                 tile_e=tile_e, tile_r=tile_r,
                                 n_windows=n_windows, interpret=interpret)
    ident = _identity_for(combiner, vals.dtype)
    written = jnp.repeat(window_written, tile_r,
                         total_repeat_length=n_windows * tile_r)
    return jnp.where(written, out, ident)[:num_segments]
