"""Pallas TPU kernels for the paper's compute hot-spot (edge traversal).

``edge_gather``: fused receiver-side scatter+gather (semiring segment
combine), ``ops``: jitted dispatch, ``ref``: pure-jnp oracles, ``layout``:
static tile layout builder.
"""
from . import layout, ops, ref  # noqa: F401
