"""Multi-tenant graph residency + fair-share policy.

:class:`GraphStore` is the versioned, memory-budgeted registry of
device-resident partitioned graphs (LRU eviction, query-pinning,
transparent refault, atomic version publish);
:class:`TenantRegistry` holds per-tenant quotas (token-bucket admission)
and fair-share weights the continuous scheduler enforces.

    from repro.store import GraphStore
    store = GraphStore(budget_bytes=2 * pg.device_nbytes)
    v1 = store.publish("tenant-a", graph_a)
    with store.acquire("tenant-a") as lease:   # pinned while in use
        run_queries(lease.pg)
"""
from .registry import GraphLease, GraphStore, StoreError
from .tenancy import (DEFAULT_TENANT, TenantPolicy, TenantRegistry,
                      TokenBucket)

__all__ = [
    "GraphLease", "GraphStore", "StoreError",
    "DEFAULT_TENANT", "TenantPolicy", "TenantRegistry", "TokenBucket",
]
