"""Per-tenant quotas and fair-share policy for the query service.

Two mechanisms, both opt-in per tenant (unconfigured tenants get weight
1.0 and no rate limit):

  * **Token-bucket admission** — ``rate_qps`` sustained queries/sec with
    ``burst`` headroom. A tenant that exhausts its bucket is shed at
    submit time with :class:`~repro.service.batching.AdmissionError`
    before it can occupy a scheduler slot.
  * **Weighted fair share** — ``weight`` drives stride scheduling in the
    continuous scheduler's admission window: each admitted query
    advances its tenant's virtual pass by ``1/weight``, and free lanes
    always go to the eligible tenant with the smallest pass. Over any
    contended interval tenants therefore retire queries in proportion
    to their weights (a 2.0-weight tenant gets ~2x the slots of a
    1.0-weight tenant), and one tenant's deep queries cannot starve
    another's shallow ones.

Time is injectable everywhere (``now`` parameters) so tests and the
deterministic benchmarks don't race the wall clock.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional

__all__ = ["TokenBucket", "TenantPolicy", "TenantRegistry",
           "DEFAULT_TENANT"]

DEFAULT_TENANT = "default"


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/sec refill, ``burst`` cap.
    ``try_take`` is non-blocking — admission control sheds, it never
    queues."""

    def __init__(self, rate: float, burst: float,
                 now: Optional[float] = None):
        assert rate > 0 and burst >= 1
        self.rate = float(rate)
        self.burst = float(burst)
        self._tokens = float(burst)
        self._stamp = time.perf_counter() if now is None else now

    def _refill(self, now: Optional[float]) -> None:
        now = time.perf_counter() if now is None else now
        if now > self._stamp:
            self._tokens = min(self.burst,
                               self._tokens + (now - self._stamp) * self.rate)
        self._stamp = now

    def try_take(self, n: float = 1.0, now: Optional[float] = None) -> bool:
        self._refill(now)
        if self._tokens >= n:
            self._tokens -= n
            return True
        return False

    @property
    def tokens(self) -> float:
        return self._tokens


@dataclasses.dataclass
class TenantPolicy:
    """One tenant's serving contract."""
    name: str
    weight: float = 1.0
    rate_qps: Optional[float] = None    # None = unlimited
    burst: Optional[float] = None       # defaults to max(1, rate_qps)

    def __post_init__(self):
        assert self.weight > 0, "tenant weight must be positive"
        if self.rate_qps is not None and self.burst is None:
            self.burst = max(1.0, self.rate_qps)


class TenantRegistry:
    """Thread-safe tenant policy table + per-tenant token buckets."""

    def __init__(self):
        self._lock = threading.Lock()  # lock: tenancy
        self._policies: Dict[str, TenantPolicy] = {}
        self._buckets: Dict[str, TokenBucket] = {}

    def configure(self, name: str, *, weight: float = 1.0,
                  rate_qps: Optional[float] = None,
                  burst: Optional[float] = None,
                  now: Optional[float] = None) -> TenantPolicy:
        pol = TenantPolicy(name, weight=weight, rate_qps=rate_qps,
                           burst=burst)
        with self._lock:
            self._policies[name] = pol
            if pol.rate_qps is not None:
                self._buckets[name] = TokenBucket(pol.rate_qps, pol.burst,
                                                  now=now)
            else:
                self._buckets.pop(name, None)
        return pol

    def policy(self, name: str) -> TenantPolicy:
        with self._lock:
            return self._policies.get(name) or TenantPolicy(name)

    def weight(self, name: str) -> float:
        with self._lock:
            pol = self._policies.get(name)
            return pol.weight if pol is not None else 1.0

    def admit(self, name: str, now: Optional[float] = None) -> bool:
        """Charge one query to ``name``'s token bucket; unlimited tenants
        always pass."""
        with self._lock:
            bucket = self._buckets.get(name)
            return bucket.try_take(1.0, now=now) if bucket else True

    def policies(self) -> Dict[str, TenantPolicy]:
        with self._lock:
            return dict(self._policies)
