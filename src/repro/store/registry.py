"""Multi-tenant GraphStore: a named, versioned, ref-counted registry of
device-resident partitioned graphs under an explicit memory budget.

The paper's §5 model treats per-board memory (``Platform.m_board``) as a
first-class constraint on which graphs a node can host; the serving
stack previously ignored it — every registered graph stayed
device-resident forever. This module manages graph residency the way
GraphScale manages on-accelerator graph storage and Swift decouples
residency from query execution:

  * ``publish(graph_id, graph)`` registers version N+1 of a tenant's
    graph. The host-side :class:`~repro.core.graph.Graph` and the
    partition spec (including the computed ``part_of`` assignment) are
    kept forever — they are cheap; the compiled
    :class:`~repro.core.partition.PartitionedGraph` layout is the
    expensive, budgeted resource.
  * ``acquire(graph_id)`` pins the latest (or an explicit) version and
    returns a :class:`GraphLease`. Acquiring an **evicted** version
    transparently re-materializes it (a *fault*) from the retained
    partition assignment — bit-identical to the original layout.
  * When ``resident_bytes`` exceeds ``budget_bytes`` the store evicts
    least-recently-used **unpinned** layouts; pinned layouts (queries in
    flight) are never evicted, so a burst larger than the budget
    overcommits rather than corrupts.
  * Superseded versions are evicted eagerly the moment their last pin
    drops — in-flight queries drain on version N while new arrivals
    bind N+1, and N's device arrays (and, via ``on_evict`` listeners,
    its cached compiled plans) vanish as soon as the drain completes,
    without touching any other tenant's cache entries.

``evictions`` / ``faults`` / ``resident_bytes`` are surfaced in
:meth:`GraphStore.snapshot` and folded into the service's stats
endpoint.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.graph import Graph
from ..core.partition import PartitionedGraph, partition_graph

__all__ = ["GraphStore", "GraphLease", "StoreError"]


class StoreError(RuntimeError):
    """Raised on invalid store operations (re-publishing with versioning
    disabled, acquiring an unknown graph/version, ...)."""


def _graphs_equal(a: Graph, b: Graph) -> bool:
    if a is b:
        return True
    if (a.num_vertices != b.num_vertices
            or a.num_edges != b.num_edges):
        return False
    if not (np.array_equal(a.src, b.src) and np.array_equal(a.dst, b.dst)):
        return False
    if (a.weights is None) != (b.weights is None):
        return False
    return a.weights is None or np.array_equal(a.weights, b.weights)


@dataclasses.dataclass
class _Version:
    """One published (graph_id, version): host graph + partition spec
    always; the compiled layout only while resident."""
    graph_id: str
    version: int
    graph: Graph
    num_shards: int
    method: str
    pad_multiple: int
    pg: Optional[PartitionedGraph] = None   # None = evicted
    part_of: Optional[np.ndarray] = None    # pinned partition assignment
    nbytes: int = 0                         # layout cost while resident
    pins: int = 0
    last_used: int = 0                      # LRU clock value
    superseded: bool = False
    ever_resident: bool = False

    @property
    def resident(self) -> bool:
        return self.pg is not None

    def spec(self) -> Tuple[int, str, int]:
        return (self.num_shards, self.method, self.pad_multiple)


class GraphLease:
    """A pin on one resident (graph_id, version). Release it (or use it
    as a context manager) when the query that needed the graph retires;
    unpinned layouts become evictable."""

    def __init__(self, store: "GraphStore", graph_id: str, version: int,
                 pg: PartitionedGraph):
        self._store = store
        self.graph_id = graph_id
        self.version = version
        self.pg = pg
        self._released = False

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._store.release(self.graph_id, self.version)

    def __enter__(self) -> "GraphLease":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class GraphStore:
    """Versioned, memory-budgeted registry of partitioned graphs.

    ``budget_bytes=None`` means unbounded (the pre-store behavior);
    passing a :class:`~repro.core.perfmodel.Platform` derives the budget
    from its ``m_board``. Thread-safe: every method serializes on one
    lock (materialization included — a fault is device-upload-bound, not
    lock-bound).
    """

    def __init__(self, *, budget_bytes: Optional[float] = None,
                 platform=None, versioned: bool = True,
                 num_shards: int = 4, method: str = "greedy",
                 pad_multiple: int = 256):
        if budget_bytes is None and platform is not None:
            budget_bytes = float(platform.m_board)
        self.budget_bytes: Optional[float] = (
            float(budget_bytes) if budget_bytes is not None else None)
        self.versioned = versioned
        self.defaults = dict(num_shards=num_shards, method=method,
                             pad_multiple=pad_multiple)
        self._lock = threading.RLock()
        self._versions: Dict[Tuple[str, int], _Version] = {}
        self._latest: Dict[str, int] = {}
        self._clock = 0
        self._evict_listeners: List[Callable[[str, int], None]] = []
        # counters
        self.publishes = 0
        self.evictions = 0
        self.faults = 0
        self.budget_overcommits = 0

    # ---------------- registration ------------------------------------
    def publish(self, graph_id: str, graph: Graph, *,
                num_shards: Optional[int] = None,
                method: Optional[str] = None,
                pad_multiple: Optional[int] = None,
                materialize: bool = True) -> int:
        """Register ``graph`` as the next version of ``graph_id``.

        First publish creates version 1. Re-publishing identical content
        under the same partition spec is an idempotent no-op (returns
        the current version). Different content bumps the version when
        the store is ``versioned``; with versioning disabled it raises
        :class:`StoreError` instead of silently overwriting a graph that
        in-flight queries may still be traversing.
        """
        num_shards = num_shards or self.defaults["num_shards"]
        method = method or self.defaults["method"]
        pad_multiple = pad_multiple or self.defaults["pad_multiple"]
        with self._lock:
            cur = self._latest.get(graph_id)
            head = None
            if cur is not None:
                head = self._versions[(graph_id, cur)]
                same_spec = head.spec() == (num_shards, method, pad_multiple)
                if same_spec and _graphs_equal(head.graph, graph):
                    return cur          # idempotent re-register
                if not self.versioned:
                    raise StoreError(
                        f"graph {graph_id!r} already published and "
                        "versioning is disabled; re-publishing different "
                        "content would silently invalidate in-flight "
                        "queries (construct the store with versioned=True "
                        "to swap versions atomically)")
                head.superseded = True
            ver = (cur or 0) + 1
            entry = _Version(graph_id=graph_id, version=ver, graph=graph,
                             num_shards=num_shards, method=method,
                             pad_multiple=pad_multiple)
            self._versions[(graph_id, ver)] = entry
            self._latest[graph_id] = ver
            self.publishes += 1
            # retire a drained (unpinned) predecessor AFTER the new head
            # is registered, so evict listeners observe the new latest
            # (stale plans and cached results are scoped to `cur`)
            if head is not None and head.pins == 0:
                self._retire_superseded_locked(head)
            if materialize:
                self._materialize_locked(entry, fault=False)
                self._evict_to_budget_locked()
            return ver

    def remove(self, graph_id: str) -> None:
        """Drop every version of ``graph_id`` (refuses while pinned)."""
        with self._lock:
            keys = [k for k in self._versions if k[0] == graph_id]
            if not keys:
                raise KeyError(f"graph {graph_id!r} not in store")
            for k in keys:
                if self._versions[k].pins > 0:
                    raise StoreError(
                        f"graph {graph_id!r} v{k[1]} is pinned by "
                        f"{self._versions[k].pins} in-flight queries")
            for k in keys:
                entry = self._versions.pop(k)
                if entry.resident:
                    self._evict_locked(entry, count=False)
            del self._latest[graph_id]

    # ---------------- lookup / pinning --------------------------------
    def latest_version(self, graph_id: str) -> int:
        with self._lock:
            ver = self._latest.get(graph_id)
            if ver is None:
                raise KeyError(f"graph {graph_id!r} not in store")
            return ver

    def known_version(self, graph_id: str) -> int:
        """Like :meth:`latest_version` but 0 for unknown ids (lets
        callers defer the missing-graph error to dispatch time)."""
        with self._lock:
            return self._latest.get(graph_id, 0)

    def graph_ids(self) -> List[str]:
        with self._lock:
            return sorted(self._latest)

    def _entry(self, graph_id: str, version: Optional[int]) -> _Version:
        ver = version or self._latest.get(graph_id)
        if ver is None:
            raise KeyError(f"graph {graph_id!r} not in store")
        entry = self._versions.get((graph_id, ver))
        if entry is None:
            raise KeyError(f"graph {graph_id!r} has no version {ver}")
        return entry

    def acquire(self, graph_id: str, version: Optional[int] = None
                ) -> GraphLease:
        """Pin (graph_id, version) — latest when ``version`` is None —
        re-materializing it first if it was evicted. The pin blocks
        eviction until released."""
        with self._lock:
            entry = self._entry(graph_id, version)
            if not entry.resident:
                self._materialize_locked(entry, fault=True)
            entry.pins += 1
            self._touch_locked(entry)
            self._evict_to_budget_locked()
            return GraphLease(self, entry.graph_id, entry.version, entry.pg)

    def release(self, graph_id: str, version: int) -> None:
        with self._lock:
            entry = self._versions.get((graph_id, version))
            if entry is None:
                return      # removed while leased — nothing left to unpin
            entry.pins = max(0, entry.pins - 1)
            # superseded versions exist only for their in-flight drain:
            # last pin out turns off the lights (device arrays + plans +
            # host payloads — no new arrival can ever bind them again)
            if entry.pins == 0 and entry.superseded:
                self._retire_superseded_locked(entry)
            else:
                self._evict_to_budget_locked()

    def peek(self, graph_id: str, version: Optional[int] = None
             ) -> PartitionedGraph:
        """The resident layout, without pinning. Raises
        :class:`StoreError` if the version is evicted — callers on the
        query path must hold a lease instead."""
        with self._lock:
            entry = self._entry(graph_id, version)
            if not entry.resident:
                raise StoreError(
                    f"graph {graph_id!r} v{entry.version} is evicted; "
                    "acquire() a lease to fault it back in")
            self._touch_locked(entry)
            return entry.pg

    def host_graph(self, graph_id: str,
                   version: Optional[int] = None) -> Graph:
        with self._lock:
            entry = self._entry(graph_id, version)
            if entry.graph is None:
                raise StoreError(
                    f"graph {graph_id!r} v{entry.version} was superseded "
                    "and has drained; its host graph is released")
            return entry.graph

    def partition_spec(self, graph_id: str,
                       version: Optional[int] = None) -> Dict[str, object]:
        with self._lock:
            e = self._entry(graph_id, version)
            return dict(num_shards=e.num_shards, method=e.method,
                        pad_multiple=e.pad_multiple)

    # ---------------- eviction ----------------------------------------
    def add_evict_listener(self, fn: Callable[[str, int], None]) -> None:
        """``fn(graph_id, version)`` fires (under the store lock) when a
        layout leaves device residency — the plan cache uses this to
        drop the engines/plans compiled against the evicted arrays."""
        self._evict_listeners.append(fn)

    def evict(self, graph_id: str, version: Optional[int] = None) -> bool:
        """Explicitly evict one version's layout. Returns False (and
        leaves it resident) if the version is pinned."""
        with self._lock:
            entry = self._entry(graph_id, version)
            if not entry.resident:
                return True
            if entry.pins > 0:
                return False
            self._evict_locked(entry)
            return True

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return sum(e.nbytes for e in self._versions.values()
                       if e.resident)

    def snapshot(self) -> Dict[str, float]:
        """Store counters for the service stats endpoint."""
        with self._lock:
            resident = [e for e in self._versions.values() if e.resident]
            return {
                "graphs": len(self._latest),
                "versions": len(self._versions),
                "resident_graphs": len(resident),
                "resident_bytes": float(sum(e.nbytes for e in resident)),
                "pinned_graphs": sum(1 for e in resident if e.pins > 0),
                "budget_bytes": (float(self.budget_bytes)
                                 if self.budget_bytes is not None else -1.0),
                "publishes": self.publishes,
                "evictions": self.evictions,
                "faults": self.faults,
                "budget_overcommits": self.budget_overcommits,
            }

    def describe(self) -> List[Dict[str, object]]:
        with self._lock:
            return [{
                "graph_id": e.graph_id, "version": e.version,
                "resident": e.resident, "pins": e.pins,
                "superseded": e.superseded, "nbytes": e.nbytes,
                "num_shards": e.num_shards, "method": e.method,
            } for e in self._versions.values()]

    # ---------------- internals (lock held) ----------------------------
    def _touch_locked(self, entry: _Version) -> None:
        self._clock += 1
        entry.last_used = self._clock

    def _materialize_locked(self, entry: _Version, *, fault: bool) -> None:
        if entry.graph is None:
            raise StoreError(
                f"graph {entry.graph_id!r} v{entry.version} was "
                "superseded and has drained; only the latest version "
                "can be acquired")
        # Re-materialization reuses the pinned part_of assignment, so a
        # faulted-back layout is array-for-array identical to the
        # original (partitioners are deterministic anyway; this also
        # skips their O(V)/O(E) host work on the fault path).
        entry.pg = partition_graph(
            entry.graph, entry.num_shards, method=entry.method,
            pad_multiple=entry.pad_multiple, part_of=entry.part_of)
        if entry.part_of is None:
            entry.part_of = entry.pg.part_of
        entry.nbytes = entry.pg.device_nbytes
        # a fresh layout is by definition the most recently used — without
        # this touch its last_used of 0 would make it the LRU victim of
        # the very budget sweep its own materialization triggers
        self._touch_locked(entry)
        if fault and entry.ever_resident:
            self.faults += 1
        entry.ever_resident = True

    def _evict_locked(self, entry: _Version, *, count: bool = True) -> None:
        entry.pg = None
        if count:
            self.evictions += 1
        for fn in self._evict_listeners:
            fn(entry.graph_id, entry.version)

    def _retire_superseded_locked(self, entry: _Version) -> None:
        """A drained superseded version: evict its layout AND drop the
        host-side Graph / partition assignment. A long-running service
        that republishes a tenant's graph for months must not retain
        every predecessor's E-sized edge arrays; the metadata tombstone
        stays for describe()/snapshot() introspection."""
        if entry.resident:
            self._evict_locked(entry)
        entry.graph = None
        entry.part_of = None

    def _evict_to_budget_locked(self) -> None:
        if self.budget_bytes is None:
            return
        while True:
            resident = [e for e in self._versions.values() if e.resident]
            total = sum(e.nbytes for e in resident)
            if total <= self.budget_bytes:
                return
            victims = [e for e in resident if e.pins == 0]
            if not victims:
                # everything over budget is serving in-flight queries —
                # overcommit rather than corrupt; the next release
                # re-runs this sweep
                self.budget_overcommits += 1
                return
            self._evict_locked(min(victims, key=lambda e: e.last_used))
