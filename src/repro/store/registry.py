"""Multi-tenant GraphStore: a named, versioned, ref-counted registry of
device-resident partitioned graphs under an explicit memory budget, with
a host-spill residency tier underneath it.

The paper's §5 model treats per-board memory (``Platform.m_board``) as a
first-class constraint on which graphs a node can host; the serving
stack previously ignored it — every registered graph stayed
device-resident forever. This module manages graph residency the way
GraphScale manages on-accelerator graph storage and Swift decouples
residency from query execution:

  * ``publish(graph_id, graph)`` registers version N+1 of a tenant's
    graph. The host-side :class:`~repro.core.graph.Graph` and the
    partition spec (including the computed ``part_of`` assignment) are
    kept forever — they are cheap; the compiled
    :class:`~repro.core.partition.PartitionedGraph` layout is the
    expensive, budgeted resource.
  * ``acquire(graph_id)`` pins the latest (or an explicit) version and
    returns a :class:`GraphLease`. Acquiring a non-resident version
    transparently re-materializes it (a *fault*) — bit-identical to the
    original layout.
  * When ``resident_bytes`` exceeds ``budget_bytes`` the store evicts
    least-recently-used **unpinned** layouts; pinned layouts (queries in
    flight) are never evicted, so a burst larger than the budget
    overcommits rather than corrupts.

Residency is a three-tier state machine (README "Graph residency"):

  DEVICE ──evict──▶ SPILLED ──overflow/retire──▶ DISCARDED
     ▲                 │                            │
     └──── refault ────┘◀──────── cold fault ───────┘

  * **DEVICE**: the layout is resident and charged against
    ``budget_bytes`` (``m_board``).
  * **SPILLED**: eviction *demotes* the layout's arrays to pinned host
    copies instead of dropping them (the Swift/GraphScale move:
    on-accelerator storage is a cache over a larger host tier). A fault
    from this tier is a **device re-upload** — no partitioner re-run,
    and, because shapes/dtypes are unchanged, no engine re-trace: the
    plan cache keeps the version's compiled plans across spill/refault
    and only drops them on true discard. Spilled bytes are charged
    against a second-level ``spill_budget_bytes`` (None = unbounded
    host tier; 0 disables spilling — the pre-spill discard behavior);
    overflow discards the LRU spilled layout.
  * **DISCARDED**: only the host ``Graph`` + ``part_of`` survive; the
    next fault re-runs the partition compile and the plan cache
    re-builds engines/plans (the evict listeners fire here, not on
    spill).

Faults **materialize outside the store lock**: the faulting thread marks
the entry in-progress and builds with the registry unlocked, so one
tenant's multi-second cold fault no longer head-of-line-blocks every
other tenant's ``submit``/``acquire``. Double-faulting threads wait on
the *entry's* condition variable (not the registry) and share the single
materialization.

Superseded versions are retired (a true discard of both tiers plus the
host payloads) the moment their last pin drops — in-flight queries drain
on version N while new arrivals bind N+1.

Two further byte flows share the budgets (PR 5):

  * **engine-tier accounting**: the plan cache reports every engine's
    TRUE device bytes (:meth:`note_engine_bytes`); while on record they
    replace the partition-layout proxy in the version's budget charge,
    so a graph serving three kernels is charged all three engines.
  * **parked lanes**: a preempted query's host-parked carry checkpoint
    is charged against ``spill_budget_bytes``
    (:meth:`reserve_parked`/:meth:`release_parked`) — the ParkedQueue
    is bounded by the same host tier the spilled layouts live in.

``evictions`` / ``spills`` / ``discards`` / ``faults`` /
``resident_bytes`` / ``spilled_bytes`` / ``refault_upload_ms`` /
``parked_bytes`` are surfaced in :meth:`GraphStore.snapshot` and folded
into the service's stats endpoint.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.graph import Graph
from ..core.partition import PARTITIONERS, PartitionedGraph, partition_graph

__all__ = ["GraphStore", "GraphLease", "StoreError"]


class StoreError(RuntimeError):
    """Raised on invalid store operations (re-publishing with versioning
    disabled, acquiring a superseded version whose retirement is
    pending, non-positive partition specs, ...). Unknown graph ids and
    versions raise plain :class:`KeyError`."""


def _graphs_equal(a: Graph, b: Graph) -> bool:
    if a is b:
        return True
    if (a.num_vertices != b.num_vertices
            or a.num_edges != b.num_edges):
        return False
    if not (np.array_equal(a.src, b.src) and np.array_equal(a.dst, b.dst)):
        return False
    if (a.weights is None) != (b.weights is None):
        return False
    return a.weights is None or np.array_equal(a.weights, b.weights)


@dataclasses.dataclass
class _Version:
    """One published (graph_id, version): host graph + partition spec
    always; the compiled layout in the device tier (``pg``), the host
    tier (``spilled``), or neither (discarded)."""
    graph_id: str
    version: int
    graph: Graph
    num_shards: int
    method: str
    pad_multiple: int
    pg: Optional[PartitionedGraph] = None       # None = not device-resident
    spilled: Optional[PartitionedGraph] = None  # host-spill copy
    part_of: Optional[np.ndarray] = None    # pinned partition assignment
    nbytes: int = 0                         # charged cost (either tier)
    layout_nbytes: int = 0                  # partition-layout proxy bytes
    engine_bytes: int = 0                   # TRUE engine-tier device bytes
    pins: int = 0
    last_used: int = 0                      # LRU clock value
    superseded: bool = False
    ever_resident: bool = False
    building: bool = False                  # a fault is materializing
    cond: Optional[threading.Condition] = None  # entry-scoped waiters

    @property
    def resident(self) -> bool:
        return self.pg is not None

    @property
    def in_spill(self) -> bool:
        return self.spilled is not None

    def spec(self) -> Tuple[int, str, int]:
        return (self.num_shards, self.method, self.pad_multiple)


class GraphLease:
    """A pin on one resident (graph_id, version). Release it (or use it
    as a context manager) when the query that needed the graph retires;
    unpinned layouts become evictable."""

    def __init__(self, store: "GraphStore", graph_id: str, version: int,
                 pg: PartitionedGraph):
        self._store = store
        self.graph_id = graph_id
        self.version = version
        self.pg = pg
        self._released = False

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._store.release(self.graph_id, self.version)

    def __enter__(self) -> "GraphLease":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class GraphStore:
    """Versioned, memory-budgeted registry of partitioned graphs.

    ``budget_bytes=None`` means unbounded (the pre-store behavior);
    passing a :class:`~repro.core.perfmodel.Platform` derives the budget
    from its ``m_board``. ``spill_budget_bytes`` caps the host-spill
    tier (None = unbounded host tier, 0 = spilling disabled — evictions
    discard as before). Thread-safe: metadata operations serialize on
    one lock, but fault **materialization runs with the lock released**
    (per-entry in-progress flag + condition variable), so a slow fault
    never blocks other entries' operations.
    """

    def __init__(self, *, budget_bytes: Optional[float] = None,
                 platform=None, versioned: bool = True,
                 num_shards: int = 4, method: str = "greedy",
                 pad_multiple: int = 256,
                 spill_budget_bytes: Optional[float] = None):
        if budget_bytes is None and platform is not None:
            budget_bytes = float(platform.m_board)
        self.budget_bytes: Optional[float] = (
            float(budget_bytes) if budget_bytes is not None else None)
        self.spill_budget_bytes: Optional[float] = (
            float(spill_budget_bytes) if spill_budget_bytes is not None
            else None)
        self.versioned = versioned
        self.defaults = dict(num_shards=num_shards, method=method,
                             pad_multiple=pad_multiple)
        self._lock = threading.RLock()  # lock: store
        self._versions: Dict[Tuple[str, int], _Version] = {}
        self._latest: Dict[str, int] = {}
        self._clock = 0
        self._evict_listeners: List[Callable[[str, int], None]] = []
        self._spill_listeners: List[Callable[[str, int], None]] = []
        self._refault_listeners: List[Callable[[str, int], None]] = []
        # spills recorded under the lock, fired after it is released
        self._pending_spills: List[Tuple[str, int]] = []
        # counters
        self.publishes = 0
        self.evictions = 0
        self.spills = 0
        self.discards = 0
        self.faults = 0
        self.budget_overcommits = 0
        self.refault_upload_ms = 0.0    # wall spent promoting spilled
        # host bytes of preempted lanes' parked carries (the continuous
        # scheduler's ParkedQueue charges them here against the spill
        # budget — a parked checkpoint is host-resident state exactly
        # like a spilled layout)
        self.parked_bytes = 0
        self.lane_parks = 0             # reservations granted
        # optional duck-typed lifecycle event bus (service.trace.TraceBus)
        self._trace = None

    def set_trace(self, bus) -> None:
        """Attach a lifecycle event bus (anything with ``emit(kind,
        **fields)``); residency transitions (publish / spill / refault /
        evict) then land on the same timeline as the service's query
        events. The bus append is a leaf lock, so emitting under the
        store lock is ordering-safe."""
        self._trace = bus

    def _emit(self, kind: str, **fields) -> None:
        if self._trace is not None:
            self._trace.emit(kind, **fields)

    @property
    def _spill_enabled(self) -> bool:
        return self.spill_budget_bytes is None or self.spill_budget_bytes > 0

    # ---------------- registration ------------------------------------
    def publish(self, graph_id: str, graph: Graph, *,
                num_shards: Optional[int] = None,
                method: Optional[str] = None,
                pad_multiple: Optional[int] = None,
                materialize: bool = True) -> int:
        """Register ``graph`` as the next version of ``graph_id``.

        First publish creates version 1. Re-publishing identical content
        under the same partition spec is an idempotent no-op (returns
        the current version). Different content bumps the version when
        the store is ``versioned``; with versioning disabled it raises
        :class:`StoreError` instead of silently overwriting a graph that
        in-flight queries may still be traversing.
        """
        # explicit zeros must not silently fall back to the defaults —
        # a 0-shard "request" is a caller bug, not a request for 4
        if num_shards is None:
            num_shards = self.defaults["num_shards"]
        if method is None:
            method = self.defaults["method"]
        if pad_multiple is None:
            pad_multiple = self.defaults["pad_multiple"]
        if num_shards <= 0:
            raise StoreError(
                f"num_shards must be positive, got {num_shards!r} "
                f"(omit it or pass None for the store default "
                f"{self.defaults['num_shards']})")
        if pad_multiple <= 0:
            raise StoreError(
                f"pad_multiple must be positive, got {pad_multiple!r} "
                f"(omit it or pass None for the store default "
                f"{self.defaults['pad_multiple']})")
        if method not in PARTITIONERS:
            raise StoreError(
                f"unknown partition method {method!r}; have "
                f"{sorted(PARTITIONERS)}")
        with self._lock:
            cur = self._latest.get(graph_id)
            head = None
            if cur is not None:
                head = self._versions[(graph_id, cur)]
                same_spec = head.spec() == (num_shards, method, pad_multiple)
                if same_spec and _graphs_equal(head.graph, graph):
                    return cur          # idempotent re-register
                if not self.versioned:
                    raise StoreError(
                        f"graph {graph_id!r} already published and "
                        "versioning is disabled; re-publishing different "
                        "content would silently invalidate in-flight "
                        "queries (construct the store with versioned=True "
                        "to swap versions atomically)")
                head.superseded = True
            ver = (cur or 0) + 1
            entry = _Version(graph_id=graph_id, version=ver, graph=graph,
                             num_shards=num_shards, method=method,
                             pad_multiple=pad_multiple,
                             cond=threading.Condition(self._lock))  # lock: store
            self._versions[(graph_id, ver)] = entry
            self._latest[graph_id] = ver
            self.publishes += 1
            # retire a drained (unpinned) predecessor AFTER the new head
            # is registered, so evict listeners observe the new latest
            # (stale plans and cached results are scoped to `cur`)
            if head is not None and head.pins == 0:
                self._retire_superseded_locked(head)
        self._emit("publish", graph_id=graph_id, version=ver,
                   num_vertices=int(graph.num_vertices),
                   num_edges=int(graph.num_edges))
        if materialize:
            # outside the lock: a large publish compiles its layout
            # without stalling other tenants (same protocol as a fault)
            self._ensure_resident(graph_id, ver, fault=False, pin=False)
        return ver

    def remove(self, graph_id: str) -> None:
        """Drop every version of ``graph_id`` (refuses while pinned)."""
        with self._lock:
            keys = [k for k in self._versions if k[0] == graph_id]
            if not keys:
                raise KeyError(f"graph {graph_id!r} not in store")
            for k in keys:
                if self._versions[k].pins > 0:
                    raise StoreError(
                        f"graph {graph_id!r} v{k[1]} is pinned by "
                        f"{self._versions[k].pins} in-flight queries")
            for k in keys:
                entry = self._versions.pop(k)
                if entry.resident:
                    self._evict_locked(entry, count=False, spill=False)
                elif entry.in_spill:
                    self._discard_locked(entry, count=False)
                if entry.building:
                    # an in-flight fault installs into an orphaned entry;
                    # wake its waiters so they re-resolve (and KeyError)
                    entry.cond.notify_all()
            del self._latest[graph_id]

    # ---------------- lookup / pinning --------------------------------
    def latest_version(self, graph_id: str) -> int:
        with self._lock:
            ver = self._latest.get(graph_id)
            if ver is None:
                raise KeyError(f"graph {graph_id!r} not in store")
            return ver

    def known_version(self, graph_id: str) -> int:
        """Like :meth:`latest_version` but 0 for unknown ids (lets
        callers defer the missing-graph error to dispatch time)."""
        with self._lock:
            return self._latest.get(graph_id, 0)

    def graph_ids(self) -> List[str]:
        with self._lock:
            return sorted(self._latest)

    def _entry(self, graph_id: str, version: Optional[int]) -> _Version:
        ver = version or self._latest.get(graph_id)
        if ver is None:
            raise KeyError(f"graph {graph_id!r} not in store")
        entry = self._versions.get((graph_id, ver))
        if entry is None:
            raise KeyError(f"graph {graph_id!r} has no version {ver}")
        return entry

    def acquire(self, graph_id: str, version: Optional[int] = None
                ) -> GraphLease:
        """Pin (graph_id, version) — latest when ``version`` is None —
        re-materializing it first if it is not device-resident (a
        *fault*: re-upload from the host-spill tier, or re-partition
        from the retained assignment). The pin blocks eviction until
        released. Materialization happens with the store lock released;
        a concurrent fault of the same entry waits on the entry, not the
        registry. Acquiring a superseded version whose retirement is
        pending (no longer resident) raises :class:`StoreError` — only
        the latest version can be (re-)materialized."""
        lease = self._ensure_resident(graph_id, version, fault=True,
                                      pin=True)
        assert lease is not None
        return lease

    def release(self, graph_id: str, version: int) -> None:
        try:
            with self._lock:
                entry = self._versions.get((graph_id, version))
                if entry is None:
                    return  # removed while leased — nothing left to unpin
                entry.pins = max(0, entry.pins - 1)
                # superseded versions exist only for their in-flight
                # drain: last pin out turns off the lights (device
                # arrays + plans + host payloads — no new arrival can
                # ever bind them again)
                if entry.pins == 0 and entry.superseded:
                    self._retire_superseded_locked(entry)
                else:
                    self._evict_to_budget_locked()
        finally:
            self._fire_pending_spills()

    def peek(self, graph_id: str, version: Optional[int] = None
             ) -> PartitionedGraph:
        """The device-resident layout, without pinning. Raises
        :class:`StoreError` if the version is spilled or discarded —
        callers on the query path must hold a lease instead."""
        with self._lock:
            entry = self._entry(graph_id, version)
            if not entry.resident:
                raise StoreError(
                    f"graph {graph_id!r} v{entry.version} is "
                    f"{'spilled' if entry.in_spill else 'evicted'}; "
                    "acquire() a lease to fault it back in")
            self._touch_locked(entry)
            return entry.pg

    def host_graph(self, graph_id: str,
                   version: Optional[int] = None) -> Graph:
        with self._lock:
            entry = self._entry(graph_id, version)
            if entry.graph is None:
                raise StoreError(
                    f"graph {graph_id!r} v{entry.version} was superseded "
                    "and has drained; its host graph is released")
            return entry.graph

    def partition_spec(self, graph_id: str,
                       version: Optional[int] = None) -> Dict[str, object]:
        with self._lock:
            e = self._entry(graph_id, version)
            return dict(num_shards=e.num_shards, method=e.method,
                        pad_multiple=e.pad_multiple)

    # ---------------- eviction ----------------------------------------
    def add_evict_listener(self, fn: Callable[[str, int], None]) -> None:
        """``fn(graph_id, version)`` fires (under the store lock) when a
        layout is **discarded** — dropped from both residency tiers
        (spill overflow, version retirement, remove). The plan cache
        uses this to drop the engines/plans compiled against the
        version. Budget evictions that *spill* do NOT fire it — spilled
        versions keep their compiled plans (see
        :meth:`add_spill_listener`)."""
        self._evict_listeners.append(fn)

    def add_spill_listener(self, fn: Callable[[str, int], None]) -> None:
        """``fn(graph_id, version)`` fires — with the store lock
        RELEASED, on the thread whose operation triggered the eviction —
        when a layout is demoted device → host. The plan cache uses
        this to offload the version's engine device arrays while
        keeping the compiled plans. The transfer runs unlocked (a big
        layout's device→host copy must not stall the registry, budget
        sweeps run on the fault path too); the store re-checks under
        the lock that the entry is still spilled and not mid-refault
        before firing, so an offload cannot clobber a concurrent
        fault's re-upload."""
        self._spill_listeners.append(fn)

    def add_refault_listener(self, fn: Callable[[str, int], None]) -> None:
        """``fn(graph_id, version)`` fires — with the store lock
        RELEASED, on the faulting thread — when a fault promotes a
        layout back to device residency. The plan cache re-uploads the
        version's engine arrays here; the wall time of the whole
        promotion (listeners included) accumulates in
        ``refault_upload_ms``."""
        self._refault_listeners.append(fn)

    def evict(self, graph_id: str, version: Optional[int] = None, *,
              spill: Optional[bool] = None) -> bool:
        """Explicitly evict one version's layout (``spill=None`` follows
        the store's spill policy; ``spill=False`` forces a discard).
        Returns False (and leaves it resident) if the version is
        pinned."""
        try:
            with self._lock:
                entry = self._entry(graph_id, version)
                if entry.building:
                    # a fault is materializing from this entry's layout
                    # right now — discarding under it would drop the
                    # version's plans mid-refault (same guard as the
                    # spill-budget sweep)
                    return False
                if not entry.resident:
                    if spill is False and entry.in_spill:
                        self._discard_locked(entry)
                    return True
                if entry.pins > 0:
                    return False
                self._evict_locked(entry, spill=spill)
                return True
        finally:
            self._fire_pending_spills()

    # ---------------- engine-tier byte accounting ----------------------
    def note_engine_bytes(self, graph_id: str, version: int,
                          delta: int) -> None:
        """Fold true engine-tier device bytes into the version's budget
        charge. The plan cache reports ``+engine.device_nbytes`` when it
        builds an engine against this version and the negative sum when
        a discard drops them; while any engine bytes are on record they
        replace the partition-layout proxy estimate (a version serving
        several kernels/modes charges every engine's arrays). Unknown
        (graph_id, version) pairs are ignored — the engine outlived the
        version's removal."""
        fire = False
        try:
            with self._lock:
                entry = self._versions.get((graph_id, version))
                if entry is None:
                    return
                entry.engine_bytes = max(0, entry.engine_bytes
                                         + int(delta))
                entry.nbytes = entry.engine_bytes or entry.layout_nbytes
                if delta > 0:
                    # a bigger charge may push the registry over budget
                    fire = True
                    self._evict_to_budget_locked()
        finally:
            if fire:
                self._fire_pending_spills()

    # ---------------- parked-lane (preemption) accounting --------------
    def reserve_parked(self, nbytes: int) -> bool:
        """Charge ``nbytes`` of a preempted lane's host-parked carry
        checkpoint against the **spill budget** (parked carries are
        host-resident state exactly like spilled layouts). Makes room by
        discarding LRU spilled layouts first; returns ``False`` — the
        scheduler then skips the preemption — when the budget cannot fit
        the checkpoint. ``spill_budget_bytes=0`` (host tier disabled)
        refuses every park; ``None`` (unbounded) accepts every park."""
        nbytes = int(nbytes)
        with self._lock:
            if self.spill_budget_bytes is not None:
                if self.spill_budget_bytes <= 0:
                    return False
                if self.parked_bytes + nbytes > self.spill_budget_bytes:
                    # can never fit even with every spilled layout
                    # discarded — refuse BEFORE the sweep, or an
                    # infeasible park would destroy the host tier
                    # (cold faults + re-traces) for nothing
                    return False
                # tentatively charge and let the ONE shared host-tier
                # sweep make room (it discards LRU spilled layouts and
                # honors the in-flight-refault guard); refuse if the
                # checkpoint still does not fit once victims run out
                self.parked_bytes += nbytes
                self._spill_to_budget_locked()
                total = self.parked_bytes + sum(
                    e.nbytes for e in self._versions.values()
                    if e.in_spill and not e.building)
                if total > self.spill_budget_bytes:
                    self.parked_bytes -= nbytes
                    return False
            else:
                self.parked_bytes += nbytes
            self.lane_parks += 1
            return True

    def release_parked(self, nbytes: int) -> None:
        """Un-charge a parked carry (its lane was restored, retired, or
        failed)."""
        with self._lock:
            self.parked_bytes = max(0, self.parked_bytes - int(nbytes))

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return sum(e.nbytes for e in self._versions.values()
                       if e.resident)

    @property
    def spilled_bytes(self) -> int:
        with self._lock:
            return sum(e.nbytes for e in self._versions.values()
                       if e.in_spill)

    # snapshot() keys that are MONOTONE event counts (Prometheus
    # counters); everything else in the snapshot is a point-in-time
    # level (gauge). The metrics registry classifies the feed with this.
    METRIC_COUNTER_KEYS = frozenset({
        "publishes", "evictions", "spills", "discards", "faults",
        "budget_overcommits", "lane_parks",
    })

    def metrics_feed(self) -> "tuple[Dict[str, float], Dict[str, float]]":
        """``(counters, gauges)`` split of :meth:`snapshot` for the
        metrics registry (``refault_upload_ms`` is cumulative wall and
        counts as a counter too)."""
        snap = self.snapshot()
        counter_keys = self.METRIC_COUNTER_KEYS | {"refault_upload_ms"}
        counters = {k: float(snap[k]) for k in counter_keys}
        gauges = {k: float(v) for k, v in snap.items()
                  if k not in counter_keys}
        return counters, gauges

    def snapshot(self) -> Dict[str, float]:
        """Store counters for the service stats endpoint."""
        with self._lock:
            resident = [e for e in self._versions.values() if e.resident]
            spilled = [e for e in self._versions.values() if e.in_spill]
            return {
                "graphs": len(self._latest),
                "versions": len(self._versions),
                "resident_graphs": len(resident),
                "resident_bytes": float(sum(e.nbytes for e in resident)),
                "spilled_graphs": len(spilled),
                "spilled_bytes": float(sum(e.nbytes for e in spilled)),
                "pinned_graphs": sum(1 for e in resident if e.pins > 0),
                "budget_bytes": (float(self.budget_bytes)
                                 if self.budget_bytes is not None else -1.0),
                "spill_budget_bytes": (
                    float(self.spill_budget_bytes)
                    if self.spill_budget_bytes is not None else -1.0),
                "publishes": self.publishes,
                "evictions": self.evictions,
                "spills": self.spills,
                "discards": self.discards,
                "faults": self.faults,
                "budget_overcommits": self.budget_overcommits,
                "refault_upload_ms": float(self.refault_upload_ms),
                "parked_bytes": float(self.parked_bytes),
                "lane_parks": self.lane_parks,
            }

    def describe(self) -> List[Dict[str, object]]:
        with self._lock:
            return [{
                "graph_id": e.graph_id, "version": e.version,
                "resident": e.resident, "spilled": e.in_spill,
                "pins": e.pins,
                "superseded": e.superseded, "nbytes": e.nbytes,
                "num_shards": e.num_shards, "method": e.method,
            } for e in self._versions.values()]

    # ---------------- materialization (out of lock) --------------------
    def _fire_pending_spills(self) -> None:
        """Fire spill listeners recorded by a budget sweep, with the
        registry lock released — the plan cache's offload is a
        device→host transfer that must not stall other tenants. Each
        entry is re-checked under the lock first: one that was refaulted
        (or started refaulting) since its sweep is skipped, so a late
        offload can never clobber an in-flight promotion."""
        while True:
            with self._lock:
                if not self._pending_spills:
                    return
                graph_id, version = self._pending_spills.pop(0)
                entry = self._versions.get((graph_id, version))
                if entry is None or not entry.in_spill or entry.building:
                    continue
            for fn in self._spill_listeners:
                fn(graph_id, version)

    def _ensure_resident(self, graph_id: str, version: Optional[int], *,
                         fault: bool, pin: bool) -> Optional[GraphLease]:
        try:
            return self._ensure_resident_inner(graph_id, version,
                                               fault=fault, pin=pin)
        finally:
            # budget sweeps inside (fast path and install block) may
            # have queued spills; offload them with the lock released
            self._fire_pending_spills()

    def _ensure_resident_inner(self, graph_id: str, version: Optional[int],
                               *, fault: bool, pin: bool
                               ) -> Optional[GraphLease]:
        """Make (graph_id, version) device-resident, materializing with
        the store lock **released**; returns a lease when ``pin``.

        The in-progress protocol: the first thread to find the entry
        non-resident claims ``entry.building`` and builds unlocked;
        concurrent faulters of the SAME entry wait on the entry's
        condition variable (which releases the registry lock, so every
        other entry's store operations proceed meanwhile) and share the
        one materialization. ``pin=False`` callers (publish) skip
        quietly when the entry was superseded or removed underneath
        them."""
        with self._lock:
            while True:
                try:
                    entry = self._entry(graph_id, version)
                except KeyError:
                    if pin:
                        raise
                    return None
                if entry.graph is None:     # retired tombstone
                    if pin:
                        raise StoreError(
                            f"graph {graph_id!r} v{entry.version} was "
                            "superseded and has drained; only the latest "
                            "version can be acquired")
                    return None
                if entry.resident:
                    if not pin:
                        return None
                    entry.pins += 1
                    self._touch_locked(entry)
                    self._evict_to_budget_locked()
                    return GraphLease(self, entry.graph_id, entry.version,
                                      entry.pg)
                if entry.superseded:
                    # not resident + retirement pending: re-materializing
                    # it would hand new work a version that can never be
                    # latest again (the "only the latest version can be
                    # acquired" contract, enforced before the drain
                    # completes, not just after)
                    if pin:
                        raise StoreError(
                            f"graph {graph_id!r} v{entry.version} is "
                            "superseded and no longer resident; its "
                            "retirement is pending the in-flight drain — "
                            "acquire the latest version instead")
                    return None
                if not entry.building:
                    entry.building = True
                    break
                entry.cond.wait()   # entry-scoped; registry lock released
            # snapshot everything the unlocked build needs
            graph = entry.graph
            num_shards, method, pad_multiple = entry.spec()
            part_of = entry.part_of
            spilled = entry.spilled
            was_resident = entry.ever_resident

        # ---- build with the registry unlocked -------------------------
        t0 = time.perf_counter()
        pg = None
        err: Optional[BaseException] = None
        try:
            if spilled is not None:
                # host-tier hit: the layout arrays survive verbatim; the
                # expensive part is the engines' device re-upload, which
                # the refault listeners perform below
                pg = spilled
            else:
                # cold fault / first materialization: reuse the pinned
                # part_of assignment, so a faulted-back layout is
                # array-for-array identical to the original
                # (partitioners are deterministic anyway; this also
                # skips their O(V)/O(E) host work on the fault path)
                pg = partition_graph(graph, num_shards, method=method,
                                     pad_multiple=pad_multiple,
                                     part_of=part_of)
            if fault and was_resident:
                for fn in self._refault_listeners:
                    fn(graph_id, entry.version)
        except BaseException as exc:    # noqa: BLE001 — report to waiters
            err = exc
        wall_ms = (time.perf_counter() - t0) * 1e3

        with self._lock:
            entry.building = False
            entry.cond.notify_all()     # waiters re-check residency
            if err is not None:
                raise err
            if (self._versions.get((graph_id, entry.version)) is not entry
                    or entry.graph is None):
                # removed — or superseded AND retired (a publish landed
                # while we built and the entry had no pins) — during the
                # unlocked build. Installing pg would resurrect the
                # tombstone and hand out a lease on a version that can
                # never be latest again; drop the build instead.
                if pin:
                    raise StoreError(
                        f"graph {graph_id!r} v{entry.version} was removed "
                        "or superseded while its fault was materializing; "
                        "acquire the latest version instead")
                return None
            entry.pg = pg
            entry.spilled = None
            if entry.part_of is None:
                entry.part_of = pg.part_of
            # charge: true engine-tier bytes once any engine reported
            # them (note_engine_bytes), the layout proxy until then
            entry.layout_nbytes = pg.device_nbytes
            entry.nbytes = entry.engine_bytes or entry.layout_nbytes
            # a fresh layout is by definition the most recently used —
            # without this touch its last_used of 0 would make it the LRU
            # victim of the very budget sweep its own fault triggers
            self._touch_locked(entry)
            if fault and entry.ever_resident:
                self.faults += 1
                if spilled is not None:
                    self.refault_upload_ms += wall_ms
                self._emit("refault", graph_id=graph_id,
                           version=entry.version, dur_s=wall_ms / 1e3,
                           cold=spilled is None)
            entry.ever_resident = True
            lease = None
            if pin:
                entry.pins += 1
                lease = GraphLease(self, entry.graph_id, entry.version,
                                   entry.pg)
            self._evict_to_budget_locked()
            return lease

    # ---------------- internals (lock held) ----------------------------
    def _touch_locked(self, entry: _Version) -> None:
        self._clock += 1
        entry.last_used = self._clock

    def _evict_locked(self, entry: _Version, *, count: bool = True,
                      spill: Optional[bool] = None) -> None:
        """Drop device residency: demote to the host-spill tier when
        enabled (superseded versions skip it — they are retiring), else
        discard."""
        if spill is None:
            spill = self._spill_enabled and not entry.superseded
        pg = entry.pg
        entry.pg = None
        if count:
            self.evictions += 1
        if spill and pg is not None:
            entry.spilled = pg
            self.spills += 1
            self._emit("spill", graph_id=entry.graph_id,
                       version=entry.version, nbytes=entry.nbytes)
            # listeners fire AFTER the lock is released (the offload is
            # a device->host transfer; see _fire_pending_spills)
            self._pending_spills.append((entry.graph_id, entry.version))
            self._spill_to_budget_locked()
        else:
            self._discard_locked(entry, count=count)

    def _discard_locked(self, entry: _Version, *, count: bool = True) -> None:
        """Drop the host-spill copy too; the version's compiled plans go
        with it (evict listeners)."""
        entry.spilled = None
        if count:
            self.discards += 1
        self._emit("evict", graph_id=entry.graph_id,
                   version=entry.version)
        # evict listeners intentionally fire under the store lock: they
        # only invalidate plan/result caches keyed by (graph, version)
        # and must observe the same atomic snapshot as the discard
        # itself (registering docs require lock-aware, non-blocking fns)
        for fn in self._evict_listeners:
            fn(entry.graph_id, entry.version)  # analysis: allow(LCK004)

    def _retire_superseded_locked(self, entry: _Version) -> None:
        """A drained superseded version: discard its layout (both tiers)
        AND drop the host-side Graph / partition assignment. A
        long-running service that republishes a tenant's graph for
        months must not retain every predecessor's E-sized edge arrays;
        the metadata tombstone stays for describe()/snapshot()
        introspection."""
        if entry.resident:
            self._evict_locked(entry, spill=False)
        elif entry.in_spill:
            self._discard_locked(entry)
        entry.graph = None
        entry.part_of = None

    def _evict_to_budget_locked(self) -> None:
        if self.budget_bytes is None:
            return
        while True:
            resident = [e for e in self._versions.values() if e.resident]
            total = sum(e.nbytes for e in resident)
            if total <= self.budget_bytes:
                return
            victims = [e for e in resident if e.pins == 0]
            if not victims:
                # everything over budget is serving in-flight queries —
                # overcommit rather than corrupt; the next release
                # re-runs this sweep
                self.budget_overcommits += 1
                return
            self._evict_locked(min(victims, key=lambda e: e.last_used))

    def _spill_to_budget_locked(self) -> None:
        if self.spill_budget_bytes is None:
            return
        while True:
            spilled = [e for e in self._versions.values()
                       if e.in_spill and not e.building]
            # parked lane carries share the host tier's budget
            if (sum(e.nbytes for e in spilled) + self.parked_bytes
                    <= self.spill_budget_bytes or not spilled):
                return
            # host-tier overflow degrades to the pre-spill behavior:
            # discard the LRU spilled layout (its next fault is cold)
            self._discard_locked(min(spilled, key=lambda e: e.last_used))
