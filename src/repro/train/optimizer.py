"""AdamW with warmup-cosine schedule, global-norm clipping.

Written from scratch (no optax in this environment). Optimizer moments are
fp32 and inherit the FSDP sharding of their parameters (ZeRO-3-equivalent:
each device holds only its param shard's m/v). Params may be bf16; the
update is computed in fp32 and cast back.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "clip_by_global_norm",
           "warmup_cosine"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    lr_min: float = 3e-5
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def warmup_cosine(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(1, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(
        jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(m=zeros,
                      v=jax.tree.map(jnp.copy, zeros),
                      count=jnp.zeros((), jnp.int32))


def clip_by_global_norm(grads, max_norm: float):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig, step):
    lr = warmup_cosine(cfg, step)
    c = state.count + 1
    cf = c.astype(jnp.float32)
    b1c = 1.0 - cfg.b1 ** cf
    b2c = 1.0 - cfg.b2 ** cf

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m2 / b1c
        vhat = v2 / b2c
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = pf - lr * (step_ + wd * pf)
        return new_p.astype(p.dtype), m2, v2

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(m=new_m, v=new_v, count=c)
