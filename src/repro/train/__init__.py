"""Training substrate: optimizer, loop, checkpointing, compression."""
from . import checkpoint, compress, loop, optimizer  # noqa: F401
