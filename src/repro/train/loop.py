"""Training loop: jitted train_step builder + fault-tolerant driver.

``make_train_step(cfg, mesh)`` builds the family-appropriate loss/step;
``Trainer`` wires data, checkpointing (async, atomic), resume, and
restart-after-failure. Synchronous SPMD has no intra-step stragglers; the
cross-step mitigation is the checkpoint cadence + deterministic data (see
data/pipeline.py) + elastic resume (checkpoints restore onto any mesh).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import sharding as SH
from ..data.pipeline import DataConfig, SyntheticTokens
from ..models import encdec as ED
from ..models import layers as L
from ..models import lm as LM
from . import checkpoint as CKPT
from .optimizer import (AdamWConfig, AdamWState, adamw_init, adamw_update,
                        clip_by_global_norm)

__all__ = ["make_forward", "make_train_step", "Trainer", "TrainConfig"]


def cross_entropy(logits, labels, mask=None):
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def make_forward(cfg: LM.ArchCfg, mesh=None) -> Callable:
    """batch dict -> logits, per family."""
    if cfg.family == "encdec":
        def fwd(params, batch):
            return ED.encdec_forward(params, batch["frames"],
                                     batch["tokens"], cfg, mesh=mesh)
        return fwd
    if cfg.family == "vlm":
        def fwd(params, batch):
            return LM.lm_forward(params, batch["tokens"], cfg, mesh=mesh,
                                 prefix_embeds=batch["patch_embeds"])
        return fwd

    def fwd(params, batch):
        return LM.lm_forward(params, batch["tokens"], cfg, mesh=mesh)
    return fwd


def make_loss(cfg: LM.ArchCfg, mesh=None) -> Callable:
    fwd = make_forward(cfg, mesh)

    def loss_fn(params, batch):
        logits = fwd(params, batch)
        labels = batch["labels"]
        if cfg.family == "vlm":
            # prefix positions carry no LM loss
            logits = logits[:, cfg.prefix_len:, :]
        return cross_entropy(logits, labels)
    return loss_fn


def make_train_step(cfg: LM.ArchCfg, opt_cfg: AdamWConfig, mesh=None,
                    *, microbatch: Optional[int] = None,
                    accum_dtype=jnp.float32) -> Callable:
    """(params, opt_state, batch, step) -> (params, opt_state, metrics).

    ``microbatch``: optional gradient-accumulation factor (splits the batch
    along axis 0 into chunks scanned sequentially — activation memory
    divides by the factor at identical math)."""
    loss_fn = make_loss(cfg, mesh)
    if getattr(cfg, "accum_bf16", False):
        accum_dtype = jnp.bfloat16

    def step_fn(params, opt_state, batch, step):
        if microbatch and microbatch > 1:
            # reshape (B, ...) -> (mb, B/mb, ...) and scan over axis 0.
            # NEVER dynamic-slice the sharded batch axis with a traced
            # index — SPMD would all-gather the whole batch per chunk.
            def to_chunks(a):
                a = a.reshape((microbatch, a.shape[0] // microbatch)
                              + a.shape[1:])
                if mesh is not None:
                    from .. import sharding as SHs
                    spec = SHs.logical_to_spec(
                        mesh, (None, "batch") + (None,) * (a.ndim - 2),
                        a.shape)
                    a = jax.lax.with_sharding_constraint(
                        a, jax.sharding.NamedSharding(mesh, spec))
                return a

            chunks = jax.tree.map(to_chunks, batch)

            def acc_body(carry, mb_batch):
                loss_sum, grad_sum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb_batch)
                return (loss_sum + l,
                        jax.tree.map(
                            lambda a, b: a + b.astype(accum_dtype),
                            grad_sum, g)), ()

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.float32(0.0), zero), chunks,
                unroll=microbatch if getattr(cfg, "scan_unroll", False)
                else 1)
            loss = loss / microbatch
            grads = jax.tree.map(lambda g: g / microbatch, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.clip_norm)
        params, opt_state = adamw_update(grads, opt_state, params, opt_cfg,
                                         step)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return step_fn


# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainConfig:
    steps: int = 200
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    microbatch: Optional[int] = None
    seed: int = 0


class Trainer:
    """Restartable trainer. Construction is cheap; ``run`` resumes from the
    latest complete checkpoint automatically (fault tolerance: kill the
    process at any point and call run() again)."""

    def __init__(self, cfg: LM.ArchCfg, data_cfg: DataConfig,
                 opt_cfg: AdamWConfig, tc: TrainConfig, mesh=None):
        self.cfg, self.data_cfg, self.opt_cfg, self.tc = (
            cfg, data_cfg, opt_cfg, tc)
        self.mesh = mesh
        if cfg.family == "encdec":
            self.spec = ED.encdec_spec(cfg, cfg.n_enc, cfg.n_dec)
        else:
            self.spec = LM.lm_spec(cfg)
        self.data = SyntheticTokens(data_cfg)
        self._step_fn = jax.jit(
            make_train_step(cfg, opt_cfg, mesh, microbatch=tc.microbatch),
            donate_argnums=(0, 1))
        self.ckpt = (CKPT.Checkpointer(tc.ckpt_dir)
                     if tc.ckpt_dir else None)

    def _init_state(self):
        params = L.init_params(jax.random.PRNGKey(self.tc.seed), self.spec)
        return params, adamw_init(params)

    def _make_batch(self, step: int) -> Dict[str, Any]:
        b = self.data.batch(step)
        cfg = self.cfg
        if cfg.family == "vlm":
            n = b["tokens"].shape[0]
            rng = np.random.default_rng([step, 7])
            b["patch_embeds"] = rng.standard_normal(
                (n, cfg.prefix_len, cfg.d_model), dtype=np.float32
            ).astype(jnp.bfloat16)
        if cfg.family == "encdec":
            n = b["tokens"].shape[0]
            rng = np.random.default_rng([step, 11])
            enc_len = min(self.data_cfg.seq_len, 64)
            b["frames"] = rng.standard_normal(
                (n, enc_len, cfg.d_model), dtype=np.float32
            ).astype(jnp.bfloat16)
        return b

    def run(self, *, fail_at_step: Optional[int] = None) -> Dict[str, Any]:
        """Train to tc.steps, resuming from the latest checkpoint.
        ``fail_at_step`` injects a crash (for fault-tolerance tests)."""
        params, opt_state = self._init_state()
        start = 0
        if self.ckpt:
            restored, meta = CKPT.restore_latest(
                self.tc.ckpt_dir, {"params": params, "opt": opt_state})
            if restored is not None:
                # device_put (donation requires jax.Array, not numpy)
                params = jax.tree.map(jnp.asarray, restored["params"])
                opt_state = jax.tree.map(jnp.asarray, restored["opt"])
                start = int(meta["step"]) + 1
        losses = []
        t0 = time.time()
        for step in range(start, self.tc.steps):
            if fail_at_step is not None and step == fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            batch = self._make_batch(step)
            params, opt_state, metrics = self._step_fn(
                params, opt_state, batch, jnp.int32(step))
            if step % self.tc.log_every == 0 or step == self.tc.steps - 1:
                losses.append((step, float(metrics["loss"])))
            if self.ckpt and (step % self.tc.ckpt_every == 0
                              or step == self.tc.steps - 1):
                self.ckpt.save_async(
                    step, {"params": params, "opt": opt_state},
                    extra={"arch": self.cfg.name})
        if self.ckpt:
            self.ckpt.wait()
        return {"losses": losses, "params": params,
                "seconds": time.time() - t0, "final_step": self.tc.steps - 1}
