"""Fault-tolerant checkpointing.

Design (scales to multi-host; degenerates cleanly to this 1-process box):
  * one ``.npz`` per process holding that process's addressable shards,
    keys are flattened pytree paths + global shapes (resume-with-reshard:
    a checkpoint saved on one mesh restores onto any other mesh — shards
    are re-cut by ``device_put`` with the new sharding),
  * two-phase commit: write to ``step_XXXX.tmp/``, fsync, atomic rename to
    ``step_XXXX/`` and update a ``LATEST`` pointer file last — a crash
    mid-write never corrupts the restore point,
  * async double-buffered saves: device_get happens synchronously (cheap,
    sharded), file IO runs on a background thread; at most one in flight,
  * ``restore_latest`` walks backwards past incomplete directories.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "restore_latest", "Checkpointer"]

_SEP = "//"
_DT = "@@"  # dtype tag for numpy-unrepresentable dtypes (bfloat16 etc.)


def _encode(arr: np.ndarray):
    """np.savez can't store ml_dtypes (bfloat16) — view as uint16/uint8
    and tag the key with the real dtype."""
    if arr.dtype.kind == "V" or "bfloat16" in arr.dtype.name:
        return arr.view(np.uint16), "bfloat16"
    if "float8" in arr.dtype.name:
        return arr.view(np.uint8), arr.dtype.name
    return arr, None


def _decode(arr: np.ndarray, dtype_name: str):
    if dtype_name is None:
        return arr
    import ml_dtypes
    return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr, tag = _encode(np.asarray(leaf))
        flat[key + (_DT + tag if tag else "")] = arr
    return flat


def save(directory: str, step: int, tree, *, extra: Optional[dict] = None):
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    proc = jax.process_index()
    np.savez(os.path.join(tmp, f"shard_{proc:05d}.npz"), **flat)
    meta = {"step": step, "num_processes": jax.process_count(),
            "keys": sorted(flat), **(extra or {})}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.rename(os.path.join(directory, "LATEST.tmp"),
              os.path.join(directory, "LATEST"))
    return final


def _unflatten_into(template, flat: Dict[str, np.ndarray],
                    shardings=None):
    # strip dtype tags into a lookup
    decoded = {}
    for k, v in flat.items():
        if _DT in k:
            base, tag = k.split(_DT, 1)
            decoded[base] = _decode(v, tag)
        else:
            decoded[k] = v
    paths = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else None)
    for i, (path, leaf) in enumerate(paths[0]):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = decoded[key]
        if shard_leaves is not None:
            arr = jax.device_put(arr, shard_leaves[i])
        leaves.append(arr)
    return jax.tree.unflatten(paths[1], leaves)


def restore(path: str, template, *, shardings=None) -> Tuple[Any, dict]:
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    flat: Dict[str, np.ndarray] = {}
    for fn in sorted(os.listdir(path)):
        if fn.startswith("shard_") and fn.endswith(".npz"):
            with np.load(os.path.join(path, fn)) as z:
                for k in z.files:
                    flat[k] = z[k]
    return _unflatten_into(template, flat, shardings), meta


def restore_latest(directory: str, template, *, shardings=None):
    """Walk back past incomplete checkpoints. Returns (tree, meta) or
    (None, None) if nothing restorable."""
    if not os.path.isdir(directory):
        return None, None
    candidates = sorted(
        (d for d in os.listdir(directory)
         if d.startswith("step_") and not d.endswith(".tmp")),
        reverse=True)
    latest = os.path.join(directory, "LATEST")
    if os.path.exists(latest):
        with open(latest) as f:
            pointed = f.read().strip()
        if pointed in candidates:
            candidates.remove(pointed)
            candidates.insert(0, pointed)
    for name in candidates:
        path = os.path.join(directory, name)
        if not os.path.exists(os.path.join(path, "meta.json")):
            continue  # incomplete — crashed mid-write
        try:
            return restore(path, template, shardings=shardings)
        except Exception:
            continue
    return None, None


class Checkpointer:
    """Async double-buffered checkpoint writer with retention."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save_async(self, step: int, tree, *, extra: Optional[dict] = None):
        host_tree = jax.tree.map(np.asarray, tree)  # sync device_get
        self.wait()

        def work():
            save(self.directory, step, host_tree, extra=extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            (d for d in os.listdir(self.directory)
             if d.startswith("step_") and not d.endswith(".tmp")))
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, d),
                          ignore_errors=True)
