"""Gradient compression for the slowest link tier (cross-pod DCI).

The paper's central move is shrinking what crosses the slowest network by
exchanging the compact dual (updates) instead of the expanded stream
(messages). The DP analogue: pods exchange int8 block-scaled gradients
instead of f32/bf16 — 4x/2x fewer wire bytes on the pod axis, where
bandwidth is scarcest.

``allreduce_int8(x, axis)`` is used inside shard_map over the "pod" axis:
per-block absmax scales (f32, one per 256 values) + int8 payload are
all_gathered, dequantized, and summed. Stochastic rounding keeps the
quantizer unbiased (E[q] = x), which is what makes SGD tolerate it.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "allreduce_int8",
           "wire_bytes"]

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    flat = jnp.pad(x.reshape(-1), (0, pad))
    return flat.reshape(-1, BLOCK), n


def quantize_int8(x, key) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """x: any-shape f32/bf16 -> (int8 blocks, f32 scales, orig_size).
    Stochastic rounding: unbiased."""
    blocks, n = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    y = blocks / scale
    noise = jax.random.uniform(key, y.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale[:, 0], n


def dequantize_int8(q, scale, n, shape, dtype):
    x = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return x.reshape(shape).astype(dtype)


def allreduce_int8(x, axis: str, key):
    """Unbiased int8 all-reduce over a mesh axis (use inside shard_map).
    Wire bytes per element: 1 (payload) + 4/BLOCK (scales) vs 4 for f32."""
    q, scale, n = quantize_int8(x, key)
    q_all = jax.lax.all_gather(q, axis)          # (P, nblk, BLOCK) int8
    s_all = jax.lax.all_gather(scale, axis)      # (P, nblk) f32
    deq = q_all.astype(jnp.float32) * s_all[..., None]
    total = jnp.sum(deq, axis=0).reshape(-1)[:n]
    return total.reshape(x.shape).astype(x.dtype)


def wire_bytes(num_elements: int, dtype_bytes: int = 4) -> dict:
    """Analytic wire cost per element for EXPERIMENTS.md."""
    blocks = -(-num_elements // BLOCK)
    return {
        "f32_psum": num_elements * dtype_bytes,
        "int8_allgather": num_elements + blocks * 4,
        "ratio": (num_elements * dtype_bytes)
                 / (num_elements + blocks * 4),
    }
