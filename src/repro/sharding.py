"""Mesh-aware sharding rules (FSDP x TP x optional pod DP).

Single source of truth for how every tensor class is laid out on the
production meshes:

  (16, 16)    ("data", "model")           — one pod, 256 chips
  (2, 16, 16) ("pod", "data", "model")    — two pods, 512 chips

Rules:
  * batch/tokens  : ("pod", "data")  (pod axis joins data parallelism)
  * params        : FSDP over ("pod","data") on the largest divisible dim
                    x TP over "model" on the contraction/feature dim
  * attention     : query/kv heads over "model" when divisible, else the
                    KV sequence axis (flash-decoding style) for decode
  * MoE experts   : over "model" (expert parallelism)
  * vocab/embed   : vocab over "model"
  * graph engine  : shard axis over every mesh axis flattened
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = [
    "batch_axes", "fsdp_axes", "model_axis", "spec", "shard",
    "logical_to_spec", "param_sharding_rules",
]


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return batch_axes(mesh)


def model_axis(mesh: Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def axis_size(mesh: Mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    s = 1
    for n in names:
        s *= mesh.shape[n]
    return s


def spec(mesh: Mesh, *axes) -> NamedSharding:
    return NamedSharding(mesh, P(*axes))


def shard(mesh: Mesh, x, *axes):
    return jax.device_put(x, spec(mesh, *axes))


# ---------------------------------------------------------------------------
# Logical axis names -> PartitionSpec. Model code annotates params with
# logical axes; this table maps them onto the physical mesh.
# ---------------------------------------------------------------------------

def logical_to_spec(mesh: Mesh, logical: Sequence[Optional[str]],
                    shape: Sequence[int]) -> P:
    """Map logical axis names to mesh axes, dropping assignments that do
    not divide the dimension (padding-free rule)."""
    b = batch_axes(mesh)
    m = model_axis(mesh)
    table = {
        None: None,
        "batch": b if b else None,
        "fsdp": b if b else None,          # FSDP shards dim over data(+pod)
        "model": m,
        "expert": m,
        "vocab": m,
        "seq": None,
        "kv_seq_model": m,                 # decode flash-split
        "kv_seq_pdm": tuple(list(b) + ([m] if m else [])) or None,
        "seq_model": m,                    # sequence parallelism
        "heads": m,
        "stack": None,                     # scan-stacked layer dim
    }
    out = []
    for ax_logical, dim in zip(logical, shape):
        phys = table.get(ax_logical, None)
        if phys is None:
            out.append(None)
            continue
        sz = axis_size(mesh, phys)
        if dim % sz != 0:
            out.append(None)  # not divisible: replicate rather than pad
        else:
            out.append(phys)
    return P(*out)


def parse_axes(s: str):
    """'fsdp,model' -> ("fsdp", "model"); '.' entries mean replicated."""
    return tuple(None if a in (".", "") else a for a in s.split(","))


def param_sharding_rules(mesh: Mesh, abstract_params, logical_axes):
    """abstract_params: pytree of ShapeDtypeStruct; logical_axes: matching
    pytree of comma-joined logical-axis STRINGS (string = leaf, so the two
    trees share a structure). Returns pytree of NamedSharding."""
    def one(a, names):
        ax = parse_axes(names)
        assert len(ax) == len(a.shape), (names, a.shape)
        return NamedSharding(mesh, logical_to_spec(mesh, ax, a.shape))
    return jax.tree.map(one, abstract_params, logical_axes)
