"""The paper's §5 analytical performance model.

T_sys = min(L_PE, L_mem, L_if, L_net)            (eq. 9)

with
  L_PE  = n_nodes * n_pe * f_clk / CPE           (eq. 1)
  L_mem = n_nodes * BW_mem / m_edge              (eq. 2, + §5.4 access-
          granularity refinement)
  L_if  = BW_if/(2 m_update) * n/(n-1) * |E|/|V| (eq. 3, GraVF-M)
        = BW_if/(2 m_message) * n^2/(n-1)        (eq. 4, GraVF)
  L_net = BW_net/((n-1) m_update) * |E|/|V|      (eq. 6, GraVF-M)
        = BW_net * n/((n-1) m_message)           (eq. 7, GraVF)

speedup(GraVF-M / GraVF) = |E|/|V| * 1/n * m_update/m_message   (eq. 5/8)

Two platform profiles ship with the model:
  * ``PAPER_PLATFORM`` — the 4x Micron AC-510 (KU060 + HMC, PCIe backplane)
    system of §6.1, with the experimentally measured constants (Table 2).
    Used to validate the model against the paper's own published numbers.
  * ``TPU_V5E`` — the adaptation target: one chip plays one "FPGA"
    (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI). The PE-throughput
    limit is re-derived from the VPU/MXU cost of the Pallas edge kernel
    instead of a hardware pipeline CPE (see kernels/edge_gather.py):
    the mask-expansion kernel does ~4 VPU lane-ops per (row, edge) pair,
    so CPE ~= tile_r * 4 / (8*128) cycles/edge at f_clk ~= 0.94 GHz.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

__all__ = [
    "Platform", "AlgoProfile", "Workload", "limits", "speedup_eq5",
    "optimize", "PAPER_PLATFORM", "TPU_V5E", "PAPER_ALGOS", "tpu_algo",
    "words_per_superstep", "traffic_reduction", "EXCHANGES",
    "PHASE_TERMS", "phase_projection", "overlapped_limits",
    "overlapped_projection",
]

GiB = 1024.0 ** 3


@dataclasses.dataclass(frozen=True)
class Platform:
    name: str
    f_clk: float          # Hz
    n_pe_max: int         # PEs per node the fabric fits
    bw_mem: float         # bytes/s per node (edge storage interface)
    bw_if: float          # bytes/s per node network interface (send+recv)
    bw_network: float     # bytes/s total network
    m_board: float        # bytes memory per node
    m_memword: int        # bytes per memory access word (§5.4 granularity)
    n_nodes_max: int = 4


@dataclasses.dataclass(frozen=True)
class AlgoProfile:
    name: str
    cpe: float            # cycles per edge (paper §5.3, measured §6.1)
    m_vertex: int         # bytes of vertex state
    m_update: int         # bytes per update (incl. id/routing overhead)
    m_message: int        # bytes per message
    m_edge: int           # bytes per stored edge


@dataclasses.dataclass(frozen=True)
class Workload:
    num_vertices: int
    num_edges: int

    @property
    def avg_degree(self) -> float:
        return self.num_edges / max(1, self.num_vertices)


# --- §6.1 evaluation platform: 4x AC-510 (KU060 + 4GB HMC), EX-750 PCIe --
PAPER_PLATFORM = Platform(
    name="4xAC-510 (paper §6.1)",
    f_clk=187.5e6,
    n_pe_max=9,
    bw_mem=21.7 * GiB,          # GUPS-measured peak HMC bandwidth
    bw_if=11.7 * GiB,           # Table 2 (send+recv; 5.85 GiB/s each way)
    bw_network=23.4 * GiB,      # lower bound — never limiting (§6.1)
    m_board=4 * GiB,
    m_memword=16,               # HMC 128-bit access granularity
    n_nodes_max=4,
)

# Paper §6.1: measured CPE per algorithm; §3 layouts give the data sizes
# (updates/messages carry a 32-bit vertex id + payload on the wire).
PAPER_ALGOS = {
    "wcc": AlgoProfile("wcc", cpe=1.05, m_vertex=5, m_update=8, m_message=8,
                       m_edge=8),
    "bfs": AlgoProfile("bfs", cpe=1.10, m_vertex=5, m_update=8, m_message=8,
                       m_edge=8),
    "pagerank": AlgoProfile("pagerank", cpe=1.42, m_vertex=8, m_update=8,
                            m_message=8, m_edge=8),
}


# --- Adaptation target: TPU v5e ----------------------------------------
TPU_V5E = Platform(
    name="TPU v5e pod",
    f_clk=0.94e9,                # core clock
    n_pe_max=8 * 128,            # VPU lanes play the PE role
    bw_mem=819e9,                # HBM bytes/s per chip
    bw_if=4 * 50e9,              # 4 ICI links/chip x ~50 GB/s
    bw_network=256 * 2 * 50e9,   # bisection-ish for a 16x16 torus pod
    m_board=16e9,                # HBM capacity per chip
    m_memword=512,               # VMEM tile granularity (§5.4 analogue)
    n_nodes_max=512,
)


def tpu_algo(name: str, *, tile_r: int = 256, ops_per_pair: float = 4.0,
             mxu: bool = False, m_update: int = 8, m_message: int = 8,
             m_vertex: int = 8, m_edge: int = 20) -> AlgoProfile:
    """Derive a CPE for the Pallas edge kernel on TPU.

    VPU path: each edge is tested against tile_r rows; ~ops_per_pair lane
    ops each; 8x128 lanes/cycle -> CPE = tile_r*ops_per_pair/1024.
    MXU path (one-hot matmul, add-semiring): 128x128 MACs/cycle/pass ->
    CPE = tile_r/ (128*128/128) ... effectively tile_r/128 per 128-edge
    group = tile_r/128/128 cycles/edge.
    ``m_edge`` counts the per-lane static stream (slot, w, gid, outdeg,
    rel) the kernel pulls through VMEM.
    """
    if mxu:
        cpe = tile_r / (128.0 * 128.0)
    else:
        cpe = tile_r * ops_per_pair / (8.0 * 128.0)
    return AlgoProfile(name=name, cpe=cpe, m_vertex=m_vertex,
                       m_update=m_update, m_message=m_message, m_edge=m_edge)


# --- Exchange-schedule traffic model (degree-factor compression) --------
EXCHANGES = ("allgather", "ring", "frontier", "unicast", "combined")


def words_per_superstep(exchange: str, wl: Workload, n_nodes: int, *,
                        v_max: Optional[float] = None,
                        e_pair_max: Optional[float] = None,
                        remote_dst_max: Optional[float] = None,
                        frontier_cap: Optional[float] = None,
                        ) -> Dict[str, float]:
    """Wire words one superstep moves under each exchange schedule.

    Per-shard words (each of the ``P`` shards sends this much):

      allgather/ring:  v_max * (P-1)            — whole vertex window, P-1x
      frontier:        2 * cap * (P-1)          — (id, payload) per slot
      unicast:         e_pair_max * (P-1)       — one payload per cut edge
      combined:        min(2*r, e_pair_max) * (P-1)
                                                — (id, payload) per DISTINCT
                                                  remote destination vertex

    where ``r`` is the per-(shard, peer) distinct-destination count. The
    ``min`` clamps combined at the per-edge cost: when fewer than two
    edges share a destination, shipping per-edge blocks (ids static in the
    layout, as unicast does) is never worse, so a schedule that combines
    at source degrades to that. By default the shape parameters are the
    uniform-partition estimates v_max = ceil(V/P), e_pair_max =
    ceil(E/P^2), and r follows the occupancy (coupon-collector) estimate
    ``v*(1-(1-1/v)^e)`` — e edges thrown at v destination slots. Pass the
    exact padded layout values (``meta.v_max``, ``meta.e_pair_max``,
    ``meta.comb_max``) to reproduce the engine's measured counters
    exactly.
    """
    P = int(n_nodes)
    if P <= 1:
        return {"per_shard": 0.0, "total": 0.0}
    vm = float(v_max) if v_max is not None else float(
        math.ceil(wl.num_vertices / P))
    epm = float(e_pair_max) if e_pair_max is not None else float(
        math.ceil(wl.num_edges / (P * P)))
    if exchange in ("allgather", "ring"):
        per = vm * (P - 1)
    elif exchange == "frontier":
        cap = float(frontier_cap) if frontier_cap is not None else vm
        per = 2.0 * cap * (P - 1)
    elif exchange == "unicast":
        per = epm * (P - 1)
    elif exchange == "combined":
        if remote_dst_max is not None:
            r = float(remote_dst_max)
        else:
            v = max(vm, 1.0)
            r = v * (1.0 - (1.0 - 1.0 / v) ** epm)
        per = min(2.0 * r, epm) * (P - 1)
    else:
        raise ValueError(f"unknown exchange {exchange!r}")
    return {"per_shard": float(per), "total": float(per * P)}


def traffic_reduction(wl: Workload, n_nodes: int, **shape) -> float:
    """Degree-factor traffic reduction: unicast words / combined words.

    Saturates at ~e_pair_max/(2*remote_dst) ~= deg/(2*P) * v/r — the
    paper's combine-at-source claim that traffic drops by the average
    degree once many edges share each remote destination."""
    uni = words_per_superstep("unicast", wl, n_nodes, **shape)["total"]
    comb = words_per_superstep("combined", wl, n_nodes, **shape)["total"]
    if comb <= 0.0:
        return 1.0
    return uni / comb


# ------------------------------------------------------------------------
def limits(platform: Platform, algo: AlgoProfile, wl: Workload, *,
           n_nodes: int, n_pe: Optional[int] = None, mode: str = "gravfm",
           granularity: bool = False, exchange: Optional[str] = None,
           wire_words: Optional[float] = None,
           v_max: Optional[float] = None,
           e_pair_max: Optional[float] = None,
           remote_dst_max: Optional[float] = None,
           frontier_cap: Optional[float] = None) -> Dict[str, float]:
    """All four §5 limits (TEPS) + the binding constraint (eq. 9).

    When ``exchange`` (or a measured ``wire_words`` total per superstep)
    is given, L_if and L_net are derived from the exchange schedule's
    actual wire traffic instead of the closed-form eq. 3/6 (which assume
    the allgather/update-combining schedule): a superstep traverses |E|
    edges while moving ``w`` words per shard, so

        L_if  = BW_if * |E| / (2 * w * m_update)       (send+recv)
        L_net = BW_net * |E| / (P * w * m_update)

    This reproduces eq. 3/6 exactly for ``exchange="allgather"`` with the
    analytic v_max = |V|/P.
    """
    assert mode in ("gravf", "gravfm")
    n_pe = platform.n_pe_max if n_pe is None else n_pe
    deg = wl.avg_degree

    l_pe = n_nodes * n_pe * platform.f_clk / algo.cpe                # eq. 1

    if granularity:                                                   # §5.4
        nv_ne = wl.num_vertices / max(1, wl.num_edges)
        spread = min(1.0, nv_ne * n_pe)
        eff_edge = algo.m_edge + spread * (platform.m_memword - algo.m_edge)
        l_mem = n_nodes * platform.bw_mem / eff_edge
    else:
        l_mem = n_nodes * platform.bw_mem / algo.m_edge              # eq. 2

    if n_nodes <= 1:
        l_if = math.inf
        l_net = math.inf
    elif exchange is not None or wire_words is not None:
        if wire_words is not None:
            w_total = float(wire_words)
        else:
            w_total = words_per_superstep(
                exchange, wl, n_nodes, v_max=v_max, e_pair_max=e_pair_max,
                remote_dst_max=remote_dst_max,
                frontier_cap=frontier_cap)["total"]
        if w_total <= 0.0:
            l_if = math.inf
            l_net = math.inf
        else:
            w_shard = w_total / n_nodes
            l_if = (platform.bw_if * wl.num_edges
                    / (2 * w_shard * algo.m_update))
            l_net = (platform.bw_network * wl.num_edges
                     / (w_total * algo.m_update))
    elif mode == "gravfm":
        l_if = (platform.bw_if / (2 * algo.m_update)
                * n_nodes / (n_nodes - 1) * deg)                      # eq. 3
        l_net = (platform.bw_network / ((n_nodes - 1) * algo.m_update)
                 * deg)                                               # eq. 6
    else:
        l_if = (platform.bw_if / (2 * algo.m_message)
                * n_nodes ** 2 / (n_nodes - 1))                       # eq. 4
        l_net = (platform.bw_network * n_nodes
                 / ((n_nodes - 1) * algo.m_message))                  # eq. 7

    t_sys = min(l_pe, l_mem, l_if, l_net)                             # eq. 9
    bottleneck = min(
        (("L_PE", l_pe), ("L_mem", l_mem), ("L_if", l_if), ("L_net", l_net)),
        key=lambda kv: kv[1])[0]
    return {"L_PE": l_pe, "L_mem": l_mem, "L_if": l_if, "L_net": l_net,
            "T_sys": t_sys, "bottleneck": bottleneck}


# Which §5 limit term a measured superstep phase exercises. The phase
# profiler (core/stepper.py profiled mode) attributes superstep wall
# time into these phases; mapping each onto its model term lets the
# observability layer compare the measured split against ``limits()``
# term by term (§6's roofline methodology, per term instead of per
# T_sys). ``probe`` is pure host/dispatch overhead — no model term.
PHASE_TERMS: Dict[str, Optional[str]] = {
    "scatter": "L_mem",       # receiver-side scatter: memory traffic
    "combine": "L_PE",        # gather-combine fold: PE compute (L_node)
    "apply": "L_PE",          # vertex apply: PE compute (L_node)
    "exchange": "L_if",       # shard collective: interface/network wire
    "exchange_serial": "L_if",  # profiled overlapped steppers' serial-
                                # reference exchange (overlap accounting)
    "probe": None,            # host sync — outside the model
}


def phase_projection(lim: Dict[str, float]) -> Dict[str, Optional[float]]:
    """Per-phase TEPS ceiling from a :func:`limits` dict: the model term
    (eq. 1/2/3/6) each measured phase is bounded by, keyed like the
    profiler's ``last_phases``. ``None`` for phases the model has no
    term for (host overhead)."""
    return {phase: (float(lim[term]) if term is not None else None)
            for phase, term in PHASE_TERMS.items()}


def overlapped_limits(lim: Dict[str, float]) -> Dict[str, float]:
    """Overlapped-pipeline projection from a :func:`limits` dict.

    eq. 9's ``T_sys = min(...)`` implicitly assumes the exchange is off
    the critical path — each resource is the bottleneck only when every
    other runs concurrently. A SYNCHRONOUS schedule (collective as a
    barrier between scatter and apply) does NOT satisfy that: compute
    and wire time add per superstep, so its realistic ceiling is the
    harmonic composition

        T_serial  = 1 / (1/L_compute + 1/L_wire)

    with L_compute = min(L_PE, L_mem) and L_wire = min(L_if, L_net).
    The overlapped (window-pipelined) schedule issues the collective for
    window k+1 while window k's scatter/combine folds, hiding the
    smaller of the two costs per window:

        T_overlap = min(L_compute, L_wire) = T_sys

    — i.e. overlap is exactly what makes eq. 9 attainable. Returns
    ``{"T_serial", "T_overlap", "overlap_gain"}`` (gain = projected
    overlapped/serial speedup, >= 1; 1.0 on single-node limits where
    L_wire is infinite)."""
    l_compute = min(lim["L_PE"], lim["L_mem"])
    l_wire = min(lim["L_if"], lim["L_net"])
    if not math.isfinite(l_wire):
        return {"T_serial": l_compute, "T_overlap": l_compute,
                "overlap_gain": 1.0}
    t_serial = 1.0 / (1.0 / l_compute + 1.0 / l_wire)
    t_overlap = min(l_compute, l_wire)
    return {"T_serial": t_serial, "T_overlap": t_overlap,
            "overlap_gain": t_overlap / t_serial}


def overlapped_projection(t_compute: float,
                          t_wire: float) -> Dict[str, float]:
    """Time-domain counterpart of :func:`overlapped_limits`, for
    calibrating against PROFILED phase walls instead of model limits:
    given one superstep's measured local-compute seconds (scatter +
    combine + apply) and exchange seconds under the synchronous
    schedule, project

        serial_s     = t_compute + t_wire     (what synchronous pays)
        overlapped_s = max(t_compute, t_wire) (the pipelined floor)

    and the projected ``gain`` = serial_s/overlapped_s. The mesh
    benchmark divides its measured overlapped superstep wall by
    ``overlapped_s`` for the measured/projected roofline-efficiency
    gate (the §6 methodology applied to the overlap claim)."""
    t_compute = max(0.0, float(t_compute))
    t_wire = max(0.0, float(t_wire))
    serial = t_compute + t_wire
    over = max(t_compute, t_wire)
    return {"serial_s": serial, "overlapped_s": over,
            "gain": serial / over if over > 0 else 1.0}


def speedup_eq5(algo: AlgoProfile, wl: Workload, n_nodes: int) -> float:
    """eq. 5/8: GraVF-M over GraVF when network-limited. The §4.3 filter
    guarantees >= 1 in practice; the raw model value may be < 1."""
    return (wl.avg_degree / n_nodes) * (algo.m_update / algo.m_message)


def min_nodes_for_memory(platform: Platform, algo: AlgoProfile,
                         wl: Workload) -> int:
    """§5.2: enough boards to host vertex state + edges."""
    bytes_needed = (wl.num_vertices * algo.m_vertex
                    + wl.num_edges * algo.m_edge)
    return max(1, math.ceil(bytes_needed / platform.m_board))


def optimize(platform: Platform, algo: AlgoProfile, wl: Workload, *,
             mode: str = "gravfm") -> Dict[str, float]:
    """§5.7: pick n_nodes maximizing T_sys (L_PE/L_mem rise with n, L_if/
    L_net fall), then shrink n_pe to the throughput-preserving minimum
    (power optimization)."""
    lo = min_nodes_for_memory(platform, algo, wl)
    best = None
    for n in range(lo, platform.n_nodes_max + 1):
        lim = limits(platform, algo, wl, n_nodes=n, mode=mode)
        if best is None or lim["T_sys"] > best[1]["T_sys"]:
            best = (n, lim)
    n_nodes, lim = best
    n_pe_needed = math.ceil(
        lim["T_sys"] * algo.cpe / (n_nodes * platform.f_clk))
    n_pe = min(platform.n_pe_max, max(1, n_pe_needed))
    return {"n_nodes": n_nodes, "n_pe": n_pe, **lim}
