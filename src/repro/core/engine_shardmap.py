"""Explicit-collective (shard_map) variant of the GraVF-M engine.

The global-array engine in ``engine.py`` relies on XLA SPMD to infer the
collectives. This variant drives them explicitly, which is where the
paper's architectural ideas become *schedulable*:

  exchange="allgather"  — paper-faithful GraVF-M: one all_gather of the
      per-shard update arrays per superstep (the broadcast of §4.1), then
      receiver-side scatter+gather over the local dst-partitioned edges.

  exchange="ring"       — the floating-barrier analogue (§4.3): the
      broadcast is decomposed into P-1 ``ppermute`` hops around the mesh
      ring. Each arriving chunk is scattered/gathered IMMEDIATELY while
      the next hop is in flight, so transport overlaps compute and no
      shard waits for a full-system barrier — different shards are
      working on different "parts" of the superstep at any instant,
      exactly the paper's floating barrier invariant (all messages of a
      superstep are still folded before apply runs).

  exchange="frontier"   — beyond-paper: the §4.3 neighbor-filter idea
      taken further. Instead of the dense |V|/P update array, each shard
      compacts its ACTIVE updates into a capacity-bounded (id, payload)
      buffer; a one-scalar psum picks the smallest sufficient capacity
      bucket per superstep (lax.switch over precompiled sizes) and only
      that buffer is broadcast. Traffic tracks the live frontier the way
      BFS/WCC actually behave, not |V|.

  mode="gravf"          — baseline unicast: per-destination-shard message
      blocks exchanged with one ``all_to_all`` per superstep (Fig. 4
      left), gather at the receiver.

  exchange="combined"   — the paper's headline degree-factor trick:
      per-edge messages are segment-reduced AT THE SOURCE by
      (destination shard, destination vertex) — the Pallas windowed
      segment-combine over a dst-sorted per-pair layout — and the
      ``all_to_all`` then ships ONE (id, payload) entry per remote
      destination vertex instead of one per edge. The receiver folds the
      pre-combined partials into its accumulator with the same monoid,
      so wire words drop by roughly the average degree (perfmodel's
      ``words_per_superstep`` predicts the exact padded-layout cost).

All exchanges produce bit-identical states to ``engine.py`` (tested in a
multi-device subprocess; see tests/test_engine_shardmap.py).

Every exchange additionally has an **overlapped** (pipelined) schedule,
selected per stepper/run with ``overlap=True``: the superstep is split
into partition windows and the collective for window ``k+1`` is issued
*before* the scatter/combine of window ``k`` runs, double-buffering the
in-flight receive block inside the shard_map body (the window index is a
``lax.fori_loop`` carry, never a Python int — see analysis rule RTR005).
Concretely:

  allgather/frontier — the one-shot ``all_gather`` is decomposed into P
      ``ppermute`` hops accumulating into the same flat receive array the
      gather would have produced; each arriving chunk is placed while the
      next hop is already in flight, then ONE receiver-side consume runs
      (bit-identical by construction: the flat array equals the gathered
      one, and the reported wire words are unchanged).
  ring — the hop for chunk ``k+1`` is issued before chunk ``k``'s bucket
      consume instead of after it; consume/merge order is unchanged.
  unicast/combined — the ``all_to_all`` payload is chunked into column
      windows folded one behind the collective; per-window partials merge
      with the ring schedule's lexicographic ``merge_carry`` (exact for
      min/max combiners, the same construction the ring/unicast equality
      test already proves). Kernels with ``got_from_identity`` skip the
      activity (and sync-combined's per-slot got) streams entirely —
      activity is recovered as ``recv != identity`` — so the overlapped
      wire carries fewer collective launches than the synchronous one
      while reporting the same words (the bytes the serial schedule
      would move; stats stay comparable across schedules).

Both schedules are traced once per (width, overlap) at warm; toggling
``overlap`` per request re-traces nothing.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..kernels import ops as kops
from ..kernels import ref as kref
from .gas import GasKernel
from .partition import PartitionedGraph
from .stepper import (LaneStepperBase, StepCarry, SuperstepProgram,
                      select_lanes)

__all__ = ["ShardEngine", "ShardLaneStepper", "build_shard_data",
           "ShardData"]

AXIS = "graph"

if hasattr(jax, "shard_map"):          # jax >= 0.6 public API
    def _shard_map(f, *, mesh, in_specs, out_specs):
        # version-compat shim, invoked only from _build-time factories
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,  # analysis: allow(RTR002)
                             out_specs=out_specs, check_vma=False)
else:                                  # 0.4.x experimental API
    from jax.experimental.shard_map import shard_map as _sm_legacy

    def _shard_map(f, *, mesh, in_specs, out_specs):
        # version-compat shim, invoked only from _build-time factories
        return _sm_legacy(f, mesh=mesh, in_specs=in_specs,  # analysis: allow(RTR002)
                          out_specs=out_specs, check_rep=False)


class ShardData(NamedTuple):
    """All arrays carry a leading shard axis sharded over mesh axis
    ``graph``; inside shard_map each block is one shard's data."""
    vert_gid: jnp.ndarray       # (P, Vm)
    vert_valid: jnp.ndarray     # (P, Vm)
    out_deg: jnp.ndarray        # (P, Vm)
    flt_cnt: jnp.ndarray        # (P, Vm)
    # CSC lanes in Pallas layout (allgather/frontier paths)
    wid: jnp.ndarray            # (P, n_tiles)
    rel: jnp.ndarray            # (P, L)
    window_written: jnp.ndarray  # (P, n_windows)
    src_slot: jnp.ndarray       # (P, L) global slot = part*Vm + local
    src_gid: jnp.ndarray        # (P, L)
    src_outdeg: jnp.ndarray     # (P, L)
    w: jnp.ndarray              # (P, L)
    lane_valid: jnp.ndarray     # (P, L)
    seg: jnp.ndarray            # (P, L) local segment (dst_local; pad Vm)
    # ring buckets: in-edges grouped by SOURCE shard (transposed pair layout)
    rb_src_local: jnp.ndarray   # (P, P, E2)
    rb_src_gid: jnp.ndarray
    rb_src_outdeg: jnp.ndarray
    rb_w: jnp.ndarray
    rb_dst_local: jnp.ndarray
    rb_valid: jnp.ndarray
    # gravf unicast blocks (source-side layout)
    pair_src_local: jnp.ndarray  # (P, P, E2)
    pair_src_gid: jnp.ndarray
    pair_src_outdeg: jnp.ndarray
    pair_w: jnp.ndarray
    pair_valid: jnp.ndarray
    recv_dst_local: jnp.ndarray  # (P, P, E2)
    # combined exchange: source-side dst-sorted edge lanes (Pallas layout
    # over flat (dest shard, dst rank) segments) + the static per-(peer,
    # rank) receive ids — the wire never carries ids at runtime
    comb_wid: jnp.ndarray = None        # (P, comb_tiles)
    comb_rel: jnp.ndarray = None        # (P, CL)
    comb_written: jnp.ndarray = None    # (P, comb_windows)
    comb_src_local: jnp.ndarray = None  # (P, CL)
    comb_src_gid: jnp.ndarray = None    # (P, CL)
    comb_src_outdeg: jnp.ndarray = None  # (P, CL)
    comb_w: jnp.ndarray = None          # (P, CL)
    comb_valid: jnp.ndarray = None      # (P, CL)
    comb_seg: jnp.ndarray = None        # (P, CL) flat q*(R+1)+rank; pad Sc
    comb_recv_dst_local: jnp.ndarray = None  # (P, P, comb_max)


@dataclasses.dataclass(frozen=True)
class ShardMeta:
    P: int
    v_max: int
    e_pair_max: int
    n_tiles: int
    n_windows: int
    tile_e: int
    tile_r: int
    num_vertices: int
    frontier_capacities: tuple = ()
    comb_max: int = 0        # padded distinct remote dsts per shard pair
    comb_tiles: int = 0
    comb_windows: int = 0


def _build_shard_layouts(pg: PartitionedGraph, tile_e: int, tile_r: int):
    """Per-shard Pallas layouts padded to a common tile count (SPMD)."""
    P, Vm = pg.num_parts, pg.v_max
    S = Vm + 1
    layouts = []
    for p in range(P):
        seg = pg.in_dst_local[p].astype(np.int64)
        # sorted within shard by construction
        layouts.append(kops.build_layout(seg, S, tile_e=tile_e,
                                         tile_r=tile_r))
    n_tiles = max(l.n_tiles for l in layouts)
    n_windows = layouts[0].n_windows
    L = n_tiles * tile_e

    wid = np.zeros((P, n_tiles), np.int32)
    rel = np.full((P, L), tile_r, np.int32)
    written = np.zeros((P, n_windows), bool)
    src_slot = np.zeros((P, L), np.int32)
    src_gid = np.zeros((P, L), np.int32)
    src_outdeg = np.ones((P, L), np.int32)
    w = np.zeros((P, L), np.float32)
    lane_valid = np.zeros((P, L), bool)
    seg_l = np.full((P, L), Vm, np.int32)

    for p, lo in enumerate(layouts):
        nt, ll = lo.n_tiles, lo.num_lanes
        wid[p, :nt] = lo.window_id
        # pad tiles continue accumulating (identity) into the last window
        wid[p, nt:] = lo.window_id[-1] if nt else 0
        rel[p, :ll] = lo.rel
        written[p] = lo.window_written
        ev = pg.in_valid[p]
        src_slot[p, :ll] = lo.place(pg.in_src_slot[p], 0)
        src_gid[p, :ll] = lo.place(pg.in_src_gid[p], 0)
        src_outdeg[p, :ll] = lo.place(pg.in_src_outdeg[p], 1)
        w[p, :ll] = lo.place(pg.in_w[p], 0.0)
        lane_valid[p, :ll] = lo.place(ev, False) & lo.lane_valid
        seg_l[p, :ll] = lo.place(pg.in_dst_local[p], Vm)

    return (dict(wid=wid, rel=rel, window_written=written,
                 src_slot=src_slot, src_gid=src_gid, src_outdeg=src_outdeg,
                 w=w, lane_valid=lane_valid, seg=seg_l),
            n_tiles, n_windows)


def _build_combined_layouts(pg: PartitionedGraph, tile_e: int, tile_r: int):
    """Source-side layout for the combined exchange: each shard's edges,
    dst-sorted within each destination-shard bucket, as a Pallas windowed
    layout over the flat segment id ``q*(R+1) + dst_rank`` (the bucket's
    discard bin is rank R, so the flat ids stay globally sorted). The
    segment-combine over this layout yields the per-(peer, rank) partials
    that go on the wire — one slot per distinct remote destination."""
    cb = pg.combined_buckets()
    P, Vm = pg.num_parts, pg.v_max
    R = cb["comb_max"]
    Sc = P * (R + 1)
    seg_all = (np.arange(P, dtype=np.int64)[None, :, None] * (R + 1)
               + cb["dst_rank"].astype(np.int64))      # (P, P, E2)
    layouts = [kops.build_layout(seg_all[p].reshape(-1), Sc,
                                 tile_e=tile_e, tile_r=tile_r)
               for p in range(P)]
    n_tiles = max(l.n_tiles for l in layouts)
    n_windows = layouts[0].n_windows
    L = n_tiles * tile_e

    wid = np.zeros((P, n_tiles), np.int32)
    rel = np.full((P, L), tile_r, np.int32)
    written = np.zeros((P, n_windows), bool)
    src_local = np.zeros((P, L), np.int32)
    src_gid = np.zeros((P, L), np.int32)
    src_outdeg = np.ones((P, L), np.int32)
    w = np.zeros((P, L), np.float32)
    valid = np.zeros((P, L), bool)
    seg_l = np.full((P, L), Sc, np.int32)

    for p, lo in enumerate(layouts):
        nt, ll = lo.n_tiles, lo.num_lanes
        wid[p, :nt] = lo.window_id
        wid[p, nt:] = lo.window_id[-1] if nt else 0
        rel[p, :ll] = lo.rel
        written[p] = lo.window_written
        src_local[p, :ll] = lo.place(cb["src_local"][p].reshape(-1), 0)
        src_gid[p, :ll] = lo.place(cb["src_gid"][p].reshape(-1), 0)
        src_outdeg[p, :ll] = lo.place(cb["src_outdeg"][p].reshape(-1), 1)
        w[p, :ll] = lo.place(cb["w"][p].reshape(-1), 0.0)
        valid[p, :ll] = (lo.place(cb["valid"][p].reshape(-1), False)
                         & lo.lane_valid)
        seg_l[p, :ll] = lo.place(
            seg_all[p].reshape(-1).astype(np.int32), Sc)

    return (dict(comb_wid=wid, comb_rel=rel, comb_written=written,
                 comb_src_local=src_local, comb_src_gid=src_gid,
                 comb_src_outdeg=src_outdeg, comb_w=w, comb_valid=valid,
                 comb_seg=seg_l,
                 comb_recv_dst_local=np.ascontiguousarray(
                     cb["comb_dst"].swapaxes(0, 1))),
            R, n_tiles, n_windows)


def build_shard_data(pg: PartitionedGraph, *, tile_e: int = 512,
                     tile_r: int = 256) -> tuple:
    """(ShardData of numpy arrays, ShardMeta)."""
    P, Vm = pg.num_parts, pg.v_max
    lanes, n_tiles, n_windows = _build_shard_layouts(pg, tile_e, tile_r)
    comb, comb_max, comb_tiles, comb_windows = _build_combined_layouts(
        pg, tile_e, tile_r)

    flt = pg.nbr_filter.copy()
    flt[np.arange(pg.num_vertices), pg.part_of] = False
    flt_cnt = np.zeros((P, Vm), np.int32)
    flt_cnt[pg.part_of, pg.local_of] = flt.sum(axis=1).astype(np.int32)

    # ring buckets: shard p's in-edges grouped by source shard q =
    # transpose of the pair (source-side) layout. src_local is local to q.
    rb = dict(
        rb_src_local=pg.pair_src_local.swapaxes(0, 1),
        rb_src_gid=pg.pair_src_gid.swapaxes(0, 1),
        rb_src_outdeg=pg.pair_src_outdeg.swapaxes(0, 1),
        rb_w=pg.pair_w.swapaxes(0, 1),
        rb_dst_local=pg.pair_dst_local.swapaxes(0, 1),
        rb_valid=pg.pair_valid.swapaxes(0, 1),
    )

    data = ShardData(
        vert_gid=pg.vert_gid, vert_valid=pg.vert_valid, out_deg=pg.out_deg,
        flt_cnt=flt_cnt,
        **{k: np.ascontiguousarray(v) for k, v in lanes.items()},
        **{k: np.ascontiguousarray(v) for k, v in rb.items()},
        pair_src_local=pg.pair_src_local, pair_src_gid=pg.pair_src_gid,
        pair_src_outdeg=pg.pair_src_outdeg, pair_w=pg.pair_w,
        pair_valid=pg.pair_valid,
        recv_dst_local=pg.pair_dst_local.swapaxes(0, 1),
        **{k: np.ascontiguousarray(v) for k, v in comb.items()},
    )
    # frontier capacity buckets: powers of two up to Vm
    caps = []
    c = max(64, Vm // 16)
    while c < Vm:
        caps.append(c)
        c *= 4
    caps.append(Vm)
    meta = ShardMeta(P=P, v_max=Vm, e_pair_max=pg.e_pair_max,
                     n_tiles=n_tiles, n_windows=n_windows,
                     tile_e=tile_e, tile_r=tile_r,
                     num_vertices=pg.num_vertices,
                     frontier_capacities=tuple(caps),
                     comb_max=comb_max, comb_tiles=comb_tiles,
                     comb_windows=comb_windows)
    return data, meta


def abstract_shard_data(meta: ShardMeta, mesh=None,
                        exchange: str = "allgather") -> ShardData:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation). Fields
    unused by the chosen exchange are None (pruned from the input
    signature, so argument bytes reflect what that architecture loads)."""
    P, Vm, E2 = meta.P, meta.v_max, meta.e_pair_max
    Lf = meta.n_tiles * meta.tile_e
    CL = meta.comb_tiles * meta.tile_e
    i32, f32, b = jnp.int32, jnp.float32, jnp.bool_

    def sds(shape, dt):
        return jax.ShapeDtypeStruct(shape, dt)

    none6 = (None,) * 6
    csc = exchange in ("allgather", "frontier")
    ring = exchange == "ring"
    uni = exchange == "unicast"
    comb = exchange == "combined"
    return ShardData(
        vert_gid=sds((P, Vm), i32), vert_valid=sds((P, Vm), b),
        out_deg=sds((P, Vm), i32), flt_cnt=sds((P, Vm), i32),
        wid=sds((P, meta.n_tiles), i32) if csc else None,
        rel=sds((P, Lf), i32) if csc else None,
        window_written=sds((P, meta.n_windows), b) if csc else None,
        src_slot=sds((P, Lf), i32) if csc else None,
        src_gid=sds((P, Lf), i32) if csc else None,
        src_outdeg=sds((P, Lf), i32) if csc else None,
        w=sds((P, Lf), f32) if csc else None,
        lane_valid=sds((P, Lf), b) if csc else None,
        seg=sds((P, Lf), i32) if csc else None,
        rb_src_local=sds((P, P, E2), i32) if ring else None,
        rb_src_gid=sds((P, P, E2), i32) if ring else None,
        rb_src_outdeg=sds((P, P, E2), i32) if ring else None,
        rb_w=sds((P, P, E2), f32) if ring else None,
        rb_dst_local=sds((P, P, E2), i32) if ring else None,
        rb_valid=sds((P, P, E2), b) if ring else None,
        pair_src_local=sds((P, P, E2), i32) if uni else None,
        pair_src_gid=sds((P, P, E2), i32) if uni else None,
        pair_src_outdeg=sds((P, P, E2), i32) if uni else None,
        pair_w=sds((P, P, E2), f32) if uni else None,
        pair_valid=sds((P, P, E2), b) if uni else None,
        recv_dst_local=sds((P, P, E2), i32) if uni else None,
        comb_wid=sds((P, meta.comb_tiles), i32) if comb else None,
        comb_rel=sds((P, CL), i32) if comb else None,
        comb_written=sds((P, meta.comb_windows), b) if comb else None,
        comb_src_local=sds((P, CL), i32) if comb else None,
        comb_src_gid=sds((P, CL), i32) if comb else None,
        comb_src_outdeg=sds((P, CL), i32) if comb else None,
        comb_w=sds((P, CL), f32) if comb else None,
        comb_valid=sds((P, CL), b) if comb else None,
        comb_seg=sds((P, CL), i32) if comb else None,
        comb_recv_dst_local=sds((P, P, meta.comb_max), i32)
        if comb else None,
    )


class ShardEngine:
    """shard_map execution of a GasKernel over a device mesh axis."""

    def __init__(self, kernel: GasKernel, pg_or_meta, *,
                 mesh: Mesh, exchange: str = "allgather",
                 backend: str = "pallas",
                 tile_e: int = 512, tile_r: int = 256,
                 params: Optional[Dict[str, Any]] = None):
        assert exchange in ("allgather", "ring", "frontier", "unicast",
                            "combined")
        self.kernel = kernel
        self.mesh = mesh
        self.exchange = exchange
        self.backend = backend
        self.params = dict(params or {})
        if isinstance(pg_or_meta, PartitionedGraph):
            self.pg = pg_or_meta
            np_data, self.meta = build_shard_data(
                pg_or_meta, tile_e=tile_e, tile_r=tile_r)
            self.params.setdefault("num_vertices", pg_or_meta.num_vertices)
            sharding = NamedSharding(mesh, P(AXIS))
            self._data = jax.tree.map(
                lambda a: jax.device_put(jnp.asarray(a), sharding), np_data)
        else:
            self.pg = None
            self.meta = pg_or_meta
            self._data = None
        self._device_resident = self._data is not None
        self.params.setdefault("num_vertices", self.meta.num_vertices)
        self._interpret = jax.default_backend() != "tpu"
        # jitted program cache (per superstep cap) + trace counter; see
        # Engine.traces for the counting trick.
        self.traces = 0
        self._run_cache: Dict[Any, Any] = {}
        # one program per schedule; the overlapped variant is built
        # lazily (its windowed folds require a min/max combiner) and
        # both share this engine's device data and jit caches.
        self._progs: Dict[bool, SuperstepProgram] = {
            False: self._make_program(False)}
        self._prog = self._progs[False]
        self._steppers: Dict[Any, "ShardLaneStepper"] = {}

    def _prog_for(self, overlap: bool) -> SuperstepProgram:
        overlap = bool(overlap)
        prog = self._progs.get(overlap)
        if prog is None:
            prog = self._progs[overlap] = self._make_program(overlap)
        return prog

    def _make_program(self, overlap: bool = False) -> SuperstepProgram:
        """Per-shard step-granular program (runs inside shard_map blocks;
        termination uses the §4.3 distributed activity bit)."""
        if overlap and self.exchange in ("unicast", "combined") \
                and self.kernel.combiner not in ("min", "max"):
            raise ValueError(
                "overlap=True windows the all_to_all receiver fold, which "
                "is only exact for min/max combiners; kernel "
                f"{self.kernel.name!r} combines with "
                f"{self.kernel.combiner!r}")
        deliver = {
            ("allgather", False): self._deliver_allgather,
            ("ring", False): self._deliver_ring,
            ("frontier", False): self._deliver_frontier,
            ("unicast", False): self._deliver_unicast,
            ("combined", False): self._deliver_combined,
            ("allgather", True): self._deliver_allgather_ov,
            ("ring", True): self._deliver_ring_ov,
            ("frontier", True): self._deliver_frontier_ov,
            ("unicast", True): self._deliver_unicast_ov,
            ("combined", True): self._deliver_combined_ov,
        }[(self.exchange, bool(overlap))]

        def init_stats():
            return {"messages": jnp.int32(0), "words": jnp.float32(0.0)}

        def update_stats(stats, d, active, aux):
            return {"messages": stats["messages"] + aux["n_msgs"],
                    "words": stats["words"] + aux["words"]}

        def global_any(b):
            return jax.lax.pmax(b.astype(jnp.int32), AXIS) > 0

        return SuperstepProgram(self.kernel, deliver,
                                init_stats=init_stats,
                                update_stats=update_stats,
                                global_any=global_any)

    # ---------------- per-shard delivery kernels ----------------------
    def _local_combine(self, masked, d, combiner):  # analysis: traced
        """Per-shard segmented combine (Pallas kernel or jnp oracle)."""
        m = self.meta
        if self.backend == "pallas":
            from ..kernels.edge_gather import segment_combine_windows
            return segment_combine_windows(
                d.wid, d.rel, masked, combiner=combiner,
                tile_e=m.tile_e, tile_r=m.tile_r, n_windows=m.n_windows,
                window_written=d.window_written,
                num_segments=m.v_max + 1, interpret=self._interpret)
        return kref.segment_combine(masked, d.seg, m.v_max + 1, combiner)

    def _comb_combine(self, masked, d, combiner):  # analysis: traced
        """Source-side segmented combine over the dst-sorted combined
        layout: one output slot per (destination shard, dst rank)."""
        m = self.meta
        n_seg = m.P * (m.comb_max + 1)
        if self.backend == "pallas":
            from ..kernels.edge_gather import segment_combine_windows
            return segment_combine_windows(
                d.comb_wid, d.comb_rel, masked, combiner=combiner,
                tile_e=m.tile_e, tile_r=m.tile_r,
                n_windows=m.comb_windows, window_written=d.comb_written,
                num_segments=n_seg, interpret=self._interpret)
        return kref.segment_combine(masked, d.comb_seg, n_seg, combiner)

    def _consume(self, d, payload_flat, active_flat):  # analysis: traced
        """Receiver-side scatter+gather against the local CSC lanes given
        the (already transported) flat update array."""
        k, m = self.kernel, self.meta
        vals = jnp.take(payload_flat, d.src_slot)
        act = jnp.take(active_flat, d.src_slot) & d.lane_valid
        msg = k.scatter(vals, d.w, d.src_gid, d.src_outdeg)
        ident = kops.identity_for(k.combiner, k.msg_dtype)
        masked = jnp.where(act, msg, ident)
        acc = self._local_combine(masked, d, k.combiner)[: m.v_max]
        if k.got_from_identity:
            got = acc != ident
        else:
            gv = jnp.where(act, 1, 0).astype(jnp.int32)
            got = self._local_combine(gv, d, "max")[: m.v_max] > 0
        carry = None
        if k.carry_dtype is not None:
            cident = kops.identity_for("min", k.carry_dtype)
            cvals = k.scatter_carry(vals, d.w, d.src_gid, d.src_outdeg)
            acc_pad = jnp.concatenate(
                [acc, jnp.full((1,), ident, acc.dtype)])
            winner = act & (masked == jnp.take(
                acc_pad, jnp.minimum(d.seg, m.v_max)))
            cmasked = jnp.where(winner, cvals, cident)
            carry = self._local_combine(cmasked, d, "min")[: m.v_max]
        n_msgs = jnp.sum(act.astype(jnp.int32))
        return acc, got, carry, n_msgs

    # ---------------- exchanges ---------------------------------------
    def _deliver_allgather(self, d, payload, active):  # analysis: traced
        m = self.meta
        upd = jax.lax.all_gather(payload, AXIS)          # (P, Vm)
        act = jax.lax.all_gather(active, AXIS)
        # actual wire: the DENSE padded update array goes to every peer
        words = jnp.float32(m.v_max * (m.P - 1))
        acc, got, carry, n_msgs = self._consume(
            d, upd.reshape(-1), act.reshape(-1))
        return acc, got, carry, {"n_msgs": n_msgs, "words": words}

    def _deliver_frontier(self, d, payload, active):  # analysis: traced
        """Compact ACTIVE updates to (id, payload) pairs; broadcast the
        smallest sufficient capacity bucket."""
        k, m = self.kernel, self.meta
        me = jax.lax.axis_index(AXIS)
        n_act = jnp.sum(active.astype(jnp.int32))
        n_max = jax.lax.pmax(n_act, AXIS)
        caps = m.frontier_capacities
        ident = kops.identity_for(k.combiner, k.msg_dtype)

        (idx,) = jnp.nonzero(active, size=m.v_max, fill_value=m.v_max)
        drop = m.P * m.v_max  # out-of-bounds target -> dropped by scatter

        def branch(cap):
            def f(_):
                ids = idx[:cap]                    # local active vertex ids
                valid = ids < m.v_max
                safe = jnp.minimum(ids, m.v_max - 1)
                pay = jnp.take(payload, safe)
                slots = me * m.v_max + safe
                # broadcast the COMPACT (id, payload) buffer only
                slots_all = jax.lax.all_gather(slots, AXIS).reshape(-1)
                pay_all = jax.lax.all_gather(pay, AXIS).reshape(-1)
                val_all = jax.lax.all_gather(valid, AXIS).reshape(-1)
                tgt = jnp.where(val_all, slots_all, drop)
                # each slot has a unique owner => plain scatter-set is exact
                pf = jnp.full((m.P * m.v_max,), ident, pay_all.dtype)
                pf = pf.at[tgt].set(pay_all, mode="drop")
                af = jnp.zeros((m.P * m.v_max,), bool)
                af = af.at[tgt].set(True, mode="drop")
                # wire words actually moved: the padded buffer, id+payload
                words = jnp.float32(cap * 2 * (m.P - 1))
                return pf, af, words
            return f

        # smallest capacity bucket that fits the global max frontier
        sel = jnp.searchsorted(jnp.asarray(caps), n_max)
        sel = jnp.minimum(sel, len(caps) - 1)
        pf, af, words = jax.lax.switch(sel, [branch(c) for c in caps],
                                       operand=None)
        acc, got, carry, n_msgs = self._consume(d, pf, af)
        return acc, got, carry, {"n_msgs": n_msgs, "words": words}

    def _combine2(self, a, b):  # analysis: traced
        """Two-operand fold of the kernel's combiner monoid."""
        k = self.kernel
        if k.combiner == "add":
            return a + b
        return jnp.minimum(a, b) if k.combiner == "min" else jnp.maximum(a, b)

    def _merge_carry(self, ckey, ccar, acc_q, car_q):  # analysis: traced
        """Lexicographic fold of (key, carry) candidates — the two-level
        winner select the ring, and the windowed overlapped folds, use to
        keep SSSP's carried parent bit-identical to the one-shot fold."""
        k = self.kernel
        if k.combiner == "min":
            better = acc_q < ckey
        else:
            better = acc_q > ckey
        equal = acc_q == ckey
        ccar = jnp.where(better, car_q,
                         jnp.where(equal, jnp.minimum(ccar, car_q), ccar))
        return self._combine2(ckey, acc_q), ccar

    def _ring_bucket_consume(self, d, q, chunk_payload,  # analysis: traced
                             chunk_active):
        """Scatter+gather the edges whose SOURCE shard is q against the
        chunk of q's updates currently held."""
        k, m = self.kernel, self.meta
        ident = kops.identity_for(k.combiner, k.msg_dtype)
        b_src = d.rb_src_local[q]
        vals = jnp.take(chunk_payload, b_src)
        act = jnp.take(chunk_active, b_src) & d.rb_valid[q]
        msg = k.scatter(vals, d.rb_w[q], d.rb_src_gid[q],
                        d.rb_src_outdeg[q])
        masked = jnp.where(act, msg, ident)
        seg = d.rb_dst_local[q]
        acc_q = kref.segment_combine(masked, seg, m.v_max, k.combiner)
        gv = kref.segment_combine(
            jnp.where(act, 1, 0).astype(jnp.int32), seg, m.v_max, "max")
        car_q = None
        if k.carry_dtype is not None:
            cident = kops.identity_for("min", k.carry_dtype)
            cvals = k.scatter_carry(vals, d.rb_w[q], d.rb_src_gid[q],
                                    d.rb_src_outdeg[q])
            acc_pad = jnp.concatenate(
                [acc_q, jnp.full((1,), ident, acc_q.dtype)])
            win = act & (masked == jnp.take(acc_pad,
                                            jnp.minimum(seg, m.v_max)))
            car_q = kref.segment_combine(
                jnp.where(win, cvals, cident), seg, m.v_max, "min")
        return acc_q, gv > 0, car_q, jnp.sum(act.astype(jnp.int32))

    def _deliver_ring(self, d, payload, active):  # analysis: traced
        """P-hop ppermute ring; each arriving chunk is consumed against the
        matching source-shard edge bucket while the next hop is in flight
        (floating-barrier analogue)."""
        k, m = self.kernel, self.meta
        me = jax.lax.axis_index(AXIS)
        ident = kops.identity_for(k.combiner, k.msg_dtype)
        cident = (kops.identity_for("min", k.carry_dtype)
                  if k.carry_dtype is not None else None)
        perm = [(i, (i + 1) % m.P) for i in range(m.P)]
        bucket_consume = lambda q, p, a: self._ring_bucket_consume(d, q, p, a)  # noqa: E731
        merge_carry = self._merge_carry
        combine = self._combine2

        def body(i, st):
            acc, got, n_msgs, chunk_p, chunk_a, ccar = st
            q = (me - i) % m.P
            acc_q, got_q, car_q, nm = bucket_consume(q, chunk_p, chunk_a)
            if k.carry_dtype is not None:
                acc, ccar = merge_carry(acc, ccar, acc_q, car_q)
            else:
                acc = combine(acc, acc_q)
            got = got | got_q
            n_msgs = n_msgs + nm
            # next hop in flight while (in the compiled TPU schedule) the
            # next bucket's compute proceeds
            chunk_p = jax.lax.ppermute(chunk_p, AXIS, perm)
            chunk_a = jax.lax.ppermute(chunk_a, AXIS, perm)
            return acc, got, n_msgs, chunk_p, chunk_a, ccar

        acc0 = jnp.full((m.v_max,), ident, k.msg_dtype)
        got0 = jnp.zeros((m.v_max,), bool)
        ccar0 = (jnp.full((m.v_max,), cident, k.carry_dtype)
                 if k.carry_dtype is not None else jnp.int32(0))
        st = (acc0, got0, jnp.int32(0), payload, active, ccar0)
        st = jax.lax.fori_loop(0, m.P, body, st)
        acc, got, n_msgs, _, _, ccar = st
        carry = ccar if k.carry_dtype is not None else None
        # ring moves the same dense bytes as allgather, in P-1 hops
        words = jnp.float32(m.v_max * (m.P - 1))
        return acc, got, carry, {"n_msgs": n_msgs, "words": words}

    def _deliver_unicast(self, d, payload, active):  # analysis: traced
        """GraVF baseline: source-side scatter + all_to_all blocks."""
        k, m = self.kernel, self.meta
        vals = jnp.take(payload, d.pair_src_local.reshape(-1)).reshape(
            d.pair_src_local.shape)
        act = jnp.take(active, d.pair_src_local.reshape(-1)).reshape(
            d.pair_src_local.shape) & d.pair_valid
        msg = k.scatter(vals, d.pair_w, d.pair_src_gid, d.pair_src_outdeg)
        ident = kops.identity_for(k.combiner, k.msg_dtype)
        masked = jnp.where(act, msg, ident)
        recv = jax.lax.all_to_all(masked, AXIS, split_axis=0,
                                  concat_axis=0, tiled=False)
        recv_act = jax.lax.all_to_all(act, AXIS, split_axis=0,
                                      concat_axis=0, tiled=False)
        seg = d.recv_dst_local
        acc = kref.segment_combine(recv.reshape(-1), seg.reshape(-1),
                                   m.v_max, k.combiner)
        gv = kref.segment_combine(
            jnp.where(recv_act, 1, 0).astype(jnp.int32).reshape(-1),
            seg.reshape(-1), m.v_max, "max")
        got = gv > 0
        carry = None
        if k.carry_dtype is not None:
            cident = kops.identity_for("min", k.carry_dtype)
            cvals = k.scatter_carry(vals, d.pair_w, d.pair_src_gid,
                                    d.pair_src_outdeg)
            crecv = jax.lax.all_to_all(jnp.where(act, cvals, cident), AXIS,
                                       split_axis=0, concat_axis=0,
                                       tiled=False)
            acc_pad = jnp.concatenate([acc, jnp.full((1,), ident, acc.dtype)])
            winner = recv_act & (recv == jnp.take(
                acc_pad, jnp.minimum(seg, m.v_max)))
            carry = kref.segment_combine(
                jnp.where(winner, crecv, cident).reshape(-1),
                seg.reshape(-1), m.v_max, "min")
        n_msgs = jnp.sum(act.astype(jnp.int32))
        # actual wire: all_to_all ships the PADDED per-pair blocks
        words = jnp.float32(m.e_pair_max * (m.P - 1))
        return acc, got, carry, {"n_msgs": n_msgs, "words": words}

    def _deliver_combined(self, d, payload, active):  # analysis: traced
        """Combine-at-source (the paper's degree-factor headline): fold
        the per-edge messages down to one partial per (destination shard,
        destination vertex) BEFORE the wire, then all_to_all blocks of
        ``comb_max`` slots — the receiver merges pre-combined partials
        with the same monoid, so the two-level fold is exact for min/max
        (SSSP's lexicographic carry rides the same two-level winner
        select as unicast) and reorder-tolerant for add."""
        k, m = self.kernel, self.meta
        R = m.comb_max
        n_seg = m.P * (R + 1)
        vals = jnp.take(payload, d.comb_src_local)
        act = jnp.take(active, d.comb_src_local) & d.comb_valid
        msg = k.scatter(vals, d.comb_w, d.comb_src_gid, d.comb_src_outdeg)
        ident = kops.identity_for(k.combiner, k.msg_dtype)
        masked = jnp.where(act, msg, ident)
        accs = self._comb_combine(masked, d, k.combiner)       # (n_seg,)
        send = accs.reshape(m.P, R + 1)[:, :R]                 # (P, R)
        send_act = self._comb_combine(
            jnp.where(act, 1, 0).astype(jnp.int32), d, "max"
        ).reshape(m.P, R + 1)[:, :R] > 0
        recv = jax.lax.all_to_all(send, AXIS, split_axis=0,
                                  concat_axis=0, tiled=False)
        recv_act = jax.lax.all_to_all(send_act, AXIS, split_axis=0,
                                      concat_axis=0, tiled=False)
        seg = d.comb_recv_dst_local                            # (P, R)
        acc = kref.segment_combine(recv.reshape(-1), seg.reshape(-1),
                                   m.v_max, k.combiner)
        gv = kref.segment_combine(
            jnp.where(recv_act, 1, 0).astype(jnp.int32).reshape(-1),
            seg.reshape(-1), m.v_max, "max")
        got = gv > 0
        carry = None
        if k.carry_dtype is not None:
            cident = kops.identity_for("min", k.carry_dtype)
            cvals = k.scatter_carry(vals, d.comb_w, d.comb_src_gid,
                                    d.comb_src_outdeg)
            # source-level winner: the edge whose key equals its
            # (dest, rank) slot's combined key; min carry breaks ties —
            # the per-slot (key, carry) pair then folds at the receiver
            # exactly like a unicast edge would
            accs_pad = jnp.concatenate(
                [accs, jnp.full((1,), ident, accs.dtype)])
            win = act & (masked == jnp.take(
                accs_pad, jnp.minimum(d.comb_seg, n_seg)))
            csend = self._comb_combine(
                jnp.where(win, cvals, cident), d, "min"
            ).reshape(m.P, R + 1)[:, :R]
            crecv = jax.lax.all_to_all(csend, AXIS, split_axis=0,
                                       concat_axis=0, tiled=False)
            acc_pad = jnp.concatenate(
                [acc, jnp.full((1,), ident, acc.dtype)])
            winner = recv_act & (recv == jnp.take(
                acc_pad, jnp.minimum(seg, m.v_max)))
            carry = kref.segment_combine(
                jnp.where(winner, crecv, cident).reshape(-1),
                seg.reshape(-1), m.v_max, "min")
        n_msgs = jnp.sum(act.astype(jnp.int32))
        # actual wire: one (id, payload) slot per padded remote dst —
        # the degree-factor win over unicast's e_pair_max per-edge blocks
        words = jnp.float32(2 * R * (m.P - 1))
        return acc, got, carry, {"n_msgs": n_msgs, "words": words}

    # ---------------- overlapped (pipelined) exchanges ------------------
    # Window count for the chunked all_to_all pipelines. Static (it fixes
    # the traced loop bounds); the *index* of the in-flight window is a
    # fori_loop carry — see RTR005.
    OVERLAP_WINDOWS = 4

    def _n_windows(self, extent: int) -> int:
        return max(1, min(self.OVERLAP_WINDOWS, int(extent)))

    def _deliver_allgather_ov(self, d, payload, active):  # analysis: traced
        """Pipelined allgather: the broadcast decomposed into P ppermute
        hops that accumulate into the SAME flat receive array all_gather
        would produce, each chunk placed while the next hop is already in
        flight; one receiver-side consume then runs, so states, message
        counts and wire words are bit-identical to the one-shot gather."""
        m = self.meta
        me = jax.lax.axis_index(AXIS)
        perm = [(i, (i + 1) % m.P) for i in range(m.P)]

        def body(i, st):
            upd, actf, cur_p, cur_a, nxt_p, nxt_a = st
            # hop i+2's transport first: the in-flight buffer moves on
            # while chunk i is being placed (double buffer)
            new_p = jax.lax.ppermute(nxt_p, AXIS, perm)
            new_a = jax.lax.ppermute(nxt_a, AXIS, perm)
            q = (me - i) % m.P
            upd = jax.lax.dynamic_update_slice(upd, cur_p, (q * m.v_max,))
            actf = jax.lax.dynamic_update_slice(actf, cur_a, (q * m.v_max,))
            return upd, actf, nxt_p, nxt_a, new_p, new_a

        st = (jnp.zeros((m.P * m.v_max,), payload.dtype),
              jnp.zeros((m.P * m.v_max,), jnp.bool_),
              payload, active,
              jax.lax.ppermute(payload, AXIS, perm),
              jax.lax.ppermute(active, AXIS, perm))
        upd, actf = jax.lax.fori_loop(0, m.P, body, st)[:2]
        words = jnp.float32(m.v_max * (m.P - 1))
        acc, got, carry, n_msgs = self._consume(d, upd, actf)
        return acc, got, carry, {"n_msgs": n_msgs, "words": words}

    def _deliver_frontier_ov(self, d, payload, active):  # analysis: traced
        """Pipelined frontier: same capacity-bucket compaction as the
        synchronous schedule, but the compact (id, payload, valid) buffer
        rings around in P ppermute hops, each arriving chunk scatter-set
        into the flat receive arrays while the next hop is in flight.
        Slot owners are unique, so the set order cannot change a bit."""
        k, m = self.kernel, self.meta
        me = jax.lax.axis_index(AXIS)
        n_act = jnp.sum(active.astype(jnp.int32))
        n_max = jax.lax.pmax(n_act, AXIS)
        caps = m.frontier_capacities
        ident = kops.identity_for(k.combiner, k.msg_dtype)
        perm = [(i, (i + 1) % m.P) for i in range(m.P)]

        (idx,) = jnp.nonzero(active, size=m.v_max, fill_value=m.v_max)
        drop = m.P * m.v_max  # out-of-bounds target -> dropped by scatter

        def branch(cap):
            def f(_):
                ids = idx[:cap]                    # local active vertex ids
                valid = ids < m.v_max
                safe = jnp.minimum(ids, m.v_max - 1)
                pay = jnp.take(payload, safe)
                slots = me * m.v_max + safe

                def body(i, st):
                    pf, af, cs, cp, cv, ns, np_, nv = st
                    ms = jax.lax.ppermute(ns, AXIS, perm)
                    mp = jax.lax.ppermute(np_, AXIS, perm)
                    mv = jax.lax.ppermute(nv, AXIS, perm)
                    tgt = jnp.where(cv, cs, drop)
                    pf = pf.at[tgt].set(cp, mode="drop")
                    af = af.at[tgt].set(True, mode="drop")
                    return pf, af, ns, np_, nv, ms, mp, mv

                st = (jnp.full((m.P * m.v_max,), ident, pay.dtype),
                      jnp.zeros((m.P * m.v_max,), jnp.bool_),
                      slots, pay, valid,
                      jax.lax.ppermute(slots, AXIS, perm),
                      jax.lax.ppermute(pay, AXIS, perm),
                      jax.lax.ppermute(valid, AXIS, perm))
                pf, af = jax.lax.fori_loop(0, m.P, body, st)[:2]
                # wire words actually moved: identical to the sync path
                words = jnp.float32(cap * 2 * (m.P - 1))
                return pf, af, words
            return f

        sel = jnp.searchsorted(jnp.asarray(caps), n_max)
        sel = jnp.minimum(sel, len(caps) - 1)
        pf, af, words = jax.lax.switch(sel, [branch(c) for c in caps],
                                       operand=None)
        acc, got, carry, n_msgs = self._consume(d, pf, af)
        return acc, got, carry, {"n_msgs": n_msgs, "words": words}

    def _deliver_ring_ov(self, d, payload, active):  # analysis: traced
        """Double-buffered ring: hop k+1's ppermute is issued BEFORE chunk
        k's bucket consume (the sync ring permutes after). Consume and
        merge order are unchanged, so the fold is bit-identical."""
        k, m = self.kernel, self.meta
        me = jax.lax.axis_index(AXIS)
        ident = kops.identity_for(k.combiner, k.msg_dtype)
        cident = (kops.identity_for("min", k.carry_dtype)
                  if k.carry_dtype is not None else None)
        perm = [(i, (i + 1) % m.P) for i in range(m.P)]

        def body(i, st):
            acc, got, n_msgs, cur_p, cur_a, nxt_p, nxt_a, ccar = st
            # issue hop i+2's transport before touching chunk i
            new_p = jax.lax.ppermute(nxt_p, AXIS, perm)
            new_a = jax.lax.ppermute(nxt_a, AXIS, perm)
            q = (me - i) % m.P
            acc_q, got_q, car_q, nm = self._ring_bucket_consume(
                d, q, cur_p, cur_a)
            if k.carry_dtype is not None:
                acc, ccar = self._merge_carry(acc, ccar, acc_q, car_q)
            else:
                acc = self._combine2(acc, acc_q)
            got = got | got_q
            n_msgs = n_msgs + nm
            return acc, got, n_msgs, nxt_p, nxt_a, new_p, new_a, ccar

        acc0 = jnp.full((m.v_max,), ident, k.msg_dtype)
        got0 = jnp.zeros((m.v_max,), bool)
        ccar0 = (jnp.full((m.v_max,), cident, k.carry_dtype)
                 if k.carry_dtype is not None else jnp.int32(0))
        st = (acc0, got0, jnp.int32(0), payload, active,
              jax.lax.ppermute(payload, AXIS, perm),
              jax.lax.ppermute(active, AXIS, perm), ccar0)
        st = jax.lax.fori_loop(0, m.P, body, st)
        acc, got, n_msgs = st[0], st[1], st[2]
        ccar = st[7]
        carry = ccar if k.carry_dtype is not None else None
        words = jnp.float32(m.v_max * (m.P - 1))
        return acc, got, carry, {"n_msgs": n_msgs, "words": words}

    def _window_pipeline(self, seg3, masked3, act3, c3,  # analysis: traced
                         n_win, ident, cident):
        """Chunked all_to_all pipeline shared by the overlapped unicast
        and combined exchanges: the collective for column window k+1 is
        issued while window k's receive block (the double buffer riding
        the fori_loop carry) is folded into the accumulator. Per-window
        partials merge lexicographically (``_merge_carry``), which is
        exact for min/max combiners. ``act3 is None`` elides the activity
        stream for got_from_identity kernels (activity is recovered as
        ``recv != identity``); ``c3 is None`` elides the carry stream."""
        k, m = self.kernel, self.meta
        dummy = jnp.int32(0)

        def a2a(x):
            return jax.lax.all_to_all(x, AXIS, split_axis=0,
                                      concat_axis=0, tiled=False)

        def issue(wi):
            wi = jnp.minimum(wi, n_win - 1)
            bp = a2a(jax.lax.dynamic_index_in_dim(
                masked3, wi, 1, keepdims=False))
            ba = (a2a(jax.lax.dynamic_index_in_dim(
                act3, wi, 1, keepdims=False))
                if act3 is not None else dummy)
            bc = (a2a(jax.lax.dynamic_index_in_dim(
                c3, wi, 1, keepdims=False))
                if c3 is not None else dummy)
            return bp, ba, bc

        def fold(wi, acc, got, ccar, bp, ba, bc):
            seg_w = jax.lax.dynamic_index_in_dim(
                seg3, wi, 1, keepdims=False).reshape(-1)
            recv = bp.reshape(-1)
            acc_w = kref.segment_combine(recv, seg_w, m.v_max, k.combiner)
            if act3 is not None:
                ract = ba.reshape(-1)
                gv = kref.segment_combine(
                    jnp.where(ract, 1, 0).astype(jnp.int32), seg_w,
                    m.v_max, "max")
                got = got | (gv > 0)
            else:
                ract = recv != ident
            if c3 is not None:
                acc_w_pad = jnp.concatenate(
                    [acc_w, jnp.full((1,), ident, acc_w.dtype)])
                win_w = ract & (recv == jnp.take(
                    acc_w_pad, jnp.minimum(seg_w, m.v_max)))
                car_w = kref.segment_combine(
                    jnp.where(win_w, bc.reshape(-1), cident), seg_w,
                    m.v_max, "min")
                acc, ccar = self._merge_carry(acc, ccar, acc_w, car_w)
            else:
                acc = self._combine2(acc, acc_w)
            return acc, got, ccar

        def body(w, st):
            acc, got, ccar, bp, ba, bc = st
            nb = issue(w + 1)     # window w+1's collective in flight...
            acc, got, ccar = fold(w, acc, got, ccar, bp, ba, bc)  # ...now
            return (acc, got, ccar) + nb

        acc0 = jnp.full((m.v_max,), ident, k.msg_dtype)
        got0 = jnp.zeros((m.v_max,), bool)
        ccar0 = (jnp.full((m.v_max,), cident, k.carry_dtype)
                 if c3 is not None else dummy)
        st = jax.lax.fori_loop(
            0, n_win - 1, body, (acc0, got0, ccar0) + issue(jnp.int32(0)))
        acc, got, ccar = fold(jnp.int32(n_win - 1), *st)
        if act3 is None:
            got = acc != ident
        carry = ccar if c3 is not None else None
        return acc, got, carry

    def _window3(self, a, n_win, cw, fill):  # analysis: traced
        """(P, E) -> (P, n_win, cw) column windows, identity-padded."""
        m = self.meta
        pad = n_win * cw - a.shape[-1]
        if pad:
            a = jnp.pad(a, ((0, 0), (0, pad)), constant_values=fill)
        return a.reshape(m.P, n_win, cw)

    def _deliver_unicast_ov(self, d, payload, active):  # analysis: traced
        """Overlapped GraVF baseline: the per-pair message blocks cross
        the wire in column windows, the collective for window k+1 in
        flight while window k folds at the receiver."""
        k, m = self.kernel, self.meta
        vals = jnp.take(payload, d.pair_src_local.reshape(-1)).reshape(
            d.pair_src_local.shape)
        act = jnp.take(active, d.pair_src_local.reshape(-1)).reshape(
            d.pair_src_local.shape) & d.pair_valid
        msg = k.scatter(vals, d.pair_w, d.pair_src_gid, d.pair_src_outdeg)
        ident = kops.identity_for(k.combiner, k.msg_dtype)
        masked = jnp.where(act, msg, ident)
        has_carry = k.carry_dtype is not None
        cident = (kops.identity_for("min", k.carry_dtype)
                  if has_carry else None)
        n_win = self._n_windows(m.e_pair_max)
        cw = -(-m.e_pair_max // n_win)
        masked3 = self._window3(masked, n_win, cw, ident)
        seg3 = self._window3(d.recv_dst_local, n_win, cw, m.v_max)
        act3 = (None if k.got_from_identity
                else self._window3(act, n_win, cw, False))
        c3 = None
        if has_carry:
            cvals = k.scatter_carry(vals, d.pair_w, d.pair_src_gid,
                                    d.pair_src_outdeg)
            c3 = self._window3(jnp.where(act, cvals, cident), n_win, cw,
                               cident)
        acc, got, carry = self._window_pipeline(
            seg3, masked3, act3, c3, n_win, ident, cident)
        n_msgs = jnp.sum(act.astype(jnp.int32))
        # reported wire: the bytes the serial schedule moves (see module
        # docstring) — keeps stats comparable across schedules
        words = jnp.float32(m.e_pair_max * (m.P - 1))
        return acc, got, carry, {"n_msgs": n_msgs, "words": words}

    def _deliver_combined_ov(self, d, payload, active):  # analysis: traced
        """Overlapped combine-at-source: the per-(peer, rank) partial
        blocks cross the wire in column windows behind the receiver fold;
        the source-side segment-combine is the synchronous one."""
        k, m = self.kernel, self.meta
        R = m.comb_max
        n_seg = m.P * (R + 1)
        vals = jnp.take(payload, d.comb_src_local)
        act = jnp.take(active, d.comb_src_local) & d.comb_valid
        msg = k.scatter(vals, d.comb_w, d.comb_src_gid, d.comb_src_outdeg)
        ident = kops.identity_for(k.combiner, k.msg_dtype)
        masked = jnp.where(act, msg, ident)
        accs = self._comb_combine(masked, d, k.combiner)       # (n_seg,)
        send = accs.reshape(m.P, R + 1)[:, :R]                 # (P, R)
        has_carry = k.carry_dtype is not None
        cident = (kops.identity_for("min", k.carry_dtype)
                  if has_carry else None)
        n_win = self._n_windows(R)
        cw = -(-R // n_win) if R else 0
        masked3 = self._window3(send, n_win, cw, ident)
        seg3 = self._window3(d.comb_recv_dst_local, n_win, cw, m.v_max)
        act3 = None
        if not k.got_from_identity:
            send_act = self._comb_combine(
                jnp.where(act, 1, 0).astype(jnp.int32), d, "max"
            ).reshape(m.P, R + 1)[:, :R] > 0
            act3 = self._window3(send_act, n_win, cw, False)
        c3 = None
        if has_carry:
            cvals = k.scatter_carry(vals, d.comb_w, d.comb_src_gid,
                                    d.comb_src_outdeg)
            accs_pad = jnp.concatenate(
                [accs, jnp.full((1,), ident, accs.dtype)])
            win = act & (masked == jnp.take(
                accs_pad, jnp.minimum(d.comb_seg, n_seg)))
            csend = self._comb_combine(
                jnp.where(win, cvals, cident), d, "min"
            ).reshape(m.P, R + 1)[:, :R]
            c3 = self._window3(csend, n_win, cw, cident)
        acc, got, carry = self._window_pipeline(
            seg3, masked3, act3, c3, n_win, ident, cident)
        n_msgs = jnp.sum(act.astype(jnp.int32))
        words = jnp.float32(2 * R * (m.P - 1))
        return acc, got, carry, {"n_msgs": n_msgs, "words": words}

    # ---------------- superstep + loop ---------------------------------
    def _shard_step(self, d: ShardData, payload, active, state, superstep):
        """One superstep as a plain function (kept for the dry-run /
        roofline hooks); thin shim over the SuperstepProgram step."""
        c = self._prog.step(d, StepCarry(state, payload, active, superstep,
                                         self._prog.init_stats()))
        return (c.state, c.payload, c.active, c.stats["messages"],
                c.stats["words"])

    def _make_run(self, cap: int, qkeys: tuple = (),
                  overlap: bool = False):
        ck = ("single", cap, qkeys, bool(overlap))
        if ck in self._run_cache:
            return self._run_cache[ck]
        prog = self._prog_for(overlap)

        def shard_fn(d: ShardData, qkw):
            self.traces += 1  # trace-time side effect (see Engine.traces)
            # shard_map blocks keep a size-1 leading (sharded) axis
            d = jax.tree.map(lambda a: a[0], d)
            c = prog.while_run(d, cap, self.params, qkw)
            total_msgs = jax.lax.psum(c.stats["messages"], AXIS)
            total_words = jax.lax.psum(c.stats["words"], AXIS)
            # re-add shard axis
            state = jax.tree.map(lambda a: a[None], c.state)
            return state, c.superstep, total_msgs, total_words

        m = self.meta
        in_specs = jax.tree.map(lambda _: P(AXIS), self._data,
                                is_leaf=lambda x: x is None)
        qspec = {kk: P() for kk in qkeys}
        state_spec = P(AXIS)
        fn = _shard_map(
            shard_fn, mesh=self.mesh,
            in_specs=(in_specs, qspec),
            out_specs=(state_spec, P(), P(), P()))
        fn = jax.jit(fn)
        self._run_cache[ck] = fn
        return fn

    def _make_run_batch(self, cap: int, qkeys: tuple,
                        overlap: bool = False):
        """Query-batched shard_map program: the per-superstep exchange is
        shared by all B queries (one collective moves the (B, ·) payload);
        finished queries are frozen lane-wise so state/stats stay
        bit-identical to B sequential runs."""
        ck = ("batch", cap, qkeys, bool(overlap))
        if ck in self._run_cache:
            return self._run_cache[ck]
        prog = self._prog_for(overlap)

        def shard_fn(d: ShardData, qkw):
            self.traces += 1  # trace-time side effect
            d = jax.tree.map(lambda a: a[0], d)

            carry = jax.vmap(
                lambda kw: prog.init_carry(d, self.params, kw))(qkw)
            step_v = jax.vmap(lambda c: prog.step(d, c))

            def alive_of(c):
                # per-query distributed termination bit (§4.3, per lane)
                loc = jnp.any(c.active, axis=-1).astype(jnp.int32)  # (B,)
                return jax.lax.pmax(loc, AXIS) > 0

            def cond(st):
                s, c = st
                any_local = jnp.any(c.active).astype(jnp.int32)
                return (jax.lax.pmax(any_local, AXIS) > 0) & (s < cap)

            def body(st):
                s, c = st
                # finished lanes are frozen (select), so their state,
                # superstep count and stats stay bit-identical to a solo
                # run while the batch keeps stepping
                c = select_lanes(alive_of(c), step_v(c), c)
                return s + 1, c

            _, carry = jax.lax.while_loop(
                cond, body, (jnp.int32(0), carry))
            total_msgs = jax.lax.psum(carry.stats["messages"], AXIS)  # (B,)
            total_words = jax.lax.psum(
                jnp.sum(carry.stats["words"]), AXIS)
            # re-add shard axis leading so out spec P(AXIS) shards it
            state = jax.tree.map(lambda a: a[None], carry.state)  # (1, B, ·)
            return state, carry.superstep, total_msgs, total_words

        in_specs = jax.tree.map(lambda _: P(AXIS), self._data,
                                is_leaf=lambda x: x is None)
        qspec = {kk: P() for kk in qkeys}
        fn = _shard_map(
            shard_fn, mesh=self.mesh,
            in_specs=(in_specs, qspec),
            out_specs=(P(AXIS), P(), P(), P()))
        fn = jax.jit(fn)
        self._run_cache[ck] = fn
        return fn

    def _result_comm(self, words: float) -> Dict[str, Any]:
        return {"exchange_words": words, "wire_words": words,
                "exchange": self.exchange,
                "scheme": f"shard_{self.exchange}"}

    def run(self, max_supersteps: Optional[int] = None,
            overlap: bool = False, **query_kwargs):
        """Single query (an :class:`~.engine.EngineResult`; also indexable
        like the historical result dict). ``query_kwargs`` (e.g.
        ``root=7``) are traced scalars, matching ``Engine.run``.
        ``overlap=True`` runs the pipelined exchange schedule
        (bit-identical results; see the module docstring)."""
        unknown = set(query_kwargs) - set(self.kernel.query_params)
        if unknown:
            raise ValueError(
                f"kernel {self.kernel.name!r} takes query params "
                f"{tuple(self.kernel.query_params)}, got unexpected "
                f"{sorted(unknown)}")
        cap = (max_supersteps or self.kernel.max_supersteps or 100_000)
        qkw = {kk: jnp.asarray(v) for kk, v in query_kwargs.items()}
        fn = self._make_run(cap, tuple(sorted(qkw)), overlap)
        state, s, msgs, words = fn(self._data, qkw)
        from .engine import EngineResult, collect
        state_np = jax.tree.map(np.asarray, state)
        return EngineResult(
            state=collect(self.pg, state_np) if self.pg else state_np,
            supersteps=int(np.asarray(s)[0] if np.ndim(s) else s),
            messages=int(np.asarray(msgs).reshape(-1)[0]),
            comm=self._result_comm(
                float(np.asarray(words).reshape(-1)[0])),
            raw_state=state_np,
        )

    def run_batch(self, max_supersteps: Optional[int] = None,
                  overlap: bool = False, **query_arrays):
        """Batched multi-query run (see ``Engine.run_batch``). Returns a
        list of per-query result dicts; ``exchange_words`` is reported for
        the whole batch on each entry (the queries share the wire)."""
        if not query_arrays:
            raise ValueError("run_batch needs at least one per-query array")
        unknown = set(query_arrays) - set(self.kernel.query_params)
        if unknown:
            raise ValueError(
                f"kernel {self.kernel.name!r} takes query params "
                f"{tuple(self.kernel.query_params)}, got unexpected "
                f"{sorted(unknown)}")
        cap = (max_supersteps or self.kernel.max_supersteps or 100_000)
        qkw = {kk: jnp.atleast_1d(jnp.asarray(v))
               for kk, v in query_arrays.items()}
        fn = self._make_run_batch(cap, tuple(sorted(qkw)), overlap)
        state, sq, msgs, words = fn(self._data, qkw)
        from .engine import EngineResult, collect
        state_np = jax.tree.map(np.asarray, state)   # leaves (P, B, ...)
        sq = np.asarray(sq).reshape(-1, np.asarray(sq).shape[-1])[0]
        msgs = np.asarray(msgs).reshape(-1, np.asarray(msgs).shape[-1])[0]
        words = float(np.asarray(words).reshape(-1)[0])
        out = []
        for q in range(sq.shape[0]):
            state_q = jax.tree.map(lambda a: a[:, q], state_np)
            out.append(EngineResult(
                state=collect(self.pg, state_q) if self.pg else state_q,
                supersteps=int(sq[q]),
                messages=int(msgs[q]),
                comm=self._result_comm(words),
                raw_state=state_q,
            ))
        return out

    @property
    def device_nbytes(self) -> int:
        """Engine-tier graph bytes (0 when built meta-only)."""
        if self._data is None:
            return 0
        return int(sum(a.nbytes for a in jax.tree.leaves(self._data)))

    # ---------------- residency tier (see Engine.offload/upload) -------
    @property
    def device_resident(self) -> bool:
        return self._device_resident

    def offload(self) -> int:
        """Demote the sharded layout to host numpy copies (the engine
        tier of the store's host-spill residency); jitted programs and
        their caches survive untouched. Returns the bytes demoted."""
        if self._data is None or not self._device_resident:
            return 0
        host = jax.tree.map(np.asarray, self._data)
        self._data = host
        self._device_resident = False
        return int(sum(a.nbytes for a in jax.tree.leaves(host)))

    def upload(self) -> float:
        """Promote offloaded arrays back into mesh-sharded device
        buffers. Avals are unchanged, so the next dispatch hits the
        existing jit caches (zero re-traces). Returns wall seconds."""
        if self._data is None or self._device_resident:
            return 0.0
        t0 = time.perf_counter()
        sharding = NamedSharding(self.mesh, P(AXIS))
        data = jax.tree.map(
            lambda a: jax.device_put(jnp.asarray(a), sharding), self._data)
        jax.block_until_ready(data)
        self._data = data
        self._device_resident = True
        return time.perf_counter() - t0

    # ---------------- step-granular entry point ------------------------
    def make_stepper(self, width: int,
                     overlap: bool = False) -> "ShardLaneStepper":
        """Host-drivable ``width``-lane slot array over the explicit
        collectives (see ``Engine.make_stepper``): one jitted shard_map
        call per superstep, with admit/retire between supersteps.
        Steppers are cached per (width, overlap) — both schedules share
        this engine's device data, so toggling ``overlap`` per request
        hits an already-traced plan (zero steady-state re-traces)."""
        if self._data is None:
            raise ValueError("make_stepper needs device data; this engine "
                             "was built meta-only (dry-run)")
        key = (width, bool(overlap))
        st = self._steppers.get(key)
        if st is None:
            st = ShardLaneStepper(self, width, overlap=bool(overlap))
            self._steppers[key] = st
        return st

    def lane_result(self, carry_host, lane: int):
        """Package one retired stepper lane as an
        :class:`~.engine.EngineResult` (same fields as :meth:`run`);
        per-shard stats are folded across the shard axis (the host-side
        psum)."""
        from .engine import EngineResult, collect
        state_q = jax.tree.map(lambda a: np.asarray(a[:, lane]),
                               carry_host.state)
        return EngineResult(
            state=collect(self.pg, state_q) if self.pg else state_q,
            supersteps=int(carry_host.superstep[0, lane]),
            messages=int(carry_host.stats["messages"][:, lane].sum()),
            comm=self._result_comm(
                float(carry_host.stats["words"][:, lane].sum())),
            raw_state=state_q,
        )

    # ---------------- dry-run hooks ------------------------------------
    def superstep_fn(self):
        """One full superstep (deliver + gather + apply) as a jittable fn
        over (data, payload, active, state, superstep) — the unit that the
        multi-pod dry-run lowers and the roofline analyses."""
        def shard_fn(d, payload, active, state, superstep):
            return self._shard_step(d, payload, active, state, superstep)

        return shard_fn


class ShardLaneStepper(LaneStepperBase):
    """W-lane continuous-stepping handle over a :class:`ShardEngine`.

    Mirrors ``core.stepper.LaneStepper`` but every carry leaf keeps a
    leading shard axis (global shape ``(P, W, ...)`` sharded over the
    mesh ``graph`` axis), and admit/step are shard_map programs so each
    superstep runs the engine's explicit collective exactly once for all
    W lanes. The shard_map wrappers are built lazily on the first
    ``init`` (the carry pytree structure — hence the in/out spec trees —
    depends on the kernel's state dict and the query kwarg dtypes), then
    reused forever: steady-state admit/step/retire re-traces nothing.
    """

    def __init__(self, eng: ShardEngine, width: int,
                 overlap: bool = False):
        self.eng = eng
        self.width = width
        self.overlap = bool(overlap)
        self._prog = eng._prog_for(self.overlap)
        self._fns = None  # (init, admit, step) jitted shard_map programs
        self._restore = None   # built with the other programs
        self._exchange_serial_p = None  # profile-only serial reference
        self._probe = jax.jit(self._probe_of)

        def fetch_lane_fn(carry, lane):
            eng.traces += 1  # trace-time side effect (see Engine.traces)
            # checkpoint gathers ONLY the lane's per-shard slices
            # (leaves (P, ...)), never the whole (P, W, ...) slot array
            return jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, lane, 1, keepdims=False), carry)

        self._fetch_lane = jax.jit(fetch_lane_fn)

    def _probe_of(self, carry):
        # on the GLOBAL carry (outside shard_map): lane-alive is the
        # host-side form of the §4.3 pmax'd activity bit; the third
        # element is the cumulative wire words over all shards+lanes
        # (LaneStepperBase peels it off into ``last_wire_words`` so the
        # public (carry, act, steps) contract is unchanged)
        return (jnp.any(carry.active, axis=(0, 2)), carry.superstep[0],
                jnp.sum(carry.stats["words"]))

    def _build(self, qkw):
        eng, prog = self.eng, self._prog
        data_spec = jax.tree.map(lambda _: P(AXIS), eng._data,
                                 is_leaf=lambda x: x is None)
        qspec = {k: P() for k in qkw}
        lane_spec = P()

        def strip(t):
            return jax.tree.map(lambda a: a[0], t)

        def readd(t):
            return jax.tree.map(lambda a: a[None], t)

        def init_local(d, kw_arrays):
            return jax.vmap(
                lambda kw: prog.init_carry(d, eng.params, kw))(kw_arrays)

        # Carry structure (and so the spec trees) via eval_shape of the
        # collective-free local init.
        d_local = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), eng._data)
        qkw_struct = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                      for k, v in qkw.items()}
        carry_struct = jax.eval_shape(init_local, d_local, qkw_struct)
        carry_spec = jax.tree.map(lambda _: P(AXIS), carry_struct)

        def init_fn(d, kw):
            eng.traces += 1  # trace-time side effect (see Engine.traces)
            return readd(init_local(strip(d), kw))

        def admit_fn(d, carry, kw, fresh):
            eng.traces += 1
            d = strip(d)
            return readd(select_lanes(fresh, init_local(d, kw),
                                      strip(carry)))

        def step_fn(d, carry, alive):
            eng.traces += 1
            d, c = strip(d), strip(carry)
            return readd(select_lanes(
                alive, jax.vmap(lambda cc: prog.step(d, cc))(c), c))

        def restore_fn(carry, lane_c, fresh):
            eng.traces += 1
            c, lc = strip(carry), strip(lane_c)
            # splice the parked lane's per-shard carry slices back via
            # the admit-path select: bit-identical resume
            new = jax.tree.map(
                lambda leaf: jnp.broadcast_to(
                    leaf[None], (self.width,) + leaf.shape), lc)
            return readd(select_lanes(fresh, new, c))

        # a checkpoint slice drops the lane axis: leaves (P, ...)
        ckpt_spec = jax.tree.map(lambda _: P(AXIS), carry_struct)

        init_sm = _shard_map(init_fn, mesh=eng.mesh,
                             in_specs=(data_spec, qspec),
                             out_specs=carry_spec)
        admit_sm = _shard_map(admit_fn, mesh=eng.mesh,
                              in_specs=(data_spec, carry_spec, qspec,
                                        lane_spec),
                              out_specs=carry_spec)
        step_sm = _shard_map(step_fn, mesh=eng.mesh,
                             in_specs=(data_spec, carry_spec, lane_spec),
                             out_specs=carry_spec)
        restore_sm = _shard_map(restore_fn, mesh=eng.mesh,
                                in_specs=(carry_spec, ckpt_spec,
                                          lane_spec),
                                out_specs=carry_spec)

        # profiled-mode phase programs: the superstep cut at the
        # exchange/apply boundary. Inside shard_map the collective and
        # the receiver-side combine cannot be host-separated (the
        # delivered intermediates only exist per-shard), so the shard
        # profile is exchange (deliver + gather-combine, the L_if/L_net
        # + part of L_node term) then apply. The exchange output is
        # carry-shaped (step counter advances in apply), so both
        # programs run carry_spec -> carry_spec.
        def exchange_fn(d, carry):
            eng.traces += 1
            d, c = strip(d), strip(carry)
            return readd(jax.vmap(
                lambda cc: prog.step_exchange(d, cc))(c))

        def apply_fn(d, carry, mid, alive):
            eng.traces += 1
            d, c, m = strip(d), strip(carry), strip(mid)
            return readd(select_lanes(
                alive, jax.vmap(lambda cc: prog.step_apply(d, cc))(m), c))

        exchange_sm = _shard_map(exchange_fn, mesh=eng.mesh,
                                 in_specs=(data_spec, carry_spec),
                                 out_specs=carry_spec)
        apply_sm = _shard_map(apply_fn, mesh=eng.mesh,
                              in_specs=(data_spec, carry_spec,
                                        carry_spec, lane_spec),
                              out_specs=carry_spec)

        # overlapped steppers keep a serial-schedule exchange reference
        # for the phase profiler: timing it on the same carry (output
        # unused — the schedules are bit-identical) yields the
        # total-exchange-time denominator of overlap_efficiency. Only
        # ever dispatched in profile mode, off the serving hot path.
        if self.overlap:
            sprog = eng._prog_for(False)

            def exchange_serial_fn(d, carry):
                eng.traces += 1
                d, c = strip(d), strip(carry)
                return readd(jax.vmap(
                    lambda cc: sprog.step_exchange(d, cc))(c))

            self._exchange_serial_p = jax.jit(_shard_map(
                exchange_serial_fn, mesh=eng.mesh,
                in_specs=(data_spec, carry_spec), out_specs=carry_spec))

        # fuse the lane probe into the same dispatch (see LaneStepper)
        def with_probe(sm):
            def f(*args):
                c = sm(*args)
                return (c, *self._probe_of(c))
            return jax.jit(f)

        self._fns = (with_probe(init_sm), with_probe(admit_sm),
                     with_probe(step_sm))
        self._restore = with_probe(restore_sm)
        self._exchange_p = jax.jit(exchange_sm)
        self._apply_p = jax.jit(apply_sm)

    def init(self, qkw):
        q = self._qdev(qkw)
        if self._fns is None:
            self._build(q)
        return self._unpack(self._fns[0](self.eng._data, q))

    def admit(self, carry, qkw, fresh):
        return self._unpack(self._fns[1](self.eng._data, carry,
                                         self._qdev(qkw),
                                         jnp.asarray(fresh)))

    def step(self, carry, alive):
        if not self.profile:
            self.last_phases = None
            return self._unpack(self._fns[2](self.eng._data, carry,
                                             jnp.asarray(alive)))
        return self._profiled_step(carry, alive)

    def _profiled_step(self, carry, alive):
        """Exchange/apply/probe with host-timed boundaries — the shard
        twin of ``LaneStepper._profiled_step`` (same select/masking as
        the fused program, bit-identical results)."""
        d, alive_dev = self.eng._data, jnp.asarray(alive)
        phases = {}
        if self._exchange_serial_p is not None:
            # total-exchange-time reference: the serial schedule on the
            # same carry (bit-identical output, discarded)
            t = time.perf_counter()
            ser = self._exchange_serial_p(d, carry)
            jax.block_until_ready(ser)
            phases["exchange_serial"] = time.perf_counter() - t
        t = time.perf_counter()
        mid = self._exchange_p(d, carry)
        jax.block_until_ready(mid)
        now = time.perf_counter()
        phases["exchange"] = now - t
        t = now
        new = self._apply_p(d, carry, mid, alive_dev)
        jax.block_until_ready(new)
        now = time.perf_counter()
        phases["apply"] = now - t
        t = now
        out = self._probe(new)
        act, steps = np.asarray(out[0]), np.asarray(out[1])
        self.last_wire_words = float(np.asarray(out[2]))
        phases["probe"] = time.perf_counter() - t
        self.last_phases = phases
        return new, act, steps
