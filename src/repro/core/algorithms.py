"""The paper's benchmark algorithms (BFS, WCC, PageRank) plus SSSP and
degree centrality, written as GraVF-M kernels.

Each is a handful of elementwise-jnp lines — the direct counterpart of the
paper's ~30-line Verilog kernels (§3 WCC listing). State is a dict of
per-vertex arrays; the ``active`` convention mirrors the paper: gather sets
an ``active`` bit in state, apply reads and clears it and issues the update.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .gas import GasKernel

__all__ = ["bfs", "wcc", "pagerank", "sssp", "degree_centrality", "ALGORITHMS"]

INT_MAX = np.iinfo(np.int32).max


# ---------------------------------------------------------------------------
# WCC — the paper's worked example (§3). Propagate the lowest vertex id.
# ---------------------------------------------------------------------------

def wcc() -> GasKernel:
    def init_state(vert_gid, out_deg, valid, **_):
        gid = jnp.where(valid, vert_gid, INT_MAX)
        return {"label": gid.astype(jnp.int32),
                "active": valid}  # every vertex broadcasts its id first

    def apply(state, vert_gid, out_deg, superstep):
        payload = state["label"]
        active = state["active"]
        new_state = {"label": state["label"],
                     "active": jnp.zeros_like(active)}
        return new_state, payload, active

    def scatter(payload, weight, src_gid, src_outdeg):
        return payload  # forward the label as-is (paper Listing 3)

    def gather(state, combined, got, superstep):
        # paper Listing 1: keep the smaller label, mark active on change.
        new_label = got & (combined < state["label"])
        return {
            "label": jnp.where(new_label, combined, state["label"]),
            "active": state["active"] | new_label,
        }

    return GasKernel(
        name="wcc", init_state=init_state, apply=apply, scatter=scatter,
        gather=gather, combiner="min", msg_dtype=jnp.int32,
        update_bits=32, message_bits=32)


# ---------------------------------------------------------------------------
# BFS — parent-pointer spanning tree (graph500 flavour, paper §6.2).
# ---------------------------------------------------------------------------

def bfs(root: int = 0) -> GasKernel:
    # ``root`` is a *query parameter*: init_state accepts it as a traced
    # scalar (overridable per call / per batch lane), with the factory
    # argument as the default — so `bfs(7)` and `bfs().init_state(...,
    # root=7)` agree and the engine can vmap a batch of roots through one
    # superstep loop without re-tracing.
    def init_state(vert_gid, out_deg, valid, *, root=root, **_):
        root = jnp.asarray(root, jnp.int32)
        is_root = vert_gid == root
        return {
            "parent": jnp.where(is_root, root, -1).astype(jnp.int32),
            "active": is_root & valid,
        }

    def apply(state, vert_gid, out_deg, superstep):
        payload = vert_gid.astype(jnp.int32)  # "I am your parent"
        active = state["active"]
        return ({"parent": state["parent"],
                 "active": jnp.zeros_like(active)}, payload, active)

    def scatter(payload, weight, src_gid, src_outdeg):
        return payload

    def gather(state, combined, got, superstep):
        newly = got & (state["parent"] < 0)
        return {
            "parent": jnp.where(newly, combined, state["parent"]),
            "active": state["active"] | newly,
        }

    return GasKernel(
        name="bfs", init_state=init_state, apply=apply, scatter=scatter,
        gather=gather, combiner="min", msg_dtype=jnp.int32,
        update_bits=32, message_bits=32, query_params=("root",))


# ---------------------------------------------------------------------------
# PageRank — Pregel-style fixed 30 supersteps (paper §6.2).
# ---------------------------------------------------------------------------

def pagerank(num_supersteps: int = 30, damping: float = 0.85) -> GasKernel:
    def init_state(vert_gid, out_deg, valid, *, num_vertices, **_):
        base = jnp.where(valid, 1.0 / num_vertices, 0.0).astype(jnp.float32)
        return {"score": base, "num_vertices": jnp.float32(num_vertices)}

    def apply(state, vert_gid, out_deg, superstep):
        # contribution = score / out_degree, divided at the sender (Pregel).
        payload = state["score"] / jnp.maximum(out_deg, 1).astype(jnp.float32)
        active = jnp.full(vert_gid.shape, superstep < num_supersteps)
        return state, payload, active

    def scatter(payload, weight, src_gid, src_outdeg):
        return payload

    def gather(state, combined, got, superstep):
        n = state["num_vertices"]
        acc = jnp.where(got, combined, 0.0)
        score = (1.0 - damping) / n + damping * acc
        return {"score": score.astype(jnp.float32), "num_vertices": n}

    return GasKernel(
        name="pagerank", init_state=init_state, apply=apply, scatter=scatter,
        gather=gather, combiner="add", msg_dtype=jnp.float32,
        max_supersteps=num_supersteps, update_bits=32, message_bits=32)


# ---------------------------------------------------------------------------
# SSSP — beyond-paper. Message key = candidate distance (min-combined);
# the parent pointer travels as an argmin carry (engine resolves the min
# sender id among the winning distances — deterministic, 32-bit payloads).
# ---------------------------------------------------------------------------

def sssp(root: int = 0) -> GasKernel:
    def init_state(vert_gid, out_deg, valid, *, root=root, **_):
        root = jnp.asarray(root, jnp.int32)
        is_root = vert_gid == root
        dist = jnp.where(is_root, 0.0, jnp.inf).astype(jnp.float32)
        return {
            "dist": dist,
            "parent": jnp.where(is_root, root, -1).astype(jnp.int32),
            "active": is_root & valid,
        }

    def apply(state, vert_gid, out_deg, superstep):
        payload = state["dist"]
        active = state["active"]
        st = dict(state)
        st["active"] = jnp.zeros_like(active)
        return st, payload, active

    def scatter(payload, weight, src_gid, src_outdeg):
        return payload + weight

    def scatter_carry(payload, weight, src_gid, src_outdeg):
        return src_gid

    def gather(state, combined, carry, got, superstep):
        better = got & (combined < state["dist"])
        return {
            "dist": jnp.where(better, combined, state["dist"]),
            "parent": jnp.where(better, carry, state["parent"]),
            "active": state["active"] | better,
        }

    return GasKernel(
        name="sssp", init_state=init_state, apply=apply, scatter=scatter,
        gather=gather, combiner="min", msg_dtype=jnp.float32,
        carry_dtype=jnp.int32, scatter_carry=scatter_carry,
        update_bits=32, message_bits=64, query_params=("root",))


# ---------------------------------------------------------------------------
# Degree centrality — single-superstep sanity workload.
# ---------------------------------------------------------------------------

def degree_centrality() -> GasKernel:
    def init_state(vert_gid, out_deg, valid, **_):
        return {"indeg": jnp.zeros(vert_gid.shape, jnp.float32),
                "done": jnp.zeros(vert_gid.shape, bool)}

    def apply(state, vert_gid, out_deg, superstep):
        active = (superstep == 0) & jnp.ones(vert_gid.shape, bool)
        return state, jnp.ones(vert_gid.shape, jnp.float32), active

    def scatter(payload, weight, src_gid, src_outdeg):
        return payload

    def gather(state, combined, got, superstep):
        return {"indeg": jnp.where(got, combined, state["indeg"]),
                "done": state["done"] | got}

    return GasKernel(
        name="degree", init_state=init_state, apply=apply, scatter=scatter,
        gather=gather, combiner="add", msg_dtype=jnp.float32,
        max_supersteps=1, update_bits=32, message_bits=32)



ALGORITHMS = {
    "bfs": bfs,
    "wcc": wcc,
    "pagerank": pagerank,
    "sssp": sssp,
    "degree": degree_centrality,
}
