"""GraVF-M core: the paper's contribution as a composable JAX module.

- ``graph``      : datasets/generators (paper §6.2).
- ``partition``  : §4.4 partitioners + Fig. 4 edge layouts.
- ``gas``        : §3 three-stage programming model.
- ``algorithms`` : BFS / WCC / PageRank (+ SSSP, degree).
- ``engine``     : §4 superstep executor (GraVF baseline + GraVF-M).
- ``stepper``    : step-granular superstep core (one-superstep programs).
- ``perfmodel``  : §5 analytical performance model.
"""
from . import algorithms, gas, graph, partition
from .engine import Engine, EngineResult, collect
from .gas import GasKernel
from .graph import Graph
from .partition import PartitionedGraph, partition_graph
from .stepper import LaneStepper, StepCarry, SuperstepProgram

__all__ = [
    "algorithms", "gas", "graph", "partition",
    "Engine", "EngineResult", "collect", "GasKernel", "Graph",
    "PartitionedGraph", "partition_graph",
    "LaneStepper", "StepCarry", "SuperstepProgram",
]
