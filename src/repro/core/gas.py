"""The GraVF-M three-stage programming model (paper §3).

A graph algorithm is a :class:`GasKernel` — three small pure functions with
fixed interfaces, the JAX counterpart of the paper's three Verilog modules:

  gather  : called (logically once per message) to fold messages into
            vertex state. As in all high-throughput vertex-centric systems
            the fold must be a commutative monoid, so the engine
            pre-aggregates messages per destination with ``combiner`` and
            calls ``gather`` once per vertex with the combined value.
  apply   : called once per vertex at the end of a superstep; reads the
            final state and may issue ONE update (payload + active flag).
            This ≤1-update-per-vertex bound is what makes the GraVF-M
            broadcast optimization legal (paper §4.1).
  scatter : called once per (update, out-edge) to finalize the message.
            In GraVF-M the engine runs it at the RECEIVER, on demand.

All functions are elementwise jnp code, vectorized by the engine over
vertices/edges — the analogue of the paper's per-cycle hardware pipelines.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax.numpy as jnp
import numpy as np

__all__ = ["GasKernel", "COMBINER_IDENTITY", "segment_combine_ref"]

State = Any  # pytree of (num_vertices,) arrays


def _id_for(combiner: str, dtype) -> Any:
    dt = jnp.dtype(dtype)
    if combiner == "add":
        return np.zeros((), dt)
    if combiner == "min":
        if jnp.issubdtype(dt, jnp.floating):
            return np.array(np.inf, dt)
        return np.array(jnp.iinfo(dt).max, dt)
    if combiner == "max":
        if jnp.issubdtype(dt, jnp.floating):
            return np.array(-np.inf, dt)
        return np.array(jnp.iinfo(dt).min, dt)
    raise ValueError(f"unknown combiner {combiner}")


COMBINER_IDENTITY = _id_for


@dataclasses.dataclass(frozen=True)
class GasKernel:
    """A user graph algorithm.

    Shapes (engine-side, per shard):
      init_state(vert_gid, out_deg, valid, **params)      -> state pytree
      apply(state, vert_gid, out_deg, superstep)           -> (state, payload, active)
      scatter(payload, weight, src_gid, src_outdeg)        -> message value
      gather(state, combined_msg, got_msg, superstep)      -> state

    ``combiner`` ∈ {"min", "max", "add"} pre-aggregates messages per
    destination vertex; ``msg_dtype`` is the message value dtype;
    ``update_dtype`` the update payload dtype (usually identical — the
    paper's m_update/m_message ratio, which enters the §5 model).
    """

    name: str
    init_state: Callable[..., State]
    apply: Callable[..., Any]
    scatter: Callable[..., jnp.ndarray]
    gather: Callable[..., State]
    combiner: str
    msg_dtype: Any
    update_dtype: Any = None
    max_supersteps: int = 0  # 0 = until quiescence
    # Bit widths for the §5 performance model (paper's m_update/m_message).
    update_bits: int = 32
    message_bits: int = 32
    # got = (combined != identity) is exact for this kernel (saves a
    # reduction pass). All built-ins qualify; see engine._deliver_*.
    got_from_identity: bool = True
    # Optional argmin-style carried value: ``scatter_carry`` produces a
    # second per-message value; among messages achieving the winning key the
    # minimum carry is delivered (combiner must be min/max). gather then
    # receives (combined_key, carry, got). Keeps payloads 32-bit without
    # packing (SSSP uses this for parent pointers).
    carry_dtype: Any = None
    scatter_carry: Callable[..., jnp.ndarray] = None
    # Names of ``init_state`` keyword parameters that are *per-query* and
    # traceable (accepted as JAX scalars, e.g. BFS/SSSP ``root``). The
    # engine's ``run_batch`` maps these over a leading query-batch axis and
    # the query service uses them to validate batching compatibility.
    # Kernels with an empty tuple (WCC, PageRank) answer one global
    # question, so batching them only duplicates work.
    query_params: tuple = ()

    @property
    def identity(self):
        return _id_for(self.combiner, self.msg_dtype)

    def describe(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "combiner": self.combiner,
            "msg_dtype": str(jnp.dtype(self.msg_dtype)),
            "max_supersteps": self.max_supersteps,
            "update_bits": self.update_bits,
            "message_bits": self.message_bits,
            "query_params": list(self.query_params),
        }


def segment_combine_ref(vals, seg_ids, num_segments: int, combiner: str):
    """Pure-jnp oracle for per-destination message aggregation (the fused
    receiver-side scatter+gather hot loop). ``seg_ids`` may contain
    ``num_segments`` for padding lanes (routed to a discard bin)."""
    import jax

    n = num_segments + 1  # one discard bin for padding
    if combiner == "add":
        out = jax.ops.segment_sum(vals, seg_ids, num_segments=n)
    elif combiner == "min":
        out = jax.ops.segment_min(vals, seg_ids, num_segments=n)
    elif combiner == "max":
        out = jax.ops.segment_max(vals, seg_ids, num_segments=n)
    else:
        raise ValueError(combiner)
    # segment_min/max produce the dtype identity for empty bins already;
    # slice off the discard bin.
    return out[:num_segments]
