"""Vertex partitioning and device-layout compilation.

Implements the paper's §4.4:
  - ``round_robin``         : vertex count balance (paper's least-effort).
  - ``greedy_edge_balance`` : assign each vertex (stream order, no sort) to
                              the bin with lowest cumulative out-degree —
                              the paper's default, "near-perfect" heuristic.
  - ``snake_lpt``           : sorted longest-processing-time variant
                              (vectorized; within rounding of greedy).
  - ``ldg``                 : streaming Linear Deterministic Greedy — our
                              METIS stand-in (locality-aware, minimizes
                              cross-shard edges under a balance cap). METIS
                              itself is unavailable offline; the paper finds
                              greedy within 5% of METIS anyway (Fig. 13).

and compiles a :class:`PartitionedGraph` holding BOTH edge layouts of
paper Fig. 4:
  - GraVF   (left) : source-partitioned CSR — shard p stores out-edges of
                     its owned vertices, grouped by destination shard
                     (unicast message exchange).
  - GraVF-M (right): destination-partitioned CSC — shard p stores, for ALL
                     vertices, the subset of edges whose destination lives
                     on p (receiver-side scatter after update broadcast).

plus the neighbor-filter bitmap of §4.3 (|V| x P: which shards host
neighbors of each vertex).

All per-shard arrays are padded to identical static shapes so they stack
into SPMD-shardable global arrays with a leading shard axis.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, Optional

import numpy as np

from .graph import Graph

__all__ = [
    "round_robin",
    "greedy_edge_balance",
    "snake_lpt",
    "ldg",
    "PARTITIONERS",
    "PartitionedGraph",
    "partition_graph",
]


# ---------------------------------------------------------------------------
# Partitioners: Graph -> part_of (V,) int32
# ---------------------------------------------------------------------------

def round_robin(g: Graph, num_parts: int) -> np.ndarray:
    return (np.arange(g.num_vertices) % num_parts).astype(np.int32)


def greedy_edge_balance(g: Graph, num_parts: int) -> np.ndarray:
    """Paper default: stream vertices in natural order, assign to the bin
    with the lowest cumulative edge count. Exact heap implementation."""
    deg = g.out_degrees()
    part_of = np.zeros(g.num_vertices, np.int32)
    heap = [(0, p) for p in range(num_parts)]
    heapq.heapify(heap)
    for v in range(g.num_vertices):
        load, p = heapq.heappop(heap)
        part_of[v] = p
        heapq.heappush(heap, (load + int(deg[v]), p))
    return part_of


def snake_lpt(g: Graph, num_parts: int) -> np.ndarray:
    """Vectorized LPT approximation: sort by degree desc, deal out in
    alternating (snake) order. O(V log V), no Python loop."""
    deg = g.out_degrees()
    order = np.argsort(-deg, kind="stable")
    part_of = np.zeros(g.num_vertices, np.int32)
    n = g.num_vertices
    idx = np.arange(n)
    block = idx // num_parts
    pos = idx % num_parts
    snake_pos = np.where(block % 2 == 0, pos, num_parts - 1 - pos)
    part_of[order] = snake_pos.astype(np.int32)
    return part_of


def ldg(g: Graph, num_parts: int, *, eps: float = 0.1,
        chunk: int = 4096) -> np.ndarray:
    """Streaming Linear Deterministic Greedy (METIS stand-in): assign v to
    the shard maximizing |N(v) ∩ shard| * (1 - load/capacity). Processes
    vertices in chunks for speed (standard streaming approximation)."""
    V = g.num_vertices
    deg = g.out_degrees().astype(np.float64)
    capacity = (1.0 + eps) * max(1.0, deg.sum()) / num_parts
    part_of = np.full(V, -1, np.int32)
    load = np.zeros(num_parts, np.float64)

    # adjacency (undirected view) as CSR for neighbor lookup
    src = np.concatenate([g.src, g.dst])
    dst = np.concatenate([g.dst, g.src])
    order = np.argsort(src, kind="stable")
    src_s, dst_s = src[order], dst[order]
    starts = np.searchsorted(src_s, np.arange(V))
    ends = np.searchsorted(src_s, np.arange(V) + 1)

    for c0 in range(0, V, chunk):
        c1 = min(V, c0 + chunk)
        scores = np.zeros((c1 - c0, num_parts), np.float64)
        for i, v in enumerate(range(c0, c1)):
            nbr = dst_s[starts[v]:ends[v]]
            placed = part_of[nbr]
            placed = placed[placed >= 0]
            if placed.size:
                np.add.at(scores[i], placed, 1.0)
        scores *= np.maximum(0.0, 1.0 - load[None, :] / capacity)
        # Tie-break towards least-loaded shard.
        scores -= 1e-9 * load[None, :]
        choice = np.argmax(scores, axis=1).astype(np.int32)
        part_of[c0:c1] = choice
        np.add.at(load, choice, deg[c0:c1])
    return part_of


PARTITIONERS: Dict[str, Callable[..., np.ndarray]] = {
    "round_robin": round_robin,
    "greedy": greedy_edge_balance,
    "snake_lpt": snake_lpt,
    "ldg": ldg,
}


# ---------------------------------------------------------------------------
# PartitionedGraph
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PartitionedGraph:
    """Static per-shard device layout. Leading axis = shard (the paper's
    FPGA). All shapes identical across shards (SPMD)."""

    num_parts: int
    num_vertices: int
    num_edges: int
    v_max: int          # max owned vertices per shard (padded)
    e_in_max: int       # max in-edges per shard (GraVF-M layout, padded)
    e_pair_max: int     # max edges between any ordered shard pair (GraVF)

    # vertex ownership
    part_of: np.ndarray     # (V,) int32
    local_of: np.ndarray    # (V,) int32
    vert_gid: np.ndarray    # (P, v_max) int32, pad = -1
    vert_valid: np.ndarray  # (P, v_max) bool
    out_deg: np.ndarray     # (P, v_max) int32 (out-degree of owned verts)

    # GraVF-M destination-partitioned CSC (sorted by (shard, dst_local))
    in_src_slot: np.ndarray     # (P, e_in_max) int32: src as p*v_max+local
    in_src_gid: np.ndarray      # (P, e_in_max) int32
    in_src_outdeg: np.ndarray   # (P, e_in_max) int32
    in_dst_local: np.ndarray    # (P, e_in_max) int32, pad = v_max
    in_w: np.ndarray            # (P, e_in_max) float32, pad = 0
    in_valid: np.ndarray        # (P, e_in_max) bool

    # GraVF source-partitioned CSR grouped by destination shard
    pair_src_local: np.ndarray   # (P, P, e_pair_max) int32, pad = 0
    pair_src_gid: np.ndarray     # (P, P, e_pair_max) int32
    pair_src_outdeg: np.ndarray  # (P, P, e_pair_max) int32
    pair_dst_local: np.ndarray   # (P, P, e_pair_max) int32, pad = v_max
    pair_w: np.ndarray           # (P, P, e_pair_max) float32
    pair_valid: np.ndarray       # (P, P, e_pair_max) bool

    # §4.3 neighbor filter: nbr_filter[v, p] = does v have a neighbor on p.
    nbr_filter: np.ndarray  # (V, P) bool

    @property
    def slot_of(self) -> np.ndarray:
        return (self.part_of.astype(np.int64) * self.v_max
                + self.local_of).astype(np.int32)

    # -- byte-size accounting (the §5 model's m_board consumer) ------------
    @property
    def nbytes(self) -> int:
        """Total bytes across every compiled array (host mirror of what an
        engine uploads, plus the edge-list kept for stats)."""
        return int(sum(getattr(self, f.name).nbytes
                       for f in dataclasses.fields(self)
                       if isinstance(getattr(self, f.name), np.ndarray)))

    @property
    def device_nbytes(self) -> int:
        """Bytes of the per-shard layout arrays an engine turns into
        device buffers — what a memory-budgeted GraphStore charges a
        resident graph against ``Platform.m_board``. Excludes the
        host-only ``src_for_stats``/``dst_for_stats`` accounting copies."""
        skip = ("src_for_stats", "dst_for_stats")
        return int(sum(getattr(self, f.name).nbytes
                       for f in dataclasses.fields(self)
                       if f.name not in skip
                       and isinstance(getattr(self, f.name), np.ndarray)))

    # -- combine-at-source buckets (degree-factor exchange compression) ----
    def combined_buckets(self) -> Dict[str, np.ndarray]:
        """Re-sort each (source shard p, dest shard q) edge bucket by
        destination vertex and rank its DISTINCT destinations — the layout
        the ``combined`` exchange segment-reduces into before the wire.

        Returns a dict of (P, P, e_pair_max) edge arrays (the ``pair_*``
        fields reordered dst-sorted within each bucket, stable), plus:
          dst_rank : (P, P, e_pair_max) int32 — rank of the edge's dst
                     among the bucket's distinct dsts; invalid -> comb_max
                     (the per-bucket discard bin).
          comb_dst : (P, P, comb_max) int32 — the r-th distinct dst_local
                     of bucket (p, q); pad = v_max. Static layout, so the
                     receiver never needs ids on the wire.
          comb_max : max distinct dsts over all buckets, padded to a
                     multiple of 8 (the all_to_all block width).
        """
        P, E2, Vm = self.num_parts, self.e_pair_max, self.v_max
        key = np.where(self.pair_valid, self.pair_dst_local, Vm)
        order = np.argsort(key, axis=-1, kind="stable")

        def take(a):
            return np.ascontiguousarray(
                np.take_along_axis(a, order, axis=-1))

        dst = np.take_along_axis(key, order, axis=-1)
        valid = take(self.pair_valid)
        new = np.zeros_like(valid)
        new[..., 0] = valid[..., 0]
        new[..., 1:] = valid[..., 1:] & (dst[..., 1:] != dst[..., :-1])
        counts = new.sum(axis=-1)
        R = int(counts.max()) if counts.size else 1
        R = int(-(-max(R, 1) // 8) * 8)
        rank = np.cumsum(new, axis=-1) - 1
        rank = np.where(valid, rank, R).astype(np.int32)
        comb_dst = np.full((P, P, R), Vm, np.int32)
        pp, qq, _ = np.nonzero(new)
        comb_dst[pp, qq, rank[new]] = dst[new]
        return dict(
            src_local=take(self.pair_src_local),
            src_gid=take(self.pair_src_gid),
            src_outdeg=take(self.pair_src_outdeg),
            dst_local=take(self.pair_dst_local),
            w=take(self.pair_w),
            valid=valid,
            dst_rank=rank,
            comb_dst=comb_dst,
            comb_max=R,
        )

    # -- paper §4.3 accounting: how much the filter + broadcast save -------
    def comm_stats(self) -> Dict[str, float]:
        """Per-superstep worst-case traffic (units: payload words), for the
        perfmodel and EXPERIMENTS tables."""
        P = self.num_parts
        cross_mask = self.part_of[self.src_for_stats] != self.part_of[self.dst_for_stats]
        cross_edges = int(cross_mask.sum())
        bcast_updates = int(self.nbr_filter.sum()) - int(
            self.nbr_filter[np.arange(self.num_vertices), self.part_of].sum())
        return {
            "unicast_cross_edges": cross_edges,            # GraVF traffic
            "broadcast_naive": self.num_vertices * (P - 1),  # no filter
            "broadcast_filtered": bcast_updates,           # GraVF-M + filter
        }

    # stats helpers (original edge list retained for accounting only)
    src_for_stats: np.ndarray = dataclasses.field(default=None, repr=False)
    dst_for_stats: np.ndarray = dataclasses.field(default=None, repr=False)


def partition_graph(g: Graph, num_parts: int, *, method: str = "greedy",
                    pad_multiple: int = 256,
                    part_of: Optional[np.ndarray] = None) -> PartitionedGraph:
    """Compile ``g`` into the two padded shard layouts of Fig. 4."""
    P = num_parts
    if part_of is None:
        part_of = PARTITIONERS[method](g, P)
    part_of = part_of.astype(np.int32)
    V = g.num_vertices

    # local indices per shard, in global-id order (stable)
    local_of = np.zeros(V, np.int32)
    counts = np.zeros(P, np.int64)
    order = np.argsort(part_of, kind="stable")
    # rank within shard
    sorted_parts = part_of[order]
    ranks = np.arange(V) - np.searchsorted(sorted_parts, sorted_parts)
    local_of[order] = ranks.astype(np.int32)
    counts = np.bincount(part_of, minlength=P).astype(np.int64)

    def up(n, m):
        return int(-(-max(n, 1) // m) * m)

    v_max = up(int(counts.max()) if V else 1, pad_multiple)

    vert_gid = np.full((P, v_max), -1, np.int32)
    vert_valid = np.zeros((P, v_max), bool)
    out_deg_g = g.out_degrees().astype(np.int32)
    out_deg = np.zeros((P, v_max), np.int32)
    vert_gid[part_of, local_of] = np.arange(V, dtype=np.int32)
    vert_valid[part_of, local_of] = True
    out_deg[part_of, local_of] = out_deg_g

    w = g.weights if g.weights is not None else np.ones(g.num_edges, np.float32)
    src, dst = g.src, g.dst
    slot_of = (part_of.astype(np.int64) * v_max + local_of).astype(np.int32)

    # ---- GraVF-M: dst-partitioned CSC ------------------------------------
    dpart = part_of[dst]
    dloc = local_of[dst]
    key = dpart.astype(np.int64) * (v_max + 1) + dloc
    eorder = np.argsort(key, kind="stable")
    e_counts = np.bincount(dpart, minlength=P).astype(np.int64)
    e_in_max = up(int(e_counts.max()) if g.num_edges else 1, pad_multiple)

    in_src_slot = np.zeros((P, e_in_max), np.int32)
    in_src_gid = np.zeros((P, e_in_max), np.int32)
    in_src_outdeg = np.ones((P, e_in_max), np.int32)
    in_dst_local = np.full((P, e_in_max), v_max, np.int32)
    in_w = np.zeros((P, e_in_max), np.float32)
    in_valid = np.zeros((P, e_in_max), bool)

    es, ed, ew = src[eorder], dst[eorder], w[eorder]
    edp = dpart[eorder]
    starts = np.searchsorted(edp, np.arange(P))
    ends = np.searchsorted(edp, np.arange(P) + 1)
    for p in range(P):
        s, e = int(starts[p]), int(ends[p])
        n = e - s
        if n == 0:
            continue
        in_src_slot[p, :n] = slot_of[es[s:e]]
        in_src_gid[p, :n] = es[s:e]
        in_src_outdeg[p, :n] = np.maximum(1, out_deg_g[es[s:e]])
        in_dst_local[p, :n] = local_of[ed[s:e]]
        in_w[p, :n] = ew[s:e]
        in_valid[p, :n] = True

    # ---- GraVF: src-partitioned, grouped by destination shard ------------
    spart = part_of[src]
    pair_key = (spart.astype(np.int64) * P + dpart)
    porder = np.argsort(pair_key, kind="stable")
    pair_counts = np.bincount(pair_key, minlength=P * P).astype(np.int64)
    e_pair_max = up(int(pair_counts.max()) if g.num_edges else 1,
                    max(8, pad_multiple // 8))

    pair_src_local = np.zeros((P, P, e_pair_max), np.int32)
    pair_src_gid = np.zeros((P, P, e_pair_max), np.int32)
    pair_src_outdeg = np.ones((P, P, e_pair_max), np.int32)
    pair_dst_local = np.full((P, P, e_pair_max), v_max, np.int32)
    pair_w = np.zeros((P, P, e_pair_max), np.float32)
    pair_valid = np.zeros((P, P, e_pair_max), bool)

    ps, pd, pw = src[porder], dst[porder], w[porder]
    pk = pair_key[porder]
    pstarts = np.searchsorted(pk, np.arange(P * P))
    pends = np.searchsorted(pk, np.arange(P * P) + 1)
    for pq in range(P * P):
        s, e = int(pstarts[pq]), int(pends[pq])
        n = e - s
        if n == 0:
            continue
        p, q = pq // P, pq % P
        pair_src_local[p, q, :n] = local_of[ps[s:e]]
        pair_src_gid[p, q, :n] = ps[s:e]
        pair_src_outdeg[p, q, :n] = np.maximum(1, out_deg_g[ps[s:e]])
        pair_dst_local[p, q, :n] = local_of[pd[s:e]]
        pair_w[p, q, :n] = pw[s:e]
        pair_valid[p, q, :n] = True

    # ---- neighbor filter bitmap (§4.3) -----------------------------------
    nbr_filter = np.zeros((V, P), bool)
    nbr_filter[src, dpart] = True

    return PartitionedGraph(
        num_parts=P, num_vertices=V, num_edges=g.num_edges,
        v_max=v_max, e_in_max=e_in_max, e_pair_max=e_pair_max,
        part_of=part_of, local_of=local_of,
        vert_gid=vert_gid, vert_valid=vert_valid, out_deg=out_deg,
        in_src_slot=in_src_slot, in_src_gid=in_src_gid,
        in_src_outdeg=in_src_outdeg, in_dst_local=in_dst_local,
        in_w=in_w, in_valid=in_valid,
        pair_src_local=pair_src_local, pair_src_gid=pair_src_gid,
        pair_src_outdeg=pair_src_outdeg, pair_dst_local=pair_dst_local,
        pair_w=pair_w, pair_valid=pair_valid,
        nbr_filter=nbr_filter,
        src_for_stats=src, dst_for_stats=dst,
    )


def edge_balance(pg: PartitionedGraph) -> Dict[str, float]:
    """Imbalance metrics for Fig. 12/13 style experiments."""
    per_shard = pg.in_valid.sum(axis=1).astype(np.float64)
    mean = per_shard.mean() if per_shard.size else 0.0
    return {
        "max_over_mean": float(per_shard.max() / max(mean, 1e-9)),
        "cross_frac": float(
            (pg.part_of[pg.src_for_stats] != pg.part_of[pg.dst_for_stats]).mean()
            if pg.num_edges else 0.0),
    }
