"""Step-granular superstep core.

The engines used to bake the whole superstep iteration into one opaque
``jax.lax.while_loop``: you could run a query to completion, but nothing
could observe or intervene *between* supersteps. :class:`SuperstepProgram`
factors that loop into three small pure functions over an explicit
:class:`StepCarry`:

  init_carry(data, params, query_kwargs) -> carry
      kernel ``init_state`` + the superstep-0 ``apply`` (paper §4.3: "the
      barrier is injected into the apply modules to begin execution").
  step(data, carry) -> carry
      exactly ONE superstep: deliver (broadcast/exchange + receiver-side
      scatter + gather-combine) -> gather -> stats -> next apply.
  alive(carry)
      the per-program termination bit (any vertex still active).

The same traced ``step`` is then driven three ways:

  * ``while_run`` — a ``lax.while_loop`` over ``step``: the engines'
    fast path, bit-identical to the pre-refactor monolithic loop (same
    ops in the same order, same trace counts).
  * ``jax.vmap`` of ``while_run`` / of ``step`` — the query-batched
    paths (``run_batch`` and the shard_map batched program).
  * :class:`LaneStepper` — a host-drivable W-lane handle (jitted
    admit/step/probe) that the service's continuous scheduler uses to
    retire finished queries mid-flight and splice newly arrived roots
    into freed lanes between supersteps.

Both engines parameterize the program with their own ``deliver`` (which
collective moves the updates) and stats fold; the loop structure lives
here once.

Because the carry is explicit, a lane is *preemptible*: between
supersteps its carry slice is host-fetchable (``fetch_lane``) and can be
spliced back later (``restore``) to resume bit-identically — something a
whole-run ``lax.while_loop`` can never offer. :class:`LaneTable` packages
that lifecycle (slot occupancy, per-lane scheduling metadata, the
checkpoint/restore verbs) for the service's continuous scheduler.
"""
from __future__ import annotations

import dataclasses
import time
from typing import (Any, Callable, Dict, List, NamedTuple, Optional,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["StepCarry", "SuperstepProgram", "LaneStepper",
           "LaneStepperBase", "select_lanes",
           "LaneMeta", "LaneCheckpoint", "LaneTable", "lane_dtype",
           "PRIORITY_BOOST_S"]

# One request-priority level is worth this many seconds of deadline
# urgency. Kept finite (rather than a lexicographic priority dimension)
# so a parked lane's deadline-aging credit can eventually exceed ANY
# priority boost — the starvation-freedom guarantee.
PRIORITY_BOOST_S = 60.0


class StepCarry(NamedTuple):
    """Everything one in-flight query owns between supersteps."""
    state: Any              # kernel state pytree of per-vertex arrays
    payload: jnp.ndarray    # pending update values (apply output)
    active: jnp.ndarray     # pending update mask
    superstep: jnp.ndarray  # int32 supersteps completed
    stats: Dict[str, jnp.ndarray]


def select_lanes(mask, new, old):
    """Per-lane carry select: lanes where ``mask`` is True take ``new``,
    the rest keep ``old`` (the explicit form of the freeze that vmap of
    while_loop performs on finished lanes)."""
    def sel(n, o):
        b = mask.reshape((mask.shape[0],) + (1,) * (n.ndim - 1))
        return jnp.where(b, n, o)
    return jax.tree.map(sel, new, old)


class SuperstepProgram:
    """init/step/alive for one (kernel, graph layout, deliver) triple.

    ``deliver(data, payload, active)`` returns ``(acc, got, carry_vals,
    aux)`` where ``aux`` is a dict of per-superstep scalars folded into
    the running stats by ``update_stats(stats, data, active, aux)``
    (``active`` is the pre-apply mask of the superstep being folded).
    ``global_any`` reduces the local activity bit across shards
    (identity for the global-array engine, ``pmax`` inside shard_map).
    """

    def __init__(self, kernel, deliver: Callable[..., Any], *,
                 init_stats: Callable[[], Dict[str, jnp.ndarray]],
                 update_stats: Callable[..., Dict[str, jnp.ndarray]],
                 global_any: Optional[Callable[[jnp.ndarray],
                                               jnp.ndarray]] = None):
        self.kernel = kernel
        self.deliver = deliver
        self.init_stats = init_stats
        self.update_stats = update_stats
        self.global_any = global_any or (lambda b: b)

    # ------------------------------------------------------------------
    def init_carry(self, data, params: Dict[str, Any],
                   query_kwargs: Dict[str, Any]) -> StepCarry:
        k = self.kernel
        state = k.init_state(data.vert_gid, data.out_deg, data.vert_valid,
                             **{**params, **query_kwargs})
        state, payload, active = k.apply(state, data.vert_gid,
                                         data.out_deg, 0)
        active = active & data.vert_valid
        return StepCarry(state, payload, active, jnp.int32(0),
                         self.init_stats())

    # The superstep, split at the paper's pipeline-stage boundaries so a
    # profiled stepper can host-time each piece (scatter ~ L_mem, combine
    # + apply ~ L_PE/L_node, the deliver collective ~ L_if/L_net — see
    # perfmodel.PHASE_TERMS). ``step`` composes them back into the exact
    # pre-split op sequence, so the fused fast path traces identically.

    def step_deliver(self, data, carry: StepCarry):
        """Scatter/exchange: move this superstep's pending updates to
        their receivers. Returns the opaque delivered tuple
        ``(acc, got, carry_vals, aux)`` that ``step_combine`` folds."""
        return self.deliver(data, carry.payload, carry.active)

    def step_combine(self, data, carry: StepCarry, delivered) -> StepCarry:
        """Gather-combine the delivered updates into vertex state and
        fold the superstep's stats. Same superstep index as ``carry``
        (the counter advances in ``step_apply``)."""
        k = self.kernel
        state, payload, active, s, stats = carry
        acc, got, carry_v, aux = delivered
        if k.carry_dtype is not None:
            state = k.gather(state, acc, carry_v, got, s)
        else:
            state = k.gather(state, acc, got, s)
        stats = self.update_stats(stats, data, active, aux)
        return StepCarry(state, payload, active, s, stats)

    def step_exchange(self, data, carry: StepCarry) -> StepCarry:
        """deliver + combine fused — the shard stepper's profiled unit
        (inside shard_map the collective and the receiver-side fold
        cannot be host-separated without materializing per-shard
        intermediates)."""
        return self.step_combine(data, carry,
                                 self.step_deliver(data, carry))

    def step_apply(self, data, mid: StepCarry) -> StepCarry:
        """The vertex apply of the *next* superstep's updates: advances
        the superstep counter and re-masks activity."""
        k = self.kernel
        state, _, active, s, stats = mid
        state, payload, active = k.apply(state, data.vert_gid,
                                         data.out_deg, s + 1)
        active = active & data.vert_valid
        return StepCarry(state, payload, active, s + 1, stats)

    def step(self, data, carry: StepCarry) -> StepCarry:
        return self.step_apply(data, self.step_exchange(data, carry))

    def alive(self, carry: StepCarry) -> jnp.ndarray:
        return self.global_any(jnp.any(carry.active))

    def is_done(self, carry: StepCarry) -> jnp.ndarray:
        return ~self.alive(carry)

    # ------------------------------------------------------------------
    def while_run(self, data, cap, params: Dict[str, Any],
                  query_kwargs: Dict[str, Any]) -> StepCarry:
        """The fast path: run to quiescence (or ``cap``) in one
        ``lax.while_loop`` over ``step``."""
        carry = self.init_carry(data, params, query_kwargs)

        def cond(c):
            return self.alive(c) & (c.superstep < cap)

        def body(c):
            return self.step(data, c)

        return jax.lax.while_loop(cond, body, carry)


class LaneStepperBase:
    """Host-side plumbing shared by every lane stepper (the global-array
    LaneStepper below and engine_shardmap's ShardLaneStepper): the
    (carry, lane_active, supersteps) return contract, kwarg upload, and
    host fetch. Subclasses provide the jitted ``_init``/``_admit``/
    ``_step``/``_probe``/``_fetch_lane``/``_restore`` programs (the
    lane-indexing axis differs: the global-array stepper's carry leads
    with the lane axis, the shard stepper's with the shard axis)."""

    # cumulative wire words (across all lanes) as of the last dispatch —
    # updated by ``_unpack`` when the fused probe carries a words element;
    # LaneTable.step turns consecutive values into per-superstep deltas
    # for the trace bus.
    last_wire_words: float = 0.0

    # Opt-in phase profiling: when True, ``step`` dispatches the
    # superstep as separate phase programs with a ``block_until_ready``
    # host-timing boundary between them and leaves the wall split in
    # ``last_phases`` ({phase: seconds}); the default fused single
    # dispatch is untouched and leaves it None. The phase select/masking
    # is identical to the fused path, so results are bit-identical —
    # only the dispatch granularity (and therefore XLA's fusion scope
    # and the wall clock) changes.
    profile: bool = False
    last_phases: Optional[Dict[str, float]] = None

    def _unpack(self, out):
        carry = out[0]
        if len(out) > 3:
            self.last_wire_words = float(np.asarray(out[3]))
        return carry, np.asarray(out[1]), np.asarray(out[2])

    @staticmethod
    def _qdev(qkw: Dict[str, np.ndarray]):
        return {k: jnp.asarray(v) for k, v in qkw.items()}

    def probe(self, carry: StepCarry):
        out = self._probe(carry)
        return np.asarray(out[0]), np.asarray(out[1])

    def fetch(self, carry: StepCarry) -> StepCarry:
        return jax.tree.map(np.asarray, carry)

    def fetch_lane(self, carry: StepCarry, lane: int) -> StepCarry:
        """Host copy of exactly ONE lane's carry slice (the checkpoint
        payload): only that lane's bytes cross the device->host boundary,
        not the whole slot array. The lane index is a traced scalar, so
        parking different lanes re-traces nothing."""
        return jax.tree.map(np.asarray,
                            self._fetch_lane(carry, jnp.int32(lane)))

    def restore(self, carry: StepCarry, lane_carry: StepCarry,
                fresh: np.ndarray):
        """Splice a checkpointed lane's carry back into ``fresh`` slots
        of the in-flight slot array — the admit-path select with the
        parked carry instead of a fresh ``init_carry``, so the lane
        resumes bit-identically from its parked superstep (state,
        superstep counter and running stats all survive verbatim)."""
        if getattr(self, "_restore", None) is None:
            raise RuntimeError(
                "stepper has no compiled programs yet; init() a slot "
                "array before restoring a checkpoint into it")
        lane_dev = jax.tree.map(jnp.asarray, lane_carry)
        return self._unpack(self._restore(carry, lane_dev,
                                          jnp.asarray(fresh)))

    def bind_data(self, data) -> None:
        """Swap the graph-layout pytree the jitted programs are driven
        with — the engine's offload/upload across the store's host-spill
        tier. Shapes/dtypes must match the original (the jit caches key
        on avals, so a rebind re-traces nothing)."""
        self._data = data


class LaneStepper(LaneStepperBase):
    """Host-drivable fixed-width slot array over a SuperstepProgram.

    All functions are jitted once per (width, dtypes) signature; the
    fresh/alive masks are traced values, so steady-state slot recycling
    re-traces nothing (``trace_hook`` — usually the owning engine's
    trace counter bump — fires at trace time only, which the service's
    plan cache asserts against).

    ``init``/``admit``/``step`` return ``(carry, lane_active (W,),
    supersteps (W,))`` — the probe is fused into the same device call,
    so the continuous scheduler's steady state costs exactly ONE
    dispatch per superstep (and blocks on only 2·W scalars, not the
    vertex state).

      init(qkw)                -> all W lanes initialized
      admit(carry, qkw, fresh) -> ``fresh`` lanes re-initialized
      step(carry, alive)       -> one superstep for ``alive`` lanes,
                                  everything else frozen
      probe(carry)             -> host (lane_active (W,), supersteps (W,))
      fetch(carry)             -> host copy of the whole carry
    """

    def __init__(self, prog: SuperstepProgram, data, params: Dict[str, Any],
                 width: int, *, trace_hook: Callable[[], None] = None,
                 wire_stat: Optional[str] = None):
        self.width = width
        hook = trace_hook or (lambda: None)

        def probe_of(carry):
            # ``wire_stat`` names the stats entry that counts words this
            # engine's scheme actually puts on the wire; its lane sum
            # rides the fused probe so per-superstep traffic telemetry
            # costs no extra dispatch (see LaneStepperBase._unpack)
            out = (jax.vmap(lambda c: jnp.any(c.active))(carry),
                   carry.superstep)
            if wire_stat is not None:
                out = out + (jnp.sum(carry.stats[wire_stat]),)
            return out

        def init_fn(d, qkw):
            hook()
            c = jax.vmap(lambda kw: prog.init_carry(d, params, kw))(qkw)
            return (c, *probe_of(c))

        def admit_fn(d, carry, qkw, fresh):
            hook()
            new = jax.vmap(
                lambda kw: prog.init_carry(d, params, kw))(qkw)
            c = select_lanes(fresh, new, carry)
            return (c, *probe_of(c))

        def step_fn(d, carry, alive):
            hook()
            new = jax.vmap(lambda c: prog.step(d, c))(carry)
            c = select_lanes(alive, new, carry)
            return (c, *probe_of(c))

        # profiled-mode phase programs (traced only if profiling is ever
        # turned on): the same superstep as step_fn, cut at the
        # scatter / combine / apply boundaries so the host can time each
        def deliver_fn(d, carry):
            hook()
            return jax.vmap(lambda c: prog.step_deliver(d, c))(carry)

        def combine_fn(d, carry, delivered):
            hook()
            return jax.vmap(
                lambda c, dv: prog.step_combine(d, c, dv))(carry, delivered)

        def apply_fn(d, carry, mid, alive):
            hook()
            new = jax.vmap(lambda c: prog.step_apply(d, c))(mid)
            return select_lanes(alive, new, carry)

        def fetch_lane_fn(carry, lane):
            hook()
            return jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, lane, 0, keepdims=False), carry)

        def restore_fn(carry, lane_carry, fresh):
            hook()
            new = jax.tree.map(
                lambda leaf: jnp.broadcast_to(leaf[None],
                                              (width,) + leaf.shape),
                lane_carry)
            c = select_lanes(fresh, new, carry)
            return (c, *probe_of(c))

        self._data = data
        self._init = jax.jit(init_fn)
        self._admit = jax.jit(admit_fn)
        self._step = jax.jit(step_fn)
        self._probe = jax.jit(probe_of)
        self._fetch_lane = jax.jit(fetch_lane_fn)
        self._restore = jax.jit(restore_fn)
        self._deliver_p = jax.jit(deliver_fn)
        self._combine_p = jax.jit(combine_fn)
        self._apply_p = jax.jit(apply_fn)

    def init(self, qkw: Dict[str, np.ndarray]):
        return self._unpack(self._init(self._data, self._qdev(qkw)))

    def admit(self, carry: StepCarry, qkw: Dict[str, np.ndarray],
              fresh: np.ndarray):
        return self._unpack(self._admit(self._data, carry,
                                        self._qdev(qkw),
                                        jnp.asarray(fresh)))

    def step(self, carry: StepCarry, alive: np.ndarray):
        if not self.profile:
            self.last_phases = None
            return self._unpack(self._step(self._data, carry,
                                           jnp.asarray(alive)))
        return self._profiled_step(carry, alive)

    def _profiled_step(self, carry: StepCarry, alive: np.ndarray):
        """One superstep as four phase dispatches with host-timed
        ``block_until_ready`` boundaries. Same ops and the same
        select/masking as the fused path (bit-identical results); the
        extra syncs are the profiling overhead, which is exactly what
        makes the per-phase wall split measurable."""
        d, alive_dev = self._data, jnp.asarray(alive)
        phases: Dict[str, float] = {}
        t = time.perf_counter()
        delivered = self._deliver_p(d, carry)
        jax.block_until_ready(delivered)
        now = time.perf_counter()
        phases["scatter"] = now - t
        t = now
        mid = self._combine_p(d, carry, delivered)
        jax.block_until_ready(mid)
        now = time.perf_counter()
        phases["combine"] = now - t
        t = now
        new = self._apply_p(d, carry, mid, alive_dev)
        jax.block_until_ready(new)
        now = time.perf_counter()
        phases["apply"] = now - t
        t = now
        out = self._probe(new)
        act, steps = np.asarray(out[0]), np.asarray(out[1])
        if len(out) > 2:
            self.last_wire_words = float(np.asarray(out[2]))
        phases["probe"] = time.perf_counter() - t
        self.last_phases = phases
        return new, act, steps


# ---------------------------------------------------------------------------
# lane lifecycle: LaneTable + checkpoint/restore
# ---------------------------------------------------------------------------

def lane_dtype(value) -> np.dtype:
    """Canonical lane-array dtype for a query kwarg (matches the int32 /
    float32 the kernels trace with, so admits never change signature)."""
    a = np.asarray(value)
    if a.dtype.kind in "iub":
        return np.dtype(np.int32)
    if a.dtype.kind == "f":
        return np.dtype(np.float32)
    return a.dtype


@dataclasses.dataclass
class LaneMeta:
    """Per-lane scheduling metadata. ``payload`` is opaque to the core
    (the service stores its (request, future) pair there); everything
    else is what admission, preemption and depth packing decide on.

    ``credit_s`` is the deadline-aging credit a lane accrues while
    parked: the scheduler subtracts it from ``deadline_s`` when ranking,
    so a repeatedly preempted query becomes monotonically more urgent
    and cannot starve (and, once restored, is not the first victim of
    the next preemption)."""

    payload: Any
    qkw: Dict[str, Any]
    tenant: str = "default"
    priority: int = 0
    deadline_s: float = float("inf")
    predicted_depth: float = 0.0
    credit_s: float = 0.0
    parks: int = 0
    seq: int = 0
    # depth-prediction bucket label ("d<decile>" of the root's degree,
    # or None): which per-bucket depth EWMA predicted_depth came from —
    # retirement scores the observation back into the same bucket
    depth_bucket: Optional[str] = None

    def effective_deadline(self) -> float:
        """Scalar urgency (smaller = more urgent): the deadline minus
        the aging credit, with each priority level worth
        :data:`PRIORITY_BOOST_S` seconds. Priority therefore dominates
        ordinary deadline spreads, while a long-parked lane's credit
        grows without bound and eventually outranks any priority."""
        return (self.deadline_s - self.credit_s
                - PRIORITY_BOOST_S * float(self.priority))


@dataclasses.dataclass
class LaneCheckpoint:
    """One parked lane: the host copy of its carry slice plus its
    metadata. ``restore`` splices the carry back into a free slot and
    the query resumes bit-identically from ``superstep`` — state,
    superstep counter and running stats are all part of the carry."""

    carry: StepCarry
    meta: LaneMeta
    superstep: int
    nbytes: int


class LaneTable:
    """First-class lane lifecycle for one stepper's W-wide slot array.

    Owns slot occupancy, the device carry + host probe mirrors
    (``act``/``steps``), the per-lane kwarg arrays, and the per-lane
    :class:`LaneMeta`. The scheduler's policy (who gets a slot, who is
    preempted) stays outside; the mechanics of the four lifecycle verbs
    live here:

      admit(assignments)    — splice fresh queries into free slots (one
                              lane-masked device call for all of them)
      step(alive)           — one superstep for the alive lanes
      checkpoint(slot)      — fetch ONLY that lane's carry slice to host
                              and free the slot (zero re-traces; the
                              preemption "park" half)
      restore(slot, ckpt)   — splice a parked carry back into a free
                              slot via the admit-path select; the lane
                              resumes bit-identically from its parked
                              superstep

    Freed/parked lanes' stale device carry stays in place until a later
    admit/restore overwrites it — the lane-masked select never steps an
    unoccupied lane, so it is inert.

    ``trace`` is an optional duck-typed event bus (anything with an
    ``emit(kind, **fields)`` method — in practice the service layer's
    ``TraceBus``; the core stays import-free of the service package).
    When set, ``step`` emits one ``superstep`` event per dispatch with
    the lane→query attribution (slot -> meta.seq) of the lanes that
    actually stepped, so a query span can be reconstructed into its
    active vs parked intervals.
    """

    def __init__(self, stepper, width: int, query_params, *,
                 trace=None, label: Optional[str] = None,
                 devices: Tuple[str, ...] = ()):
        self.stepper = stepper
        self.width = width
        self.query_params = tuple(query_params)
        self.trace = trace
        self.label = label
        # mesh device attribution for superstep events (shard steppers
        # dispatch to every device of their 1-D graph mesh; () for
        # single-device tables keeps those events unchanged)
        self.devices = tuple(devices)
        self.meta: List[Optional[LaneMeta]] = [None] * width
        self.carry = None
        self.act: Optional[np.ndarray] = None    # (W,) lane-alive probe
        self.steps: Optional[np.ndarray] = None  # (W,) lane supersteps
        self._qkw: Optional[Dict[str, np.ndarray]] = None

    # ---------------- occupancy ---------------------------------------
    @property
    def occupied(self) -> np.ndarray:
        return np.array([m is not None for m in self.meta], bool)

    def in_flight(self) -> int:
        return sum(m is not None for m in self.meta)

    def free_slots(self) -> List[int]:
        return [i for i, m in enumerate(self.meta) if m is None]

    def lanes_of(self, tenant: str) -> int:
        return sum(1 for m in self.meta
                   if m is not None and m.tenant == tenant)

    def active_slots(self) -> List[int]:
        return [i for i, m in enumerate(self.meta) if m is not None]

    def alive_mask(self, cap: int) -> np.ndarray:
        return self.occupied & self.act & (self.steps < cap)

    def done_slots(self, cap: int) -> List[int]:
        """Occupied lanes whose termination mask flipped or that hit the
        superstep cap — ready to retire."""
        return [i for i in range(self.width)
                if self.meta[i] is not None
                and (not self.act[i] or self.steps[i] >= cap)]

    def lane_nbytes(self) -> int:
        """Host bytes one lane's checkpoint occupies (every carry leaf's
        lane axis divides its bytes evenly across the W lanes)."""
        if self.carry is None:
            return 0
        return int(sum(a.nbytes for a in jax.tree.leaves(self.carry))
                   // self.width)

    def predicted_remaining(self, slot: int, residual: float = 1.0
                            ) -> float:
        """Predicted supersteps this lane still needs: its admission-time
        depth prediction minus observed progress; a lane that outlived
        its prediction falls back to the class's observed-depth residual
        (the expected overshoot), floored at one superstep."""
        m = self.meta[slot]
        rem = m.predicted_depth - float(self.steps[slot])
        return rem if rem > 0 else max(float(residual), 1.0)

    # ---------------- lifecycle verbs ---------------------------------
    def _ensure_qkw(self, meta: LaneMeta) -> None:
        if self._qkw is None:
            # lane arrays keyed by the kernel's DECLARED params (not one
            # request's keys), seeded with this request's values — idle
            # lanes then hold a valid query, like the bucketed batcher's
            # padding lanes
            self._qkw = {p: np.full((self.width,), meta.qkw[p],
                                    dtype=lane_dtype(meta.qkw[p]))
                         for p in self.query_params}

    def admit(self, assignments: Dict[int, LaneMeta]) -> None:
        """Splice fresh queries into the given free slots — one
        lane-masked ``init_carry`` select for all of them."""
        if not assignments:
            return
        fresh = np.zeros(self.width, bool)
        # install EVERY meta before anything that can raise: a failure
        # below (missing declared param, device error) then finds all
        # affected lanes in the table, so the class-failure path can
        # resolve their futures instead of stranding them
        for slot, meta in assignments.items():
            assert self.meta[slot] is None, f"slot {slot} occupied"
            self.meta[slot] = meta
            fresh[slot] = True
        for slot, meta in assignments.items():
            self._ensure_qkw(meta)
            for p in self._qkw:
                # a missing declared param raises here and fails the
                # class loudly instead of silently reusing the slot's
                # previous occupant's value
                self._qkw[p][slot] = meta.qkw[p]
        if self.carry is None:
            self.carry, self.act, self.steps = self.stepper.init(self._qkw)
        else:
            self.carry, self.act, self.steps = self.stepper.admit(
                self.carry, self._qkw, fresh)

    def step(self, alive: np.ndarray) -> None:  # analysis: host
        if self.trace is None:
            self.carry, self.act, self.steps = self.stepper.step(
                self.carry, alive)
            return
        # lane->query attribution captured BEFORE the dispatch (a lane
        # that retires this superstep must still be attributed to it)
        lanes = {int(i): self.meta[i].seq
                 for i in np.flatnonzero(alive) if self.meta[i] is not None}
        w0 = getattr(self.stepper, "last_wire_words", 0.0)
        t0 = time.perf_counter()
        self.carry, self.act, self.steps = self.stepper.step(
            self.carry, alive)
        # the probe arrays in the return are host numpy, so perf_counter
        # here bounds the full dispatch+sync, not just the enqueue
        w1 = getattr(self.stepper, "last_wire_words", 0.0)
        extra = {}
        ph = getattr(self.stepper, "last_phases", None)
        if ph is not None:
            # profiled mode: the measured scatter/combine/apply/probe
            # wall split rides the event (Perfetto args pane / L_* term
            # comparison against perfmodel.phase_projection)
            extra["phase"] = dict(ph)
        if self.devices:
            # per-device attribution: the mesh devices this dispatch
            # fanned out to (single-device tables omit the column)
            extra["devices"] = list(self.devices)
        self.trace.emit("superstep", klass=self.label,
                        ts=t0, dur_s=time.perf_counter() - t0,
                        lanes=lanes, n_alive=len(lanes),
                        words=max(0.0, w1 - w0), **extra)

    def fetch(self) -> StepCarry:
        return self.stepper.fetch(self.carry)

    def release(self, slot: int) -> LaneMeta:
        """Free one retired lane's slot; returns its metadata."""
        meta = self.meta[slot]
        self.meta[slot] = None
        return meta

    def checkpoint(self, slot: int) -> LaneCheckpoint:
        """Park one lane: fetch its carry slice to host and free the
        slot. The device never sees a shape change and the fetch is
        jitted once, so parking re-traces nothing."""
        meta = self.meta[slot]
        assert meta is not None, f"slot {slot} is empty"
        nbytes = self.lane_nbytes()
        lane = self.stepper.fetch_lane(self.carry, slot)
        self.meta[slot] = None
        meta.parks += 1
        return LaneCheckpoint(carry=lane, meta=meta,
                              superstep=int(self.steps[slot]),
                              nbytes=nbytes)

    def restore(self, slot: int, ckpt: LaneCheckpoint) -> None:
        """Un-park a checkpointed lane into a free slot. The splice goes
        through the same lane-masked select as ``admit``, so the resumed
        computation is bit-identical to never having been parked."""
        assert self.meta[slot] is None, f"slot {slot} occupied"
        meta = ckpt.meta
        # meta first (see admit): a failure in the splice below must
        # leave the lane visible to the class-failure path
        self.meta[slot] = meta
        self._ensure_qkw(meta)
        for p in self._qkw:
            self._qkw[p][slot] = meta.qkw[p]
        if self.carry is None:
            # empty table: materialize a carry first (idle lanes hold a
            # valid dummy query), then overwrite the restored slot
            self.carry, self.act, self.steps = self.stepper.init(self._qkw)
        fresh = np.zeros(self.width, bool)
        fresh[slot] = True
        self.carry, self.act, self.steps = self.stepper.restore(
            self.carry, ckpt.carry, fresh)

    def clear(self) -> List[LaneMeta]:
        """Drop every lane (class failure path); returns the metadata of
        the lanes that were occupied."""
        out = [m for m in self.meta if m is not None]
        self.meta = [None] * self.width
        self.carry = self.act = self.steps = None
        return out
