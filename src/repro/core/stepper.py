"""Step-granular superstep core.

The engines used to bake the whole superstep iteration into one opaque
``jax.lax.while_loop``: you could run a query to completion, but nothing
could observe or intervene *between* supersteps. :class:`SuperstepProgram`
factors that loop into three small pure functions over an explicit
:class:`StepCarry`:

  init_carry(data, params, query_kwargs) -> carry
      kernel ``init_state`` + the superstep-0 ``apply`` (paper §4.3: "the
      barrier is injected into the apply modules to begin execution").
  step(data, carry) -> carry
      exactly ONE superstep: deliver (broadcast/exchange + receiver-side
      scatter + gather-combine) -> gather -> stats -> next apply.
  alive(carry)
      the per-program termination bit (any vertex still active).

The same traced ``step`` is then driven three ways:

  * ``while_run`` — a ``lax.while_loop`` over ``step``: the engines'
    fast path, bit-identical to the pre-refactor monolithic loop (same
    ops in the same order, same trace counts).
  * ``jax.vmap`` of ``while_run`` / of ``step`` — the query-batched
    paths (``run_batch`` and the shard_map batched program).
  * :class:`LaneStepper` — a host-drivable W-lane handle (jitted
    admit/step/probe) that the service's continuous scheduler uses to
    retire finished queries mid-flight and splice newly arrived roots
    into freed lanes between supersteps.

Both engines parameterize the program with their own ``deliver`` (which
collective moves the updates) and stats fold; the loop structure lives
here once.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["StepCarry", "SuperstepProgram", "LaneStepper",
           "LaneStepperBase", "select_lanes"]


class StepCarry(NamedTuple):
    """Everything one in-flight query owns between supersteps."""
    state: Any              # kernel state pytree of per-vertex arrays
    payload: jnp.ndarray    # pending update values (apply output)
    active: jnp.ndarray     # pending update mask
    superstep: jnp.ndarray  # int32 supersteps completed
    stats: Dict[str, jnp.ndarray]


def select_lanes(mask, new, old):
    """Per-lane carry select: lanes where ``mask`` is True take ``new``,
    the rest keep ``old`` (the explicit form of the freeze that vmap of
    while_loop performs on finished lanes)."""
    def sel(n, o):
        b = mask.reshape((mask.shape[0],) + (1,) * (n.ndim - 1))
        return jnp.where(b, n, o)
    return jax.tree.map(sel, new, old)


class SuperstepProgram:
    """init/step/alive for one (kernel, graph layout, deliver) triple.

    ``deliver(data, payload, active)`` returns ``(acc, got, carry_vals,
    aux)`` where ``aux`` is a dict of per-superstep scalars folded into
    the running stats by ``update_stats(stats, data, active, aux)``
    (``active`` is the pre-apply mask of the superstep being folded).
    ``global_any`` reduces the local activity bit across shards
    (identity for the global-array engine, ``pmax`` inside shard_map).
    """

    def __init__(self, kernel, deliver: Callable[..., Any], *,
                 init_stats: Callable[[], Dict[str, jnp.ndarray]],
                 update_stats: Callable[..., Dict[str, jnp.ndarray]],
                 global_any: Optional[Callable[[jnp.ndarray],
                                               jnp.ndarray]] = None):
        self.kernel = kernel
        self.deliver = deliver
        self.init_stats = init_stats
        self.update_stats = update_stats
        self.global_any = global_any or (lambda b: b)

    # ------------------------------------------------------------------
    def init_carry(self, data, params: Dict[str, Any],
                   query_kwargs: Dict[str, Any]) -> StepCarry:
        k = self.kernel
        state = k.init_state(data.vert_gid, data.out_deg, data.vert_valid,
                             **{**params, **query_kwargs})
        state, payload, active = k.apply(state, data.vert_gid,
                                         data.out_deg, 0)
        active = active & data.vert_valid
        return StepCarry(state, payload, active, jnp.int32(0),
                         self.init_stats())

    def step(self, data, carry: StepCarry) -> StepCarry:
        k = self.kernel
        state, payload, active, s, stats = carry
        acc, got, carry_v, aux = self.deliver(data, payload, active)
        if k.carry_dtype is not None:
            state = k.gather(state, acc, carry_v, got, s)
        else:
            state = k.gather(state, acc, got, s)
        stats = self.update_stats(stats, data, active, aux)
        state, payload, active = k.apply(state, data.vert_gid,
                                         data.out_deg, s + 1)
        active = active & data.vert_valid
        return StepCarry(state, payload, active, s + 1, stats)

    def alive(self, carry: StepCarry) -> jnp.ndarray:
        return self.global_any(jnp.any(carry.active))

    def is_done(self, carry: StepCarry) -> jnp.ndarray:
        return ~self.alive(carry)

    # ------------------------------------------------------------------
    def while_run(self, data, cap, params: Dict[str, Any],
                  query_kwargs: Dict[str, Any]) -> StepCarry:
        """The fast path: run to quiescence (or ``cap``) in one
        ``lax.while_loop`` over ``step``."""
        carry = self.init_carry(data, params, query_kwargs)

        def cond(c):
            return self.alive(c) & (c.superstep < cap)

        def body(c):
            return self.step(data, c)

        return jax.lax.while_loop(cond, body, carry)


class LaneStepperBase:
    """Host-side plumbing shared by every lane stepper (the global-array
    LaneStepper below and engine_shardmap's ShardLaneStepper): the
    (carry, lane_active, supersteps) return contract, kwarg upload, and
    host fetch. Subclasses provide the jitted ``_init``/``_admit``/
    ``_step``/``_probe`` programs."""

    @staticmethod
    def _unpack(out):
        carry, act, steps = out
        return carry, np.asarray(act), np.asarray(steps)

    @staticmethod
    def _qdev(qkw: Dict[str, np.ndarray]):
        return {k: jnp.asarray(v) for k, v in qkw.items()}

    def probe(self, carry: StepCarry):
        act, steps = self._probe(carry)
        return np.asarray(act), np.asarray(steps)

    def fetch(self, carry: StepCarry) -> StepCarry:
        return jax.tree.map(np.asarray, carry)

    def bind_data(self, data) -> None:
        """Swap the graph-layout pytree the jitted programs are driven
        with — the engine's offload/upload across the store's host-spill
        tier. Shapes/dtypes must match the original (the jit caches key
        on avals, so a rebind re-traces nothing)."""
        self._data = data


class LaneStepper(LaneStepperBase):
    """Host-drivable fixed-width slot array over a SuperstepProgram.

    All functions are jitted once per (width, dtypes) signature; the
    fresh/alive masks are traced values, so steady-state slot recycling
    re-traces nothing (``trace_hook`` — usually the owning engine's
    trace counter bump — fires at trace time only, which the service's
    plan cache asserts against).

    ``init``/``admit``/``step`` return ``(carry, lane_active (W,),
    supersteps (W,))`` — the probe is fused into the same device call,
    so the continuous scheduler's steady state costs exactly ONE
    dispatch per superstep (and blocks on only 2·W scalars, not the
    vertex state).

      init(qkw)                -> all W lanes initialized
      admit(carry, qkw, fresh) -> ``fresh`` lanes re-initialized
      step(carry, alive)       -> one superstep for ``alive`` lanes,
                                  everything else frozen
      probe(carry)             -> host (lane_active (W,), supersteps (W,))
      fetch(carry)             -> host copy of the whole carry
    """

    def __init__(self, prog: SuperstepProgram, data, params: Dict[str, Any],
                 width: int, *, trace_hook: Callable[[], None] = None):
        self.width = width
        hook = trace_hook or (lambda: None)

        def probe_of(carry):
            return (jax.vmap(lambda c: jnp.any(c.active))(carry),
                    carry.superstep)

        def init_fn(d, qkw):
            hook()
            c = jax.vmap(lambda kw: prog.init_carry(d, params, kw))(qkw)
            return (c, *probe_of(c))

        def admit_fn(d, carry, qkw, fresh):
            hook()
            new = jax.vmap(
                lambda kw: prog.init_carry(d, params, kw))(qkw)
            c = select_lanes(fresh, new, carry)
            return (c, *probe_of(c))

        def step_fn(d, carry, alive):
            hook()
            new = jax.vmap(lambda c: prog.step(d, c))(carry)
            c = select_lanes(alive, new, carry)
            return (c, *probe_of(c))

        self._data = data
        self._init = jax.jit(init_fn)
        self._admit = jax.jit(admit_fn)
        self._step = jax.jit(step_fn)
        self._probe = jax.jit(probe_of)

    def init(self, qkw: Dict[str, np.ndarray]):
        return self._unpack(self._init(self._data, self._qdev(qkw)))

    def admit(self, carry: StepCarry, qkw: Dict[str, np.ndarray],
              fresh: np.ndarray):
        return self._unpack(self._admit(self._data, carry,
                                        self._qdev(qkw),
                                        jnp.asarray(fresh)))

    def step(self, carry: StepCarry, alive: np.ndarray):
        return self._unpack(self._step(self._data, carry,
                                       jnp.asarray(alive)))
