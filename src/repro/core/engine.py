"""The GraVF-M superstep engine.

Executes a :class:`GasKernel` over a :class:`PartitionedGraph` in either of
the paper's two architectures (§4.1, Fig. 4):

  mode="gravf"   — baseline: scatter runs at the SOURCE shard, per-edge
                   messages are exchanged shard-to-shard (unicast; the
                   axis-transpose below lowers to all_to_all when the shard
                   axis is device-sharded).
  mode="gravfm"  — the paper's contribution: apply emits ≤1 update per
                   vertex; the per-shard update arrays are broadcast (the
                   flat take below lowers to all_gather); scatter runs at
                   the RECEIVER against its destination-partitioned edge
                   list, and messages are generated on demand and consumed
                   immediately (in VMEM, inside the Pallas kernel).

The engine is written as a *global-array* program with an explicit leading
shard axis: it runs unchanged on one CPU device (this container) and on a
TPU mesh by sharding the leading axis (`launch/mesh.py` + jit shardings) —
XLA SPMD then emits the all_gather / all_to_all named above. An explicit
shard_map variant with a compute/communication-overlapped ring broadcast
(the floating-barrier analogue) lives in `engine_shardmap.py`.

Superstep loop semantics follow §4.3: apply runs on the initial state first
("the barrier is injected into the apply modules to begin execution"), and
distributed termination is the all-reduced "no shard sent updates" bit.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from ..kernels import ref as kref
from .gas import GasKernel
from .partition import PartitionedGraph
from .stepper import LaneStepper, SuperstepProgram

__all__ = ["Engine", "EngineResult", "collect"]

HARD_SUPERSTEP_CAP = 100_000


class _GravfmData(NamedTuple):
    vert_gid: jnp.ndarray       # (P, Vm) int32
    vert_valid: jnp.ndarray     # (P, Vm) bool
    out_deg: jnp.ndarray        # (P, Vm) int32
    flt_cnt: jnp.ndarray        # (P, Vm) int32 remote shards w/ neighbors
    src_slot: jnp.ndarray       # (L,) int32 lanes
    src_gid: jnp.ndarray        # (L,) int32
    src_outdeg: jnp.ndarray     # (L,) int32
    w: jnp.ndarray              # (L,) f32
    lane_valid: jnp.ndarray     # (L,) bool
    lane_remote: jnp.ndarray    # (L,) bool: src shard != dst shard
    seg: jnp.ndarray            # (L,) int32 clipped segment ids (carry path)


class _GravfData(NamedTuple):
    vert_gid: jnp.ndarray
    vert_valid: jnp.ndarray
    out_deg: jnp.ndarray
    flt_cnt: jnp.ndarray
    pair_src_local: jnp.ndarray    # (P, P, E2)
    pair_src_gid: jnp.ndarray
    pair_src_outdeg: jnp.ndarray
    pair_w: jnp.ndarray
    pair_valid: jnp.ndarray
    recv_dst_local: jnp.ndarray    # (P, P, E2) static swapped dst locals


@dataclasses.dataclass
class EngineResult:
    state: Dict[str, np.ndarray]   # per-vertex global arrays (V,)
    supersteps: int
    messages: int                  # traversed edges (paper's TEPS numerator)
    comm: Dict[str, float]         # measured network words by scheme
    raw_state: Any = None          # sharded (P, Vm) state pytree

    _FIELDS = ("state", "supersteps", "messages", "comm", "raw_state")

    def __getitem__(self, key):
        """Dict-style access (``res["state"]``, ``res["exchange_words"]``)
        for callers written against the shard engine's historical result
        dicts; unknown keys fall through to ``comm``."""
        if key in self._FIELDS:
            return getattr(self, key)
        return self.comm[key]


def collect(pg: PartitionedGraph, state) -> Dict[str, np.ndarray]:
    """(P, Vm) shard layout -> (V,) global arrays."""
    out = {}
    for k, v in state.items():
        v = np.asarray(v)
        if v.ndim >= 2 and v.shape[:2] == (pg.num_parts, pg.v_max):
            out[k] = v[pg.part_of, pg.local_of]
        else:
            out[k] = v
    return out


class Engine:
    """Builds and runs the jitted superstep program for one (kernel, graph,
    mode) triple — the analogue of the paper's RTL elaboration."""

    def __init__(self, kernel: GasKernel, pg: PartitionedGraph, *,
                 mode: str = "gravfm", backend: str = "pallas",
                 tile_e: int = 512, tile_r: int = 256,
                 params: Optional[Dict[str, Any]] = None):
        assert mode in ("gravf", "gravfm")
        assert backend in ("pallas", "ref")
        self.kernel = kernel
        self.pg = pg
        self.mode = mode
        self.backend = backend
        self.params = dict(params or {})
        self.params.setdefault("num_vertices", pg.num_vertices)

        P, Vm = pg.num_parts, pg.v_max
        self._P, self._Vm = P, Vm
        # remote-shard neighbor count per vertex (paper's filter bitmap)
        flt = pg.nbr_filter.copy()
        flt[np.arange(pg.num_vertices), pg.part_of] = False
        flt_cnt_g = flt.sum(axis=1).astype(np.int32)
        flt_cnt = np.zeros((P, Vm), np.int32)
        flt_cnt[pg.part_of, pg.local_of] = flt_cnt_g

        if mode == "gravfm":
            self._data = self._build_gravfm(flt_cnt, tile_e, tile_r)
        else:
            self._data = self._build_gravf(flt_cnt)

        # Trace accounting: the loop body bumps this Python counter, which
        # only executes while JAX is *tracing* — so it counts compilations,
        # not calls. The service plan cache asserts steady-state serving
        # performs zero re-traces against this.
        self.traces = 0
        self._device_resident = True
        self._prog = self._make_program()
        self._steppers: Dict[int, LaneStepper] = {}
        loop = self._make_loop()
        self._step = jax.jit(loop)
        # Batched variant: a leading query axis on the per-query kwargs.
        # vmap of the while_loop freezes finished queries' carries (their
        # cond is False), so quiescent queries ride along at zero semantic
        # cost until the whole batch terminates.
        self._batch_step = jax.jit(jax.vmap(loop, in_axes=(None, None, 0)))

    # ------------------------------------------------------------------
    def _build_gravfm(self, flt_cnt, tile_e, tile_r) -> _GravfmData:
        pg, P, Vm = self.pg, self._P, self._Vm
        S = P * (Vm + 1)
        seg_flat = (np.arange(P, dtype=np.int64)[:, None] * (Vm + 1)
                    + pg.in_dst_local).reshape(-1)
        valid_flat = pg.in_valid.reshape(-1)
        # Padding edges already carry dst_local == Vm -> their segment is the
        # shard's discard bin; the array stays sorted.
        if self.backend == "pallas":
            layout = kops.build_layout(seg_flat, S, tile_e=tile_e,
                                       tile_r=tile_r)
            self._layout = layout
            place = layout.place
            src_slot = place(pg.in_src_slot.reshape(-1), 0)
            src_gid = place(pg.in_src_gid.reshape(-1), 0)
            src_outdeg = place(pg.in_src_outdeg.reshape(-1), 1)
            w = place(pg.in_w.reshape(-1), 0.0)
            lane_valid = place(valid_flat, False) & layout.lane_valid
            seg = place(seg_flat.astype(np.int32), S)
        else:
            self._layout = None
            src_slot = pg.in_src_slot.reshape(-1)
            src_gid = pg.in_src_gid.reshape(-1)
            src_outdeg = pg.in_src_outdeg.reshape(-1)
            w = pg.in_w.reshape(-1)
            lane_valid = valid_flat
            seg = seg_flat.astype(np.int32)
        self._num_segments = S
        # src shard of each lane vs owning shard of its segment
        src_part = src_slot // Vm
        dst_part = seg // (Vm + 1)
        lane_remote = (src_part != dst_part) & lane_valid
        return _GravfmData(
            vert_gid=jnp.asarray(pg.vert_gid),
            vert_valid=jnp.asarray(pg.vert_valid),
            out_deg=jnp.asarray(pg.out_deg),
            flt_cnt=jnp.asarray(flt_cnt),
            src_slot=jnp.asarray(src_slot),
            src_gid=jnp.asarray(src_gid),
            src_outdeg=jnp.asarray(src_outdeg),
            w=jnp.asarray(w),
            lane_valid=jnp.asarray(lane_valid),
            lane_remote=jnp.asarray(lane_remote),
            seg=jnp.asarray(np.minimum(seg, S).astype(np.int32)),
        )

    def _build_gravf(self, flt_cnt) -> _GravfData:
        pg = self.pg
        return _GravfData(
            vert_gid=jnp.asarray(pg.vert_gid),
            vert_valid=jnp.asarray(pg.vert_valid),
            out_deg=jnp.asarray(pg.out_deg),
            flt_cnt=jnp.asarray(flt_cnt),
            pair_src_local=jnp.asarray(pg.pair_src_local),
            pair_src_gid=jnp.asarray(pg.pair_src_gid),
            pair_src_outdeg=jnp.asarray(pg.pair_src_outdeg),
            pair_w=jnp.asarray(pg.pair_w),
            pair_valid=jnp.asarray(pg.pair_valid),
            recv_dst_local=jnp.asarray(pg.pair_dst_local.swapaxes(0, 1)),
        )

    # ------------------------------------------------------------------
    def _deliver_gravfm(self, data: _GravfmData, payload, active):  # analysis: traced
        """Broadcast updates; receiver-side scatter + gather-combine."""
        k, P, Vm = self.kernel, self._P, self._Vm
        payload_flat = payload.reshape(P * Vm)
        active_flat = active.reshape(P * Vm)
        # THE broadcast: every shard reads every shard's updates (lowers to
        # all_gather of the |V|-bounded update array under SPMD sharding).
        vals = jnp.take(payload_flat, data.src_slot)
        act = jnp.take(active_flat, data.src_slot) & data.lane_valid
        msg = k.scatter(vals, data.w, data.src_gid, data.src_outdeg)
        ident = kops.identity_for(k.combiner, k.msg_dtype)
        masked = jnp.where(act, msg, ident)

        if self.backend == "pallas":
            acc_full = kops.segment_combine_layout(
                masked, self._layout, k.combiner)
        else:
            acc_full = kref.segment_combine(
                masked, data.seg, self._num_segments, k.combiner)
        acc = acc_full.reshape(P, Vm + 1)[:, :Vm]

        if k.got_from_identity:
            got = acc != ident
        else:
            gv = jnp.where(act, 1, 0).astype(jnp.int32)
            if self.backend == "pallas":
                got_full = kops.segment_combine_layout(
                    gv, self._layout, "max")
            else:
                got_full = kref.segment_combine(
                    gv, data.seg, self._num_segments, "max")
            got = got_full.reshape(P, Vm + 1)[:, :Vm] > 0

        carry = None
        if k.carry_dtype is not None:
            cident = kops.identity_for("min", k.carry_dtype)
            cvals = k.scatter_carry(vals, data.w, data.src_gid,
                                    data.src_outdeg)
            acc_at_lane = jnp.take(acc_full, jnp.minimum(
                data.seg, self._num_segments - 1))
            winner = act & (masked == acc_at_lane)
            cmasked = jnp.where(winner, cvals, cident)
            if self.backend == "pallas":
                carry_full = kops.segment_combine_layout(
                    cmasked, self._layout, "min")
            else:
                carry_full = kref.segment_combine(
                    cmasked, data.seg, self._num_segments, "min")
            carry = carry_full.reshape(P, Vm + 1)[:, :Vm]

        n_msgs = jnp.sum(act.astype(jnp.int32))
        n_remote_msgs = jnp.sum((act & data.lane_remote).astype(jnp.int32))
        return acc, got, carry, {"n_msgs": n_msgs, "n_remote": n_remote_msgs}

    def _deliver_gravf(self, data: _GravfData, payload, active):  # analysis: traced
        """Source-side scatter, unicast exchange (paper Fig. 4 left)."""
        k, P, Vm = self.kernel, self._P, self._Vm
        pe = jnp.broadcast_to(payload[:, None, :], (P, P, Vm))
        ae = jnp.broadcast_to(active[:, None, :], (P, P, Vm))
        vals = jnp.take_along_axis(pe, data.pair_src_local, axis=2)
        act = jnp.take_along_axis(ae, data.pair_src_local, axis=2)
        act = act & data.pair_valid
        msg = k.scatter(vals, data.pair_w, data.pair_src_gid,
                        data.pair_src_outdeg)
        ident = kops.identity_for(k.combiner, k.msg_dtype)
        masked = jnp.where(act, msg, ident)

        # THE unicast exchange: shard-axis transpose (lowers to all_to_all).
        recv = jnp.swapaxes(masked, 0, 1)
        recv_act = jnp.swapaxes(act, 0, 1)
        seg = (jnp.arange(P, dtype=jnp.int32)[:, None, None] * (Vm + 1)
               + data.recv_dst_local)
        S = P * (Vm + 1)
        acc_full = kref.segment_combine(
            recv.reshape(-1), seg.reshape(-1), S, k.combiner)
        acc = acc_full.reshape(P, Vm + 1)[:, :Vm]

        if k.got_from_identity:
            got = acc != ident
        else:
            got_full = kref.segment_combine(
                jnp.where(recv_act, 1, 0).astype(jnp.int32).reshape(-1),
                seg.reshape(-1), S, "max")
            got = got_full.reshape(P, Vm + 1)[:, :Vm] > 0

        carry = None
        if k.carry_dtype is not None:
            cident = kops.identity_for("min", k.carry_dtype)
            cvals = k.scatter_carry(vals, data.pair_w, data.pair_src_gid,
                                    data.pair_src_outdeg)
            crecv = jnp.swapaxes(jnp.where(act, cvals, cident), 0, 1)
            acc_at_edge = jnp.take(
                acc_full, jnp.minimum(seg.reshape(-1), S - 1)).reshape(seg.shape)
            winner = recv_act & (recv == acc_at_edge)
            cmasked = jnp.where(winner, crecv, cident)
            carry_full = kref.segment_combine(
                cmasked.reshape(-1), seg.reshape(-1), S, "min")
            carry = carry_full.reshape(P, Vm + 1)[:, :Vm]

        n_msgs = jnp.sum(act.astype(jnp.int32))
        cross = ~jnp.eye(P, dtype=bool)[:, :, None]
        n_remote = jnp.sum((act & cross).astype(jnp.int32))
        return acc, got, carry, {"n_msgs": n_msgs, "n_remote": n_remote}

    # ------------------------------------------------------------------
    def _make_program(self) -> SuperstepProgram:
        """The step-granular core: one superstep = deliver -> gather ->
        stats -> apply, factored so run/run_batch (while_loop over it)
        and the service's continuous scheduler (host-driven, one step at
        a time) execute the exact same traced computation."""
        deliver = (self._deliver_gravfm if self.mode == "gravfm"
                   else self._deliver_gravf)
        P = self._P

        def init_stats():
            return {
                "messages": jnp.int32(0),
                "unicast_words": jnp.float32(0.0),
                "bcast_naive_words": jnp.float32(0.0),
                "bcast_filtered_words": jnp.float32(0.0),
            }

        def update_stats(stats, data, active, aux):
            n_act = jnp.sum(active.astype(jnp.int32))
            n_flt = jnp.sum(jnp.where(active, data.flt_cnt, 0))
            return {
                "messages": stats["messages"] + aux["n_msgs"],
                "unicast_words":
                    stats["unicast_words"]
                    + aux["n_remote"].astype(jnp.float32),
                "bcast_naive_words":
                    stats["bcast_naive_words"]
                    + (n_act * (P - 1)).astype(jnp.float32),
                "bcast_filtered_words":
                    stats["bcast_filtered_words"]
                    + n_flt.astype(jnp.float32),
            }

        return SuperstepProgram(self.kernel, deliver,
                                init_stats=init_stats,
                                update_stats=update_stats)

    def _make_loop(self):
        prog = self._prog

        def loop(data, cap, query_kwargs):
            self.traces += 1  # Python side effect: runs at trace time only
            c = prog.while_run(data, cap, self.params, query_kwargs)
            return c.state, c.superstep, c.stats

        return loop

    # ------------------------------------------------------------------
    @property
    def device_resident(self) -> bool:
        """Whether the graph-layout pytree currently lives in device
        buffers (vs host-spill numpy copies)."""
        return self._device_resident

    @property
    def device_nbytes(self) -> int:
        """Bytes of the engine-tier graph layout (the pytree the jitted
        programs are driven with — exactly what :meth:`offload` demotes).
        The GraphStore charges these true engine-tier bytes against its
        budget instead of the partition-layout proxy."""
        return int(sum(a.nbytes for a in jax.tree.leaves(self._data)))

    def offload(self) -> int:
        """Demote the graph's device arrays to host (numpy) copies — the
        engine tier of the GraphStore's host-spill residency. The traced
        programs (and their jit caches) survive untouched; dispatching
        while offloaded still works (the runtime re-uploads per call),
        it is just slower until :meth:`upload` promotes the arrays back.
        Returns the bytes demoted."""
        if not self._device_resident:
            return 0
        host = jax.tree.map(np.asarray, self._data)
        self._rebind_data(host, resident=False)
        return int(sum(a.nbytes for a in jax.tree.leaves(host)))

    def upload(self) -> float:
        """Promote offloaded graph arrays back into device buffers.
        Shapes/dtypes are unchanged, so the next dispatch hits the
        existing jit cache — the spill/refault contract is zero
        re-traces. Returns the wall seconds the upload took."""
        if self._device_resident:
            return 0.0
        t0 = time.perf_counter()
        data = jax.tree.map(jnp.asarray, self._data)
        jax.block_until_ready(data)
        self._rebind_data(data, resident=True)
        return time.perf_counter() - t0

    def _rebind_data(self, data, *, resident: bool) -> None:
        self._data = data
        self._device_resident = resident
        for st in self._steppers.values():
            st.bind_data(data)

    # ------------------------------------------------------------------
    def _check_query_kwargs(self, kwargs: Dict[str, Any]) -> None:
        # A misspelled name would be swallowed by init_state's **_ and the
        # kernel would silently run with its defaults — reject instead.
        unknown = set(kwargs) - set(self.kernel.query_params)
        if unknown:
            raise ValueError(
                f"kernel {self.kernel.name!r} takes query params "
                f"{tuple(self.kernel.query_params)}, got unexpected "
                f"{sorted(unknown)}")

    def run(self, max_supersteps: Optional[int] = None,
            **query_kwargs) -> EngineResult:
        """Single query. ``query_kwargs`` (e.g. ``root=7``) are traced
        scalars forwarded to the kernel's ``init_state`` — they override
        the constructor ``params`` without re-tracing."""
        cap = max_supersteps or self.kernel.max_supersteps or HARD_SUPERSTEP_CAP
        self._check_query_kwargs(query_kwargs)
        qkw = {kk: jnp.asarray(v) for kk, v in query_kwargs.items()}
        state, s, stats = self._step(self._data, jnp.int32(cap), qkw)
        state = jax.tree.map(np.asarray, state)
        comm_scheme = ("gravfm_broadcast" if self.mode == "gravfm"
                       else "gravf_unicast")
        comm = {kk: float(v) for kk, v in jax.tree.map(np.asarray,
                                                       stats).items()}
        comm["scheme"] = comm_scheme
        comm["wire_words"] = comm[self.wire_stat]
        return EngineResult(
            state=collect(self.pg, state),
            supersteps=int(s),
            messages=int(stats["messages"]),
            comm=comm,
            raw_state=state,
        )

    def run_batch(self, max_supersteps: Optional[int] = None,
                  **query_arrays) -> "list[EngineResult]":
        """One superstep loop over a leading query-batch axis.

        ``query_arrays`` maps per-query kernel parameters (the kernel's
        ``query_params``, e.g. BFS/SSSP ``root``) to (B,) arrays. All B
        queries share every per-superstep broadcast/exchange; per-query
        termination masks (the vmapped while_loop carry select) let
        finished queries go quiescent without stalling the batch.
        Returns one :class:`EngineResult` per query, bit-identical to B
        sequential :meth:`run` calls.
        """
        if not query_arrays:
            raise ValueError(
                "run_batch needs at least one per-query array, e.g. "
                "root=np.array([...]); see GasKernel.query_params")
        self._check_query_kwargs(query_arrays)
        cap = max_supersteps or self.kernel.max_supersteps or HARD_SUPERSTEP_CAP
        qkw = {kk: jnp.atleast_1d(jnp.asarray(v))
               for kk, v in query_arrays.items()}
        sizes = {kk: v.shape[0] for kk, v in qkw.items()}
        batch = next(iter(sizes.values()))
        if any(b != batch for b in sizes.values()):
            raise ValueError(f"inconsistent query batch sizes: {sizes}")
        state, s, stats = self._batch_step(self._data, jnp.int32(cap), qkw)
        state = jax.tree.map(np.asarray, state)
        s = np.asarray(s)
        stats = jax.tree.map(np.asarray, stats)
        comm_scheme = ("gravfm_broadcast" if self.mode == "gravfm"
                       else "gravf_unicast")
        results = []
        for q in range(batch):
            state_q = jax.tree.map(lambda a: a[q], state)
            comm = {kk: float(v[q]) for kk, v in stats.items()}
            comm["scheme"] = comm_scheme
            comm["wire_words"] = comm[self.wire_stat]
            results.append(EngineResult(
                state=collect(self.pg, state_q),
                supersteps=int(s[q]),
                messages=int(stats["messages"][q]),
                comm=comm,
                raw_state=state_q,
            ))
        return results

    # ------------------------------------------------------------------
    def make_stepper(self, width: int) -> LaneStepper:
        """A host-drivable ``width``-lane slot array over this engine's
        superstep program — the step-granular entry point the continuous
        scheduler drives (admit / one-superstep / probe / retire). Lanes
        run the same vmapped computation as :meth:`run_batch`, so a lane
        is bit-identical to a solo :meth:`run` of its query regardless
        of which superstep it was spliced in at. Cached per width: the
        jitted admit/step programs trace once, then recycle slots
        forever with zero re-traces."""
        assert width >= 1
        st = self._steppers.get(width)
        if st is None:
            st = LaneStepper(self._prog, self._data, self.params, width,
                             trace_hook=self._bump_traces,
                             wire_stat=self.wire_stat)
            self._steppers[width] = st
        return st

    @property
    def wire_stat(self) -> str:
        """Which stats entry counts the words this mode's scheme actually
        puts on the wire (filtered broadcast for GraVF-M, per-edge unicast
        for GraVF) — surfaced uniformly as ``comm["wire_words"]``."""
        return ("bcast_filtered_words" if self.mode == "gravfm"
                else "unicast_words")

    def _bump_traces(self) -> None:
        self.traces += 1

    def lane_result(self, carry_host, lane: int) -> EngineResult:
        """Package one retired lane of a host-fetched stepper carry as an
        :class:`EngineResult` (same fields as :meth:`run`)."""
        state_q = jax.tree.map(lambda a: np.asarray(a[lane]),
                               carry_host.state)
        comm = {kk: float(v[lane]) for kk, v in carry_host.stats.items()}
        comm["scheme"] = ("gravfm_broadcast" if self.mode == "gravfm"
                          else "gravf_unicast")
        comm["wire_words"] = comm[self.wire_stat]
        return EngineResult(
            state=collect(self.pg, state_q),
            supersteps=int(carry_host.superstep[lane]),
            messages=int(carry_host.stats["messages"][lane]),
            comm=comm,
            raw_state=state_q,
        )
