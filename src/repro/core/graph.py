"""Graph containers and generators.

Host-side (numpy) graph construction mirrors the paper's preprocessing
stage: graphs are read/generated as edge lists, partitioned, and compiled
into fixed-shape per-shard device arrays. All device-side structures are
padded to static shapes so they are SPMD/jit friendly.

Generators reproduce the paper's datasets:
  - ``uniform``  : Erdos-Renyi-style, every vertex close to average degree
                   (paper Figs. 7, 8, 9).
  - ``rmat``     : Chakrabarti et al. recursive-matrix power-law graphs
                   (paper Fig. 12/13, Table 3 social-graph stand-in).
  - ``ladder``   : the width-w depth-d synthetic graphs of Fig. 10/11 used
                   to isolate superstep-synchronization latency.
  - ``line``     : ladder with w=1 (the 16385-vertex latency probe).
  - ``road``     : low-degree grid-like graph (the PA-road-network stand-in,
                   average degree ~2.8).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "Graph",
    "uniform",
    "rmat",
    "ladder",
    "line",
    "road",
]


@dataclasses.dataclass(frozen=True)
class Graph:
    """Immutable COO graph. ``src[i] -> dst[i]`` directed edges.

    ``weights`` is optional per-edge f32 data (the paper's edge data /
    message weight input to the scatter kernel).
    """

    num_vertices: int
    src: np.ndarray  # (E,) int32
    dst: np.ndarray  # (E,) int32
    weights: Optional[np.ndarray] = None  # (E,) float32 or None

    def __post_init__(self):
        assert self.src.dtype == np.int32 and self.dst.dtype == np.int32
        assert self.src.shape == self.dst.shape
        if self.weights is not None:
            assert self.weights.shape == self.src.shape

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def avg_degree(self) -> float:
        return self.num_edges / max(1, self.num_vertices)

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.num_vertices).astype(np.int64)

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.num_vertices).astype(np.int64)

    def with_unit_weights(self) -> "Graph":
        w = np.ones(self.num_edges, np.float32)
        return dataclasses.replace(self, weights=w)

    def symmetrized(self) -> "Graph":
        """Add reverse edges (paper's WCC operates on undirected reach)."""
        src = np.concatenate([self.src, self.dst])
        dst = np.concatenate([self.dst, self.src])
        w = None
        if self.weights is not None:
            w = np.concatenate([self.weights, self.weights])
        g = Graph(self.num_vertices, src.astype(np.int32), dst.astype(np.int32), w)
        return g.deduplicated()

    def deduplicated(self) -> "Graph":
        keys = self.src.astype(np.int64) * self.num_vertices + self.dst
        _, idx = np.unique(keys, return_index=True)
        idx.sort()
        w = self.weights[idx] if self.weights is not None else None
        return Graph(self.num_vertices, self.src[idx], self.dst[idx], w)

    def without_self_loops(self) -> "Graph":
        keep = self.src != self.dst
        w = self.weights[keep] if self.weights is not None else None
        return Graph(self.num_vertices, self.src[keep], self.dst[keep], w)


def _finalize(num_vertices: int, src: np.ndarray, dst: np.ndarray,
              rng: np.random.Generator, weighted: bool) -> Graph:
    src = src.astype(np.int32)
    dst = dst.astype(np.int32)
    w = rng.uniform(0.5, 2.0, size=src.shape).astype(np.float32) if weighted else None
    return Graph(num_vertices, src, dst, w).without_self_loops().deduplicated()


def uniform(num_vertices: int, avg_degree: float, *, seed: int = 0,
            weighted: bool = False) -> Graph:
    """Uniform random graph: edges with equal probability for any pair."""
    rng = np.random.default_rng(seed)
    num_edges = int(num_vertices * avg_degree)
    src = rng.integers(0, num_vertices, size=num_edges, dtype=np.int64)
    dst = rng.integers(0, num_vertices, size=num_edges, dtype=np.int64)
    return _finalize(num_vertices, src, dst, rng, weighted)


def rmat(scale: int, edge_factor: int = 16, *, a: float = 0.57, b: float = 0.19,
         c: float = 0.19, seed: int = 0, weighted: bool = False) -> Graph:
    """R-MAT generator (Chakrabarti et al. 2004) as used by graph500 and the
    paper's scale-free datasets. ``2**scale`` vertices, ``edge_factor *
    2**scale`` edges before dedup."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    num_edges = edge_factor * n
    src = np.zeros(num_edges, np.int64)
    dst = np.zeros(num_edges, np.int64)
    # Vectorized: per bit level, choose quadrant.
    p_src1 = c + (1.0 - a - b - c)  # P(src bit = 1) = c + d
    for level in range(scale):
        r1 = rng.random(num_edges)
        r2 = rng.random(num_edges)
        sbit = (r1 < p_src1).astype(np.int64)
        # P(dst bit = 1 | src bit) — conditional quadrant probabilities.
        d_ = 1.0 - a - b - c
        p_d1_given_s0 = b / (a + b)
        p_d1_given_s1 = d_ / (c + d_)
        p = np.where(sbit == 1, p_d1_given_s1, p_d1_given_s0)
        dbit = (r2 < p).astype(np.int64)
        src = src * 2 + sbit
        dst = dst * 2 + dbit
    # Random vertex relabeling to break degree-locality artifacts.
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    return _finalize(n, src, dst, rng, weighted)


def ladder(width: int, depth: int, extra_degree: int = 0, *, seed: int = 0) -> Graph:
    """Paper Fig. 10 synthetic: a root vertex, then ``depth`` ranks of
    ``width`` vertices. Every vertex in rank r connects to every vertex of
    rank r+1? No — the paper's solid edges form a BFS spanning tree with
    exactly ``width`` active vertices per superstep; dashed intra-rank edges
    raise average degree without changing activation timing.

    We connect vertex i of rank r to vertex i of rank r+1 (spanning chain)
    plus ``extra_degree`` intra-rank edges per vertex.
    """
    rng = np.random.default_rng(seed)
    n = 1 + width * depth
    srcs, dsts = [], []

    def vid(rank: int, i: int) -> int:
        return 1 + (rank - 1) * width + i if rank >= 1 else 0

    # Root to all of rank 1.
    srcs.append(np.zeros(width, np.int64))
    dsts.append(np.arange(1, 1 + width, dtype=np.int64))
    # Rank chains.
    for r in range(1, depth):
        base_a = 1 + (r - 1) * width
        base_b = 1 + r * width
        srcs.append(np.arange(base_a, base_a + width, dtype=np.int64))
        dsts.append(np.arange(base_b, base_b + width, dtype=np.int64))
    # Intra-rank (dashed) edges.
    if extra_degree > 0 and width > 1:
        for r in range(1, depth + 1):
            base = 1 + (r - 1) * width
            s = np.repeat(np.arange(base, base + width, dtype=np.int64), extra_degree)
            d = base + rng.integers(0, width, size=width * extra_degree)
            srcs.append(s)
            dsts.append(d)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    return _finalize(n, src, dst, rng, weighted=False)


def line(length: int) -> Graph:
    """The paper's 16385-vertex latency probe is ``line(16384)``."""
    return ladder(1, length)


def road(side: int, *, seed: int = 0) -> Graph:
    """Grid-like low-degree graph; average degree ~2.8 like the PA road
    network subgraph in the paper (we drop a fraction of grid edges)."""
    rng = np.random.default_rng(seed)
    n = side * side
    ii, jj = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    v = (ii * side + jj).astype(np.int64)
    right_s, right_d = v[:, :-1].ravel(), v[:, 1:].ravel()
    down_s, down_d = v[:-1, :].ravel(), v[1:, :].ravel()
    src = np.concatenate([right_s, down_s, right_d, down_d])
    dst = np.concatenate([right_d, down_d, right_s, down_s])
    keep = rng.random(src.shape[0]) < 0.7
    return _finalize(n, src[keep], dst[keep], rng, weighted=False)
