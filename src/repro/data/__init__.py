"""Deterministic restart-safe data pipeline."""
from . import pipeline  # noqa: F401
