"""Deterministic, restart-safe synthetic data pipeline.

Batches are a pure function of (seed, step): after a crash/elastic
re-mesh, the loop resumes at step k and sees exactly the token stream it
would have seen — no stateful shuffle to lose. This is the data-side half
of the fault-tolerance story (checkpoint.py is the model-side half).

The stream is Zipf-distributed token ids over the model vocab with
document boundaries (EOS every ~doc_len tokens) — enough structure for a
~100M-param model's loss to fall measurably in a few hundred steps.
Per-host sharding: each process materializes only its slice of the global
batch (process_index-strided), matching multi-host jax.make_array...
semantics; on this 1-process box that is the whole batch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticTokens", "batch_for_step"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 1234
    zipf_a: float = 1.3
    doc_len: int = 512
    eos_id: int = 0


class SyntheticTokens:
    def __init__(self, cfg: DataConfig, *, process_index: int = 0,
                 process_count: int = 1):
        self.cfg = cfg
        self.process_index = process_index
        self.process_count = process_count
        assert cfg.global_batch % process_count == 0

    @property
    def local_batch(self) -> int:
        return self.cfg.global_batch // self.process_count

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """(tokens, labels) of shape (local_batch, seq_len), int32."""
        c = self.cfg
        rows = []
        base = np.random.SeedSequence(
            [c.seed, step, self.process_index])
        rng = np.random.default_rng(base)
        n = self.local_batch
        # zipf over vocab, clipped; deterministic given (seed, step, proc)
        raw = rng.zipf(c.zipf_a, size=(n, c.seq_len + 1))
        toks = (raw % (c.vocab - 1)) + 1  # reserve 0 for EOS
        # document boundaries
        doc_phase = rng.integers(0, c.doc_len, size=(n, 1))
        pos = np.arange(c.seq_len + 1)[None, :]
        toks[(pos + doc_phase) % c.doc_len == 0] = c.eos_id
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def batch_for_step(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    return SyntheticTokens(cfg).batch(step)
