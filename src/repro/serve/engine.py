"""Serving engine: batched prefill + decode with static cache buffers.

``make_serve_fns(cfg, mesh)`` builds the jitted pair:
  prefill(params, tokens)             -> (next_token_logits, cache)
  decode_step(params, cache, tok, pos)-> (logits, cache)   [donated cache]

Caches follow models/lm.py layouts; attention KV buffers are allocated at
``max_len`` and sharded (batch over data, KV-seq over model — the
flash-decoding split; see sharding.py). Recurrent archs carry O(1) state.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import lm as LM

__all__ = ["make_serve_fns", "place_prefill_cache", "greedy_generate"]


def place_prefill_cache(cfg: LM.ArchCfg, prefill_cache, buffers, seq_len):
    """Copy prefill-produced caches (length S) into max_len buffers.
    Recurrent entries are final states and replace the buffer outright."""
    def merge(path, buf, new):
        if new is None:
            return buf
        # attention kv / mla latents: (…, S, …) -> paste at offset 0
        if buf.ndim == new.ndim and buf.shape != new.shape:
            idx = tuple(0 for _ in buf.shape)
            return jax.lax.dynamic_update_slice(
                buf, new.astype(buf.dtype), idx)
        return new.astype(buf.dtype)
    return jax.tree_util.tree_map_with_path(
        lambda p, b, n: merge(p, b, n), buffers, prefill_cache)


def make_serve_fns(cfg: LM.ArchCfg, mesh=None, *,
                   batch: int, max_len: int,
                   prefix_embeds: bool = False):
    """Returns (prefill_fn, decode_fn, init_cache_fn)."""

    def init_cache_fn():
        return LM.init_cache(cfg, batch, max_len)

    def prefill_fn(params, tokens, prefix=None):
        logits, cache = LM.lm_forward(
            params, tokens, cfg, mesh=mesh, prefix_embeds=prefix,
            return_cache=True, last_only=True)
        return logits, cache

    def decode_fn(params, cache, tokens, pos):
        return LM.lm_decode_step(params, cache, tokens, pos, cfg, mesh=mesh)

    prefill_jit = jax.jit(prefill_fn)
    decode_jit = jax.jit(decode_fn, donate_argnums=(1,))
    return prefill_jit, decode_jit, init_cache_fn


def greedy_generate(cfg: LM.ArchCfg, params, prompt_tokens: np.ndarray,
                    *, num_new: int, max_len: Optional[int] = None,
                    mesh=None, prefix=None):
    """End-to-end batched greedy decoding (prefill -> N decode steps)."""
    B, S = prompt_tokens.shape
    max_len = max_len or (S + num_new + 1)
    prefill, decode, init_cache = make_serve_fns(
        cfg, mesh, batch=B, max_len=max_len)
    logits, pre_cache = prefill(params, jnp.asarray(prompt_tokens),
                                prefix)
    cache = place_prefill_cache(cfg, pre_cache, init_cache(), S)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    out = [np.asarray(tok)]
    pos = S
    for _ in range(num_new - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        out.append(np.asarray(tok))
        pos += 1
    return np.concatenate(out, axis=1)
