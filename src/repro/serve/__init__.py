"""Serving substrate: prefill/decode with static cache buffers."""
from . import engine  # noqa: F401
