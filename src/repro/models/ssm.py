"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory with exponential gating).

Faithful-to-structure implementation with the paper's stabilized
exponential gating (m-state). Recurrences run as lax.scan over time for
training/prefill; decode advances the state one step — the state is O(1)
in sequence length, which is why xlstm-350m is a ``long_500k``-eligible
architecture. Block layout follows the paper: mLSTM with pre-up-projection
(factor 2) + causal conv + qkv heads; sLSTM with post-FFN (factor 4/3).
Simplifications noted in DESIGN.md: single-direction scan only, conv width
4, no bias on projections.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import PSpec, dense, rmsnorm

__all__ = [
    "mlstm_spec", "mlstm_scan", "mlstm_step", "mlstm_init_state",
    "slstm_spec", "slstm_scan", "slstm_step", "slstm_init_state",
]

CONV_W = 4


def _causal_conv(x, w):
    """Depthwise causal conv. x: (B, S, C), w: (CONV_W, C)."""
    pads = jnp.pad(x, ((0, 0), (CONV_W - 1, 0), (0, 0)))
    out = sum(pads[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(CONV_W))
    return out


def _conv_step(buf, x_t, w):
    """buf: (B, CONV_W-1, C) previous inputs; x_t: (B, C)."""
    full = jnp.concatenate([buf, x_t[:, None, :]], axis=1)  # (B, CONV_W, C)
    out = jnp.einsum("bwc,wc->bc", full, w)
    return out, full[:, 1:, :]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_spec(d_model: int, n_heads: int, *, proj_factor: float = 2.0,
               stack: Optional[int] = None) -> Dict[str, PSpec]:
    di = int(d_model * proj_factor)
    st = (stack,) if stack else ()
    pre = "stack," if stack else ""
    return {
        "norm": PSpec(st + (d_model,), pre + ".", init="ones"),
        "w_up": PSpec(st + (d_model, di), pre + "fsdp,model",
                      fan_in=d_model),
        "w_z": PSpec(st + (d_model, di), pre + "fsdp,model", fan_in=d_model),
        "conv": PSpec(st + (CONV_W, di), pre + ".,model", init="normal",
                      fan_in=CONV_W),
        "w_q": PSpec(st + (di, di), pre + "model,.", fan_in=di),
        "w_k": PSpec(st + (di, di), pre + "model,.", fan_in=di),
        "w_v": PSpec(st + (di, di), pre + "model,.", fan_in=di),
        "w_i": PSpec(st + (d_model, n_heads), pre + "fsdp,.",
                     fan_in=d_model),
        "w_f": PSpec(st + (d_model, n_heads), pre + "fsdp,.",
                     fan_in=d_model),
        "out_norm": PSpec(st + (di,), pre + ".", init="ones"),
        "w_down": PSpec(st + (di, d_model), pre + "model,fsdp", fan_in=di),
    }


def mlstm_init_state(batch: int, d_model: int, n_heads: int,
                     proj_factor: float = 2.0, dtype=jnp.float32):
    di = int(d_model * proj_factor)
    dh = di // n_heads
    return {
        "C": jnp.zeros((batch, n_heads, dh, dh), dtype),
        "n": jnp.zeros((batch, n_heads, dh), dtype),
        "m": jnp.full((batch, n_heads), -jnp.inf, dtype),
        "conv": jnp.zeros((batch, CONV_W - 1, di), jnp.bfloat16),
    }


def _mlstm_cell(state, q, k, v, i_t, f_t):
    """One recurrent step. q/k/v: (B,H,dh); i_t/f_t: (B,H) pre-activations.
    Stabilized exponential gating (paper eq. 19-27)."""
    C, n, m = state
    dh = q.shape[-1]
    k = k / math.sqrt(dh)
    log_f = jax.nn.log_sigmoid(f_t.astype(jnp.float32))
    m_new = jnp.maximum(log_f + m, i_t.astype(jnp.float32))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    i_g = jnp.exp(i_t.astype(jnp.float32) - m_safe)
    f_g = jnp.exp(log_f + jnp.where(jnp.isfinite(m), m, -jnp.inf) - m_safe)
    f_g = jnp.where(jnp.isfinite(m)[..., None, None], f_g[..., None, None],
                    0.0)
    kf, vf, qf = (a.astype(jnp.float32) for a in (k, v, q))
    C_new = f_g * C + i_g[..., None, None] * (vf[..., :, None]
                                              * kf[..., None, :])
    n_new = (f_g[..., :, 0] * n + i_g[..., None] * kf)
    h_num = jnp.einsum("bhvk,bhk->bhv", C_new, qf)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, qf)), 1.0)
    h = h_num / h_den[..., None]
    return (C_new, n_new, m_new), h


def _mlstm_gates(p, xn, up):
    c = jax.nn.silu(_causal_conv(up, p["conv"]).astype(jnp.float32)
                    ).astype(up.dtype)
    q = dense(c, p["w_q"])
    k = dense(c, p["w_k"])
    v = dense(up, p["w_v"])
    i_pre = dense(xn, p["w_i"])
    f_pre = dense(xn, p["w_f"])
    return q, k, v, i_pre, f_pre


def mlstm_scan(p, x, *, n_heads: int):
    """Full-sequence training/prefill. x: (B,S,D) -> (B,S,D) residual
    branch output (caller adds residual)."""
    B, S, D = x.shape
    xn = rmsnorm(x, p["norm"])
    up = dense(xn, p["w_up"])
    z = dense(xn, p["w_z"])
    di = up.shape[-1]
    dh = di // n_heads
    q, k, v, i_pre, f_pre = _mlstm_gates(p, xn, up)

    def split(a):
        return a.reshape(B, S, n_heads, dh)

    q, k, v = split(q), split(k), split(v)

    # Two-level chunked scan: the naive time scan's BACKWARD saves the
    # (B, H, dh, dh) matrix state at every timestep — O(S * dh^2), which
    # is what makes recurrent-form training infeasible at 4k+ context.
    # Chunking + remat saves states only at chunk boundaries (O(S/C))
    # and recomputes the C-step window in the backward pass.
    CHUNK = 64
    pad = (-S) % CHUNK
    nchunks = (S + pad) // CHUNK

    def padt(a):  # pad time axis (axis=1) and cut into chunks
        a = jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        return jnp.moveaxis(
            a.reshape((B, nchunks, CHUNK) + a.shape[2:]), 1, 0)

    qc, kc, vc = padt(q), padt(k), padt(v)
    ic, fc = padt(i_pre), padt(f_pre)
    tvalid = jnp.moveaxis(jnp.broadcast_to(
        (jnp.arange(S + pad) < S)[None, :], (B, S + pad)
    ).reshape(B, nchunks, CHUNK), 1, 0)

    @jax.checkpoint
    def chunk_step(carry, inp):
        qq, kk, vv, ii, ff, tv = inp

        def step(c, t):
            c2, h = _mlstm_cell(c, qq[:, t], kk[:, t], vv[:, t],
                                ii[:, t], ff[:, t])
            # padded timesteps must not perturb the state (prefill handoff)
            ok = tv[:, t]
            c2 = jax.tree.map(
                lambda new, old: jnp.where(
                    ok.reshape((-1,) + (1,) * (new.ndim - 1)), new, old),
                c2, c)
            return c2, h
        carry, hs = jax.lax.scan(step, carry, jnp.arange(CHUNK))
        return carry, hs

    C0 = jnp.zeros((B, n_heads, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, n_heads, dh), jnp.float32)
    m0 = jnp.full((B, n_heads), -jnp.inf, jnp.float32)
    (Cf, nf, mf), hs = jax.lax.scan(chunk_step, (C0, n0, m0),
                                    (qc, kc, vc, ic, fc, tvalid))
    # hs: (nchunks, CHUNK, B, H, dh) -> (B, S, di)
    hs = jnp.moveaxis(hs.reshape(nchunks * CHUNK, B, n_heads, dh), 0, 1)
    hs = hs[:, :S]
    h = hs.reshape(B, S, di).astype(x.dtype)
    h = rmsnorm(h, p["out_norm"])
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    # last CONV_W-1 conv inputs (zero-padded when S < CONV_W-1)
    conv_buf = jnp.pad(up, ((0, 0), (CONV_W - 1, 0), (0, 0))
                       )[:, S:S + CONV_W - 1].astype(jnp.bfloat16)
    state = {"C": Cf, "n": nf, "m": mf, "conv": conv_buf}
    return dense(h, p["w_down"]), state


def mlstm_step(p, x_t, state, *, n_heads: int):
    """Single-token decode. x_t: (B,1,D); state from mlstm_init_state."""
    B, _, D = x_t.shape
    xn = rmsnorm(x_t[:, 0], p["norm"])
    up = dense(xn, p["w_up"])
    z = dense(xn, p["w_z"])
    di = up.shape[-1]
    dh = di // n_heads
    c, conv_buf = _conv_step(state["conv"], up.astype(state["conv"].dtype),
                             p["conv"])
    c = jax.nn.silu(c.astype(jnp.float32)).astype(up.dtype)
    q = dense(c, p["w_q"]).reshape(B, n_heads, dh)
    k = dense(c, p["w_k"]).reshape(B, n_heads, dh)
    v = dense(up, p["w_v"]).reshape(B, n_heads, dh)
    i_pre = dense(xn, p["w_i"])
    f_pre = dense(xn, p["w_f"])
    (C, n, m), h = _mlstm_cell((state["C"], state["n"], state["m"]),
                               q, k, v, i_pre, f_pre)
    h = h.reshape(B, di).astype(x_t.dtype)
    h = rmsnorm(h, p["out_norm"])
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x_t.dtype)
    out = dense(h, p["w_down"])[:, None, :]
    new_state = {"C": C, "n": n, "m": m, "conv": conv_buf}
    return out, new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_spec(d_model: int, n_heads: int, *, ff_factor: float = 4.0 / 3.0,
               stack: Optional[int] = None) -> Dict[str, PSpec]:
    st = (stack,) if stack else ()
    pre = "stack," if stack else ""
    dff = int(d_model * ff_factor)
    return {
        "norm": PSpec(st + (d_model,), pre + ".", init="ones"),
        "w_gates": PSpec(st + (d_model, 4 * d_model), pre + "fsdp,model",
                         fan_in=d_model),
        "r_gates": PSpec(st + (n_heads, d_model // n_heads,
                               4 * (d_model // n_heads)),
                         pre + ".,.,.", fan_in=d_model),
        "out_norm": PSpec(st + (d_model,), pre + ".", init="ones"),
        "ffn_norm": PSpec(st + (d_model,), pre + ".", init="ones"),
        "w_ff_gate": PSpec(st + (d_model, dff), pre + "fsdp,model",
                           fan_in=d_model),
        "w_ff_up": PSpec(st + (d_model, dff), pre + "fsdp,model",
                         fan_in=d_model),
        "w_ff_down": PSpec(st + (dff, d_model), pre + "model,fsdp",
                           fan_in=dff),
    }


def slstm_init_state(batch: int, d_model: int, dtype=jnp.float32):
    z = jnp.zeros((batch, d_model), dtype)
    return {"c": z, "n": z, "h": z,
            "m": jnp.full((batch, d_model), -jnp.inf, dtype)}


def _slstm_cell(p, state, gx, n_heads: int):
    """gx: (B, 4D) input gate pre-activations. Head-blocked recurrence."""
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    B, D = c.shape
    dh = D // n_heads
    hr = h.reshape(B, n_heads, dh).astype(jnp.float32)
    rec = jnp.einsum("bhk,hkg->bhg", hr, p["r_gates"].astype(jnp.float32))
    g = gx.astype(jnp.float32).reshape(B, n_heads, 4 * dh) + rec
    zi, ii, fi, oi = jnp.split(g, 4, axis=-1)  # each (B, H, dh)
    zi, ii, fi, oi = (a.reshape(B, D) for a in (zi, ii, fi, oi))
    zt = jnp.tanh(zi)
    log_f = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(log_f + m, ii)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    i_g = jnp.exp(ii - m_safe)
    f_g = jnp.where(jnp.isfinite(m), jnp.exp(log_f + m - m_safe), 0.0)
    c_new = f_g * c + i_g * zt
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(oi) * c_new / jnp.maximum(n_new, 1.0)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_scan(p, x, *, n_heads: int):
    B, S, D = x.shape
    xn = rmsnorm(x, p["norm"])
    gx = dense(xn, p["w_gates"])  # (B,S,4D)

    # chunked like mlstm_scan (backward saves chunk-boundary states only)
    CHUNK = 64
    pad = (-S) % CHUNK
    nchunks = (S + pad) // CHUNK
    gxp = jnp.pad(gx, ((0, 0), (0, pad), (0, 0)))
    gxc = jnp.moveaxis(
        gxp.reshape(B, nchunks, CHUNK, gx.shape[-1]), 1, 0)
    tvalid = jnp.moveaxis(jnp.broadcast_to(
        (jnp.arange(S + pad) < S)[None, :], (B, S + pad)
    ).reshape(B, nchunks, CHUNK), 1, 0)

    @jax.checkpoint
    def chunk_step(state, inp):
        gchunk, tv = inp

        def step(st, t):
            st2 = _slstm_cell(p, st, gchunk[:, t], n_heads)
            ok = tv[:, t][:, None]
            st2 = jax.tree.map(lambda n, o: jnp.where(ok, n, o), st2, st)
            return st2, st2["h"]
        return jax.lax.scan(step, state, jnp.arange(CHUNK))

    final_state, hs = jax.lax.scan(chunk_step, slstm_init_state(B, D),
                                   (gxc, tvalid))
    hs = jnp.moveaxis(hs.reshape(nchunks * CHUNK, B, D), 0, 1)[:, :S]
    h = hs.astype(x.dtype)
    h = rmsnorm(h, p["out_norm"])
    # post-FFN (paper: sLSTM block with ff factor 4/3, gated)
    y = x + h  # inner residual around the recurrence
    yn = rmsnorm(y, p["ffn_norm"])
    ff = (jax.nn.silu(dense(yn, p["w_ff_gate"]).astype(jnp.float32)
                      ).astype(x.dtype) * dense(yn, p["w_ff_up"]))
    return h + dense(ff, p["w_ff_down"]), final_state


def slstm_step(p, x_t, state, *, n_heads: int):
    B = x_t.shape[0]
    xn = rmsnorm(x_t[:, 0], p["norm"])
    gx = dense(xn, p["w_gates"])
    state = _slstm_cell(p, state, gx, n_heads)
    h = state["h"].astype(x_t.dtype)
    h = rmsnorm(h, p["out_norm"])
    y = x_t[:, 0] + h
    yn = rmsnorm(y, p["ffn_norm"])
    ff = (jax.nn.silu(dense(yn, p["w_ff_gate"]).astype(jnp.float32)
                      ).astype(x_t.dtype) * dense(yn, p["w_ff_up"]))
    out = (h + dense(ff, p["w_ff_down"]))[:, None, :]
    return out, state
