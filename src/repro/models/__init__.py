"""Model zoo for the assigned architectures (see configs/)."""
from . import layers, lm, mla, moe, rglru, ssm, encdec  # noqa: F401
