"""Encoder-decoder assembly (seamless-m4t-medium backbone).

Per the assignment, the modality frontend is a STUB: ``input_specs()``
provides precomputed audio frame embeddings (B, T_enc, d_model); the
transformer backbone (12L encoder + 12L decoder, d=1024, 16H, ff=4096)
is what we build. Decoder layers = causal self-attention + cross-attention
over the encoder output + MLP. Decode caches both the growing self KV and
the static cross KV.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .layers import PSpec
from .lm import ArchCfg, _norm

__all__ = ["encdec_spec", "encode", "decode_train", "encdec_forward",
           "encdec_decode_step", "init_encdec_cache",
           "abstract_encdec_cache", "encdec_cache_axes"]


def _block(cfg: ArchCfg, stack: int, *, cross: bool) -> Dict[str, Any]:
    s = {
        "mix_norm": _norm_spec(cfg, stack),
        "attn": L.attn_spec(cfg.d_model, cfg.n_heads, cfg.n_kv,
                            cfg.head_dim, stack=stack),
        "ffn_norm": _norm_spec(cfg, stack),
        "mlp": L.mlp_spec(cfg.d_model, cfg.d_ff, gated=False, stack=stack),
    }
    if cross:
        s["cross_norm"] = _norm_spec(cfg, stack)
        s["cross"] = L.attn_spec(cfg.d_model, cfg.n_heads, cfg.n_kv,
                                 cfg.head_dim, stack=stack)
    return s


def _norm_spec(cfg: ArchCfg, stack):
    st = (stack,) if stack else ()
    pre = "stack," if stack else ""
    return PSpec(st + (cfg.d_model,), pre + ".", init="ones")


def encdec_spec(cfg: ArchCfg, n_enc: int, n_dec: int) -> Dict[str, Any]:
    return {
        "embed": L.embed_spec(cfg.vocab_padded, cfg.d_model),
        "enc": _block(cfg, n_enc, cross=False),
        "enc_norm": _norm_spec(cfg, None),
        "dec": _block(cfg, n_dec, cross=True),
        "final_norm": _norm_spec(cfg, None),
    }


# ---------------------------------------------------------------------------

def _cross_full(p, x, enc_kv, cfg):
    """Full-sequence cross attention. enc_kv: (k, v) (B, T, Hkv, hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k, v = enc_kv
    out = L.blockwise_attention(q, k, v, causal=False,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def _cross_kv(p, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return k, v


def encode(params, frames, cfg: ArchCfg, mesh=None):
    """frames: (B, T, d_model) stub embeddings -> encoder output."""
    from .lm import _constrain_act

    def body(x, p):
        x = L.grad_cast_bf16(_constrain_act(x, mesh, cfg))
        h, _ = L.gqa_full(p["attn"], _norm(cfg, x, p["mix_norm"]),
                          rope_base=10000.0, causal=False,
                          q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        x = x + h
        x = x + L.mlp_apply(p["mlp"], _norm(cfg, x, p["ffn_norm"]),
                            act="gelu")
        return x, ()
    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, frames, params["enc"],
                        unroll=cfg.n_enc if cfg.scan_unroll else 1)
    return L.rmsnorm(x, params["enc_norm"])


def decode_train(params, enc_out, tokens, cfg: ArchCfg, mesh=None,
                 last_only: bool = False):
    """Teacher-forced decoder. tokens: (B, S)."""
    from .lm import _constrain_act
    x = L.embed_apply(params["embed"], tokens, scale=cfg.embed_scale)

    def body(x, p):
        x = L.grad_cast_bf16(_constrain_act(x, mesh, cfg))
        h, _ = L.gqa_full(p["attn"], _norm(cfg, x, p["mix_norm"]),
                          rope_base=10000.0, causal=True,
                          q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        x = x + h
        x = x + _cross_full(p["cross"], _norm(cfg, x, p["cross_norm"]),
                            _cross_kv(p["cross"], enc_out), cfg)
        x = x + L.mlp_apply(p["mlp"], _norm(cfg, x, p["ffn_norm"]),
                            act="gelu")
        return x, ()
    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec"],
                        unroll=cfg.n_dec if cfg.scan_unroll else 1)
    if last_only:
        x = x[:, -1:]
    x = _norm(cfg, x, params["final_norm"])
    from .lm import _logits
    return _logits(params, x, cfg, mesh)


def encdec_forward(params, frames, tokens, cfg: ArchCfg, mesh=None):
    return decode_train(params, encode(params, frames, cfg, mesh), tokens,
                        cfg, mesh=mesh)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def _cache_shapes(cfg: ArchCfg, n_dec: int, batch: int, max_len: int,
                  enc_len: int):
    kv = (batch, max_len, cfg.n_kv, cfg.head_dim)
    xkv = (batch, enc_len, cfg.n_kv, cfg.head_dim)
    return {
        "self_k": ((n_dec,) + kv, jnp.bfloat16),
        "self_v": ((n_dec,) + kv, jnp.bfloat16),
        "cross_k": ((n_dec,) + xkv, jnp.bfloat16),
        "cross_v": ((n_dec,) + xkv, jnp.bfloat16),
    }


def init_encdec_cache(cfg, n_dec, batch, max_len, enc_len):
    return {k: jnp.zeros(sh, dt) for k, (sh, dt) in
            _cache_shapes(cfg, n_dec, batch, max_len, enc_len).items()}


def abstract_encdec_cache(cfg, n_dec, batch, max_len, enc_len):
    return {k: jax.ShapeDtypeStruct(sh, dt) for k, (sh, dt) in
            _cache_shapes(cfg, n_dec, batch, max_len, enc_len).items()}


def encdec_cache_axes(cfg, n_dec, batch, max_len, enc_len):
    return {k: "stack,batch,kv_seq_model,.,." for k in
            _cache_shapes(cfg, n_dec, batch, max_len, enc_len)}


def fill_cross_cache(params, enc_out, cache, cfg: ArchCfg):
    """Compute the static cross-attention KV for every decoder layer."""
    def body(_, p):
        k, v = _cross_kv(p["cross"], enc_out)
        return (), (k, v)
    _, (ks, vs) = jax.lax.scan(body, (), params["dec"])
    cache = dict(cache)
    cache["cross_k"] = ks.astype(cache["cross_k"].dtype)
    cache["cross_v"] = vs.astype(cache["cross_v"].dtype)
    return cache


def encdec_decode_step(params, cache, tokens, pos, cfg: ArchCfg,
                       mesh=None):
    """One decoder token. tokens: (B,1); returns (logits, new cache)."""
    x = L.embed_apply(params["embed"], tokens, scale=cfg.embed_scale)

    def body(x, inp):
        p, sk, sv, xk, xv = inp
        h, sk, sv = L.gqa_decode(p["attn"], _norm(cfg, x, p["mix_norm"]),
                                 sk, sv, pos, rope_base=10000.0)
        x = x + h
        # cross attention against the static encoder KV
        xn = _norm(cfg, x, p["cross_norm"])
        q = jnp.einsum("bsd,dhk->bshk", xn, p["cross"]["wq"])
        B, _, H, hd = q.shape
        Hkv = xk.shape[2]
        G = H // Hkv
        qg = q.reshape(B, Hkv, G, hd)
        f32 = jnp.float32
        s = jnp.einsum("bhgk,bthk->bhgt", qg.astype(f32), xk.astype(f32))
        a = jax.nn.softmax(s / math.sqrt(hd), axis=-1)
        o = jnp.einsum("bhgt,bthk->bhgk", a, xv.astype(f32)).astype(x.dtype)
        o = o.reshape(B, 1, H, hd)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["cross"]["wo"])
        x = x + L.mlp_apply(p["mlp"], _norm(cfg, x, p["ffn_norm"]),
                            act="gelu")
        return x, (sk, sv)

    # fori_loop with in-place stack-axis updates (see lm.lm_decode_step —
    # a scan would double-buffer the KV cache).
    def idx(a, i):
        return jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False)

    def one_layer(i, x, sk_all, sv_all):
        p = jax.tree.map(lambda a: idx(a, i), params["dec"])
        x, (sk, sv) = body(x, (p, idx(sk_all, i), idx(sv_all, i),
                               idx(cache["cross_k"], i),
                               idx(cache["cross_v"], i)))
        sk_all = jax.lax.dynamic_update_index_in_dim(
            sk_all, sk.astype(sk_all.dtype), i, 0)
        sv_all = jax.lax.dynamic_update_index_in_dim(
            sv_all, sv.astype(sv_all.dtype), i, 0)
        return x, sk_all, sv_all

    if cfg.scan_unroll:
        sks, svs = cache["self_k"], cache["self_v"]
        for i in range(cfg.n_dec):
            x, sks, svs = one_layer(i, x, sks, svs)
    else:
        def fbody(i, carry):
            return one_layer(i, *carry)
        x, sks, svs = jax.lax.fori_loop(
            0, cfg.n_dec, fbody, (x, cache["self_k"], cache["self_v"]))
    new_cache = dict(cache)
    new_cache["self_k"], new_cache["self_v"] = sks, svs
    x = _norm(cfg, x, params["final_norm"])
    from .lm import _logits
    logits = _logits(params, x, cfg, mesh)
    return logits, new_cache
