"""Real-Gated Linear Recurrent Unit blocks (Griffin / RecurrentGemma,
arXiv:2402.19427).

Temporal-mixing block: gated branch + (causal conv -> RG-LRU) branch,
elementwise product, down-projection. Recurrence:

    r_t = sigmoid(W_a x_t + b_a)                (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)                (input gate)
    a_t = exp(c * softplus(Lambda) * (-r_t))    (0 < a_t < 1, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

State is O(1) in sequence length (long_500k-eligible). Used in a 2:1
pattern with local (sliding-window, MQA) attention in recurrentgemma-9b.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .layers import PSpec, dense, rmsnorm
from .ssm import CONV_W, _causal_conv, _conv_step

__all__ = ["rglru_spec", "rglru_scan", "rglru_step", "rglru_init_state"]

C_FACTOR = 8.0


def rglru_spec(d_model: int, *, lru_width: Optional[int] = None,
               stack: Optional[int] = None) -> Dict[str, PSpec]:
    dr = lru_width or d_model
    st = (stack,) if stack else ()
    pre = "stack," if stack else ""
    return {
        "norm": PSpec(st + (d_model,), pre + ".", init="ones"),
        "w_gate": PSpec(st + (d_model, dr), pre + "fsdp,model",
                        fan_in=d_model),
        "w_x": PSpec(st + (d_model, dr), pre + "fsdp,model", fan_in=d_model),
        "conv": PSpec(st + (CONV_W, dr), pre + ".,model", fan_in=CONV_W),
        "w_a": PSpec(st + (dr, dr), pre + "model,.", fan_in=dr),
        "b_a": PSpec(st + (dr,), pre + ".", init="zeros"),
        "w_i": PSpec(st + (dr, dr), pre + "model,.", fan_in=dr),
        "b_i": PSpec(st + (dr,), pre + ".", init="zeros"),
        "lam": PSpec(st + (dr,), pre + ".", init="ones",
                     dtype=jnp.float32),
        "w_down": PSpec(st + (dr, d_model), pre + "model,fsdp", fan_in=dr),
    }


def rglru_init_state(batch: int, dr: int):
    return {"h": jnp.zeros((batch, dr), jnp.float32),
            "conv": jnp.zeros((batch, CONV_W - 1, dr), jnp.bfloat16)}


def _lru_gates(p, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(dense(uf, p["w_a"].astype(jnp.float32)) + p["b_a"])
    i = jax.nn.sigmoid(dense(uf, p["w_i"].astype(jnp.float32)) + p["b_i"])
    log_a = -C_FACTOR * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, mult * (i * uf)


def rglru_scan(p, x):
    """x: (B, S, D) -> residual-branch output (B, S, D)."""
    B, S, D = x.shape
    xn = rmsnorm(x, p["norm"])
    gate = jax.nn.gelu(dense(xn, p["w_gate"]).astype(jnp.float32),
                       approximate=True)
    u = _causal_conv(dense(xn, p["w_x"]), p["conv"])
    a, bx = _lru_gates(p, u)  # (B,S,dr) each, f32

    # associative scan over time: h_t = a_t h_{t-1} + b_t
    def bin_op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    a_s, h = jax.lax.associative_scan(bin_op, (a, bx), axis=1)
    y = (gate * h).astype(x.dtype)
    conv_buf = jnp.pad(dense(xn, p["w_x"]), ((0, 0), (CONV_W - 1, 0), (0, 0))
                       )[:, S:S + CONV_W - 1].astype(jnp.bfloat16)
    state = {"h": h[:, -1].astype(jnp.float32), "conv": conv_buf}
    return dense(y, p["w_down"]), state


def rglru_step(p, x_t, state):
    """x_t: (B, 1, D); state: {"h": (B,dr) f32, "conv": (B,3,dr)}."""
    xn = rmsnorm(x_t[:, 0], p["norm"])
    gate = jax.nn.gelu(dense(xn, p["w_gate"]).astype(jnp.float32),
                       approximate=True)
    ux = dense(xn, p["w_x"])
    u, conv_buf = _conv_step(state["conv"], ux.astype(state["conv"].dtype),
                             p["conv"])
    a, bx = _lru_gates(p, u)
    h = a * state["h"] + bx
    y = (gate * h).astype(x_t.dtype)
    out = dense(y, p["w_down"])[:, None, :]
    return out, {"h": h, "conv": conv_buf}
