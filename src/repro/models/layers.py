"""Shared layer library for the assigned architectures.

Functional, module-free style: every layer is (spec, apply). ``spec``
returns a nested dict of :class:`PSpec` leaves describing each parameter
(shape, dtype, logical sharding axes, initializer); generic materializers
turn a spec tree into real params (``init_params``), abstract stand-ins for
the dry-run (``abstract_params``; no allocation), or the sharding-axes tree
(``axes_tree``).

Compute policy: params bf16, matmuls bf16, softmax/norms/router/logits f32.
Attention is blockwise (flash-style lax.scan over KV chunks, f32 running
max/sum) so 32k prefill never materializes an S x S score matrix.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PSpec", "init_params", "abstract_params", "axes_tree",
    "rmsnorm", "rope", "blockwise_attention", "dense", "gqa_full",
    "gqa_decode", "mlp_apply", "mlp_spec", "attn_spec", "embed_spec",
    "softcap",
]

DTYPE = jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    axes: str                      # comma-joined logical axes, '.' = repl.
    dtype: Any = DTYPE
    init: str = "normal"           # normal | zeros | ones | embed
    fan_in: Optional[int] = None   # override for stacked shapes


def _is_spec(x):
    return isinstance(x, PSpec)


def init_params(rng: jax.Array, spec_tree):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(rng, len(leaves))

    def one(key, s: PSpec):
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        if s.init == "embed":
            return (jax.random.normal(key, s.shape, jnp.float32)
                    .astype(s.dtype))
        fan_in = s.fan_in or (s.shape[-2] if len(s.shape) >= 2 else s.shape[-1])
        std = 1.0 / math.sqrt(max(1, fan_in))
        return (jax.random.normal(key, s.shape, jnp.float32) * std
                ).astype(s.dtype)

    return jax.tree.unflatten(treedef, [one(k, s) for k, s in
                                        zip(keys, leaves)])


def abstract_params(spec_tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree,
        is_leaf=_is_spec)


def axes_tree(spec_tree):
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=_is_spec)


def param_count(spec_tree) -> int:
    return sum(int(np.prod(s.shape)) for s in
               jax.tree.leaves(spec_tree, is_leaf=_is_spec))


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, *, eps: float = 1e-6, plus_one: bool = False):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    s = scale.astype(jnp.float32)
    s = s + 1.0 if plus_one else s
    return (y * s).astype(x.dtype)


def softcap(x, cap: Optional[float]):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


@jax.custom_vjp
def grad_cast_bf16(x):
    """Identity that casts the COTANGENT to bf16. Placed at block
    boundaries so f32 cotangents born in f32-accumulated ops (softmax,
    flash accumulators, logits) do not propagate f32 activation-gradients
    through the whole backward pass (2x memory + bandwidth)."""
    return x


def _gcb_fwd(x):
    return x, None


def _gcb_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


grad_cast_bf16.defvjp(_gcb_fwd, _gcb_bwd)


def rope(x, positions, *, base: float = 10000.0):
    """x: (..., S, H, hd) with positions (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
        axis=-1).astype(x.dtype)


def dense(x, w, *, out_axes: int = 1):
    """x: (..., d_in), w: (d_in, ...out). Contract last dim of x."""
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=x.dtype)


def masked_cache_update(cache, new, pos, *, axis: int = 1):
    """Write one token's entry at ``pos`` along ``axis``.

    NOT a dynamic_update_slice: a traced start index on a SHARDED sequence
    axis makes SPMD gather the whole cache. The iota==pos select is
    elementwise, so every shard updates (or keeps) its local slice with
    zero communication. Costs one full cache read+write — the decode
    attention reads the full cache anyway (same order); the shard_map+cond
    zero-copy variant is a recorded §Perf lever."""
    assert new.shape[axis] == 1
    t = jax.lax.broadcasted_iota(jnp.int32, cache.shape, axis)
    newb = jnp.broadcast_to(new.astype(cache.dtype), cache.shape)
    return jnp.where(t == pos, newb, cache)


# ---------------------------------------------------------------------------
# blockwise (flash-style) attention — pure jnp + lax.scan
# ---------------------------------------------------------------------------

def blockwise_attention(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        q_positions=None,
                        logit_cap: Optional[float] = None,
                        q_chunk: int = 512, kv_chunk: int = 1024,
                        scale: Optional[float] = None,
                        skip_masked_blocks: bool = False):
    """q: (B, Sq, H, hd); k/v: (B, Skv, Hkv, hd). GQA via head grouping.
    Never materializes (Sq, Skv); memory is O(q_chunk * kv_chunk).

    ``window``: sliding-window size (local attention) — a kv position t is
    visible from query position s iff s - window < t <= s.
    ``q_positions``: absolute positions of the queries (default arange);
    kv positions are arange(Skv) (prefill) — decode uses gqa_decode.

    ``skip_masked_blocks`` (§Perf lever): with causal and/or window masks,
    most (q_block, kv_block) pairs are FULLY masked; instead of scanning
    all kv blocks per q block, scan only the fixed-size band that can be
    visible — ceil((q_chunk+window)/kv_chunk)+1 blocks for local layers,
    and the causal prefix for global layers — fetching kv blocks by
    dynamic index. FLOPs/bytes drop by ~Skv/(window+q_chunk) on window
    layers (gemma3: 5/6 of the net) with identical numerics.
    """
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    if q_positions is None:
        q_positions = jnp.arange(Sq, dtype=jnp.int32)

    nq = -(-Sq // q_chunk)
    nk = -(-Skv // kv_chunk)
    Sq_pad, Skv_pad = nq * q_chunk, nk * kv_chunk

    def pad(x, n, axis):
        cfg = [(0, 0)] * x.ndim
        cfg[axis] = (0, n - x.shape[axis])
        return jnp.pad(x, cfg)

    qp = pad(q, Sq_pad, 1).reshape(B, nq, q_chunk, Hkv, G, hd)
    kp = pad(k, Skv_pad, 1).reshape(B, nk, kv_chunk, Hkv, hd)
    vp = pad(v, Skv_pad, 1).reshape(B, nk, kv_chunk, Hkv, hd)
    qpos = pad(q_positions, Sq_pad, 0).reshape(nq, q_chunk)
    kpos = jnp.arange(Skv_pad, dtype=jnp.int32).reshape(nk, kv_chunk)
    kvalid = (jnp.arange(Skv_pad) < Skv).reshape(nk, kv_chunk)

    def q_block(qi):
        qc = qp[:, qi]                       # (B, qc, Hkv, G, hd)
        pos_q = qpos[qi]                     # (qc,)

        # remat: without this the backward of the kv scan saves every
        # block's (q_chunk x kv_chunk) probability matrix — an O(S^2)
        # residual. Rematerializing them from (q, k, v, m, l) is the
        # flash-attention backward strategy.
        @jax.checkpoint
        def kv_step(carry, inp):
            m, l, acc = carry
            kc, vc, pos_k, val_k = inp
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            s = softcap(s, logit_cap)
            mask = val_k[None, :]
            if causal:
                mask = mask & (pos_k[None, :] <= pos_q[:, None])
            if window is not None:
                mask = mask & (pos_k[None, :] > pos_q[:, None] - window)
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), ()

        m0 = jnp.full((B, Hkv, G, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, hd), jnp.float32)
        if skip_masked_blocks and window is not None:
            # only kv blocks intersecting [q_start - window, q_end] can be
            # visible: a fixed-size band, fetched by dynamic index.
            nband = min(nk, (q_chunk + window) // kv_chunk + 2)
            first = jnp.maximum(
                (qi * q_chunk - window) // kv_chunk, 0)
            first = jnp.minimum(first, nk - nband)

            def band_step(carry, j):
                ki = first + j
                kc = jax.lax.dynamic_index_in_dim(kp, ki, 1, False)
                vc = jax.lax.dynamic_index_in_dim(vp, ki, 1, False)
                pk = jax.lax.dynamic_index_in_dim(kpos, ki, 0, False)
                vk = jax.lax.dynamic_index_in_dim(kvalid, ki, 0, False)
                return kv_step(carry, (kc, vc, pk, vk))

            (m, l, acc), _ = jax.lax.scan(
                band_step, (m0, l0, a0), jnp.arange(nband))
        elif skip_masked_blocks and causal:
            # causal prefix: kv blocks after this q block are fully masked
            nneed = min(nk, (Sq_pad + kv_chunk - 1) // kv_chunk)

            def causal_step(carry, j):
                visible = (j * kv_chunk) <= (qi * q_chunk + q_chunk - 1)

                def go(c):
                    kc = jax.lax.dynamic_index_in_dim(kp, j, 1, False)
                    vc = jax.lax.dynamic_index_in_dim(vp, j, 1, False)
                    pk = jax.lax.dynamic_index_in_dim(kpos, j, 0, False)
                    vk = jax.lax.dynamic_index_in_dim(kvalid, j, 0, False)
                    return kv_step(c, (kc, vc, pk, vk))[0]

                return jax.lax.cond(visible, go, lambda c: c, carry), ()

            (m, l, acc), _ = jax.lax.scan(
                causal_step, (m0, l0, a0), jnp.arange(nneed))
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0),
                (jnp.moveaxis(kp, 1, 0), jnp.moveaxis(vp, 1, 0), kpos,
                 kvalid))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out  # (B, Hkv, G, qc, hd)

    outs = jax.lax.map(q_block, jnp.arange(nq))      # (nq, B, Hkv, G, qc, hd)
    out = jnp.moveaxis(outs, 0, 3)                   # (B, Hkv, G, nq, qc, hd)
    out = out.reshape(B, Hkv, G, Sq_pad, hd)[:, :, :, :Sq]
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer (spec + full/decode applies)
# ---------------------------------------------------------------------------

def attn_spec(d_model: int, n_heads: int, n_kv: int, head_dim: int, *,
              qkv_bias: bool = False, qk_norm: bool = False,
              stack: Optional[int] = None) -> Dict[str, PSpec]:
    st = (stack,) if stack else ()
    pre = "stack," if stack else ""
    s = {
        "wq": PSpec(st + (d_model, n_heads, head_dim),
                    pre + "fsdp,heads,.", fan_in=d_model),
        "wk": PSpec(st + (d_model, n_kv, head_dim),
                    pre + "fsdp,heads,.", fan_in=d_model),
        "wv": PSpec(st + (d_model, n_kv, head_dim),
                    pre + "fsdp,heads,.", fan_in=d_model),
        "wo": PSpec(st + (n_heads, head_dim, d_model),
                    pre + "heads,.,fsdp", fan_in=n_heads * head_dim),
    }
    if qkv_bias:
        s["bq"] = PSpec(st + (n_heads, head_dim), pre + "heads,.",
                        init="zeros")
        s["bk"] = PSpec(st + (n_kv, head_dim), pre + "heads,.", init="zeros")
        s["bv"] = PSpec(st + (n_kv, head_dim), pre + "heads,.", init="zeros")
    if qk_norm:
        s["q_norm"] = PSpec(st + (head_dim,), pre + ".", init="ones")
        s["k_norm"] = PSpec(st + (head_dim,), pre + ".", init="ones")
    return s


def _project_qkv(p, x, positions, *, rope_base, qk_norm):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"][None, None]
        k = k + p["bk"][None, None]
        v = v + p["bv"][None, None]
    if qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if rope_base:
        q = rope(q, positions, base=rope_base)
        k = rope(k, positions, base=rope_base)
    return q, k, v


def gqa_full(p, x, *, rope_base: float = 10000.0, causal: bool = True,
             window: Optional[int] = None, qk_norm: bool = False,
             logit_cap: Optional[float] = None,
             q_chunk: int = 512, kv_chunk: int = 1024,
             skip_masked_blocks: bool = False):
    """Training / prefill path. x: (B, S, D). Returns (out, (k, v))."""
    B, S, D = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    q, k, v = _project_qkv(p, x, positions, rope_base=rope_base,
                           qk_norm=qk_norm)
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              logit_cap=logit_cap, q_chunk=q_chunk,
                              kv_chunk=kv_chunk,
                              skip_masked_blocks=skip_masked_blocks)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, (k, v)


def gqa_decode(p, x, cache_k, cache_v, pos, *, rope_base: float = 10000.0,
               window: Optional[int] = None, qk_norm: bool = False,
               logit_cap: Optional[float] = None):
    """Single-token decode. x: (B, 1, D); cache_k/v: (B, Smax, Hkv, hd);
    pos: () int32 current position. Returns (out, new_k_cache, new_v_cache).
    The KV sequence axis may be sharded over "model" (flash-decoding):
    einsums below reduce over it and XLA inserts the psum."""
    B, _, D = x.shape
    positions = jnp.full((1,), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, positions, rope_base=rope_base,
                                   qk_norm=qk_norm)
    cache_k = masked_cache_update(cache_k, k_new, pos, axis=1)
    cache_v = masked_cache_update(cache_v, v_new, pos, axis=1)
    Smax, Hkv = cache_k.shape[1], cache_k.shape[2]
    H = q.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, -1)
    f32 = jnp.float32
    s = jnp.einsum("bhgk,bthk->bhgt", qg.astype(f32), cache_k.astype(f32))
    s = s / math.sqrt(q.shape[-1])
    s = softcap(s, logit_cap)
    t = jnp.arange(Smax, dtype=jnp.int32)
    mask = t[None, None, None, :] <= pos
    if window is not None:
        mask = mask & (t[None, None, None, :] > pos - window)
    s = jnp.where(mask, s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthk->bhgk", a, cache_v.astype(f32))
    out = out.reshape(B, 1, H, -1).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_spec(d_model: int, d_ff: int, *, gated: bool = True,
             stack: Optional[int] = None) -> Dict[str, PSpec]:
    st = (stack,) if stack else ()
    pre = "stack," if stack else ""
    s = {
        "w_up": PSpec(st + (d_model, d_ff), pre + "fsdp,model",
                      fan_in=d_model),
        "w_down": PSpec(st + (d_ff, d_model), pre + "model,fsdp",
                        fan_in=d_ff),
    }
    if gated:
        s["w_gate"] = PSpec(st + (d_model, d_ff), pre + "fsdp,model",
                            fan_in=d_model)
    return s


def mlp_apply(p, x, *, act: str = "silu"):
    up = dense(x, p["w_up"])
    if "w_gate" in p:
        g = dense(x, p["w_gate"])
        if act == "silu":
            h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * up
        else:
            h = jax.nn.gelu(g.astype(jnp.float32), approximate=True
                            ).astype(x.dtype) * up
    else:
        if act == "relu2":   # nemotron/minitron squared relu
            r = jax.nn.relu(up)
            h = r * r
        elif act == "relu":
            h = jax.nn.relu(up)
        else:
            h = jax.nn.gelu(up.astype(jnp.float32), approximate=True
                            ).astype(x.dtype)
    return dense(h, p["w_down"])


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------

def embed_spec(vocab: int, d_model: int) -> PSpec:
    return PSpec((vocab, d_model), "vocab,.", init="embed")


def embed_apply(table, tokens, *, scale: bool = False):
    x = jnp.take(table, tokens, axis=0)
    if scale:
        x = x * jnp.asarray(math.sqrt(table.shape[1]), x.dtype)
    return x


def logits_apply(table_or_w, x, *, transpose: bool = True,
                 cap: Optional[float] = None):
    # matmul in model dtype (backward stays bf16); upcast AFTER for the
    # f32 softmax/loss.
    w = table_or_w
    if transpose:  # tied embedding (vocab, d) -> project with transpose
        out = jnp.einsum("bsd,vd->bsv", x, w)
    else:
        out = jnp.einsum("bsd,dv->bsv", x, w)
    return softcap(out.astype(jnp.float32), cap)
