"""Fine-grained Mixture-of-Experts (DeepSeek-MoE / DeepSeek-V2 style):
``n_shared`` always-on experts + ``n_routed`` experts with top-k routing.

Expert parallelism follows the GraVF-M lesson (DESIGN.md §8): a token with
top-k experts is a vertex with out-degree k. Instead of unicasting k copies
of every token through an all_to_all (the GraVF pattern), the token
activations — already replicated across the "model" axis by the preceding
TP attention psum — play the broadcast update, and each expert shard
*receiver-side scatters*: it selects, from the replicated token stream,
exactly the (token, expert) pairs whose expert it hosts, computes them, and
a single psum combines. Cross-chip traffic per token is the d-sized output
reduction (independent of k), not k dispatched copies.

Dispatch inside each shard is sort-based into per-expert capacity buffers
(static shapes; overflow drops, standard with capacity_factor >= 1).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from .layers import PSpec, dense, mlp_apply, mlp_spec

__all__ = ["moe_spec", "moe_apply", "MoECfg"]


def moe_spec(d_model: int, d_ff_expert: int, n_routed: int, n_shared: int,
             *, stack: Optional[int] = None) -> Dict[str, PSpec]:
    st = (stack,) if stack else ()
    pre = "stack," if stack else ""
    s = {
        "router": PSpec(st + (d_model, n_routed), pre + ".,.",
                        dtype=jnp.float32, fan_in=d_model),
        "we_gate": PSpec(st + (n_routed, d_model, d_ff_expert),
                         pre + "expert,fsdp,.", fan_in=d_model),
        "we_up": PSpec(st + (n_routed, d_model, d_ff_expert),
                       pre + "expert,fsdp,.", fan_in=d_model),
        "we_down": PSpec(st + (n_routed, d_ff_expert, d_model),
                         pre + "expert,.,fsdp", fan_in=d_ff_expert),
    }
    if n_shared:
        s["shared"] = mlp_spec(d_model, d_ff_expert * n_shared, gated=True,
                               stack=stack)
    return s


def _expert_ffn(wg, wu, wd, buf):
    """buf: (E_loc, C, d) -> (E_loc, C, d). Gated SiLU experts."""
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _dispatch_compute(x2, p_router, wg, wu, wd, *, topk: int, capacity: int,
                      n_routed: int, e_start, e_local: int,
                      renormalize: bool):
    """Receiver-side scatter for one expert shard.

    x2: (T, d) tokens (replicated across expert shards); wg/wu/wd hold only
    this shard's ``e_local`` experts. Returns this shard's partial output
    (T, d) — caller psums across shards.
    """
    T, d = x2.shape
    logits = x2.astype(jnp.float32) @ p_router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)              # (T, E)
    gate_vals, idx = jax.lax.top_k(probs, topk)          # (T, topk) global e
    if renormalize:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- select the (token, expert) edges this shard owns ----------------
    e_loc = idx - e_start                                # (T, topk)
    mine = (e_loc >= 0) & (e_loc < e_local)
    flat_e = jnp.where(mine, e_loc, e_local).reshape(-1)  # (T*topk,)
    slot_tok = jnp.arange(T * topk, dtype=jnp.int32) // topk

    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    tok_sorted = slot_tok[order]
    start_of_e = jnp.searchsorted(e_sorted, jnp.arange(e_local + 1))
    pos = jnp.arange(T * topk, dtype=jnp.int32) - jnp.take(
        start_of_e, jnp.minimum(e_sorted, e_local))
    ok = (e_sorted < e_local) & (pos < capacity)

    buf = jnp.zeros((e_local + 1, capacity, d), x2.dtype)
    tgt_e = jnp.where(ok, e_sorted, e_local)
    tgt_p = jnp.where(ok, pos, 0)
    buf = buf.at[tgt_e, tgt_p].set(
        jnp.where(ok[:, None], jnp.take(x2, tok_sorted, axis=0), 0.0),
        mode="drop")

    out_buf = _expert_ffn(wg, wu, wd, buf[:-1])

    y_sorted = jnp.where(
        ok[:, None],
        out_buf.reshape(-1, d)[jnp.minimum(
            tgt_e * capacity + tgt_p, e_local * capacity - 1)],
        0.0)
    y_slots = jnp.zeros((T * topk, d), x2.dtype).at[order].set(y_sorted)
    gates = gate_vals.reshape(T * topk).astype(x2.dtype)
    y = (y_slots * gates[:, None]).reshape(T, topk, d).sum(axis=1)
    return y


def moe_apply(p, x, *, topk: int, n_routed: int, capacity: int,
              renormalize: bool = True, mesh: Optional[Mesh] = None):
    """x: (B, S, d) -> (B, S, d) routed-expert output + shared experts.

    With a mesh, the routed computation runs under shard_map over the
    "model" axis (expert parallelism, receiver-side dispatch); tokens stay
    sharded over ("pod","data") and replicated over "model".
    """
    from .layers import grad_cast_bf16
    B, S, d = x.shape
    x2 = grad_cast_bf16(x.reshape(B * S, d))

    if mesh is not None and "model" in mesh.axis_names:
        em = mesh.shape["model"]
        e_local = n_routed // em
        batch_ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

        def shard_fn(x2b, router, wg, wu, wd):
            # blocks: x2b (T_local, d); wg/wu/wd (e_local, d, ff)
            me = jax.lax.axis_index("model")
            y = _dispatch_compute(
                x2b, router, wg, wu, wd, topk=topk,
                capacity=capacity, n_routed=n_routed,
                e_start=me * e_local, e_local=e_local,
                renormalize=renormalize)
            return jax.lax.psum(y, "model")

        fn = jax.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(batch_ax, None), P(None, None),
                      P("model", None, None), P("model", None, None),
                      P("model", None, None)),
            out_specs=P(batch_ax, None),
            check_vma=False)
        y = fn(x2, p["router"], p["we_gate"], p["we_up"], p["we_down"])
    else:
        y = _dispatch_compute(
            x2, p["router"], p["we_gate"], p["we_up"], p["we_down"],
            topk=topk, capacity=capacity, n_routed=n_routed,
            e_start=0, e_local=n_routed, renormalize=renormalize)

    y = grad_cast_bf16(y.reshape(B, S, d))
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, act="silu")
    return y
