"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed to a ``kv_lora`` latent (512) plus one shared decoupled
RoPE key (64) per token — the cache stores 576 dims/token regardless of the
128 heads. Decode uses the ABSORBED form: q_nope is folded through W_uk so
scores are taken directly against the latent cache, and the attention
context is un-projected through W_uv afterwards; full K/V are never
materialized at decode time.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .layers import (PSpec, blockwise_attention, rmsnorm, rope)

__all__ = ["mla_spec", "mla_full", "mla_decode"]


def mla_spec(d_model: int, n_heads: int, *, q_lora: int = 1536,
             kv_lora: int = 512, qk_nope: int = 128, qk_rope: int = 64,
             v_dim: int = 128, stack: Optional[int] = None) -> Dict[str, PSpec]:
    st = (stack,) if stack else ()
    pre = "stack," if stack else ""
    return {
        "w_dq": PSpec(st + (d_model, q_lora), pre + "fsdp,.",
                      fan_in=d_model),
        "q_norm": PSpec(st + (q_lora,), pre + ".", init="ones"),
        "w_uq": PSpec(st + (q_lora, n_heads, qk_nope + qk_rope),
                      pre + "fsdp,heads,.", fan_in=q_lora),
        "w_dkv": PSpec(st + (d_model, kv_lora + qk_rope), pre + "fsdp,.",
                       fan_in=d_model),
        "kv_norm": PSpec(st + (kv_lora,), pre + ".", init="ones"),
        "w_uk": PSpec(st + (kv_lora, n_heads, qk_nope),
                      pre + ".,heads,.", fan_in=kv_lora),
        "w_uv": PSpec(st + (kv_lora, n_heads, v_dim),
                      pre + ".,heads,.", fan_in=kv_lora),
        "w_o": PSpec(st + (n_heads, v_dim, d_model), pre + "heads,.,fsdp",
                     fan_in=n_heads * v_dim),
    }


def _project(p, x, positions, *, qk_nope, qk_rope, kv_lora,
             rope_base=10000.0):
    q_lat = rmsnorm(dense_(x, p["w_dq"]), p["q_norm"])
    q = jnp.einsum("bsl,lhk->bshk", q_lat, p["w_uq"])
    q_nope, q_pe = q[..., :qk_nope], q[..., qk_nope:]
    q_pe = rope(q_pe, positions, base=rope_base)

    dkv = dense_(x, p["w_dkv"])
    c_kv = rmsnorm(dkv[..., :kv_lora], p["kv_norm"])      # (B,S,kv_lora)
    k_pe = dkv[..., kv_lora:][:, :, None, :]              # (B,S,1,rope)
    k_pe = rope(k_pe, positions, base=rope_base)
    return q_nope, q_pe, c_kv, k_pe


def dense_(x, w):
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=x.dtype)


def mla_full(p, x, *, qk_nope: int = 128, qk_rope: int = 64,
             kv_lora: int = 512, v_dim: int = 128,
             rope_base: float = 10000.0, q_chunk: int = 512,
             kv_chunk: int = 1024):
    """Training/prefill. Returns (out, (c_kv, k_pe)) — the decode cache."""
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    q_nope, q_pe, c_kv, k_pe = _project(
        p, x, positions, qk_nope=qk_nope, qk_rope=qk_rope, kv_lora=kv_lora,
        rope_base=rope_base)
    H = q_nope.shape[2]
    k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsl,lhk->bshk", c_kv, p["w_uv"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe, (B, S, H, qk_rope))], axis=-1)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    # pad v to qk dims for the shared blockwise kernel, slice after
    scale = 1.0 / math.sqrt(qk_nope + qk_rope)
    if v_dim != q.shape[-1]:
        v_in = jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                           (0, q.shape[-1] - v_dim)))
    else:
        v_in = v
    out = blockwise_attention(q, k, v_in, causal=True, scale=scale,
                              q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = out[..., :v_dim]
    out = jnp.einsum("bshk,hkd->bsd", out, p["w_o"])
    return out, (c_kv, k_pe[:, :, 0, :])


def mla_decode(p, x, cache_ckv, cache_kpe, pos, *, qk_nope: int = 128,
               qk_rope: int = 64, kv_lora: int = 512, v_dim: int = 128,
               rope_base: float = 10000.0):
    """Absorbed single-token decode.
    cache_ckv: (B, Smax, kv_lora); cache_kpe: (B, Smax, qk_rope)."""
    B = x.shape[0]
    positions = jnp.full((1,), pos, jnp.int32)
    q_nope, q_pe, c_kv_new, k_pe_new = _project(
        p, x, positions, qk_nope=qk_nope, qk_rope=qk_rope, kv_lora=kv_lora,
        rope_base=rope_base)
    from .layers import masked_cache_update
    cache_ckv = masked_cache_update(cache_ckv, c_kv_new, pos, axis=1)
    cache_kpe = masked_cache_update(cache_kpe, k_pe_new[:, :, 0, :],
                                    pos, axis=1)

    # absorb q_nope through W_uk: (B,1,H,nope) x (lora,H,nope) -> latent q
    q_lat = jnp.einsum("bshk,lhk->bshl", q_nope, p["w_uk"])
    f32 = jnp.float32
    s = (jnp.einsum("bshl,btl->bhst", q_lat.astype(f32),
                    cache_ckv.astype(f32))
         + jnp.einsum("bshk,btk->bhst", q_pe.astype(f32),
                      cache_kpe.astype(f32)))
    s = s * (1.0 / math.sqrt(qk_nope + qk_rope))
    t = jnp.arange(cache_ckv.shape[1], dtype=jnp.int32)
    s = jnp.where(t[None, None, None, :] <= pos, s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhst,btl->bshl", a,
                     cache_ckv.astype(f32)).astype(x.dtype)
    out = jnp.einsum("bshl,lhk->bshk", ctx, p["w_uv"])    # un-absorb W_uv
    out = jnp.einsum("bshk,hkd->bsd", out, p["w_o"])
    return out, cache_ckv, cache_kpe
