"""Decoder-only LM assembly for all assigned architectures.

A model is a sequence of *blocks* described by :class:`LayerKind`
(temporal mixer + channel mixer). Architectures declare a repeating
``block_pattern`` (scanned with stacked params — keeps HLO size O(pattern)
regardless of depth) plus an optional non-repeating ``tail``.

Families covered here: dense GQA (qwen3/qwen2/minitron/internvl2 backbone),
local:global hybrids (gemma3), MLA+MoE (deepseek-v2), GQA+MoE
(deepseek-moe), xLSTM (mlstm/slstm), RG-LRU hybrids (recurrentgemma).
Encoder-decoder (seamless-m4t) lives in encdec.py on the same blocks.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import mla as MLA
from . import moe as MOE
from . import rglru as RG
from . import ssm as SSM
from .layers import PSpec

__all__ = ["LayerKind", "ArchCfg", "lm_spec", "lm_forward",
           "lm_decode_step", "init_cache", "abstract_cache", "num_params"]


@dataclasses.dataclass(frozen=True)
class LayerKind:
    mixer: str = "attn"          # attn | mla | mlstm | slstm | rglru
    ffn: str = "mlp"             # mlp | moe | none
    window: Optional[int] = None  # sliding window for attn
    rope_base: float = 10000.0


@dataclasses.dataclass(frozen=True)
class MoeCfg:
    n_routed: int
    n_shared: int
    topk: int
    d_ff_expert: int
    renormalize: bool = True
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MlaCfg:
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ArchCfg:
    name: str
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    block_pattern: Tuple[LayerKind, ...]
    repeats: int
    tail: Tuple[LayerKind, ...] = ()
    # attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    act: str = "silu"            # mlp activation: silu | gelu | relu2
    logit_cap: Optional[float] = None
    # norms / embeddings
    norm_plus_one: bool = False  # gemma-style (1 + w) RMSNorm, zero-init
    post_norms: bool = False     # gemma-style sandwich norms
    embed_scale: bool = False
    tie_embeddings: bool = True
    # family extras
    moe: Optional[MoeCfg] = None
    mla: Optional[MlaCfg] = None
    xlstm_heads: int = 4
    lru_width: Optional[int] = None
    prefix_len: int = 0          # VLM / multimodal stub prefix tokens
    # family plumbing
    family: str = "lm"           # lm | encdec | vlm
    n_enc: int = 0               # encoder layers (encdec only)
    n_dec: int = 0               # decoder layers (encdec only)
    # runtime
    q_chunk: int = 512
    kv_chunk: int = 1024
    remat: bool = True
    long_context_ok: bool = False  # sub-quadratic: eligible for long_500k
    # embedding/logits table padding (vocab not divisible by the TP
    # degree would force replication — e.g. seamless's 256206).
    vocab_pad_to: int = 0
    # accumulate microbatch gradients in bf16 (halves grad memory;
    # unbiased-ish at mb<=16). §Perf lever for the 236B cells.
    accum_bf16: bool = False
    # skip fully-masked KV blocks in blockwise attention (window/causal
    # band scheduling — see layers.blockwise_attention). §Perf lever.
    attn_block_skip: bool = False
    # sequence parallelism: shard boundary activations (the remat saves)
    # over "model" on the seq axis (Megatron-SP analogue). §Perf lever.
    seq_shard_acts: bool = False
    # Dry-run accounting: XLA cost_analysis counts a scan body ONCE, not
    # x trip-count; the dry-run sets scan_unroll=True so the lowered HLO
    # contains every layer and FLOP/byte/collective counts are exact.
    scan_unroll: bool = False

    @property
    def n_layers(self) -> int:
        return len(self.block_pattern) * self.repeats + len(self.tail)

    @property
    def vocab_padded(self) -> int:
        if not self.vocab_pad_to:
            return self.vocab
        m = self.vocab_pad_to
        return -(-self.vocab // m) * m


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------

def _norm_spec(cfg: ArchCfg, stack):
    st = (stack,) if stack else ()
    pre = "stack," if stack else ""
    init = "zeros" if cfg.norm_plus_one else "ones"
    return PSpec(st + (cfg.d_model,), pre + ".", init=init)


def block_spec(kind: LayerKind, cfg: ArchCfg,
               stack: Optional[int] = None) -> Dict[str, Any]:
    s: Dict[str, Any] = {}
    if kind.mixer == "attn":
        s["mix_norm"] = _norm_spec(cfg, stack)
        s["attn"] = L.attn_spec(cfg.d_model, cfg.n_heads, cfg.n_kv,
                                cfg.head_dim, qkv_bias=cfg.qkv_bias,
                                qk_norm=cfg.qk_norm, stack=stack)
    elif kind.mixer == "mla":
        s["mix_norm"] = _norm_spec(cfg, stack)
        m = cfg.mla
        s["attn"] = MLA.mla_spec(cfg.d_model, cfg.n_heads, q_lora=m.q_lora,
                                 kv_lora=m.kv_lora, qk_nope=m.qk_nope,
                                 qk_rope=m.qk_rope, v_dim=m.v_dim,
                                 stack=stack)
    elif kind.mixer == "mlstm":
        s["mlstm"] = SSM.mlstm_spec(cfg.d_model, cfg.xlstm_heads,
                                    stack=stack)
    elif kind.mixer == "slstm":
        s["slstm"] = SSM.slstm_spec(cfg.d_model, cfg.xlstm_heads,
                                    stack=stack)
    elif kind.mixer == "rglru":
        s["rglru"] = RG.rglru_spec(cfg.d_model, lru_width=cfg.lru_width,
                                   stack=stack)
    else:
        raise ValueError(kind.mixer)

    if cfg.post_norms and kind.mixer in ("attn", "mla"):
        s["mix_post_norm"] = _norm_spec(cfg, stack)

    if kind.ffn == "mlp":
        s["ffn_norm"] = _norm_spec(cfg, stack)
        s["mlp"] = L.mlp_spec(cfg.d_model, cfg.d_ff,
                              gated=cfg.act in ("silu", "gelu"),
                              stack=stack)
        if cfg.post_norms:
            s["ffn_post_norm"] = _norm_spec(cfg, stack)
    elif kind.ffn == "moe":
        mo = cfg.moe
        s["ffn_norm"] = _norm_spec(cfg, stack)
        s["moe"] = MOE.moe_spec(cfg.d_model, mo.d_ff_expert, mo.n_routed,
                                mo.n_shared, stack=stack)
    return s


def lm_spec(cfg: ArchCfg) -> Dict[str, Any]:
    s: Dict[str, Any] = {
        "embed": L.embed_spec(cfg.vocab_padded, cfg.d_model),
        "final_norm": _norm_spec(cfg, None),
        "stage": {str(i): block_spec(k, cfg, stack=cfg.repeats)
                  for i, k in enumerate(cfg.block_pattern)},
    }
    if cfg.tail:
        s["tail"] = {str(i): block_spec(k, cfg, stack=None)
                     for i, k in enumerate(cfg.tail)}
    if not cfg.tie_embeddings:
        s["lm_head"] = PSpec((cfg.d_model, cfg.vocab_padded), ".,vocab",
                             fan_in=cfg.d_model)
    return s


def num_params(cfg: ArchCfg) -> int:
    return L.param_count(lm_spec(cfg))


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _norm(cfg, x, w):
    return L.rmsnorm(x, w, plus_one=cfg.norm_plus_one)


def _constrain_act(x, mesh, cfg=None):
    """Pin activations to (batch over data(+pod), seq/feature replicated)
    at block boundaries — otherwise SPMD propagation can flip them onto
    the feature axis (replicating the batch) deep in the stack.

    With ``cfg.seq_shard_acts`` (sequence parallelism), the boundary
    activations — which are exactly the remat-saved residuals — are ALSO
    sharded over "model" on the sequence axis, dividing the dominant
    activation-memory term by the TP degree at the cost of per-layer
    gathers (a §Perf lever)."""
    if mesh is None:
        return x
    from .. import sharding as SH
    seq = "seq_model" if (cfg is not None and
                          getattr(cfg, "seq_shard_acts", False)
                          and x.ndim == 3) else None
    spec = SH.logical_to_spec(
        mesh, ("batch", seq) + (None,) * (x.ndim - 2) if x.ndim >= 2
        else ("batch",), x.shape)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def _moe_capacity(cfg: ArchCfg, n_tokens_local: int) -> int:
    mo = cfg.moe
    c = math.ceil(n_tokens_local * mo.topk * mo.capacity_factor
                  / mo.n_routed)
    return max(8, -(-c // 8) * 8)


def _apply_ffn(kind, p, x, cfg, mesh):
    if kind.ffn == "mlp":
        h = L.mlp_apply(p["mlp"], _norm(cfg, x, p["ffn_norm"]), act=cfg.act)
        if cfg.post_norms:
            h = _norm(cfg, h, p["ffn_post_norm"])
        return x + L.grad_cast_bf16(h)
    if kind.ffn == "moe":
        B, S, _ = x.shape
        dp = 1
        if mesh is not None:
            for a in ("pod", "data"):
                if a in mesh.axis_names:
                    dp *= mesh.shape[a]
        cap = _moe_capacity(cfg, max(1, (B * S) // dp))
        h = MOE.moe_apply(p["moe"], _norm(cfg, x, p["ffn_norm"]),
                          topk=cfg.moe.topk, n_routed=cfg.moe.n_routed,
                          capacity=cap, renormalize=cfg.moe.renormalize,
                          mesh=mesh)
        return x + h
    return x


def block_full(kind: LayerKind, p, x, cfg: ArchCfg, mesh=None):
    """Training/prefill through one block. Returns (x, cache_entry)."""
    if kind.mixer == "attn":
        h, (k, v) = L.gqa_full(
            p["attn"], _norm(cfg, x, p["mix_norm"]), rope_base=kind.rope_base,
            window=kind.window, qk_norm=cfg.qk_norm,
            logit_cap=cfg.logit_cap, q_chunk=cfg.q_chunk,
            kv_chunk=cfg.kv_chunk,
            skip_masked_blocks=cfg.attn_block_skip)
        if cfg.post_norms:
            h = _norm(cfg, h, p["mix_post_norm"])
        x = x + L.grad_cast_bf16(h)
        cache = {"k": k, "v": v}
    elif kind.mixer == "mla":
        m = cfg.mla
        h, (ckv, kpe) = MLA.mla_full(
            p["attn"], _norm(cfg, x, p["mix_norm"]), qk_nope=m.qk_nope,
            qk_rope=m.qk_rope, kv_lora=m.kv_lora, v_dim=m.v_dim,
            rope_base=kind.rope_base, q_chunk=cfg.q_chunk,
            kv_chunk=cfg.kv_chunk)
        x = x + L.grad_cast_bf16(h)
        cache = {"ckv": ckv, "kpe": kpe}
    elif kind.mixer == "mlstm":
        h, cache = SSM.mlstm_scan(p["mlstm"], x, n_heads=cfg.xlstm_heads)
        x = x + h
    elif kind.mixer == "slstm":
        h, cache = SSM.slstm_scan(p["slstm"], x, n_heads=cfg.xlstm_heads)
        x = x + h
    elif kind.mixer == "rglru":
        h, cache = RG.rglru_scan(p["rglru"], x)
        x = x + h
    else:
        raise ValueError(kind.mixer)
    x = _apply_ffn(kind, p, x, cfg, mesh)
    return x, cache


def block_decode(kind: LayerKind, p, x, cache, pos, cfg: ArchCfg,
                 mesh=None):
    """Single-token decode through one block. Returns (x, new_cache)."""
    if kind.mixer == "attn":
        h, ck, cv = L.gqa_decode(
            p["attn"], _norm(cfg, x, p["mix_norm"]), cache["k"], cache["v"],
            pos, rope_base=kind.rope_base, window=kind.window,
            qk_norm=cfg.qk_norm, logit_cap=cfg.logit_cap)
        if cfg.post_norms:
            h = _norm(cfg, h, p["mix_post_norm"])
        x = x + h
        cache = {"k": ck, "v": cv}
    elif kind.mixer == "mla":
        m = cfg.mla
        h, ckv, kpe = MLA.mla_decode(
            p["attn"], _norm(cfg, x, p["mix_norm"]), cache["ckv"],
            cache["kpe"], pos, qk_nope=m.qk_nope, qk_rope=m.qk_rope,
            kv_lora=m.kv_lora, v_dim=m.v_dim, rope_base=kind.rope_base)
        x = x + L.grad_cast_bf16(h)
        cache = {"ckv": ckv, "kpe": kpe}
    elif kind.mixer == "mlstm":
        h, cache = SSM.mlstm_step(p["mlstm"], x, cache,
                                  n_heads=cfg.xlstm_heads)
        x = x + h
    elif kind.mixer == "slstm":
        h, cache = SSM.slstm_step(p["slstm"], x, cache,
                                  n_heads=cfg.xlstm_heads)
        x = x + h
    elif kind.mixer == "rglru":
        h, cache = RG.rglru_step(p["rglru"], x, cache)
        x = x + h
    x = _apply_ffn(kind, p, x, cfg, mesh)
    return x, cache


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _block_cache_shapes(kind: LayerKind, cfg: ArchCfg, batch: int,
                        max_len: int):
    d = cfg.d_model
    if kind.mixer == "attn":
        sh = (batch, max_len, cfg.n_kv, cfg.head_dim)
        return {"k": (sh, jnp.bfloat16), "v": (sh, jnp.bfloat16)}
    if kind.mixer == "mla":
        m = cfg.mla
        return {"ckv": ((batch, max_len, m.kv_lora), jnp.bfloat16),
                "kpe": ((batch, max_len, m.qk_rope), jnp.bfloat16)}
    if kind.mixer == "mlstm":
        di = int(d * 2.0)
        dh = di // cfg.xlstm_heads
        return {"C": ((batch, cfg.xlstm_heads, dh, dh), jnp.float32),
                "n": ((batch, cfg.xlstm_heads, dh), jnp.float32),
                "m": ((batch, cfg.xlstm_heads), jnp.float32),
                "conv": ((batch, SSM.CONV_W - 1, di), jnp.bfloat16)}
    if kind.mixer == "slstm":
        sh = (batch, d)
        return {"c": (sh, jnp.float32), "n": (sh, jnp.float32),
                "h": (sh, jnp.float32), "m": (sh, jnp.float32)}
    if kind.mixer == "rglru":
        dr = cfg.lru_width or d
        return {"h": ((batch, dr), jnp.float32),
                "conv": ((batch, SSM.CONV_W - 1, dr), jnp.bfloat16)}
    raise ValueError(kind.mixer)


def _make_cache(cfg: ArchCfg, batch: int, max_len: int, fn):
    """fn(shape_without_stack, dtype, stacked: bool) -> leaf."""
    out = {"stage": {}}
    for i, kind in enumerate(cfg.block_pattern):
        shapes = _block_cache_shapes(kind, cfg, batch, max_len)
        out["stage"][str(i)] = {
            k: fn(sh, dt, True) for k, (sh, dt) in shapes.items()}
    if cfg.tail:
        out["tail"] = {}
        for i, kind in enumerate(cfg.tail):
            shapes = _block_cache_shapes(kind, cfg, batch, max_len)
            out["tail"][str(i)] = {
                k: fn(sh, dt, False) for k, (sh, dt) in shapes.items()}
    return out


def init_cache(cfg: ArchCfg, batch: int, max_len: int):
    def mk(sh, dt, stacked):
        full = ((cfg.repeats,) + sh) if stacked else sh
        return jnp.zeros(full, dt)
    cache = _make_cache(cfg, batch, max_len, mk)

    # m-stabilizer states start at -inf
    def fix(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else None
        if name == "m" and leaf.dtype == jnp.float32 and leaf.ndim <= 3:
            return jnp.full(leaf.shape, -jnp.inf, leaf.dtype)
        return leaf
    return jax.tree_util.tree_map_with_path(fix, cache)


def abstract_cache(cfg: ArchCfg, batch: int, max_len: int):
    def mk(sh, dt, stacked):
        full = ((cfg.repeats,) + sh) if stacked else sh
        return jax.ShapeDtypeStruct(full, dt)
    return _make_cache(cfg, batch, max_len, mk)


def cache_axes(cfg: ArchCfg, batch: int, max_len: int):
    """Logical sharding axes matching the cache pytree: batch over data,
    KV sequence over model (flash-decoding split)."""
    def mk(sh, dt, stacked):
        if len(sh) >= 2 and sh[1] == max_len:
            names = ["batch", "kv_seq_model"] + ["."] * (len(sh) - 2)
        else:
            names = ["batch"] + ["."] * (len(sh) - 1)
        if stacked:
            names = ["stack"] + names
        return ",".join(names)
    return _make_cache(cfg, batch, max_len, mk)


# ---------------------------------------------------------------------------
# forward / decode
# ---------------------------------------------------------------------------

def lm_forward(params, tokens, cfg: ArchCfg, *, mesh=None,
               prefix_embeds=None, return_cache: bool = False,
               last_only: bool = False):
    """tokens: (B, S) int32. prefix_embeds: optional (B, Sp, D) multimodal
    stub prefix (internvl2/seamless-style). Returns logits (B, S_total, V)
    (f32) and optionally the prefill KV caches (cache pytree WITHOUT
    padding to a max_len — caller places them into serve buffers)."""
    x = L.embed_apply(params["embed"], tokens, scale=cfg.embed_scale)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)

    caches = {"stage": {}} if return_cache else None

    # repeating stages: scan over stacked params
    stage_params = params["stage"]

    def stage_body(x, layer_params):
        x = L.grad_cast_bf16(_constrain_act(x, mesh, cfg))
        cs = {}
        for i, kind in enumerate(cfg.block_pattern):
            x, c = block_full(kind, layer_params[str(i)], x, cfg, mesh)
            if return_cache:
                cs[str(i)] = c
        x = _constrain_act(x, mesh, cfg)
        return x, cs

    body = stage_body
    if cfg.remat:
        body = jax.checkpoint(stage_body)
    x, stage_caches = jax.lax.scan(
        body, x, stage_params,
        unroll=cfg.repeats if cfg.scan_unroll else 1)
    if return_cache:
        caches["stage"] = stage_caches

    if cfg.tail:
        if return_cache:
            caches["tail"] = {}
        for i, kind in enumerate(cfg.tail):
            x, c = block_full(kind, params["tail"][str(i)], x, cfg, mesh)
            if return_cache:
                caches["tail"][str(i)] = c

    if last_only:
        x = x[:, -1:]  # serve prefill: only the last position's logits
    x = _norm(cfg, x, params["final_norm"])
    logits = _logits(params, x, cfg, mesh)
    return (logits, caches) if return_cache else logits


def _logits(params, x, cfg: ArchCfg, mesh):
    if cfg.tie_embeddings:
        logits = L.logits_apply(params["embed"], x, transpose=True,
                                cap=cfg.logit_cap)
    else:
        logits = L.logits_apply(params["lm_head"], x, transpose=False,
                                cap=cfg.logit_cap)
    if cfg.vocab_padded != cfg.vocab:
        # mask padding ids out of the softmax (elementwise: sharding-safe)
        vid = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(vid < cfg.vocab, logits, -1e9)
    if mesh is not None:
        # keep the f32 logits sharded (batch over data, vocab over model) —
        # without this XLA may replicate the (tokens x vocab) tensor.
        from .. import sharding as SH
        spec = SH.logical_to_spec(mesh, ("batch", None, "vocab"),
                                  logits.shape)
        logits = jax.lax.with_sharding_constraint(
            logits, jax.sharding.NamedSharding(mesh, spec))
    return logits


def lm_decode_step(params, cache, tokens, pos, cfg: ArchCfg, *, mesh=None):
    """tokens: (B, 1); pos: () int32. Returns (logits (B,1,V), new cache).

    Layers run under a fori_loop with in-place dynamic updates on the
    (leading, unsharded) stack axis of the cache — a lax.scan with cache
    xs/ys would double-buffer the multi-GB KV cache (xs and stacked ys are
    distinct buffers), which blows the HBM budget at 32k context."""
    x = L.embed_apply(params["embed"], tokens, scale=cfg.embed_scale)

    def one_layer(i, x, stage_cache):
        x = _constrain_act(x, mesh)
        p_i = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            params["stage"])
        c_i = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            stage_cache)
        new_c = {}
        for j, kind in enumerate(cfg.block_pattern):
            x, c = block_decode(kind, p_i[str(j)], x, c_i[str(j)], pos,
                                cfg, mesh)
            new_c[str(j)] = c
        stage_cache = jax.tree.map(
            lambda buf, n: jax.lax.dynamic_update_index_in_dim(
                buf, n.astype(buf.dtype), i, 0),
            stage_cache, new_c)
        return x, stage_cache

    if cfg.scan_unroll:  # cost-pass accounting: statically unrolled
        stage_cache = cache["stage"]
        for i in range(cfg.repeats):
            x, stage_cache = one_layer(i, x, stage_cache)
        new_stage_cache = stage_cache
    else:
        def body(i, carry):
            x, sc = carry
            return one_layer(i, x, sc)
        x, new_stage_cache = jax.lax.fori_loop(
            0, cfg.repeats, body, (x, cache["stage"]))
    new_cache = {"stage": new_stage_cache}

    if cfg.tail:
        new_cache["tail"] = {}
        for i, kind in enumerate(cfg.tail):
            x, c = block_decode(kind, params["tail"][str(i)], x,
                                cache["tail"][str(i)], pos, cfg, mesh)
            new_cache["tail"][str(i)] = c

    x = _norm(cfg, x, params["final_norm"])
    logits = _logits(params, x, cfg, mesh)
    return logits, new_cache
