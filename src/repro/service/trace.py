"""Query-lifecycle tracing: a bounded event bus + span assembly +
Chrome-trace export.

The stats endpoint (stats.py) answers "how fast is the service"; this
module answers "where did THIS query's latency go". Every layer that
owns a lifecycle phase emits a typed :class:`TraceEvent` into one
shared, thread-safe, bounded :class:`TraceBus`:

  ==========  =======================================================
  kind        emitted by / meaning
  ==========  =======================================================
  submit      server — request entered the service (deadline attached)
  queue       server/scheduler — request entered a scheduler queue
  admit       scheduler — request took a lane / joined a dispatched
              batch (``reason``: fresh | preempt | batch)
  superstep   LaneTable (core/stepper.py) — one fused device dispatch,
              with wall time and the lane→query attribution map
  park        scheduler — an active lane was checkpointed to host
              (``by``: the preempting request's qid)
  restore     scheduler — a parked lane was spliced back in
  retire      scheduler/server — the query resolved (``supersteps``,
              ``messages``, ``deadline_slack_s``; ``reason``:
              retired | cache | error)
  shed        server — admission refused it (``reason``:
              quota | deadline)
  publish     store — a graph version was registered
  spill       store — a layout was demoted device → host
  refault     store — a fault promoted a layout back to device
              (``cold``: the host copy was gone too)
  evict       store — a layout was discarded from both tiers
  alert       watchdog (metrics.py) — an SLO/model rule transitioned
              (``rule``, ``state``: firing | resolved, ``value``,
              ``threshold``; ``klass`` carries the subject)
  ==========  =======================================================

The bus is a ring buffer: a long-running service keeps the most recent
``capacity`` events and counts what it dropped — tracing never grows
without bound and never blocks a hot path (one leaf-lock append per
event; a disabled bus costs one attribute read).

On top of the raw events, :func:`assemble_spans` folds each query's
events into a :class:`QuerySpan` — its queued interval, active
interval(s), parked interval(s), and outcome — and
:func:`chrome_trace` renders spans + superstep dispatches + store
residency transitions as Chrome trace-event JSON
(``chrome://tracing`` / https://ui.perfetto.dev load it directly;
``GraphQueryService.dump_trace(path)`` is the one-call export).
"""
from __future__ import annotations

import collections
import dataclasses
import json
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["TraceEvent", "TraceBus", "QuerySpan", "EVENT_KINDS",
           "assemble_spans", "chrome_trace"]

EVENT_KINDS = frozenset({
    "submit", "queue", "admit", "superstep", "park", "restore", "retire",
    "shed", "publish", "spill", "refault", "evict", "alert",
})


@dataclasses.dataclass
class TraceEvent:
    """One lifecycle event. ``ts`` is ``time.perf_counter()`` seconds
    (the same clock every deadline and latency in the service uses);
    ``dur_s`` is nonzero only for events that cover an interval
    (superstep dispatches). ``qid``/``tenant``/``klass`` attribute the
    event to a query / tenant / query class; store events leave them
    None and carry ``graph_id``/``version`` in ``attrs``."""

    kind: str
    ts: float
    qid: Optional[int] = None
    tenant: Optional[str] = None
    klass: Optional[str] = None
    dur_s: float = 0.0
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)


class TraceBus:
    """Thread-safe bounded ring buffer of :class:`TraceEvent`.

    ``emit`` is the only hot-path entry point and is deliberately
    minimal: one enabled-flag read when tracing is off, one leaf-lock
    deque append when it is on. The lock is never held while calling
    out, so the bus can be emitted into from under any scheduler/store
    lock without ordering constraints."""

    def __init__(self, capacity: int = 65536, enabled: bool = True):
        assert capacity >= 1
        self.capacity = capacity
        self.enabled = enabled
        self._lock = threading.Lock()  # lock: trace
        self._events: "collections.deque[TraceEvent]" = collections.deque(
            maxlen=capacity)
        self.emitted = 0        # total ever emitted (ring may have dropped)

    # ------------------------------------------------------------------
    def emit(self, kind: str, *, qid: Optional[int] = None,
             tenant: Optional[str] = None, klass: Optional[str] = None,
             dur_s: float = 0.0, ts: Optional[float] = None,
             **attrs) -> None:
        if not self.enabled:
            return
        assert kind in EVENT_KINDS, f"unknown trace event kind {kind!r}"
        ev = TraceEvent(kind=kind,
                        ts=time.perf_counter() if ts is None else ts,
                        qid=qid, tenant=tenant, klass=klass,
                        dur_s=dur_s, attrs=attrs)
        with self._lock:
            self._events.append(ev)
            self.emitted += 1

    @property
    def dropped(self) -> int:
        """Events the ring buffer has overwritten."""
        with self._lock:
            return self.emitted - len(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def snapshot(self) -> List[TraceEvent]:
        """Copy of the retained events in emission order."""
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.emitted = 0

    # ------------------------------------------------------------------
    def spans(self) -> Dict[int, "QuerySpan"]:
        return assemble_spans(self.snapshot())

    def chrome_trace(self) -> Dict[str, Any]:
        return chrome_trace(self.snapshot())

    def dump(self, path: str) -> str:
        """Write the Chrome-trace JSON to ``path`` (load it in
        ``chrome://tracing`` or https://ui.perfetto.dev); returns the
        path."""
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(), fh)
        return path


# ---------------------------------------------------------------------------
# span assembly
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QuerySpan:
    """One query's lifecycle, reconstructed from its events.

    Interval ends are ``None`` while the phase is still open at
    snapshot time (a query mid-flight has an open ``active`` interval).
    ``outcome`` is None (in flight), ``"retired"``, ``"cache_hit"``,
    ``"shed"``, or ``"error"``."""

    qid: int
    tenant: Optional[str] = None
    klass: Optional[str] = None
    submitted_s: Optional[float] = None
    queued: Optional[Tuple[float, Optional[float]]] = None
    active: List[Tuple[float, Optional[float]]] = \
        dataclasses.field(default_factory=list)
    parked: List[Tuple[float, Optional[float]]] = \
        dataclasses.field(default_factory=list)
    retired_s: Optional[float] = None
    outcome: Optional[str] = None
    supersteps: Optional[int] = None
    messages: Optional[int] = None
    deadline_slack_s: Optional[float] = None
    parks: int = 0

    # -- conveniences for tests / dashboards ---------------------------
    def queued_s(self) -> float:
        if self.queued is None or self.queued[1] is None:
            return 0.0
        return self.queued[1] - self.queued[0]

    def active_s(self) -> float:
        return sum(b - a for a, b in self.active if b is not None)

    def parked_s(self) -> float:
        return sum(b - a for a, b in self.parked if b is not None)


def _close(intervals: List[Tuple[float, Optional[float]]],
           ts: float) -> None:
    if intervals and intervals[-1][1] is None:
        intervals[-1] = (intervals[-1][0], ts)


def assemble_spans(events: List[TraceEvent]) -> Dict[int, QuerySpan]:
    """Fold per-query events into :class:`QuerySpan`\\ s.

    Robust to ring-buffer truncation: an event for a qid whose
    ``submit`` was overwritten still opens a span (phases before the
    first retained event are simply absent). Events are processed in
    timestamp order."""
    spans: Dict[int, QuerySpan] = {}
    for ev in sorted((e for e in events if e.qid is not None),
                     key=lambda e: e.ts):
        sp = spans.get(ev.qid)
        if sp is None:
            sp = spans[ev.qid] = QuerySpan(qid=ev.qid)
        if ev.tenant is not None:
            sp.tenant = ev.tenant
        if ev.klass is not None:
            sp.klass = ev.klass
        if ev.kind == "submit":
            sp.submitted_s = ev.ts
            if sp.queued is None:
                sp.queued = (ev.ts, None)
        elif ev.kind == "queue":
            if sp.queued is None:
                sp.queued = (ev.ts, None)
        elif ev.kind == "admit":
            if sp.queued is not None and sp.queued[1] is None:
                sp.queued = (sp.queued[0], ev.ts)
            elif sp.queued is None:     # submit/queue fell off the ring
                sp.queued = (ev.ts, ev.ts)
            sp.active.append((ev.ts, None))
        elif ev.kind == "park":
            _close(sp.active, ev.ts)
            sp.parked.append((ev.ts, None))
            sp.parks += 1
        elif ev.kind == "restore":
            _close(sp.parked, ev.ts)
            sp.active.append((ev.ts, None))
        elif ev.kind == "shed":
            if sp.queued is not None and sp.queued[1] is None:
                sp.queued = (sp.queued[0], ev.ts)
            sp.retired_s = ev.ts
            sp.outcome = "shed"
        elif ev.kind == "retire":
            _close(sp.active, ev.ts)
            if sp.queued is not None and sp.queued[1] is None:
                # resolved straight out of the queue (cache hit / error)
                sp.queued = (sp.queued[0], ev.ts)
            sp.retired_s = ev.ts
            reason = ev.attrs.get("reason", "retired")
            sp.outcome = {"cache": "cache_hit"}.get(reason, reason)
            if "supersteps" in ev.attrs:
                sp.supersteps = int(ev.attrs["supersteps"])
            if "messages" in ev.attrs:
                sp.messages = int(ev.attrs["messages"])
            if ev.attrs.get("deadline_slack_s") is not None:
                sp.deadline_slack_s = float(ev.attrs["deadline_slack_s"])
    return spans


# ---------------------------------------------------------------------------
# Chrome trace-event export
# ---------------------------------------------------------------------------

_QUERY_PID = 1
_SCHED_PID = 2
_STORE_PID = 3


def _json_safe(v):
    """Chrome-trace ``args`` must be JSON; numpy scalars and dict int
    keys are converted, anything else falls back to str."""
    try:
        json.dumps(v)
        return v
    except TypeError:
        if isinstance(v, dict):
            return {str(k): _json_safe(x) for k, x in v.items()}
        if isinstance(v, (list, tuple)):
            return [_json_safe(x) for x in v]
        if hasattr(v, "item"):
            return v.item()
        return str(v)


def chrome_trace(events: List[TraceEvent]) -> Dict[str, Any]:
    """Render events as Chrome trace-event JSON (Perfetto-loadable).

    Layout: process 1 holds one thread per query (its queued / active /
    parked phases as complete "X" slices, shed/retire reasons in args);
    process 2 one thread per query class (the per-superstep device
    dispatches, each with its lane→query attribution); process 3 the
    graph store's residency transitions as instant events. Timestamps
    are µs relative to the earliest retained event."""
    out: List[Dict[str, Any]] = []
    if not events:
        return {"traceEvents": out, "displayTimeUnit": "ms"}
    base = min(e.ts for e in events)
    end = max(e.ts + e.dur_s for e in events)

    def us(t: float) -> float:
        return (t - base) * 1e6

    out.append({"ph": "M", "pid": _QUERY_PID, "name": "process_name",
                "args": {"name": "queries"}})
    out.append({"ph": "M", "pid": _SCHED_PID, "name": "process_name",
                "args": {"name": "scheduler"}})
    out.append({"ph": "M", "pid": _STORE_PID, "name": "process_name",
                "args": {"name": "graph-store"}})

    # ---- per-query phase slices --------------------------------------
    spans = assemble_spans(events)
    for qid, sp in sorted(spans.items()):
        label = f"q{qid}" + (f" [{sp.tenant}]" if sp.tenant else "")
        if sp.klass:
            label += f" {sp.klass}"
        out.append({"ph": "M", "pid": _QUERY_PID, "tid": qid,
                    "name": "thread_name", "args": {"name": label}})
        phases = []
        if sp.queued is not None:
            phases.append(("queued", [sp.queued]))
        phases.append(("active", sp.active))
        phases.append(("parked", sp.parked))
        for name, intervals in phases:
            for a, b in intervals:
                b_eff = end if b is None else b
                out.append({
                    "ph": "X", "pid": _QUERY_PID, "tid": qid,
                    "name": name, "cat": "query",
                    "ts": us(a), "dur": max(0.0, us(b_eff) - us(a)),
                    "args": {"open": b is None},
                })
        if sp.retired_s is not None:
            args = {"outcome": sp.outcome}
            if sp.supersteps is not None:
                args["supersteps"] = sp.supersteps
            if sp.messages is not None:
                args["messages"] = sp.messages
            if sp.deadline_slack_s is not None:
                args["deadline_slack_ms"] = sp.deadline_slack_s * 1e3
            out.append({"ph": "i", "pid": _QUERY_PID, "tid": qid,
                        "name": sp.outcome or "retire", "cat": "query",
                        "ts": us(sp.retired_s), "s": "t",
                        "args": _json_safe(args)})

    # ---- scheduler dispatches + store transitions --------------------
    class_tids: Dict[str, int] = {}
    for ev in sorted(events, key=lambda e: e.ts):
        if ev.kind == "superstep":
            key = ev.klass or "?"
            tid = class_tids.get(key)
            if tid is None:
                tid = class_tids[key] = len(class_tids) + 1
                out.append({"ph": "M", "pid": _SCHED_PID, "tid": tid,
                            "name": "thread_name", "args": {"name": key}})
            out.append({"ph": "X", "pid": _SCHED_PID, "tid": tid,
                        "name": "superstep", "cat": "dispatch",
                        "ts": us(ev.ts), "dur": ev.dur_s * 1e6,
                        "args": _json_safe(ev.attrs)})
        elif ev.kind in ("publish", "spill", "refault", "evict"):
            out.append({"ph": "i", "pid": _STORE_PID, "tid": 1,
                        "name": ev.kind, "cat": "store",
                        "ts": us(ev.ts), "s": "t",
                        "args": _json_safe(ev.attrs)})
    return {"traceEvents": out, "displayTimeUnit": "ms"}
