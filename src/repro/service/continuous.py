"""Continuous batching with a preemptible lane lifecycle.

The bucketed batcher (batching.py) forms a batch, runs it to
completion, and only then looks at the queue again — so a BFS that
quiesces in 3 supersteps waits for the batch's 12-superstep straggler,
and new arrivals wait for the whole loop to drain. This module instead
holds a fixed-width *slot array* per query class — a
:class:`~repro.core.stepper.LaneTable` over the engine's step-granular
:class:`~repro.core.stepper.LaneStepper` — and drives it one superstep
at a time:

  * after every superstep, slots whose per-query termination mask
    flipped are **retired** — their Futures resolve immediately, at
    their own depth, not the batch maximum;
  * freed slots are **refilled** from the class queues between
    supersteps by re-running ``init_carry`` for just those lanes (a
    lane-masked select — the device never sees a shape change, so
    steady-state recycling re-traces nothing).

Each lane's computation is the same vmapped program ``run_batch``
executes, so a query spliced in at in-flight superstep t is
bit-identical to a solo ``Engine.run`` (asserted in
tests/test_continuous.py).

The lane lifecycle is **preemptible** (queued → active → parked →
active → retired):

  * admission is **deadline-priority**: within a tenant's queue the
    most urgent request (highest ``QueryRequest.priority``, then
    earliest aged deadline) takes the next free lane; requests with
    comparable urgency are ordered by **predicted depth** (the
    admission cost model's per-class depth EWMA), so co-scheduled lanes
    tend to retire together and retire-fetches amortize;
  * when a tight-deadline request arrives and every slot is busy, the
    scheduler **preempts** the active lane with the latest effective
    deadline (tie-broken by highest predicted remaining depth —
    observed progress against the depth EWMA, falling back to the
    class's observed-depth residual once a lane outlives its
    prediction). The victim's carry is checkpointed to host
    (``LaneTable.checkpoint`` — only that lane's slice moves, zero
    re-traces) and parked in a bounded :class:`ParkedQueue` charged
    against the graph store's spill budget; the freed slot takes the
    urgent arrival in the same admission window;
  * parked lanes **age**: every second parked earns ``aging_rate``
    seconds of deadline credit, so a preempted query becomes
    monotonically more urgent, is restored ahead of fresh arrivals once
    its aged deadline wins, and — keeping its credit after restore —
    is not the next preemption's first victim. Restoration
    (``LaneTable.restore``) splices the parked carry back through the
    admit-path select, resuming bit-identically from the parked
    superstep.

Multi-tenancy (PR 3) is unchanged underneath: queues are per tenant
within a class, free lanes are handed out by weighted stride scheduling
with soft lane caps, and each active class holds a
:class:`~repro.store.GraphLease` pin from first submit until its last
lane retires (parked lanes keep the class — and so the pin — alive).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from ..core.stepper import LaneCheckpoint, LaneMeta, LaneTable
from .batching import QueryClass, QueryRequest
from .plans import StepperPlan

__all__ = ["ContinuousScheduler", "ParkedQueue", "class_key"]


def class_key(qclass: QueryClass) -> str:
    """Stable string key for per-class cost-model stats. Overlapped
    shard classes get a ``~ov`` suffix: the pipelined schedule has a
    different superstep cost structure (exchange off the critical
    path), so sharing EWMAs/roofline accumulators with the synchronous
    schedule would blur both."""
    base = (f"{qclass.graph_id}@v{qclass.version}/"
            f"{qclass.kernel}/{qclass.mode}")
    if getattr(qclass, "exchange", ""):
        base += f"+{qclass.exchange}"
        if getattr(qclass, "overlap", False):
            base += "~ov"
    return base


@dataclasses.dataclass
class _Parked:
    """One parked lane: its checkpoint plus when it was parked (the
    deadline-aging clock)."""
    ckpt: LaneCheckpoint
    parked_at_s: float

    def aged_key(self, now_s: float, aging_rate: float) -> float:
        return (self.ckpt.meta.effective_deadline()
                - aging_rate * (now_s - self.parked_at_s))


class ParkedQueue:
    """Bounded host-side queue of preempted lanes for one query class.

    Every park is charged against the graph store's **spill budget**
    (the parked carry is exactly the kind of host-resident bytes the
    spill tier accounts): ``try_park`` calls the charge hook first and
    refuses the park — so the preemption simply does not happen — when
    the budget is exhausted. ``pop_best`` returns the entry with the
    most urgent *aged* deadline and releases its charge."""

    def __init__(self, charge: Optional[Callable[[int], bool]] = None,
                 release: Optional[Callable[[int], None]] = None):
        self._charge = charge
        self._release = release
        self.entries: List[_Parked] = []

    def __len__(self) -> int:
        return len(self.entries)

    def reserve(self, nbytes: int) -> bool:
        """Charge ``nbytes`` ahead of the checkpoint fetch (refused =
        no preemption)."""
        return self._charge is None or self._charge(nbytes)

    def refund(self, nbytes: int) -> None:
        if self._release is not None:
            self._release(nbytes)

    def park(self, ckpt: LaneCheckpoint, now_s: float) -> _Parked:
        entry = _Parked(ckpt, now_s)
        self.entries.append(entry)
        return entry

    def peek_key(self, now_s: float, aging_rate: float):
        if not self.entries:
            return None
        return min(e.aged_key(now_s, aging_rate) for e in self.entries)

    def pop_best(self, now_s: float, aging_rate: float
                 ) -> Optional[_Parked]:
        if not self.entries:
            return None
        best = min(self.entries,
                   key=lambda e: e.aged_key(now_s, aging_rate))
        self.entries.remove(best)
        self.refund(best.ckpt.nbytes)
        return best

    def drain(self) -> List[_Parked]:
        """Remove (and un-charge) everything — the class-failure path."""
        out, self.entries = self.entries, []
        for e in out:
            self.refund(e.ckpt.nbytes)
        return out


class _ClassRun:
    """One query class's lane table + per-tenant queues + graph pin +
    parked lanes."""

    def __init__(self, splan: StepperPlan, slots: int, cap: int, lease,
                 parked: ParkedQueue, *, trace=None,
                 label: Optional[str] = None):
        self.splan = splan
        self.cap = cap
        self.lease = lease                      # GraphLease or None
        # per-device attribution for shard classes: the mesh devices
        # every superstep dispatch runs on (() for single-device plans)
        mesh = getattr(splan.engine, "mesh", None)
        self.devices: tuple = (
            tuple(str(d) for d in mesh.devices.flat)
            if mesh is not None else ())
        self.table = LaneTable(splan.stepper, slots, splan.query_params,
                               trace=trace, label=label,
                               devices=self.devices)
        self.queues: "Dict[str, collections.deque]" = {}
        self.passes: Dict[str, float] = {}      # stride-scheduling state
        self.parked = parked

    def in_flight(self) -> int:
        return self.table.in_flight()

    def queued(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def lanes_of(self, tenant: str) -> int:
        return self.table.lanes_of(tenant)

    def idle(self) -> bool:
        return (self.queued() == 0 and self.in_flight() == 0
                and len(self.parked) == 0)

    def close(self) -> None:
        if self.lease is not None:
            self.lease.release()
            self.lease = None


class ContinuousScheduler:
    """Slot-array scheduler over step-granular engine plans.

    ``pump()`` advances every class with work by exactly one superstep
    (retire -> admit/restore/preempt -> step); callers loop it —
    synchronously (``drain``) or from the service's scheduler thread.
    Not re-entrant: all public methods serialize on one lock, so a
    ``submit`` racing a ``pump`` just lands in the queue for the next
    inter-superstep admission window. Reads like :meth:`backlog` /
    :meth:`pending` / :meth:`parked` take the same lock, so a stats
    snapshot can never observe a half-spliced slot array (see
    tests/test_continuous.py)."""

    def __init__(self, *, slots: int = 16,
                 max_supersteps: Optional[int] = None,
                 stats=None,
                 get_stepper: Callable[[QueryClass], StepperPlan] = None,
                 on_result: Callable[..., None] = None,
                 tenant_weight: Callable[[str], float] = None,
                 acquire: Callable[[QueryClass], Any] = None,
                 preemption: bool = True,
                 aging_rate: float = 4.0,
                 depth_bucket_s: float = 0.1,
                 preempt_margin_s: float = 0.05,
                 park_charge: Callable[[int], bool] = None,
                 park_release: Callable[[int], None] = None,
                 depth_bucket_of: Callable[
                     [QueryClass, QueryRequest], Optional[str]] = None,
                 trace=None, metrics=None, profile: bool = False):
        assert slots >= 1
        self.slots = slots
        self.max_supersteps = max_supersteps
        self.stats = stats
        # duck-typed event bus (service.trace.TraceBus); None = no tracing
        self.trace = trace
        # duck-typed metrics registry (service.metrics.MetricsRegistry);
        # None = no per-class phase histograms
        self.metrics = metrics
        # when True every class's stepper runs in profiled mode (phase
        # wall split on superstep events + phase histograms)
        self.profile = profile
        self.preemption = preemption
        self.aging_rate = aging_rate
        self.depth_bucket_s = depth_bucket_s
        # a park+restore costs two device splices and a host round trip:
        # only preempt when the arrival is at least this much more
        # urgent than the victim (microsecond-level arrival jitter must
        # never thrash lanes)
        self.preempt_margin_s = preempt_margin_s
        self._get_stepper = get_stepper
        self._on_result = on_result or (lambda req, res, version=0: None)
        self._weight = tenant_weight or (lambda tenant: 1.0)
        self._acquire = acquire or (lambda qclass: None)
        self._park_charge = park_charge
        self._park_release = park_release
        # optional (qclass, request) -> depth-bucket label (e.g. the
        # root's degree decile, "d0".."d9"); sharpens the admission
        # predictor's depth EWMA per bucket. None = class-wide EWMA.
        self._depth_bucket_of = depth_bucket_of
        self._classes: Dict[QueryClass, _ClassRun] = {}
        self._lock = threading.RLock()  # lock: scheduler

    # ---------------- admission ---------------------------------------
    def _predict_depth(self, qclass: QueryClass,
                       bucket: Optional[str] = None) -> float:
        if self.stats is None:
            return 0.0
        if bucket:
            _, depth = self.stats.class_cost_model(class_key(qclass),
                                                   bucket=bucket)
        else:
            # plain call keeps duck-typed stats without the bucket
            # keyword working (no bucket to pass anyway)
            _, depth = self.stats.class_cost_model(class_key(qclass))
        return float(depth) if depth is not None else 0.0

    def _depth_residual(self, qclass: QueryClass) -> float:
        if self.stats is None:
            return 1.0
        resid = self.stats.depth_residual(class_key(qclass))
        return float(resid) if resid is not None else 1.0

    def submit(self, qclass: QueryClass, req: QueryRequest, fut) -> None:
        with self._lock:
            cr = self._classes.get(qclass)
            if cr is None:
                # pin the graph version BEFORE compiling against it: the
                # lease both faults an evicted graph back in and blocks
                # eviction for as long as this class has work
                lease = self._acquire(qclass)
                try:
                    splan = self._get_stepper(qclass)
                except Exception:
                    if lease is not None:
                        lease.release()
                    raise
                from ..core.engine import HARD_SUPERSTEP_CAP
                cap = (self.max_supersteps
                       or splan.engine.kernel.max_supersteps
                       or HARD_SUPERSTEP_CAP)
                cr = _ClassRun(splan, self.slots, cap, lease,
                               ParkedQueue(self._park_charge,
                                           self._park_release),
                               trace=self.trace,
                               label=class_key(qclass))
                # profiled mode is a stepper-level switch: flip it when
                # the class's stepper enters service (steppers are
                # engine-cached per width, so a re-created class run
                # keeps the mode consistent)
                splan.stepper.profile = self.profile
                self._classes[qclass] = cr
            q = cr.queues.get(req.tenant)
            if q is None:
                q = cr.queues[req.tenant] = collections.deque()
            if not q:
                # (re)activating tenant: sync its stride pass to the
                # current frontier so it neither monopolizes lanes (pass
                # stuck at 0) nor is penalized for having been idle
                active = [cr.passes[t] for t, qq in cr.queues.items()
                          if (qq or cr.lanes_of(t)) and t in cr.passes]
                floor = min(active) if active else 0.0
                cr.passes[req.tenant] = max(
                    cr.passes.get(req.tenant, 0.0), floor)
            bucket = (self._depth_bucket_of(qclass, req)
                      if self._depth_bucket_of is not None else None)
            meta = LaneMeta(
                payload=(req, fut), qkw=dict(req.query_kwargs),
                tenant=req.tenant,
                priority=int(getattr(req, "priority", 0)),
                deadline_s=req.deadline_s,
                predicted_depth=self._predict_depth(qclass, bucket),
                seq=int(getattr(req, "qid", 0)),
                depth_bucket=bucket)
            q.append(meta)
            self._emit("queue", qid=meta.seq, tenant=req.tenant,
                       klass=class_key(qclass), priority=meta.priority,
                       predicted_depth=meta.predicted_depth)

    def _emit(self, kind: str, **fields) -> None:
        if self.trace is not None:
            self.trace.emit(kind, **fields)

    def backlog(self, qclass: QueryClass) -> int:
        """Queued (not yet admitted) depth for one class. Taken under
        the scheduler lock: a concurrent pump's slot splice is never
        half-observed."""
        with self._lock:
            cr = self._classes.get(qclass)
            return cr.queued() if cr else 0

    def pending(self) -> int:
        """Queued + in-flight + parked queries across all classes
        (lock-consistent, see :meth:`backlog`)."""
        with self._lock:
            return sum(cr.queued() + cr.in_flight() + len(cr.parked)
                       for cr in self._classes.values())

    def parked(self) -> int:
        """Currently parked (preempted, not yet restored) lanes.
        (Parked BYTES are accounted authoritatively by the GraphStore —
        ``store_parked_bytes`` in the service stats.)"""
        with self._lock:
            return sum(len(cr.parked) for cr in self._classes.values())

    def has_work(self) -> bool:
        return self.pending() > 0

    # ---------------- the superstep pump ------------------------------
    def pump(self) -> int:
        """One superstep for every class with work; returns the number
        of queries retired. Classes that go idle release their graph
        pin (the store may then evict the graph under budget
        pressure)."""
        retired = 0
        with self._lock:
            for qclass, cr in list(self._classes.items()):
                retired += self._pump_class(qclass, cr)
                self._reap_if_idle(qclass)
        return retired

    def drain(self, qclass: Optional[QueryClass] = None,
              max_pumps: int = 1_000_000) -> int:
        """Pump until ``qclass`` (or everything) has no queued,
        in-flight or parked queries; returns total retired. The
        scheduler lock is released between supersteps (each pump takes
        it internally), so the between-supersteps admission window stays
        open during a drain: a concurrent ``submit`` lands in the very
        drain it raced with instead of blocking until the whole drain
        finishes."""
        total = 0
        for _ in range(max_pumps):
            if qclass is None:
                if not self.has_work():
                    break
                total += self.pump()
            else:
                with self._lock:
                    cr = self._classes.get(qclass)
                    if cr is None or cr.idle():
                        self._reap_if_idle(qclass)
                        break
                    total += self._pump_class(qclass, cr)
                    self._reap_if_idle(qclass)
        return total

    # ---------------- internals ---------------------------------------
    def _reap_if_idle(self, qclass: QueryClass) -> None:
        cr = self._classes.get(qclass)
        if cr is not None and cr.idle():
            cr.close()
            del self._classes[qclass]

    def _pump_class(self, qclass: QueryClass, cr: _ClassRun) -> int:
        if cr.idle():
            return 0
        try:
            return self._pump_class_inner(qclass, cr)
        except Exception as exc:    # noqa: BLE001 — fail the slot array
            # Mirror the bucketed batcher's contract: a device/program
            # error must resolve every affected Future, not strand them
            # (and not kill the async scheduler thread). The class state
            # resets; the next submit starts clean.
            self._fail_class(cr, exc)
            return 0

    def _fail_class(self, cr: _ClassRun, exc: Exception) -> None:
        err = type(exc).__name__

        def _emit_err(meta):
            self._emit("retire", qid=meta.seq, tenant=meta.tenant,
                       klass=cr.table.label, reason="error", error=err)

        for meta in cr.table.clear():
            meta.payload[1].set_exception(exc)
            _emit_err(meta)
        for entry in cr.parked.drain():
            entry.ckpt.meta.payload[1].set_exception(exc)
            _emit_err(entry.ckpt.meta)
        for q in cr.queues.values():
            while q:
                meta = q.popleft()
                fut = meta.payload[1]
                if fut.set_running_or_notify_cancel():
                    fut.set_exception(exc)
                    _emit_err(meta)

    def _pump_class_inner(self, qclass: QueryClass, cr: _ClassRun) -> int:
        # retire everything the previous pump's step finished, FIRST,
        # so its freed slots are refilled and stepped in this very pump
        # (no lane idles a superstep while the queue is non-empty)
        retired = self._retire(qclass, cr) if cr.table.carry is not None \
            else 0
        self._admit(qclass, cr)
        if cr.table.carry is None or cr.in_flight() == 0:
            return retired
        # fresh lanes come back from admit with their probe bits, so a
        # dead-on-arrival query is excluded here and retired below at 0
        # supersteps — the stepper analogue of Engine.run's pre-loop
        # cond check
        alive = cr.table.alive_mask(cr.cap)
        if not alive.any():
            return retired + self._retire(qclass, cr)
        eng = cr.splan.engine
        traces0 = eng.traces
        t0 = time.perf_counter()
        cr.table.step(alive)
        wall = time.perf_counter() - t0   # probe return synced the device
        if self.stats is not None:
            self.stats.record_pump_step()
            if eng.traces == traces0:
                self.stats.record_busy(wall, class_key=class_key(qclass))
                self.stats.record_superstep_time(class_key(qclass), wall)
            else:
                # a traced step's wall is compile time, not execution:
                # it would poison the cost model (and, with admission
                # control on, shed the class forever) AND inflate
                # busy_time_s, understating qps_busy/TEPS for the run
                self.stats.record_compile(wall)
        if eng.traces == traces0:
            ck = class_key(qclass)
            # profiled mode: per-class phase histograms + exchange
            # overlap accounting (compile walls excluded for the same
            # reason as above)
            phases = getattr(cr.splan.stepper, "last_phases", None)
            if phases:
                if self.stats is not None and "exchange" in phases:
                    # exposed = the serving schedule's exchange wall;
                    # total = the serial-reference wall (profiled
                    # overlapped steppers time both; synchronous ones
                    # have no reference, so exposed == total -> 1.0)
                    self.stats.record_exchange_overlap(
                        ck, phases["exchange"],
                        phases.get("exchange_serial", phases["exchange"]))
                if self.metrics is not None:
                    for phase, secs in phases.items():
                        self.metrics.observe(
                            "gravfm_superstep_phase_seconds", secs,
                            help="Measured superstep wall split by phase "
                                 "(profiled mode)",
                            **{"class": ck, "phase": phase})
            if self.metrics is not None and cr.devices:
                # per-device attribution: every mesh device ran this
                # superstep's shard_map dispatch
                for dev in cr.devices:
                    self.metrics.inc(
                        "gravfm_device_supersteps_total", 1,
                        help="Supersteps dispatched per mesh device "
                             "(shard classes)",
                        **{"class": ck, "device": dev})
        return retired

    # ---------------- queue selection ----------------------------------
    def _order_key(self, meta: LaneMeta):
        """Within-tenant pop order: deadline-priority first (priority,
        then aged deadline, bucketized so near-simultaneous deadlines
        tie), then predicted depth — so, urgency permitting, the refill
        co-schedules lanes of similar predicted depth and they retire
        together (one retire-fetch instead of W)."""
        dl = meta.effective_deadline()
        if self.depth_bucket_s > 0 and math.isfinite(dl):
            dl = math.floor(dl / self.depth_bucket_s)
        return (dl, meta.predicted_depth, meta.seq)

    def _stride_tenant(self, cr: _ClassRun) -> Optional[str]:
        """Weighted fair-share pick: among tenants with queued work, the
        one with the lowest stride pass wins the free lane — subject to
        a soft lane cap (its weighted share of the slot array, rounded
        up) whenever other tenants are also waiting."""
        nonempty = [t for t, q in cr.queues.items() if q]
        if not nonempty:
            return None
        eligible = nonempty
        if len(nonempty) > 1:
            total_w = sum(self._weight(t) for t in nonempty)
            under_cap = [
                t for t in nonempty
                if cr.lanes_of(t) < max(1, int(np.ceil(
                    cr.table.width * self._weight(t) / total_w)))]
            if under_cap:
                eligible = under_cap
        return min(eligible, key=lambda t: (cr.passes.get(t, 0.0), t))

    def _pop_from(self, cr: _ClassRun, tenant: str) -> Optional[LaneMeta]:
        """Pop the tenant's best item by deadline-priority/depth order
        and transition its Future to RUNNING; cancelled stragglers are
        dropped on the way."""
        q = cr.queues[tenant]
        while q:
            best = min(q, key=self._order_key)
            q.remove(best)
            if best.payload[1].set_running_or_notify_cancel():
                cr.passes[tenant] = (cr.passes.get(tenant, 0.0)
                                     + 1.0 / self._weight(tenant))
                return best
        return None

    def _next_item(self, cr: _ClassRun) -> Optional[LaneMeta]:
        while True:
            tenant = self._stride_tenant(cr)
            if tenant is None:
                return None
            item = self._pop_from(cr, tenant)
            if item is not None:
                return item
            # tenant's queue was all cancelled stragglers — re-pick

    def _pop_urgent(self, cr: _ClassRun, threshold
                    ) -> Optional[LaneMeta]:
        """Pop the most urgent queued item strictly more urgent than
        ``threshold`` (any tenant — a tight deadline overrides fair
        share; the tenant's stride pass is still charged)."""
        while True:
            cands = [(m.effective_deadline(), t)
                     for t, q in cr.queues.items() for m in q]
            if not cands:
                return None
            key, tenant = min(cands)
            if not key < threshold:
                return None
            q = cr.queues[tenant]
            best = min(q, key=lambda m: m.effective_deadline())
            q.remove(best)
            if best.payload[1].set_running_or_notify_cancel():
                cr.passes[tenant] = (cr.passes.get(tenant, 0.0)
                                     + 1.0 / self._weight(tenant))
                return best
            # cancelled — re-scan

    # ---------------- admit / restore / preempt ------------------------
    def _admit(self, qclass: QueryClass, cr: _ClassRun) -> None:
        """The between-supersteps admission window: restore parked lanes
        and splice queued queries into free slots by deadline priority,
        then preempt for still-queued tight-deadline arrivals."""
        # drop cancelled stragglers up front: they must neither divert a
        # slot from a parked lane (their deadline would poison the peek
        # below) nor pin the class as pending forever (pre-purge, a
        # tenant whose queue was ALL cancelled could live-lock the
        # stride pick and starve other tenants)
        for q in cr.queues.values():
            for m in [m for m in q if m.payload[1].cancelled()]:
                q.remove(m)
        if cr.queued() == 0 and len(cr.parked) == 0:
            return
        now = time.perf_counter()
        assignments: Dict[int, LaneMeta] = {}
        touched: set = set()
        try:
            for slot in cr.table.free_slots():
                parked_key = cr.parked.peek_key(now, self.aging_rate)
                # compare against what the fair-share pick would
                # actually admit (the stride-selected tenant's most
                # urgent item), not the global queue minimum — a parked
                # lane more urgent than the real admit candidate must
                # win the slot
                tenant = self._stride_tenant(cr)
                queue_key = (min(m.effective_deadline()
                                 for m in cr.queues[tenant])
                             if tenant is not None else None)
                if parked_key is None and queue_key is None:
                    break
                if parked_key is not None and (queue_key is None
                                               or parked_key <= queue_key):
                    self._restore_parked(cr, slot, now)
                    touched.add(slot)
                    continue
                # pop from the tenant we already stride-selected for the
                # peek above (re-running the selection would both waste
                # a scan and risk disagreeing with the comparison)
                item = self._pop_from(cr, tenant)
                if item is None:
                    # a cancel raced the peek; retry parked, else re-pick
                    if cr.parked.peek_key(now, self.aging_rate) is not None:
                        self._restore_parked(cr, slot, now)
                        touched.add(slot)
                        continue
                    item = self._next_item(cr)
                    if item is None:
                        break
                assignments[slot] = item
                touched.add(slot)
            if assignments:
                cr.table.admit(assignments)
                for slot, meta in assignments.items():
                    self._emit("admit", qid=meta.seq, tenant=meta.tenant,
                               klass=cr.table.label, reason="fresh",
                               slot=slot)
                    if self.stats is not None:
                        # submit->lane wait (the SLO watchdog's
                        # queue_wait_p95 rule reads the percentile)
                        self.stats.record_queue_wait(
                            (now - meta.payload[0].arrival_s) * 1e3)
        except BaseException as exc:   # noqa: BLE001 — no stranding
            # popped-but-not-yet-installed items are invisible to
            # _fail_class (they are in neither the table, the queues,
            # nor the parked queue) — resolve them here, then let the
            # pump's guard fail the rest of the class. Metas the table
            # DID install (admit raises after installing) are skipped:
            # _fail_class owns those.
            for meta in assignments.values():
                if not any(m is meta for m in cr.table.meta):
                    meta.payload[1].set_exception(exc)
            raise
        if self.preemption:
            self._preempt_for_queued(qclass, cr, now, touched)

    def _restore_parked(self, cr: _ClassRun, slot: int,
                        now: float) -> None:
        entry = cr.parked.pop_best(now, self.aging_rate)
        meta = entry.ckpt.meta
        # fold the accrued aging into the lane's deadline credit: once
        # restored it stays more urgent than fresh arrivals, so it is
        # not immediately re-parked (anti-thrash + starvation freedom)
        meta.credit_s += self.aging_rate * (now - entry.parked_at_s)
        t0 = time.perf_counter()
        cr.table.restore(slot, entry.ckpt)
        wall = time.perf_counter() - t0
        if self.stats is not None:
            self.stats.record_restore(wall)
        self._emit("restore", qid=meta.seq, tenant=meta.tenant,
                   klass=cr.table.label, dur_s=wall, slot=slot,
                   parked_s=now - entry.parked_at_s,
                   superstep=entry.ckpt.superstep)

    def _preempt_for_queued(self, qclass: QueryClass, cr: _ClassRun,
                            now: float, touched: set) -> None:
        """Deadline-priority preemption: while a queued request is
        strictly more urgent than the laxest active lane, park that lane
        (latest effective deadline; ties broken toward the highest
        predicted remaining depth — evicting the lane that would hold
        its slot longest) and admit the urgent request into the freed
        slot in the same admission window."""
        resid = self._depth_residual(qclass)
        for _ in range(cr.table.width):
            if cr.queued() == 0:
                return
            cands = [s for s in cr.table.active_slots()
                     if s not in touched]
            if not cands:
                return
            victim = max(cands, key=lambda s: (
                cr.table.meta[s].effective_deadline(),
                cr.table.predicted_remaining(s, resid)))
            vmeta = cr.table.meta[victim]
            if (vmeta.predicted_depth > 0
                    and cr.table.predicted_remaining(victim, resid)
                    <= 1.0):
                return      # victim retires next pump anyway
            nbytes = cr.table.lane_nbytes()
            if not cr.parked.reserve(nbytes):
                return      # park budget exhausted: no preemption
            urgent = self._pop_urgent(
                cr, vmeta.effective_deadline() - self.preempt_margin_s)
            if urgent is None:
                cr.parked.refund(nbytes)
                return
            t0 = time.perf_counter()
            try:
                ckpt = cr.table.checkpoint(victim)
            except BaseException as exc:  # noqa: BLE001 — no stranding
                # the victim is still in the table (_fail_class covers
                # it), but the popped urgent request and the byte
                # reservation are local — resolve and refund them here
                cr.parked.refund(nbytes)
                urgent.payload[1].set_exception(exc)
                raise
            wall = time.perf_counter() - t0
            cr.parked.park(ckpt, now)
            self._emit("park", qid=vmeta.seq, tenant=vmeta.tenant,
                       klass=cr.table.label, dur_s=wall, slot=victim,
                       by=urgent.seq, superstep=ckpt.superstep)
            cr.table.admit({victim: urgent})
            self._emit("admit", qid=urgent.seq, tenant=urgent.tenant,
                       klass=cr.table.label, reason="preempt",
                       slot=victim, victim=vmeta.seq)
            touched.add(victim)
            if self.stats is not None:
                self.stats.record_preempt(wall)

    # ---------------- retirement ---------------------------------------
    def _retire(self, qclass: QueryClass, cr: _ClassRun) -> int:
        """Resolve every occupied lane whose termination mask flipped
        (or that hit the superstep cap); free its slot."""
        done = cr.table.done_slots(cr.cap)
        if not done:
            return 0
        host = cr.table.fetch()
        now = time.perf_counter()
        for i in done:
            meta = cr.table.release(i)
            req, fut = meta.payload
            try:
                res = cr.splan.engine.lane_result(host, i)
            except Exception as exc:    # noqa: BLE001 — fail one lane
                fut.set_exception(exc)
                self._emit("retire", qid=meta.seq, tenant=req.tenant,
                           klass=cr.table.label, reason="error",
                           error=type(exc).__name__)
                continue
            fut.set_result(res)
            latency_ms = (now - req.arrival_s) * 1e3
            # positive slack = retired before the deadline; negative =
            # a deadline miss (an infinite deadline never misses)
            slack_s = req.deadline_s - now
            missed = slack_s < 0
            if self.stats is not None:
                self.stats.record_retire(
                    messages=res.messages, latency_ms=latency_ms,
                    class_key=class_key(qclass),
                    wire_words=float((getattr(res, "comm", None) or {})
                                     .get("wire_words", 0.0)))
                self.stats.record_query_depth(
                    class_key(qclass), res.supersteps,
                    bucket=getattr(meta, "depth_bucket", None))
                if meta.predicted_depth > 0:
                    self.stats.record_depth_error(
                        class_key(qclass),
                        abs(res.supersteps - meta.predicted_depth))
                self.stats.record_tenant(
                    req.tenant, completed=1, messages=res.messages,
                    latency_ms=latency_ms,
                    deadline_misses=1 if missed else 0)
                if missed:
                    self.stats.record_deadline_miss()
            self._emit("retire", qid=meta.seq, tenant=req.tenant,
                       klass=cr.table.label, reason="retired",
                       supersteps=int(res.supersteps),
                       messages=int(res.messages),
                       deadline_slack_s=(slack_s if math.isfinite(slack_s)
                                         else None),
                       parks=meta.parks)
            self._on_result(req, res, qclass.version)
        return len(done)
