"""Continuous batching: an in-flight superstep loop queries join and
leave without draining it.

The bucketed batcher (batching.py) forms a batch, runs it to
completion, and only then looks at the queue again — so a BFS that
quiesces in 3 supersteps waits for the batch's 12-superstep straggler,
and new arrivals wait for the whole loop to drain. This module instead
holds a fixed-width *slot array* per query class and drives the
engine's step-granular :class:`~repro.core.stepper.LaneStepper` one
superstep at a time:

  * after every superstep, slots whose per-query termination mask
    flipped are **retired** — their Futures resolve immediately, at
    their own depth, not the batch maximum;
  * freed slots are **refilled** from the class queues between
    supersteps by re-running ``init_carry`` for just those lanes (a
    lane-masked select — the device never sees a shape change, so
    steady-state recycling re-traces nothing).

Each lane's computation is the same vmapped program ``run_batch``
executes, so a query spliced in at in-flight superstep t is
bit-identical to a solo ``Engine.run`` (asserted in
tests/test_continuous.py).

Multi-tenancy additions:

  * queues are **per tenant** within a class, and free lanes are handed
    out by weighted stride scheduling (each admission advances the
    tenant's virtual pass by ``1/weight``; lowest pass wins, with a
    soft per-tenant lane cap while others wait) — so a flood of one
    tenant's deep queries cannot starve another tenant's shallow ones,
    and per-tenant throughput tracks the configured weights;
  * each active class holds a :class:`~repro.store.GraphLease` **pin**
    on its graph version from first submit until the last lane retires,
    so the memory-budgeted store can never evict a graph mid-query; the
    pin is released (and the class state dropped) once the class goes
    idle, making the graph evictable again.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .batching import QueryClass, QueryRequest
from .plans import StepperPlan

__all__ = ["ContinuousScheduler", "class_key"]


def class_key(qclass: QueryClass) -> str:
    """Stable string key for per-class cost-model stats."""
    return (f"{qclass.graph_id}@v{qclass.version}/"
            f"{qclass.kernel}/{qclass.mode}")


def _lane_dtype(value) -> np.dtype:
    """Canonical lane-array dtype for a query kwarg (matches the int32 /
    float32 the kernels trace with, so admits never change signature)."""
    a = np.asarray(value)
    if a.dtype.kind in "iub":
        return np.dtype(np.int32)
    if a.dtype.kind == "f":
        return np.dtype(np.float32)
    return a.dtype


class _ClassRun:
    """One query class's slot array + per-tenant queues + graph pin."""

    def __init__(self, splan: StepperPlan, slots: int, cap: int, lease):
        self.splan = splan
        self.slots = slots
        self.cap = cap
        self.lease = lease                      # GraphLease or None
        self.carry = None                       # device StepCarry or None
        self.act: Optional[np.ndarray] = None   # (W,) lane-alive probe
        self.steps: Optional[np.ndarray] = None  # (W,) lane supersteps
        self.lanes: List[Optional[Tuple[QueryRequest, Any]]] = \
            [None] * slots
        self.queues: "Dict[str, collections.deque]" = {}
        self.passes: Dict[str, float] = {}      # stride-scheduling state
        self.qkw: Optional[Dict[str, np.ndarray]] = None

    @property
    def occupied(self) -> np.ndarray:
        return np.array([ln is not None for ln in self.lanes], bool)

    def in_flight(self) -> int:
        return sum(ln is not None for ln in self.lanes)

    def queued(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def lanes_of(self, tenant: str) -> int:
        return sum(1 for ln in self.lanes
                   if ln is not None and ln[0].tenant == tenant)

    def idle(self) -> bool:
        return self.queued() == 0 and self.in_flight() == 0

    def close(self) -> None:
        if self.lease is not None:
            self.lease.release()
            self.lease = None


class ContinuousScheduler:
    """Slot-array scheduler over step-granular engine plans.

    ``pump()`` advances every class with work by exactly one superstep
    (admit -> step -> retire); callers loop it — synchronously
    (``drain``) or from the service's scheduler thread. Not re-entrant:
    all public methods serialize on one lock, so a ``submit`` racing a
    ``pump`` just lands in the queue for the next inter-superstep
    admission window.
    """

    def __init__(self, *, slots: int = 16,
                 max_supersteps: Optional[int] = None,
                 stats=None,
                 get_stepper: Callable[[QueryClass], StepperPlan] = None,
                 on_result: Callable[..., None] = None,
                 tenant_weight: Callable[[str], float] = None,
                 acquire: Callable[[QueryClass], Any] = None):
        assert slots >= 1
        self.slots = slots
        self.max_supersteps = max_supersteps
        self.stats = stats
        self._get_stepper = get_stepper
        self._on_result = on_result or (lambda req, res, version=0: None)
        self._weight = tenant_weight or (lambda tenant: 1.0)
        self._acquire = acquire or (lambda qclass: None)
        self._classes: Dict[QueryClass, _ClassRun] = {}
        self._lock = threading.RLock()

    # ---------------- admission ---------------------------------------
    def submit(self, qclass: QueryClass, req: QueryRequest, fut) -> None:
        with self._lock:
            cr = self._classes.get(qclass)
            if cr is None:
                # pin the graph version BEFORE compiling against it: the
                # lease both faults an evicted graph back in and blocks
                # eviction for as long as this class has work
                lease = self._acquire(qclass)
                try:
                    splan = self._get_stepper(qclass)
                except Exception:
                    if lease is not None:
                        lease.release()
                    raise
                from ..core.engine import HARD_SUPERSTEP_CAP
                cap = (self.max_supersteps
                       or splan.engine.kernel.max_supersteps
                       or HARD_SUPERSTEP_CAP)
                cr = _ClassRun(splan, self.slots, cap, lease)
                self._classes[qclass] = cr
            q = cr.queues.get(req.tenant)
            if q is None:
                q = cr.queues[req.tenant] = collections.deque()
            if not q:
                # (re)activating tenant: sync its stride pass to the
                # current frontier so it neither monopolizes lanes (pass
                # stuck at 0) nor is penalized for having been idle
                active = [cr.passes[t] for t, qq in cr.queues.items()
                          if (qq or cr.lanes_of(t)) and t in cr.passes]
                floor = min(active) if active else 0.0
                cr.passes[req.tenant] = max(
                    cr.passes.get(req.tenant, 0.0), floor)
            q.append((req, fut))

    def backlog(self, qclass: QueryClass) -> int:
        """Queued (not yet admitted) depth for one class."""
        with self._lock:
            cr = self._classes.get(qclass)
            return cr.queued() if cr else 0

    def pending(self) -> int:
        """Queued + in-flight queries across all classes."""
        with self._lock:
            return sum(cr.queued() + cr.in_flight()
                       for cr in self._classes.values())

    def has_work(self) -> bool:
        return self.pending() > 0

    # ---------------- the superstep pump ------------------------------
    def pump(self) -> int:
        """One superstep for every class with work; returns the number
        of queries retired. Classes that go idle release their graph
        pin (the store may then evict the graph under budget
        pressure)."""
        retired = 0
        with self._lock:
            for qclass, cr in list(self._classes.items()):
                retired += self._pump_class(qclass, cr)
                self._reap_if_idle(qclass)
        return retired

    def drain(self, qclass: Optional[QueryClass] = None,
              max_pumps: int = 1_000_000) -> int:
        """Pump until ``qclass`` (or everything) has no queued or
        in-flight queries; returns total retired. The scheduler lock is
        released between supersteps (each pump takes it internally), so
        the between-supersteps admission window stays open during a
        drain: a concurrent ``submit`` lands in the very drain it raced
        with instead of blocking until the whole drain finishes."""
        total = 0
        for _ in range(max_pumps):
            if qclass is None:
                if not self.has_work():
                    break
                total += self.pump()
            else:
                with self._lock:
                    cr = self._classes.get(qclass)
                    if cr is None or cr.idle():
                        self._reap_if_idle(qclass)
                        break
                    total += self._pump_class(qclass, cr)
                    self._reap_if_idle(qclass)
        return total

    # ---------------- internals ---------------------------------------
    def _reap_if_idle(self, qclass: QueryClass) -> None:
        cr = self._classes.get(qclass)
        if cr is not None and cr.idle():
            cr.close()
            del self._classes[qclass]

    def _pump_class(self, qclass: QueryClass, cr: _ClassRun) -> int:
        if cr.idle():
            return 0
        try:
            return self._pump_class_inner(qclass, cr)
        except Exception as exc:    # noqa: BLE001 — fail the slot array
            # Mirror the bucketed batcher's contract: a device/program
            # error must resolve every affected Future, not strand them
            # (and not kill the async scheduler thread). The class state
            # resets; the next submit starts clean.
            self._fail_class(cr, exc)
            return 0

    def _fail_class(self, cr: _ClassRun, exc: Exception) -> None:
        for i, ln in enumerate(cr.lanes):
            if ln is not None:
                ln[1].set_exception(exc)
                cr.lanes[i] = None
        for q in cr.queues.values():
            while q:
                _, fut = q.popleft()
                if fut.set_running_or_notify_cancel():
                    fut.set_exception(exc)
        cr.carry = cr.act = cr.steps = None

    def _pump_class_inner(self, qclass: QueryClass, cr: _ClassRun) -> int:
        # retire everything the previous pump's step finished, FIRST,
        # so its freed slots are refilled and stepped in this very pump
        # (no lane idles a superstep while the queue is non-empty)
        retired = self._retire(qclass, cr) if cr.carry is not None else 0
        self._admit(cr)
        if cr.carry is None or cr.in_flight() == 0:
            return retired
        # fresh lanes come back from admit with their probe bits, so a
        # dead-on-arrival query is excluded here and retired below at 0
        # supersteps — the stepper analogue of Engine.run's pre-loop
        # cond check
        alive = cr.occupied & cr.act & (cr.steps < cr.cap)
        if not alive.any():
            return retired + self._retire(qclass, cr)
        eng = cr.splan.engine
        traces0 = eng.traces
        t0 = time.perf_counter()
        cr.carry, cr.act, cr.steps = cr.splan.stepper.step(cr.carry, alive)
        wall = time.perf_counter() - t0   # probe return synced the device
        if self.stats is not None:
            self.stats.record_pump_step()
            if eng.traces == traces0:
                self.stats.record_busy(wall)
                self.stats.record_superstep_time(class_key(qclass), wall)
            else:
                # a traced step's wall is compile time, not execution:
                # it would poison the cost model (and, with admission
                # control on, shed the class forever) AND inflate
                # busy_time_s, understating qps_busy/TEPS for the run
                self.stats.record_compile(wall)
        return retired

    def _next_item(self, cr: _ClassRun):
        """Weighted fair-share pick: among tenants with queued work, the
        one with the lowest stride pass wins the free lane — subject to
        a soft lane cap (its weighted share of the slot array, rounded
        up) whenever other tenants are also waiting."""
        while True:
            nonempty = [t for t, q in cr.queues.items() if q]
            if not nonempty:
                return None
            eligible = nonempty
            if len(nonempty) > 1:
                total_w = sum(self._weight(t) for t in nonempty)
                under_cap = [
                    t for t in nonempty
                    if cr.lanes_of(t) < max(1, int(np.ceil(
                        cr.slots * self._weight(t) / total_w)))]
                if under_cap:
                    eligible = under_cap
            tenant = min(eligible,
                         key=lambda t: (cr.passes.get(t, 0.0), t))
            q = cr.queues[tenant]
            got = None
            while q:
                req, fut = q.popleft()
                if fut.set_running_or_notify_cancel():
                    got = (req, fut)
                    break
            if got is not None:
                cr.passes[tenant] = (cr.passes.get(tenant, 0.0)
                                     + 1.0 / self._weight(tenant))
                return got
            # tenant's queue was all cancelled stragglers — re-pick

    def _admit(self, cr: _ClassRun) -> None:
        """Splice queued queries into free lanes (one admit call for all
        fresh lanes — re-runs init_carry lane-masked)."""
        if cr.queued() == 0:
            return
        fresh = np.zeros(cr.slots, bool)
        for i in range(cr.slots):
            if cr.lanes[i] is not None:
                continue
            item = self._next_item(cr)
            if item is None:
                break   # queues exhausted (cancelled stragglers dropped)
            req, fut = item
            cr.lanes[i] = (req, fut)
            if cr.qkw is None:
                # lane arrays keyed by the kernel's DECLARED params
                # (not this request's keys), seeded with its values —
                # idle lanes then hold a valid query, like the bucketed
                # batcher's padding lanes
                cr.qkw = {p: np.full((cr.slots,), req.query_kwargs[p],
                                     dtype=_lane_dtype(req.query_kwargs[p]))
                          for p in cr.splan.query_params}
            for p in cr.qkw:
                # a missing declared param raises here and fails the
                # class loudly (pump's guard) instead of silently
                # reusing the slot's previous occupant's value
                cr.qkw[p][i] = req.query_kwargs[p]
            fresh[i] = True
        if fresh.any():
            if cr.carry is None:
                cr.carry, cr.act, cr.steps = cr.splan.stepper.init(cr.qkw)
            else:
                cr.carry, cr.act, cr.steps = cr.splan.stepper.admit(
                    cr.carry, cr.qkw, fresh)

    def _retire(self, qclass: QueryClass, cr: _ClassRun) -> int:
        """Resolve every occupied lane whose termination mask flipped
        (or that hit the superstep cap); free its slot."""
        act, steps = cr.act, cr.steps
        done = [i for i in range(cr.slots)
                if cr.lanes[i] is not None
                and (not act[i] or steps[i] >= cr.cap)]
        if not done:
            return 0
        host = cr.splan.stepper.fetch(cr.carry)
        now = time.perf_counter()
        for i in done:
            req, fut = cr.lanes[i]
            cr.lanes[i] = None
            try:
                res = cr.splan.engine.lane_result(host, i)
            except Exception as exc:    # noqa: BLE001 — fail one lane
                fut.set_exception(exc)
                continue
            fut.set_result(res)
            latency_ms = (now - req.arrival_s) * 1e3
            if self.stats is not None:
                self.stats.record_retire(
                    messages=res.messages, latency_ms=latency_ms)
                self.stats.record_query_depth(class_key(qclass),
                                              res.supersteps)
                self.stats.record_tenant(
                    req.tenant, completed=1, messages=res.messages,
                    latency_ms=latency_ms)
            self._on_result(req, res, qclass.version)
        return len(done)
