"""Continuous batching: an in-flight superstep loop queries join and
leave without draining it.

The bucketed batcher (batching.py) forms a batch, runs it to
completion, and only then looks at the queue again — so a BFS that
quiesces in 3 supersteps waits for the batch's 12-superstep straggler,
and new arrivals wait for the whole loop to drain. This module instead
holds a fixed-width *slot array* per query class and drives the
engine's step-granular :class:`~repro.core.stepper.LaneStepper` one
superstep at a time:

  * after every superstep, slots whose per-query termination mask
    flipped are **retired** — their Futures resolve immediately, at
    their own depth, not the batch maximum;
  * freed slots are **refilled** from the class queue between
    supersteps by re-running ``init_carry`` for just those lanes (a
    lane-masked select — the device never sees a shape change, so
    steady-state recycling re-traces nothing).

Each lane's computation is the same vmapped program ``run_batch``
executes, so a query spliced in at in-flight superstep t is
bit-identical to a solo ``Engine.run`` (asserted in
tests/test_continuous.py).
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .batching import QueryClass, QueryRequest
from .plans import StepperPlan

__all__ = ["ContinuousScheduler", "class_key"]


def class_key(qclass: QueryClass) -> str:
    """Stable string key for per-class cost-model stats."""
    return f"{qclass.graph_id}/{qclass.kernel}/{qclass.mode}"


def _lane_dtype(value) -> np.dtype:
    """Canonical lane-array dtype for a query kwarg (matches the int32 /
    float32 the kernels trace with, so admits never change signature)."""
    a = np.asarray(value)
    if a.dtype.kind in "iub":
        return np.dtype(np.int32)
    if a.dtype.kind == "f":
        return np.dtype(np.float32)
    return a.dtype


class _ClassRun:
    """One query class's slot array + queue."""

    def __init__(self, splan: StepperPlan, slots: int, cap: int):
        self.splan = splan
        self.slots = slots
        self.cap = cap
        self.carry = None                       # device StepCarry or None
        self.act: Optional[np.ndarray] = None   # (W,) lane-alive probe
        self.steps: Optional[np.ndarray] = None  # (W,) lane supersteps
        self.lanes: List[Optional[Tuple[QueryRequest, Any]]] = \
            [None] * slots
        self.queue: "collections.deque" = collections.deque()
        self.qkw: Optional[Dict[str, np.ndarray]] = None

    @property
    def occupied(self) -> np.ndarray:
        return np.array([ln is not None for ln in self.lanes], bool)

    def in_flight(self) -> int:
        return sum(ln is not None for ln in self.lanes)


class ContinuousScheduler:
    """Slot-array scheduler over step-granular engine plans.

    ``pump()`` advances every class with work by exactly one superstep
    (admit -> step -> retire); callers loop it — synchronously
    (``drain``) or from the service's scheduler thread. Not re-entrant:
    all public methods serialize on one lock, so a ``submit`` racing a
    ``pump`` just lands in the queue for the next inter-superstep
    admission window.
    """

    def __init__(self, *, slots: int = 16,
                 max_supersteps: Optional[int] = None,
                 stats=None,
                 get_stepper: Callable[[QueryClass], StepperPlan] = None,
                 on_result: Callable[[QueryRequest, Any], None] = None):
        assert slots >= 1
        self.slots = slots
        self.max_supersteps = max_supersteps
        self.stats = stats
        self._get_stepper = get_stepper
        self._on_result = on_result or (lambda req, res: None)
        self._classes: Dict[QueryClass, _ClassRun] = {}
        self._lock = threading.RLock()

    # ---------------- admission ---------------------------------------
    def submit(self, qclass: QueryClass, req: QueryRequest, fut) -> None:
        with self._lock:
            cr = self._classes.get(qclass)
            if cr is None:
                splan = self._get_stepper(qclass)
                from ..core.engine import HARD_SUPERSTEP_CAP
                cap = (self.max_supersteps
                       or splan.engine.kernel.max_supersteps
                       or HARD_SUPERSTEP_CAP)
                cr = _ClassRun(splan, self.slots, cap)
                self._classes[qclass] = cr
            cr.queue.append((req, fut))

    def backlog(self, qclass: QueryClass) -> int:
        """Queued (not yet admitted) depth for one class."""
        with self._lock:
            cr = self._classes.get(qclass)
            return len(cr.queue) if cr else 0

    def pending(self) -> int:
        """Queued + in-flight queries across all classes."""
        with self._lock:
            return sum(len(cr.queue) + cr.in_flight()
                       for cr in self._classes.values())

    def has_work(self) -> bool:
        return self.pending() > 0

    # ---------------- the superstep pump ------------------------------
    def pump(self) -> int:
        """One superstep for every class with work; returns the number
        of queries retired."""
        retired = 0
        with self._lock:
            for qclass, cr in list(self._classes.items()):
                retired += self._pump_class(qclass, cr)
        return retired

    def drain(self, qclass: Optional[QueryClass] = None,
              max_pumps: int = 1_000_000) -> int:
        """Pump until ``qclass`` (or everything) has no queued or
        in-flight queries; returns total retired."""
        total = 0
        with self._lock:
            for _ in range(max_pumps):
                if qclass is None:
                    if not self.has_work():
                        break
                    total += self.pump()
                else:
                    cr = self._classes.get(qclass)
                    if cr is None or (not cr.queue
                                      and cr.in_flight() == 0):
                        break
                    total += self._pump_class(qclass, cr)
        return total

    # ---------------- internals ---------------------------------------
    def _pump_class(self, qclass: QueryClass, cr: _ClassRun) -> int:
        if not cr.queue and cr.in_flight() == 0:
            return 0
        try:
            return self._pump_class_inner(qclass, cr)
        except Exception as exc:    # noqa: BLE001 — fail the slot array
            # Mirror the bucketed batcher's contract: a device/program
            # error must resolve every affected Future, not strand them
            # (and not kill the async scheduler thread). The class state
            # resets; the next submit starts clean.
            self._fail_class(cr, exc)
            return 0

    def _fail_class(self, cr: _ClassRun, exc: Exception) -> None:
        for i, ln in enumerate(cr.lanes):
            if ln is not None:
                ln[1].set_exception(exc)
                cr.lanes[i] = None
        while cr.queue:
            _, fut = cr.queue.popleft()
            if fut.set_running_or_notify_cancel():
                fut.set_exception(exc)
        cr.carry = cr.act = cr.steps = None

    def _pump_class_inner(self, qclass: QueryClass, cr: _ClassRun) -> int:
        # retire everything the previous pump's step finished, FIRST,
        # so its freed slots are refilled and stepped in this very pump
        # (no lane idles a superstep while the queue is non-empty)
        retired = self._retire(qclass, cr) if cr.carry is not None else 0
        self._admit(cr)
        if cr.carry is None or cr.in_flight() == 0:
            return retired
        # fresh lanes come back from admit with their probe bits, so a
        # dead-on-arrival query is excluded here and retired below at 0
        # supersteps — the stepper analogue of Engine.run's pre-loop
        # cond check
        alive = cr.occupied & cr.act & (cr.steps < cr.cap)
        if not alive.any():
            return retired + self._retire(qclass, cr)
        eng = cr.splan.engine
        traces0 = eng.traces
        t0 = time.perf_counter()
        cr.carry, cr.act, cr.steps = cr.splan.stepper.step(cr.carry, alive)
        wall = time.perf_counter() - t0   # probe return synced the device
        if self.stats is not None:
            self.stats.record_busy(wall)
            self.stats.record_pump_step()
            if eng.traces == traces0:
                # compile-time walls would poison the cost model (and,
                # with admission control on, shed the class forever)
                self.stats.record_superstep_time(class_key(qclass), wall)
        return retired

    def _admit(self, cr: _ClassRun) -> None:
        """Splice queued queries into free lanes (one admit call for all
        fresh lanes — re-runs init_carry lane-masked)."""
        if not cr.queue:
            return
        fresh = np.zeros(cr.slots, bool)
        for i in range(cr.slots):
            if cr.lanes[i] is not None:
                continue
            while cr.queue:
                req, fut = cr.queue.popleft()
                if fut.set_running_or_notify_cancel():
                    break
            else:
                break   # queue exhausted (cancelled stragglers dropped)
            cr.lanes[i] = (req, fut)
            if cr.qkw is None:
                # lane arrays keyed by the kernel's DECLARED params
                # (not this request's keys), seeded with its values —
                # idle lanes then hold a valid query, like the bucketed
                # batcher's padding lanes
                cr.qkw = {p: np.full((cr.slots,), req.query_kwargs[p],
                                     dtype=_lane_dtype(req.query_kwargs[p]))
                          for p in cr.splan.query_params}
            for p in cr.qkw:
                # a missing declared param raises here and fails the
                # class loudly (pump's guard) instead of silently
                # reusing the slot's previous occupant's value
                cr.qkw[p][i] = req.query_kwargs[p]
            fresh[i] = True
        if fresh.any():
            if cr.carry is None:
                cr.carry, cr.act, cr.steps = cr.splan.stepper.init(cr.qkw)
            else:
                cr.carry, cr.act, cr.steps = cr.splan.stepper.admit(
                    cr.carry, cr.qkw, fresh)

    def _retire(self, qclass: QueryClass, cr: _ClassRun) -> int:
        """Resolve every occupied lane whose termination mask flipped
        (or that hit the superstep cap); free its slot."""
        act, steps = cr.act, cr.steps
        done = [i for i in range(cr.slots)
                if cr.lanes[i] is not None
                and (not act[i] or steps[i] >= cr.cap)]
        if not done:
            return 0
        host = cr.splan.stepper.fetch(cr.carry)
        now = time.perf_counter()
        for i in done:
            req, fut = cr.lanes[i]
            cr.lanes[i] = None
            try:
                res = cr.splan.engine.lane_result(host, i)
            except Exception as exc:    # noqa: BLE001 — fail one lane
                fut.set_exception(exc)
                continue
            fut.set_result(res)
            if self.stats is not None:
                self.stats.record_retire(
                    messages=res.messages,
                    latency_ms=(now - req.arrival_s) * 1e3)
                self.stats.record_query_depth(class_key(qclass),
                                              res.supersteps)
            self._on_result(req, res)
        return len(done)
