"""Graph query service: batched multi-query execution over the GraVF-M
engine, with a compiled-plan cache and a deadline-aware scheduler.

    from repro.service import GraphQueryService, QueryRequest

    svc = GraphQueryService(num_shards=4, max_batch=32)
    svc.add_graph("social", graph)
    svc.warm("social", "bfs")                 # optional: pre-trace plans
    res = svc.query("social", "bfs", root=7)  # one EngineResult
    print(svc.stats_snapshot())               # qps / p95 / TEPS / cache
"""
from .batching import (BATCH_BUCKETS, Batcher, QueryClass, QueryRequest,
                       bucket_for)
from .plans import CompiledPlan, PlanCache, PlanKey
from .server import GraphQueryService
from .stats import ServiceStats, percentile

__all__ = [
    "BATCH_BUCKETS", "Batcher", "QueryClass", "QueryRequest", "bucket_for",
    "CompiledPlan", "PlanCache", "PlanKey",
    "GraphQueryService", "ServiceStats", "percentile",
]
