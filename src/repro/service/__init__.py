"""Graph query service: batched multi-query execution over the GraVF-M
engine, with a compiled-plan cache and a deadline-aware scheduler —
bucketed (run each batch to completion) or continuous (per-superstep
slot array with mid-flight retirement and admission of new roots).

    from repro.service import GraphQueryService, QueryRequest

    svc = GraphQueryService(num_shards=4, max_batch=32,
                            scheduling="continuous")
    svc.add_graph("social", graph)
    svc.warm("social", "bfs")                 # optional: pre-trace plans
    res = svc.query("social", "bfs", root=7)  # one EngineResult
    print(svc.stats_snapshot())               # qps / p95 / TEPS / cache
"""
from ..store import (GraphLease, GraphStore, StoreError, TenantPolicy,
                     TenantRegistry, TokenBucket)
from .batching import (BATCH_BUCKETS, AdmissionError, Batcher, QueryClass,
                       QueryRequest, bucket_for)
from .continuous import ContinuousScheduler, class_key
from .metrics import (Alert, MetricsRegistry, Watchdog, WatchdogConfig,
                      feed_service_snapshot)
from .plans import CompiledPlan, PlanCache, PlanKey, StepperPlan
from .server import GraphQueryService
from .stats import ServiceStats, percentile
from .trace import (EVENT_KINDS, QuerySpan, TraceBus, TraceEvent,
                    assemble_spans, chrome_trace)

__all__ = [
    "BATCH_BUCKETS", "AdmissionError", "Batcher", "QueryClass",
    "QueryRequest", "bucket_for",
    "CompiledPlan", "PlanCache", "PlanKey", "StepperPlan",
    "ContinuousScheduler", "class_key",
    "GraphQueryService", "ServiceStats", "percentile",
    "GraphLease", "GraphStore", "StoreError",
    "TenantPolicy", "TenantRegistry", "TokenBucket",
    "EVENT_KINDS", "QuerySpan", "TraceBus", "TraceEvent",
    "assemble_spans", "chrome_trace",
    "Alert", "MetricsRegistry", "Watchdog", "WatchdogConfig",
    "feed_service_snapshot",
]
