"""Request admission and batch formation.

Incoming single queries are grouped by :class:`QueryClass` (everything
that must match for two queries to share one compiled plan: graph,
kernel, mode, shard count, backend). Within a class the batcher fills a
batch until either

  * it reaches ``max_batch`` (dispatch immediately — throughput bound), or
  * the oldest member's latency deadline minus ``slack_ms`` arrives
    (dispatch partially full — latency bound).

Dispatched batches are padded up to the next *bucket* size (powers of
two up to ``max_batch``) so the plan cache holds O(log max_batch) traced
programs per class instead of one per occupancy; padding lanes repeat
the first query's parameters and are dropped before results are
returned.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["QueryRequest", "QueryClass", "Batcher", "bucket_for",
           "BATCH_BUCKETS", "AdmissionError"]

BATCH_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


class AdmissionError(RuntimeError):
    """Raised (via the request's Future) when admission control sheds a
    query whose deadline is already infeasible given the backlog and the
    class's observed per-superstep cost — failing fast instead of
    burning a slot on an answer nobody will wait for."""

_qid_counter = itertools.count(1)


def bucket_for(n: int, max_batch: int) -> int:
    """Smallest power-of-two bucket >= n, capped at max_batch."""
    for b in BATCH_BUCKETS:
        if b >= n:
            return min(b, max_batch)
    return max_batch


@dataclasses.dataclass
class QueryRequest:
    """One user query. ``query_kwargs`` maps the kernel's declared
    ``query_params`` (e.g. ``{"root": 7}``) to scalars; ``deadline_ms``
    is the end-to-end latency budget the scheduler batches under;
    ``tenant`` selects the quota/fair-share policy the request is
    admitted and scheduled under; ``priority`` (higher = more urgent)
    feeds the continuous scheduler's deadline-priority ordering — each
    level is worth :data:`~repro.core.stepper.PRIORITY_BOOST_S` (60 s)
    of deadline urgency, so it dominates ordinary deadline spreads but
    stays finite: deadlines more than 60 s apart (and long-parked
    lanes' aging credit) can still outrank it."""

    graph_id: str
    kernel: str
    query_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    mode: str = "gravfm"
    deadline_ms: float = 50.0
    tenant: str = "default"
    priority: int = 0
    exchange: str = ""   # shard exchange schedule ("" = service default)
    overlap: bool = False  # pipelined exchange schedule (shard classes)
    qid: int = dataclasses.field(default_factory=lambda: next(_qid_counter))
    arrival_s: float = dataclasses.field(default_factory=time.perf_counter)

    @property
    def deadline_s(self) -> float:
        return self.arrival_s + self.deadline_ms / 1e3


@dataclasses.dataclass(frozen=True)
class QueryClass:
    """Plan-compatibility key: requests in the same class can share one
    batched engine invocation. ``version`` is the published graph
    version the request bound at submit time — arrivals after a
    ``publish`` land in a fresh class (N+1) while the old class drains
    on N."""
    graph_id: str
    kernel: str
    mode: str
    num_shards: int
    backend: str
    version: int = 0
    exchange: str = ""   # "" = single-host Engine; else a ShardEngine mode
    # overlapped (pipelined) exchange schedule: a plan dimension like
    # ``exchange`` — overlapped and synchronous requests trace distinct
    # steppers but share one engine (and its device-resident graph), so
    # the toggle is free at steady state. Meaningful only for shard
    # classes (``exchange`` set); normalized off otherwise.
    overlap: bool = False

    @classmethod
    def of(cls, req: QueryRequest, num_shards: int,
           backend: str, version: int = 0,
           exchange: str = "", overlap: bool = False) -> "QueryClass":
        ex = req.exchange or exchange
        return cls(req.graph_id, req.kernel, req.mode, num_shards, backend,
                   version, ex, bool((req.overlap or overlap) and ex))


class Batcher:
    """Deadline-aware accumulator. Not thread-safe by itself — the server
    serializes access under its scheduler lock."""

    def __init__(self, *, max_batch: int = 32, slack_ms: float = 5.0):
        assert max_batch >= 1
        self.max_batch = max_batch
        self.slack_ms = slack_ms
        self._pending: Dict[QueryClass, List[Any]] = {}

    def __len__(self) -> int:
        return sum(len(v) for v in self._pending.values())

    def add(self, qclass: QueryClass, item: Any,
            batchable: bool) -> Optional[Tuple[QueryClass, List[Any]]]:
        """Enqueue one (request, future) item. Returns a full batch ready
        for dispatch, or None. Non-batchable classes (kernels with no
        query_params) dispatch immediately as singletons."""
        if not batchable:
            return qclass, [item]
        q = self._pending.setdefault(qclass, [])
        q.append(item)
        if len(q) >= self.max_batch:
            del self._pending[qclass]
            return qclass, q
        return None

    def _flush_time(self, items: List[Any]) -> float:
        """Latest time this batch can leave and still meet every member's
        deadline (minus dispatch slack)."""
        return min(it[0].deadline_s for it in items) - self.slack_ms / 1e3

    def due(self, now_s: Optional[float] = None
            ) -> List[Tuple[QueryClass, List[Any]]]:
        """Pop every class whose flush time has arrived."""
        now_s = time.perf_counter() if now_s is None else now_s
        out = []
        for qc in list(self._pending):
            items = self._pending[qc]
            if items and self._flush_time(items) <= now_s:
                out.append((qc, items))
                del self._pending[qc]
        return out

    def next_flush_s(self) -> Optional[float]:
        """Earliest pending flush time (None when idle) — what the
        scheduler thread sleeps until."""
        times = [self._flush_time(items)
                 for items in self._pending.values() if items]
        return min(times) if times else None

    def pop_class(self, qclass: QueryClass) -> List[Any]:
        """Remove and return one class's pending items ([] when none)."""
        return self._pending.pop(qclass, [])

    def pending_in_class(self, qclass: QueryClass) -> int:
        """Queued depth for one class (admission control's backlog)."""
        return len(self._pending.get(qclass, ()))

    def flush_all(self) -> List[Tuple[QueryClass, List[Any]]]:
        out = [(qc, items) for qc, items in self._pending.items() if items]
        self._pending.clear()
        return out
