"""Service observability: counters + latency/throughput accounting.

One :class:`ServiceStats` instance is shared by the plan cache, the
batcher, and the server, so a single ``snapshot()`` is the service's
stats endpoint: queries/sec, p50/p95 latency, TEPS (traversed edges per
second — the paper's §6 throughput metric, here aggregated over every
query the service executed), and the plan-cache hit/miss/trace counters
the zero-retrace guarantee is asserted against.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Dict, List

__all__ = ["ServiceStats", "percentile"]


def percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 on empty input."""
    if not values:
        return 0.0
    vs = sorted(values)
    idx = min(len(vs) - 1, max(0, int(round(q / 100.0 * (len(vs) - 1)))))
    return vs[idx]


@dataclasses.dataclass
class ServiceStats:
    """Thread-safe rolling counters for the query service."""

    queries_submitted: int = 0
    queries_completed: int = 0
    batches_dispatched: int = 0
    batch_pad_queries: int = 0      # padding lanes added to hit a bucket
    plan_cache_hits: int = 0
    plan_cache_misses: int = 0
    plan_traces: int = 0            # jit traces across all cached engines
    supersteps_total: int = 0
    messages_total: int = 0         # traversed edges (TEPS numerator)
    busy_time_s: float = 0.0        # wall time spent inside dispatch

    # Percentiles come from a bounded window of recent latencies so a
    # long-running service neither leaks memory nor pays O(total-queries)
    # sorts in snapshot().
    latency_window: int = 8192

    def __post_init__(self):
        self._lock = threading.Lock()
        self._latencies_ms = collections.deque(maxlen=self.latency_window)
        self._started_at = time.perf_counter()

    # ------------------------------------------------------------------
    def record_submit(self, n: int = 1) -> None:
        with self._lock:
            self.queries_submitted += n

    def record_batch(self, n_queries: int, n_pad: int, wall_s: float,
                     messages: int, supersteps: int,
                     latencies_ms: List[float]) -> None:
        with self._lock:
            self.batches_dispatched += 1
            self.queries_completed += n_queries
            self.batch_pad_queries += n_pad
            self.busy_time_s += wall_s
            self.messages_total += messages
            self.supersteps_total += supersteps
            self._latencies_ms.extend(latencies_ms)

    def record_cache(self, hit: bool) -> None:
        with self._lock:
            if hit:
                self.plan_cache_hits += 1
            else:
                self.plan_cache_misses += 1

    def record_traces(self, n: int) -> None:
        with self._lock:
            self.plan_traces += n

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        """The stats endpoint payload."""
        with self._lock:
            lat = list(self._latencies_ms)
            elapsed = max(time.perf_counter() - self._started_at, 1e-9)
            busy = max(self.busy_time_s, 1e-9)
            return {
                "queries_submitted": self.queries_submitted,
                "queries_completed": self.queries_completed,
                "batches_dispatched": self.batches_dispatched,
                "batch_pad_queries": self.batch_pad_queries,
                "avg_batch_size": (self.queries_completed
                                   / max(self.batches_dispatched, 1)),
                "plan_cache_hits": self.plan_cache_hits,
                "plan_cache_misses": self.plan_cache_misses,
                "plan_traces": self.plan_traces,
                "supersteps_total": self.supersteps_total,
                "messages_total": self.messages_total,
                "qps": self.queries_completed / elapsed,
                "qps_busy": self.queries_completed / busy,
                "teps": self.messages_total / busy,
                "latency_p50_ms": percentile(lat, 50),
                "latency_p95_ms": percentile(lat, 95),
                "latency_max_ms": percentile(lat, 100),
                "uptime_s": elapsed,
            }
