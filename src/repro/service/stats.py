"""Service observability: counters + latency/throughput accounting.

One :class:`ServiceStats` instance is shared by the plan cache, the
batcher, and the server, so a single ``snapshot()`` is the service's
stats endpoint: queries/sec, p50/p95 latency, TEPS (traversed edges per
second — the paper's §6 throughput metric, here aggregated over every
query the service executed), and the plan-cache hit/miss/trace counters
the zero-retrace guarantee is asserted against.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

__all__ = ["ServiceStats", "percentile"]


def percentile(values: List[float], q: float) -> float:
    """Linearly interpolated percentile (q in [0, 100]); 0.0 on empty
    input. (The previous nearest-rank form used ``int(round(...))``,
    whose banker's rounding made e.g. p50 of two samples unstable —
    flipping between the lower and upper sample as the window grew.)"""
    if not values:
        return 0.0
    vs = sorted(values)
    pos = min(max(q, 0.0), 100.0) / 100.0 * (len(vs) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(vs) - 1)
    frac = pos - lo
    return vs[lo] * (1.0 - frac) + vs[hi] * frac


@dataclasses.dataclass
class ServiceStats:
    """Thread-safe rolling counters for the query service."""

    queries_submitted: int = 0
    queries_completed: int = 0
    queries_shed: int = 0           # rejected by admission control
    batches_dispatched: int = 0
    batch_pad_queries: int = 0      # padding lanes added to hit a bucket
    plan_cache_hits: int = 0
    plan_cache_misses: int = 0
    plan_traces: int = 0            # jit traces across all cached engines
    result_cache_hits: int = 0      # memoized EngineResults served
    preemptions: int = 0            # lanes parked for tighter deadlines
    lane_restores: int = 0          # parked lanes spliced back in
    # checkpoint vs restore walls are SEPARATE counters: the two halves
    # of a preemption have different cost structures (park = one-lane
    # device->host fetch, restore = broadcast+select splice) and a
    # regression in either used to hide in their sum
    park_ms: float = 0.0            # wall spent checkpointing (parking)
    restore_ms: float = 0.0         # wall spent restoring parked lanes
    deadline_misses: int = 0        # queries retired past their deadline
    supersteps_total: int = 0
    messages_total: int = 0         # traversed edges (TEPS numerator)
    wire_words_total: float = 0.0   # exchange words moved across shards
    busy_time_s: float = 0.0        # wall time spent EXECUTING dispatches
    compile_time_s: float = 0.0     # wall time spent tracing/compiling

    # Percentiles come from a bounded window of recent latencies so a
    # long-running service neither leaks memory nor pays O(total-queries)
    # sorts in snapshot().
    latency_window: int = 8192
    # EWMA smoothing for the per-class superstep wall-time / depth
    # estimates that admission control extrapolates from.
    ewma_alpha: float = 0.2

    def __post_init__(self):
        self._lock = threading.Lock()  # lock: stats
        self._latencies_ms = collections.deque(maxlen=self.latency_window)
        # queue-wait (submit -> lane/batch admission) window: the SLO
        # watchdog's queue_wait_p95 rule reads these percentiles
        self._queue_waits_ms = collections.deque(maxlen=self.latency_window)
        self._started_at = time.perf_counter()
        # per-tenant breakdown (submitted/completed/shed/messages and a
        # bounded latency window) for the multi-tenant stats endpoint
        self._tenants: Dict[str, Dict[str, float]] = {}
        self._tenant_lat: Dict[str, collections.deque] = {}
        # per query-class key: EWMA of one superstep's wall time (ms) and
        # of supersteps-per-query — the service's cost model for deciding
        # whether a deadline is still feasible given the backlog. The
        # depth table additionally holds per-root-degree-decile sub-keys
        # ("<class>|d<decile>"): roots in different degree deciles have
        # systematically different BFS/SSSP depths, so bucketing the
        # EWMA sharpens depth packing and victim selection (PR 5
        # follow-on). Lookups fall back to the plain class key until the
        # bucket has been observed.
        self._step_ms_ewma: Dict[str, float] = {}
        self._depth_ewma: Dict[str, float] = {}
        # EWMA of |observed - predicted| supersteps per class: the
        # depth-prediction residual the preemption victim ranking falls
        # back to once a lane outlives its prediction, and the
        # ``depth_pred_abs_err`` health metric in snapshot()
        self._depth_err_ewma: Dict[str, float] = {}
        # per query-class CUMULATIVE accounting (messages / execution
        # busy seconds / completions) — the measured side of the
        # roofline_efficiency metric. The projected side comes from the
        # injected projector (set_roofline_projector): class key ->
        # perfmodel.limits()["T_sys"] TEPS, or None when unknown.
        self._class_acc: Dict[str, Dict[str, float]] = {}
        self._roofline_fn: Optional[Callable[[str], Optional[float]]] = None

    # ------------------------------------------------------------------
    def record_submit(self, n: int = 1) -> None:
        with self._lock:
            self.queries_submitted += n

    def _class_acc_of(self, class_key: str) -> Dict[str, float]:
        acc = self._class_acc.get(class_key)
        if acc is None:
            acc = self._class_acc[class_key] = {
                "messages": 0.0, "busy_s": 0.0, "completed": 0.0,
                "wire_words": 0.0,
                # exchange overlap accounting (profiled shard steppers):
                # exposed = wall the exchange actually spent on the
                # critical path under the serving schedule; total = the
                # same superstep's serial-reference exchange wall
                "exposed_exchange_s": 0.0, "total_exchange_s": 0.0}
        return acc

    def record_batch(self, n_queries: int, n_pad: int, wall_s: float,
                     messages: int, supersteps: int,
                     latencies_ms: List[float],
                     class_key: Optional[str] = None,
                     wire_words: float = 0.0) -> None:
        with self._lock:
            self.batches_dispatched += 1
            self.queries_completed += n_queries
            self.batch_pad_queries += n_pad
            self.busy_time_s += wall_s
            self.messages_total += messages
            self.supersteps_total += supersteps
            self.wire_words_total += wire_words
            self._latencies_ms.extend(latencies_ms)
            if class_key is not None:
                acc = self._class_acc_of(class_key)
                acc["messages"] += messages
                acc["busy_s"] += wall_s
                acc["completed"] += n_queries
                acc["wire_words"] += wire_words

    def record_cache(self, hit: bool) -> None:
        with self._lock:
            if hit:
                self.plan_cache_hits += 1
            else:
                self.plan_cache_misses += 1

    def record_traces(self, n: int) -> None:
        with self._lock:
            self.plan_traces += n

    def record_result_hit(self, latency_ms: float) -> None:
        """A memoized result resolved a query without execution (the
        caller also folds it into the tenant breakdown via
        ``record_tenant(..., result_hits=1)``)."""
        with self._lock:
            self.result_cache_hits += 1
            self.queries_completed += 1
            self._latencies_ms.append(latency_ms)

    def record_shed(self, n: int = 1) -> None:
        with self._lock:
            self.queries_shed += n

    def record_queue_wait(self, wait_ms: float) -> None:
        """One query's submit->admission wait (recorded where a request
        leaves a queue for a lane or a dispatched batch)."""
        with self._lock:
            self._queue_waits_ms.append(wait_ms)

    # ---- per-tenant breakdown -----------------------------------------
    def _tenant(self, tenant: str) -> Dict[str, float]:
        t = self._tenants.get(tenant)
        if t is None:
            t = self._tenants[tenant] = {
                "submitted": 0, "completed": 0, "shed": 0, "messages": 0,
                "result_cache_hits": 0, "deadline_misses": 0}
            # same window as the aggregate percentiles: a hardcoded 512
            # here used to give tenant p95s different (shorter-memory)
            # semantics than the service-wide ones
            self._tenant_lat[tenant] = collections.deque(
                maxlen=self.latency_window)
        return t

    def record_tenant(self, tenant: str, *, submitted: int = 0,
                      completed: int = 0, shed: int = 0, messages: int = 0,
                      result_hits: int = 0, deadline_misses: int = 0,
                      latency_ms: Optional[float] = None) -> None:
        """Fold one event into ``tenant``'s breakdown (the service calls
        this alongside the aggregate counters)."""
        with self._lock:
            t = self._tenant(tenant)
            t["submitted"] += submitted
            t["completed"] += completed
            t["shed"] += shed
            t["messages"] += messages
            t["result_cache_hits"] += result_hits
            t["deadline_misses"] += deadline_misses
            if latency_ms is not None:
                self._tenant_lat[tenant].append(latency_ms)

    def tenant_snapshot(self) -> Dict[str, Dict[str, float]]:
        with self._lock:
            return {name: {**vals,
                           "latency_p50_ms": percentile(
                               list(self._tenant_lat[name]), 50),
                           "latency_p95_ms": percentile(
                               list(self._tenant_lat[name]), 95)}
                    for name, vals in self._tenants.items()}

    # ---- per-class cost model (admission control / continuous) --------
    def _ewma(self, table: Dict[str, float], key: str, x: float) -> None:
        prev = table.get(key)
        table[key] = x if prev is None else (
            self.ewma_alpha * x + (1.0 - self.ewma_alpha) * prev)

    def record_busy(self, wall_s: float,
                    class_key: Optional[str] = None) -> None:
        """Wall time spent driving the engine (continuous pump steps —
        bucketed dispatch accounts its own via record_batch). Execution
        only: compile walls go to :meth:`record_compile`. ``class_key``
        additionally attributes the wall to that class's roofline
        accounting."""
        with self._lock:
            self.busy_time_s += wall_s
            if class_key is not None:
                self._class_acc_of(class_key)["busy_s"] += wall_s

    def record_compile(self, wall_s: float) -> None:
        """Wall time spent tracing/compiling a dispatch. Kept out of
        ``busy_time_s`` so ``qps_busy``/TEPS (whose denominator it is)
        reflect steady-state execution, not one-off compiles."""
        with self._lock:
            self.compile_time_s += wall_s

    def record_superstep_time(self, class_key: str, wall_s: float,
                              n_steps: int = 1) -> None:
        """One (or ``n_steps`` uniform) superstep dispatches of
        ``class_key`` took ``wall_s`` seconds of wall time (EWMA feed
        only; busy time is accounted separately)."""
        with self._lock:
            if n_steps > 0:
                self._ewma(self._step_ms_ewma, class_key,
                           wall_s * 1e3 / n_steps)

    def record_query_depth(self, class_key: str, supersteps: int,
                           bucket: Optional[str] = None) -> None:
        """Observed supersteps for one retired query. ``bucket`` (e.g.
        ``"d7"`` for a root in the 7th degree decile) additionally feeds
        the per-bucket depth EWMA the admission predictor prefers."""
        with self._lock:
            self._ewma(self._depth_ewma, class_key, float(supersteps))
            if bucket:
                self._ewma(self._depth_ewma, f"{class_key}|{bucket}",
                           float(supersteps))

    def record_depth_error(self, class_key: str, abs_err: float) -> None:
        """|observed - predicted| supersteps for one retired lane."""
        with self._lock:
            self._ewma(self._depth_err_ewma, class_key, float(abs_err))

    def depth_residual(self, class_key: str) -> Optional[float]:
        """EWMA depth-prediction absolute error for one class (None
        until a prediction has been scored)."""
        with self._lock:
            return self._depth_err_ewma.get(class_key)

    def class_cost_model(self, class_key: str,
                         bucket: Optional[str] = None):
        """(EWMA superstep wall ms, EWMA supersteps per query); either is
        None until observed — admission control then admits everything.
        When ``bucket`` is given the depth estimate prefers the
        root-degree-decile sub-key, falling back to the class-wide EWMA
        until that bucket has retired a query."""
        with self._lock:
            depth = (self._depth_ewma.get(f"{class_key}|{bucket}")
                     if bucket else None)
            if depth is None:
                depth = self._depth_ewma.get(class_key)
            return (self._step_ms_ewma.get(class_key), depth)

    # ---- preemption -----------------------------------------------------
    def record_preempt(self, wall_s: float) -> None:
        """One lane checkpointed (parked) to admit a tighter deadline."""
        with self._lock:
            self.preemptions += 1
            self.park_ms += wall_s * 1e3

    def record_restore(self, wall_s: float) -> None:
        """One parked lane spliced back into a free slot."""
        with self._lock:
            self.lane_restores += 1
            self.restore_ms += wall_s * 1e3

    def record_pump_step(self) -> None:
        """One device superstep executed by the continuous scheduler —
        the same unit record_batch's ``supersteps`` accumulates for
        bucketed dispatch (batch max = device supersteps run), so
        ``supersteps_total`` is comparable across schedulers."""
        with self._lock:
            self.supersteps_total += 1

    def record_retire(self, messages: int, latency_ms: float,
                      class_key: Optional[str] = None,
                      wire_words: float = 0.0) -> None:
        """One query retired mid-flight by the continuous scheduler.
        (Device supersteps are counted per pump via record_pump_step,
        not per query — W lanes share each superstep.)"""
        with self._lock:
            self.queries_completed += 1
            self.messages_total += messages
            self.wire_words_total += wire_words
            self._latencies_ms.append(latency_ms)
            if class_key is not None:
                acc = self._class_acc_of(class_key)
                acc["messages"] += messages
                acc["completed"] += 1
                acc["wire_words"] += wire_words

    def record_exchange_overlap(self, class_key: str, exposed_s: float,
                                total_s: float) -> None:
        """One profiled superstep's exchange walls: ``exposed_s`` is
        what the serving schedule actually paid on the critical path,
        ``total_s`` the serial-reference exchange wall for the same
        superstep. Synchronous schedules record exposed == total; the
        ratio surfaces as per-class ``overlap_efficiency``."""
        with self._lock:
            acc = self._class_acc_of(class_key)
            acc["exposed_exchange_s"] += float(exposed_s)
            acc["total_exchange_s"] += float(total_s)

    def record_deadline_miss(self, n: int = 1) -> None:
        """A query completed AFTER its deadline (counted where the
        engine resolves it — bucketed dispatch and continuous retire;
        sheds are not misses, they are ``queries_shed``)."""
        with self._lock:
            self.deadline_misses += n

    # ---- roofline (measured vs modeled) -------------------------------
    def set_roofline_projector(
            self, fn: Optional[Callable[[str], Optional[float]]]) -> None:
        """Install the class-key -> projected-TEPS function (the
        service wires :func:`repro.core.perfmodel.limits` through it).
        The projector is called OUTSIDE the stats lock — it may take
        store locks of its own."""
        self._roofline_fn = fn

    def roofline_snapshot(self) -> Dict[str, Dict[str, float]]:
        """Per-class measured TEPS vs the performance-model projection:
        ``efficiency`` is the paper's §6 measured-over-modeled ratio
        (GraVF-M reports 0.94 of its projected system limit), computed
        from cumulative per-class messages / execution-busy seconds.
        Classes with no observed busy time report 0.0; classes with no
        projection report ``projected_teps`` 0.0 and efficiency 0.0."""
        with self._lock:
            acc = {ck: dict(a) for ck, a in self._class_acc.items()}
        fn = self._roofline_fn
        out: Dict[str, Dict[str, float]] = {}
        for ck, a in acc.items():
            teps = a["messages"] / a["busy_s"] if a["busy_s"] > 0 else 0.0
            proj = fn(ck) if fn is not None else None
            ww = a.get("wire_words", 0.0)
            out[ck] = {
                "teps": teps,
                "projected_teps": float(proj) if proj else 0.0,
                "efficiency": teps / proj if proj else 0.0,
                "messages": a["messages"],
                "busy_s": a["busy_s"],
                "completed": a["completed"],
                "wire_words": ww,
                # wire cost per traversed edge: the degree-factor
                # compression shows up here as words/message << 1
                "words_per_message": (ww / a["messages"]
                                      if a["messages"] > 0 else 0.0),
            }
            te = a.get("total_exchange_s", 0.0)
            # exposed/total exchange wall: 1.0 = fully synchronous (the
            # exchange is entirely on the critical path), -> 0 = fully
            # hidden behind local compute. None until a profiled
            # superstep has fed the accumulators.
            out[ck]["overlap_efficiency"] = (
                a.get("exposed_exchange_s", 0.0) / te if te > 0 else None)
        return out

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        """The stats endpoint payload."""
        with self._lock:
            lat = list(self._latencies_ms)
            qwait = list(self._queue_waits_ms)
            elapsed = max(time.perf_counter() - self._started_at, 1e-9)
            # before any dispatch has run, busy_time_s is exactly 0 and
            # qps_busy/teps must report 0.0 — the old 1e-9 clamp leaked
            # into the numerator-less case and reported astronomically
            # large throughput from an idle service
            busy = self.busy_time_s
            snap = {
                "queries_submitted": self.queries_submitted,
                "queries_completed": self.queries_completed,
                "queries_shed": self.queries_shed,
                "batches_dispatched": self.batches_dispatched,
                "batch_pad_queries": self.batch_pad_queries,
                "avg_batch_size": (
                    self.queries_completed / self.batches_dispatched
                    if self.batches_dispatched else 0.0),
                "plan_cache_hits": self.plan_cache_hits,
                "plan_cache_misses": self.plan_cache_misses,
                "plan_traces": self.plan_traces,
                "result_cache_hits": self.result_cache_hits,
                "preemptions": self.preemptions,
                "lane_restores": self.lane_restores,
                "park_ms": self.park_ms,
                "restore_ms": self.restore_ms,
                # kept as the sum for dashboards that predate the split
                "park_restore_ms": self.park_ms + self.restore_ms,
                "deadline_misses": self.deadline_misses,
                "depth_pred_abs_err": (
                    sum(self._depth_err_ewma.values())
                    / len(self._depth_err_ewma)
                    if self._depth_err_ewma else 0.0),
                "supersteps_total": self.supersteps_total,
                "messages_total": self.messages_total,
                "wire_words_total": self.wire_words_total,
                "busy_time_s": self.busy_time_s,
                "compile_time_s": self.compile_time_s,
                "qps": self.queries_completed / elapsed,
                "qps_busy": (self.queries_completed / busy
                             if busy > 0 else 0.0),
                "teps": self.messages_total / busy if busy > 0 else 0.0,
                "latency_p50_ms": percentile(lat, 50),
                "latency_p95_ms": percentile(lat, 95),
                "latency_p99_ms": percentile(lat, 99),
                "latency_max_ms": percentile(lat, 100),
                "queue_wait_p50_ms": percentile(qwait, 50),
                "queue_wait_p95_ms": percentile(qwait, 95),
                "uptime_s": elapsed,
            }
        # outside the stats lock: the roofline projector may take the
        # graph store's lock, and store->stats is the established lock
        # order (evict listeners sync trace counters) — nesting the
        # store lock under the stats lock here would be an ABBA inversion
        roofline = self.roofline_snapshot()
        snap["roofline"] = roofline
        snap["roofline_efficiency"] = {
            ck: r["efficiency"] for ck, r in roofline.items()}
        return snap
