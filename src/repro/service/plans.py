"""Compiled-plan cache for the graph query service.

A *plan* is everything needed to answer a class of queries with zero
per-query setup cost: the partitioned, device-resident graph arrays plus
the jitted (batched) superstep program for one

    (graph id, version, kernel, mode, num_shards, batch size, backend)

query class. Building a plan is expensive (partitioning is O(E) host
work, tracing/compiling the superstep loop is seconds); executing one is
a single dispatch. The cache therefore has three levels, each shared by
the level below:

  graphs   held by the :class:`~repro.store.GraphStore` — versioned,
           memory-budgeted, LRU-evicted device residency; partition once
  engines  keyed (graph_id, version, kernel, mode, shards, backend)
                                                    — device arrays once
  plans    keyed PlanKey (adds batch_size)          — traced program once
  steppers keyed PlanKey (batch_size = slot width)  — the step-granular
           LaneStepper programs the continuous scheduler drives

Steady-state serving hits the plan/stepper level only; the
``plan_traces`` counter (fed by the engines' trace-time side effect)
proves repeated submissions of the same class re-trace nothing.

``PlanKey.version`` identifies which published version of the graph the
plan was compiled against (0 = resolve the store's latest at lookup
time). Residency hooks follow the store's three-tier state machine:

  * **spill** (budget eviction, host tier enabled): the version's
    engines *offload* their device graph arrays to host copies but the
    compiled plans/steppers stay cached — a refault re-uploads and
    re-traces nothing.
  * **refault** (fires on the faulting thread, outside the store lock):
    the engines' arrays are promoted back to device buffers before the
    lease is handed out.
  * **discard** (spill overflow, version retirement, remove): exactly
    that version's engines/plans/steppers are dropped; every other
    tenant's (and version's) entries stay hot.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, Optional, Tuple

import numpy as np

from ..core.algorithms import ALGORITHMS
from ..core.engine import Engine, EngineResult
from ..core.graph import Graph
from ..core.partition import PartitionedGraph
from ..core.stepper import LaneStepper
from ..store import GraphStore
from .stats import ServiceStats

__all__ = ["PlanKey", "CompiledPlan", "PlanCache", "StepperPlan"]


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """Identity of one compiled query class."""
    graph_id: str
    kernel: str          # name in core.algorithms.ALGORITHMS
    mode: str            # "gravfm" | "gravf"
    num_shards: int
    batch_size: int      # leading query axis (1 = unbatched program)
    backend: str = "ref"
    version: int = 0     # published graph version (0 = latest at lookup)
    exchange: str = ""   # "" = single-host Engine; else ShardEngine mode
                         # ("allgather"|"ring"|"frontier"|"unicast"|
                         #  "combined") over a num_shards-device mesh
    overlap: bool = False  # pipelined exchange schedule (shard classes):
                           # a stepper/plan dimension only — overlapped
                           # and synchronous plans share one engine (the
                           # engine cache key omits it), so toggling
                           # costs one extra trace at warm, zero after


class CompiledPlan:
    """A cached (engine, batch size) pair ready to execute."""

    def __init__(self, key: PlanKey, engine: Engine):
        self.key = key
        self.engine = engine
        self.executions = 0

    @property
    def query_params(self) -> Tuple[str, ...]:
        return tuple(self.engine.kernel.query_params)

    def execute(self, max_supersteps: "Optional[int]" = None,
                **query_arrays) -> "list[EngineResult]":
        """Run the plan on arrays already padded to ``key.batch_size``
        (scalars allowed when batch_size == 1). Returns per-query
        results in input order. ``max_supersteps`` is traced, so varying
        it costs no re-trace."""
        self.executions += 1
        # overlap=True only ever reaches a ShardEngine: the key is
        # normalized (overlap implies exchange) before the cache lookup
        ov = {"overlap": True} if self.key.overlap else {}
        if self.key.batch_size == 1:
            scalars = {k: np.asarray(v).reshape(()) for k, v
                       in query_arrays.items()}
            return [self.engine.run(max_supersteps, **ov, **scalars)]
        for k, v in query_arrays.items():
            n = np.asarray(v).shape[0]
            if n != self.key.batch_size:
                raise ValueError(
                    f"plan expects batch {self.key.batch_size}, got {n} "
                    f"for {k!r}")
        return self.engine.run_batch(max_supersteps, **ov, **query_arrays)

    def warmup(self) -> "CompiledPlan":
        """Trace + compile now (first root of the graph) so the first real
        query pays dispatch cost only."""
        if self.query_params:
            dummy = {p: np.zeros((self.key.batch_size,), np.int32)
                     for p in self.query_params}
        elif self.key.batch_size == 1:
            dummy = {}
        else:
            raise ValueError(
                f"kernel {self.key.kernel!r} has no query_params; "
                "only batch_size=1 plans are meaningful")
        self.execute(**dummy)
        return self


@dataclasses.dataclass
class StepperPlan:
    """A cached (engine, slot width) LaneStepper ready for continuous
    driving. ``engine`` packages retired lanes (``lane_result``) and
    owns the trace counter the stepper's jits bump."""
    key: PlanKey
    engine: Engine
    stepper: LaneStepper

    @property
    def query_params(self) -> Tuple[str, ...]:
        return tuple(self.engine.kernel.query_params)


class PlanCache:
    """Multi-level cache: partitioned graphs (via the GraphStore),
    device-resident engines, compiled plans, lane steppers.
    Thread-compatible (callers serialize dispatch; the server holds its
    scheduler lock across get_plan + execute). Store residency hooks
    fire synchronously — the affected version is pinned by any query
    still using it, so neither a spill (engine offload) nor a discard
    (full invalidation) ever races a live dispatch."""

    def __init__(self, stats: Optional[ServiceStats] = None,
                 store: Optional[GraphStore] = None):
        self.stats = stats or ServiceStats()
        self.store = store or GraphStore()
        self.store.add_evict_listener(self.invalidate_graph)
        self.store.add_spill_listener(self.offload_graph)
        self.store.add_refault_listener(self.promote_graph)
        # traces of engines already dropped by eviction (keeps the
        # monotonic plan_traces counter exact across invalidations)
        self._trace_floor = 0
        # serializes trace folding + invalidation: evictions can fire
        # from any thread that releases a lease (e.g. the scheduler
        # thread reaping an idle class) while another thread dispatches;
        # ordering is store lock -> this lock -> stats lock, never the
        # reverse, so it cannot deadlock with either
        self._sync_lock = threading.Lock()  # lock: plans_sync
        self._engines: Dict[Tuple[str, int, str, str, int, str, str],
                            Engine] = {}
        # bytes each engine reported to the store's budget (so a
        # discard can un-charge exactly what was charged)
        self._engine_nbytes: Dict[Tuple[str, int, str, str, int, str, str],
                                  int] = {}
        self._plans: Dict[PlanKey, CompiledPlan] = {}
        self._steppers: Dict[PlanKey, StepperPlan] = {}

    # ---------------- graphs ------------------------------------------
    def register_graph(self, graph_id: str, graph: Graph, *,
                       num_shards: int = 4, method: str = "greedy",
                       pad_multiple: int = 256) -> PartitionedGraph:
        """Publish ``graph`` to the store and pin its layout for reuse by
        every plan over it. Re-registering identical content is a no-op;
        different content is a version publish (or :class:`StoreError`
        when the store has versioning disabled)."""
        ver = self.store.publish(graph_id, graph, num_shards=num_shards,
                                 method=method, pad_multiple=pad_multiple)
        with self.store.acquire(graph_id, ver) as lease:
            return lease.pg

    def graph(self, graph_id: str, num_shards: int,
              method: str = "greedy",
              version: Optional[int] = None) -> PartitionedGraph:
        try:
            spec = self.store.partition_spec(graph_id, version)
        except KeyError:
            raise KeyError(
                f"graph {graph_id!r} not registered for {num_shards} "
                f"shards (method={method!r}); call register_graph first")
        if (spec["num_shards"], spec["method"]) != (num_shards, method):
            raise KeyError(
                f"graph {graph_id!r} not registered for {num_shards} "
                f"shards (method={method!r}); its published spec is "
                f"{spec['num_shards']} shards (method={spec['method']!r})")
        with self.store.acquire(graph_id, version) as lease:
            return lease.pg

    # ---------------- engines / plans ---------------------------------
    def resolve_key(self, key: PlanKey) -> PlanKey:
        """Pin ``version=0`` ("latest") to the store's current version so
        cache entries are always keyed by a concrete published version,
        and normalize ``overlap`` off for non-shard classes (the plain
        Engine has no exchange to pipeline)."""
        if key.overlap and not key.exchange:
            key = dataclasses.replace(key, overlap=False)
        if key.version:
            return key
        return dataclasses.replace(
            key, version=self.store.known_version(key.graph_id))

    def _engine_for(self, key: PlanKey, method: str) -> Engine:
        # NOTE: ek deliberately omits key.overlap — both schedules of a
        # class share one engine (and its device-resident graph arrays)
        ek = (key.graph_id, key.version, key.kernel, key.mode,
              key.num_shards, key.backend, key.exchange)
        eng = self._engines.get(ek)
        if eng is None:
            if key.kernel not in ALGORITHMS:
                raise KeyError(f"unknown kernel {key.kernel!r}; have "
                               f"{sorted(ALGORITHMS)}")
            pg = self.graph(key.graph_id, key.num_shards, method,
                            version=key.version or None)
            if key.exchange:
                from ..core.engine_shardmap import ShardEngine
                from ..launch.mesh import make_serving_mesh
                mesh = make_serving_mesh(key.num_shards)
                eng = ShardEngine(ALGORITHMS[key.kernel](), pg, mesh=mesh,
                                  exchange=key.exchange,
                                  backend=key.backend)
            else:
                eng = Engine(ALGORITHMS[key.kernel](), pg, mode=key.mode,
                             backend=key.backend)
            self._engines[ek] = eng
            # charge the TRUE engine-tier device bytes against the
            # store's budget (replacing the partition-layout proxy): a
            # version serving two kernels holds two engines' arrays,
            # and the budget should see both
            nb = eng.device_nbytes
            self._engine_nbytes[ek] = nb
            self.store.note_engine_bytes(key.graph_id, key.version, nb)
        return eng

    def get_plan(self, key: PlanKey, *, method: str = "greedy",
                 warm: bool = False) -> CompiledPlan:
        """Fetch (hit) or build (miss) the plan for ``key``."""
        key = self.resolve_key(key)
        plan = self._plans.get(key)
        hit = plan is not None
        self.stats.record_cache(hit)
        if not hit:
            engine = self._engine_for(key, method)
            if key.batch_size > 1 and not engine.kernel.query_params:
                raise ValueError(
                    f"kernel {key.kernel!r} declares no query_params; "
                    "it cannot be query-batched (batch_size must be 1)")
            plan = CompiledPlan(key, engine)
            if warm:
                plan.warmup()
            self._plans[key] = plan
        return plan

    def get_stepper(self, key: PlanKey, *,
                    method: str = "greedy") -> StepperPlan:
        """Fetch or build the step-granular plan for ``key`` —
        ``key.batch_size`` is the continuous scheduler's slot width.
        Shares the graph/engine tiers with :meth:`get_plan`, so a class
        served both bucketed and continuously partitions and uploads
        once."""
        key = self.resolve_key(key)
        splan = self._steppers.get(key)
        hit = splan is not None
        self.stats.record_cache(hit)
        if not hit:
            engine = self._engine_for(key, method)
            if not engine.kernel.query_params:
                raise ValueError(
                    f"kernel {key.kernel!r} declares no query_params; "
                    "it cannot be continuously batched")
            if key.exchange:
                stepper = engine.make_stepper(key.batch_size,
                                              overlap=key.overlap)
            else:
                stepper = engine.make_stepper(key.batch_size)
            splan = StepperPlan(key, engine, stepper)
            self._steppers[key] = splan
        return splan

    def _engines_of(self, graph_id: str, version: int) -> "list[Engine]":
        with self._sync_lock:
            return [e for k, e in list(self._engines.items())
                    if k[0] == graph_id and k[1] == version]

    def offload_graph(self, graph_id: str, version: int) -> int:
        """Store spill hook: demote the version's engine device arrays
        to host copies. Plans/steppers stay cached — the spill contract
        is that a refault re-uploads and re-traces nothing. Returns the
        engine-tier bytes demoted."""
        return sum(e.offload() for e in self._engines_of(graph_id, version))

    def promote_graph(self, graph_id: str, version: int) -> float:
        """Store refault hook (fires on the faulting thread with the
        store lock released): re-upload the version's engine arrays so
        the first post-fault dispatch pays dispatch cost only. Returns
        the upload wall seconds (the store folds the whole promotion
        into ``refault_upload_ms``)."""
        return sum(e.upload() for e in self._engines_of(graph_id, version))

    def invalidate_graph(self, graph_id: str, version: int) -> None:
        """Drop every engine/plan/stepper compiled against one
        DISCARDED (graph_id, version) — other versions and tenants stay
        cached, and spilled-but-not-discarded versions keep their plans.
        Trace counts of dropped engines are folded into the stats first
        so ``plan_traces`` stays monotonic."""
        freed = 0
        with self._sync_lock:
            self._sync_traces_locked()
            for ek in [k for k in list(self._engines)
                       if k[0] == graph_id and k[1] == version]:
                eng = self._engines.pop(ek, None)
                if eng is not None:
                    self._trace_floor += eng.traces
                freed += self._engine_nbytes.pop(ek, 0)
        if freed:
            self.store.note_engine_bytes(graph_id, version, -freed)
        for pk in [k for k in list(self._plans)
                   if k.graph_id == graph_id and k.version == version]:
            self._plans.pop(pk, None)
        for sk in [k for k in list(self._steppers)
                   if k.graph_id == graph_id and k.version == version]:
            self._steppers.pop(sk, None)

    def sync_trace_counters(self) -> int:
        """Fold every engine's trace count into the shared stats; returns
        the current total. Call after dispatches to keep the stats
        endpoint's ``plan_traces`` exact. (``_trace_floor`` carries the
        traces of engines already dropped by eviction.)"""
        with self._sync_lock:
            return self._sync_traces_locked()

    def _sync_traces_locked(self) -> int:
        # list() snapshots the dict atomically, so a concurrent get_plan
        # inserting an engine cannot break the iteration
        total = self._trace_floor + sum(
            e.traces for e in list(self._engines.values()))
        delta = total - self.stats.plan_traces
        if delta:
            self.stats.record_traces(delta)
        return total

    # ---------------- introspection -----------------------------------
    def describe(self) -> Dict[str, Any]:
        return {
            "graphs": sorted(
                f"{e['graph_id']}@v{e['version']}"
                + ("" if e["resident"] else " (evicted)")
                for e in self.store.describe()),
            "engines": len(self._engines),
            "plans": [dataclasses.asdict(k) for k in self._plans],
            "steppers": [dataclasses.asdict(k) for k in self._steppers],
            "plan_traces": self.sync_trace_counters(),
            "store": self.store.snapshot(),
        }
