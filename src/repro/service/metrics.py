"""Aggregate metrics + SLO watchdog: the scrapeable/alertable layer.

The stats endpoint (stats.py) is a one-shot dict and the TraceBus
(trace.py) is per-query flight recording; neither is something a
monitoring stack can scrape or page on. This module adds the two
standing pieces:

:class:`MetricsRegistry`
    Counters, gauges and log-bucketed histograms behind one leaf lock
    (same discipline as the TraceBus: the lock is never held while
    calling out, so any scheduler/store path may record under its own
    locks). Memory is bounded twice over — histograms have a fixed
    bucket vector, and each metric family caps its label-series count
    (overflow series are counted in ``series_dropped``, never grown).
    ``expose_text()`` renders the Prometheus text exposition format;
    ``snapshot()`` the JSON equivalent. Registered *collectors* pull
    the current ServiceStats / GraphStore / TraceBus / scheduler
    numbers in at read time, so scrapes see fresh values without any
    hot-path publishing.

:class:`Watchdog`
    A background thread evaluating rolling-window SLO rules against the
    service — deadline-miss rate, shed rate, queue-wait p95, a
    roofline-efficiency floor, stall detection (backlog with no retire
    progress), and **perfmodel drift** (a class's measured TEPS
    deviating from the §5 model projection beyond a tolerance: the
    paper's §6 "94% of roofline" methodology turned into a standing
    alert). Each rule drives a firing/resolved state machine per
    subject; transitions emit ``alert`` events on the TraceBus and
    increment alert counters in the registry. ``evaluate_once()`` is
    the deterministic core (tests drive it directly with an explicit
    clock); ``start()``/``stop()`` wrap it in a daemon thread.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = ["MetricsRegistry", "Histogram", "DEFAULT_BUCKETS",
           "Watchdog", "WatchdogConfig", "Alert",
           "feed_service_snapshot"]


# Half-decade log buckets spanning 1µs .. 100s — wide enough for both a
# sub-millisecond superstep phase and a multi-second stalled dispatch,
# at a fixed 17-bucket (+Inf excluded) memory cost per series.
DEFAULT_BUCKETS = tuple(10.0 ** (e / 2.0) for e in range(-12, 5))


class Histogram:
    """One log-bucketed histogram series: fixed bucket bounds, a
    non-cumulative count per bucket (cumulated at exposition time, as
    the Prometheus format requires), plus ``sum``/``count``."""

    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.bounds = tuple(float(b) for b in bounds)
        assert all(a < b for a, b in zip(self.bounds, self.bounds[1:])), \
            "histogram bounds must be strictly increasing"
        self.counts = [0] * (len(self.bounds) + 1)   # last = +Inf overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, value)] += 1
        self.sum += value
        self.count += 1

    def cumulative(self) -> List[int]:
        out, running = [], 0
        for c in self.counts:
            running += c
            out.append(running)
        return out

    def to_dict(self) -> Dict[str, Any]:
        return {"bounds": list(self.bounds), "counts": list(self.counts),
                "sum": self.sum, "count": self.count}


class _Family:
    __slots__ = ("name", "kind", "help", "buckets", "series")

    def __init__(self, name: str, kind: str, help_text: str,
                 buckets: Optional[Tuple[float, ...]] = None):
        self.name = name
        self.kind = kind
        self.help = help_text
        self.buckets = buckets or DEFAULT_BUCKETS
        # label tuple (sorted (k, v) pairs) -> float | Histogram
        self.series: Dict[Tuple[Tuple[str, str], ...], Any] = {}


def _label_key(labels: Dict[str, Any]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _escape(value: str) -> str:
    return (value.replace("\\", "\\\\").replace("\n", "\\n")
            .replace('"', '\\"'))


def _fmt(v: float) -> str:
    v = float(v)
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    if v.is_integer() and abs(v) < 1e15:
        return str(int(v))
    return format(v, ".10g")


class MetricsRegistry:
    """Bounded, thread-safe metric store with Prometheus exposition.

    Recording (``inc``/``set_gauge``/``observe``) takes one leaf lock
    and never calls out, so it is safe under any service/store lock.
    ``enabled=False`` makes every record a no-op (one attribute read,
    mirroring a disabled TraceBus) and exposition empty.
    """

    def __init__(self, *, enabled: bool = True, max_series: int = 256):
        self.enabled = enabled
        self.max_series = max_series        # per metric family
        self._lock = threading.Lock()  # lock: metrics
        self._families: "Dict[str, _Family]" = {}
        self._collectors: List[Callable[["MetricsRegistry"], None]] = []
        self.series_dropped = 0             # label sets refused by the cap

    # ---------------- recording ---------------------------------------
    def _series(self, name: str, kind: str, help_text: str,
                labels: Dict[str, Any],
                buckets: Optional[Tuple[float, ...]] = None):
        """Find-or-create one series under the lock; None when the
        family's series cap refused a new label set."""
        fam = self._families.get(name)
        if fam is None:
            fam = self._families[name] = _Family(name, kind, help_text,
                                                 buckets)
        assert fam.kind == kind, \
            f"metric {name!r} registered as {fam.kind}, recorded as {kind}"
        key = _label_key(labels)
        if key not in fam.series and len(fam.series) >= self.max_series:
            self.series_dropped += 1
            return None, key
        return fam, key

    def inc(self, name: str, value: float = 1.0, *, help: str = "",
            **labels) -> None:
        """Add ``value`` to a counter series (event-driven path)."""
        if not self.enabled:
            return
        with self._lock:
            fam, key = self._series(name, "counter", help, labels)
            if fam is not None:
                fam.series[key] = fam.series.get(key, 0.0) + float(value)

    def set_counter(self, name: str, value: float, *, help: str = "",
                    **labels) -> None:
        """Set a counter series from an already-cumulative source (the
        stats/store snapshots). Clamped monotone: exposition never shows
        a counter going backward even if a collector races a reset."""
        if not self.enabled:
            return
        with self._lock:
            fam, key = self._series(name, "counter", help, labels)
            if fam is not None:
                fam.series[key] = max(fam.series.get(key, 0.0),
                                      float(value))

    def set_gauge(self, name: str, value: float, *, help: str = "",
                  **labels) -> None:
        if not self.enabled:
            return
        with self._lock:
            fam, key = self._series(name, "gauge", help, labels)
            if fam is not None:
                fam.series[key] = float(value)

    def observe(self, name: str, value: float, *, help: str = "",
                buckets: Optional[Tuple[float, ...]] = None,
                **labels) -> None:
        if not self.enabled:
            return
        with self._lock:
            fam, key = self._series(name, "histogram", help, labels,
                                    buckets)
            if fam is None:
                return
            h = fam.series.get(key)
            if h is None:
                h = fam.series[key] = Histogram(fam.buckets)
            h.observe(float(value))

    # ---------------- collection --------------------------------------
    def add_collector(self,
                      fn: Callable[["MetricsRegistry"], None]) -> None:
        """Register a pull-time feeder: called (outside the lock) by
        ``snapshot()``/``expose_text()`` so scrapes read fresh
        stats/store/trace values without hot-path publishing."""
        self._collectors.append(fn)

    def collect(self) -> None:
        if not self.enabled:
            return
        for fn in list(self._collectors):
            fn(self)
        self.set_counter("gravfm_metrics_series_dropped_total",
                         self.series_dropped,
                         help="Label series refused by the per-family "
                              "series cap")

    # ---------------- read side ---------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """JSON-able copy: ``{name: {kind, help, series: [{labels,
        value|histogram}]}}``."""
        self.collect()
        with self._lock:
            out: Dict[str, Any] = {}
            for name, fam in sorted(self._families.items()):
                series = []
                for key, val in sorted(fam.series.items()):
                    entry: Dict[str, Any] = {"labels": dict(key)}
                    if isinstance(val, Histogram):
                        entry["histogram"] = val.to_dict()
                    else:
                        entry["value"] = val
                    series.append(entry)
                out[name] = {"kind": fam.kind, "help": fam.help,
                             "series": series}
            return out

    def expose_text(self) -> str:
        """The Prometheus text exposition format (one HELP/TYPE header
        per family, histogram buckets cumulative with ``le`` labels)."""
        self.collect()
        with self._lock:
            lines: List[str] = []
            for name, fam in sorted(self._families.items()):
                if fam.help:
                    lines.append(f"# HELP {name} {fam.help}")
                lines.append(f"# TYPE {name} {fam.kind}")
                for key, val in sorted(fam.series.items()):
                    if isinstance(val, Histogram):
                        lines.extend(self._hist_lines(name, key, val))
                    else:
                        lines.append(
                            f"{name}{self._labels(key)} {_fmt(val)}")
            return "\n".join(lines) + ("\n" if lines else "")

    @staticmethod
    def _labels(key, extra: str = "") -> str:
        parts = [f'{k}="{_escape(v)}"' for k, v in key]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}" if parts else ""

    @classmethod
    def _hist_lines(cls, name: str, key, h: Histogram) -> List[str]:
        lines = []
        cum = h.cumulative()
        for bound, c in zip(h.bounds, cum):
            le = f'le="{format(bound, ".6g")}"'
            lines.append(f"{name}_bucket{cls._labels(key, le)} {c}")
        inf = 'le="+Inf"'
        lines.append(f"{name}_bucket{cls._labels(key, inf)} {h.count}")
        lines.append(f"{name}_sum{cls._labels(key)} {_fmt(h.sum)}")
        lines.append(f"{name}_count{cls._labels(key)} {h.count}")
        return lines


# ---------------------------------------------------------------------------
# the service snapshot -> registry feed
# ---------------------------------------------------------------------------

# stats_snapshot() scalars that are monotone event counts -> counter name
_SNAP_COUNTERS = {
    "queries_submitted": "gravfm_queries_submitted_total",
    "queries_completed": "gravfm_queries_completed_total",
    "queries_shed": "gravfm_queries_shed_total",
    "batches_dispatched": "gravfm_batches_dispatched_total",
    "batch_pad_queries": "gravfm_batch_pad_queries_total",
    "plan_cache_hits": "gravfm_plan_cache_hits_total",
    "plan_cache_misses": "gravfm_plan_cache_misses_total",
    "plan_traces": "gravfm_plan_traces_total",
    "result_cache_hits": "gravfm_result_cache_hits_total",
    "preemptions": "gravfm_preemptions_total",
    "lane_restores": "gravfm_lane_restores_total",
    "deadline_misses": "gravfm_deadline_misses_total",
    "supersteps_total": "gravfm_supersteps_total",
    "messages_total": "gravfm_messages_total",
    "wire_words_total": "gravfm_wire_words_total",
    "busy_time_s": "gravfm_busy_seconds_total",
    "compile_time_s": "gravfm_compile_seconds_total",
    "park_ms": "gravfm_park_milliseconds_total",
    "restore_ms": "gravfm_restore_milliseconds_total",
    "trace_events": "gravfm_trace_events_total",
    "trace_dropped": "gravfm_trace_dropped_total",
}

# point-in-time scalars -> gauge name
_SNAP_GAUGES = {
    "qps": "gravfm_qps",
    "qps_busy": "gravfm_qps_busy",
    "teps": "gravfm_teps",
    "avg_batch_size": "gravfm_avg_batch_size",
    "latency_p50_ms": "gravfm_latency_p50_ms",
    "latency_p95_ms": "gravfm_latency_p95_ms",
    "latency_p99_ms": "gravfm_latency_p99_ms",
    "queue_wait_p50_ms": "gravfm_queue_wait_p50_ms",
    "queue_wait_p95_ms": "gravfm_queue_wait_p95_ms",
    "depth_pred_abs_err": "gravfm_depth_pred_abs_err",
    "pending": "gravfm_pending_queries",
    "parked_lanes": "gravfm_parked_lanes",
    "uptime_s": "gravfm_uptime_seconds",
}


def feed_service_snapshot(reg: MetricsRegistry, snap: Dict[str, Any],
                          store_counter_keys=frozenset()) -> None:
    """Map one ``GraphQueryService.stats_snapshot()`` payload onto the
    registry: scalar counters/gauges, ``store_*`` keys split by
    ``store_counter_keys``, the per-tenant breakdown, and the per-class
    roofline telemetry (measured vs §5-projected TEPS)."""
    for key, name in _SNAP_COUNTERS.items():
        if key in snap:
            reg.set_counter(name, float(snap[key]))
    for key, name in _SNAP_GAUGES.items():
        if key in snap:
            reg.set_gauge(name, float(snap[key]))
    for key, val in snap.items():
        if not key.startswith("store_") or not isinstance(
                val, (int, float)):
            continue
        base = key[len("store_"):]
        if base in store_counter_keys or base == "refault_upload_ms":
            reg.set_counter(f"gravfm_store_{base}_total", float(val))
        else:
            reg.set_gauge(f"gravfm_store_{base}", float(val))
    for tenant, t in (snap.get("tenants") or {}).items():
        for field in ("submitted", "completed", "shed", "messages",
                      "result_cache_hits", "deadline_misses"):
            if field in t:
                reg.set_counter(f"gravfm_tenant_{field}_total",
                                float(t[field]), tenant=tenant)
        for field in ("latency_p50_ms", "latency_p95_ms"):
            if field in t:
                reg.set_gauge(f"gravfm_tenant_{field}", float(t[field]),
                              tenant=tenant)
    for ck, r in (snap.get("roofline") or {}).items():
        reg.set_gauge("gravfm_roofline_teps", r["teps"],
                      help="Measured per-class TEPS", **{"class": ck})
        reg.set_gauge("gravfm_roofline_projected_teps",
                      r["projected_teps"],
                      help="Perfmodel T_sys projection", **{"class": ck})
        reg.set_gauge("gravfm_roofline_efficiency", r["efficiency"],
                      help="Measured / projected TEPS (paper §6)",
                      **{"class": ck})
        reg.set_counter("gravfm_class_messages_total", r["messages"],
                        **{"class": ck})
        reg.set_counter("gravfm_class_wire_words_total", r["wire_words"],
                        **{"class": ck})
        reg.set_gauge("gravfm_class_words_per_message",
                      r["words_per_message"], **{"class": ck})
        if r.get("overlap_efficiency") is not None:
            # exposed/total exchange wall (profiled shard classes):
            # 1.0 = synchronous, -> 0 = exchange fully hidden
            reg.set_gauge("gravfm_overlap_efficiency",
                          float(r["overlap_efficiency"]),
                          help="Exposed / total exchange time per class",
                          **{"class": ck})


# ---------------------------------------------------------------------------
# SLO watchdog
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WatchdogConfig:
    """Rule thresholds. A threshold of ``None`` disables that rule.

    Rate rules (miss/shed) are evaluated over a rolling ``window_s``
    of counter deltas and need at least ``min_window_events`` in the
    denominator before they can fire (an idle service never alerts on a
    0/0). Model rules (roofline floor / drift) read the cumulative
    per-class roofline accounting and need ``min_completed`` retired
    queries per class. The measured-vs-model defaults are *disabled*:
    on a CPU development box the measured TEPS is nowhere near an
    FPGA/TPU projection, so firing out of the box would be noise —
    deployments opt in with the tolerance that matches their platform.
    """

    interval_s: float = 0.25        # thread evaluation cadence
    window_s: float = 30.0          # rolling window for rate rules
    miss_rate_max: Optional[float] = 0.5
    shed_rate_max: Optional[float] = 0.9
    queue_wait_p95_ms_max: Optional[float] = None
    roofline_floor: Optional[float] = None      # min efficiency, e.g. 0.5
    drift_tol: Optional[float] = None           # e.g. 1.0 = within 2x
    stall_after_s: float = 5.0
    min_window_events: int = 8
    min_completed: int = 8


@dataclasses.dataclass
class Alert:
    """One firing/resolved episode of a rule on a subject."""

    rule: str
    subject: str            # "service" or a class key
    kind: str               # slo | liveness | model
    value: float
    threshold: float
    fired_at: float
    resolved_at: Optional[float] = None

    @property
    def state(self) -> str:
        return "resolved" if self.resolved_at is not None else "firing"


class Watchdog:
    """Evaluates :class:`WatchdogConfig` rules against a
    :class:`~repro.service.GraphQueryService`.

    One :class:`Alert` state machine per (rule, subject): the first
    evaluation where a rule's condition holds *fires* (one ``alert``
    trace event, ``gravfm_alerts_fired_total`` increment); it stays
    firing — without re-firing — until an evaluation observes the
    condition false, which *resolves* it (second event, resolved
    counter). Conditions that cannot be evaluated (not enough window
    events, class gone idle before ``min_completed``) leave the state
    machine untouched rather than flapping it.
    """

    HISTORY = 256       # resolved-alert episodes retained

    def __init__(self, service, config: Optional[WatchdogConfig] = None,
                 **overrides):
        self.service = service
        cfg = config or WatchdogConfig()
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.config = cfg
        self._lock = threading.Lock()  # lock: watchdog
        self._active: Dict[Tuple[str, str], Alert] = {}
        self._history: List[Alert] = []
        self._samples: List[Tuple[float, Dict[str, float]]] = []
        self._last_progress: Optional[Tuple[float, float]] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.evaluations = 0

    # ---------------- lifecycle ---------------------------------------
    def start(self) -> "Watchdog":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="gravfm-watchdog",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def _loop(self) -> None:
        while not self._stop.wait(self.config.interval_s):
            try:
                self.evaluate_once()
            except Exception:   # noqa: BLE001 — a scrape/eval error
                # must not kill the thread (the service keeps serving;
                # the next tick retries)
                pass

    # ---------------- alert plumbing ----------------------------------
    def active_alerts(self) -> List[Alert]:
        with self._lock:
            return list(self._active.values())

    def alerts(self) -> List[Alert]:
        """Active + recently resolved episodes."""
        with self._lock:
            return list(self._history) + list(self._active.values())

    def _metrics(self):
        return getattr(self.service, "metrics", None)

    def _emit(self, alert: Alert) -> None:
        trace = getattr(self.service, "trace", None)
        if trace is not None:
            trace.emit("alert", klass=alert.subject, rule=alert.rule,
                       state=alert.state, alert_kind=alert.kind,
                       value=alert.value, threshold=alert.threshold)
        reg = self._metrics()
        if reg is not None:
            which = ("gravfm_alerts_resolved_total"
                     if alert.resolved_at is not None
                     else "gravfm_alerts_fired_total")
            reg.inc(which, rule=alert.rule)

    def _transition(self, key: Tuple[str, str], firing: bool,
                    kind: str, value: float, threshold: float,
                    now: float) -> None:
        with self._lock:
            cur = self._active.get(key)
            if firing and cur is None:
                alert = self._active[key] = Alert(
                    rule=key[0], subject=key[1], kind=kind,
                    value=value, threshold=threshold, fired_at=now)
            elif not firing and cur is not None:
                cur.resolved_at = now
                cur.value = value
                del self._active[key]
                self._history.append(cur)
                del self._history[:-self.HISTORY]
                alert = cur
            else:
                if cur is not None:
                    cur.value = value   # keep the live reading fresh
                return
        self._emit(alert)

    # ---------------- evaluation --------------------------------------
    def evaluate_once(self, now: Optional[float] = None) -> List[Alert]:
        """One evaluation pass; returns the alerts active afterwards.
        ``now`` defaults to ``time.perf_counter()`` — tests pass an
        explicit clock to step the window/stall logic deterministically.
        """
        cfg = self.config
        now = time.perf_counter() if now is None else now
        self.evaluations += 1
        snap = self.service.stats.snapshot()
        pending = self.service.pending()

        # rolling-window deltas for the rate rules
        cur = {"completed": float(snap["queries_completed"]),
               "submitted": float(snap["queries_submitted"]),
               "shed": float(snap["queries_shed"]),
               "misses": float(snap["deadline_misses"])}
        self._samples.append((now, cur))
        while (len(self._samples) > 1
               and self._samples[1][0] <= now - cfg.window_s):
            self._samples.pop(0)
        base = self._samples[0][1]
        d_completed = cur["completed"] - base["completed"]
        d_submitted = cur["submitted"] - base["submitted"]
        d_shed = cur["shed"] - base["shed"]
        d_misses = cur["misses"] - base["misses"]

        if cfg.miss_rate_max is not None and \
                d_completed >= cfg.min_window_events:
            rate = d_misses / d_completed
            self._transition(("deadline_miss_rate", "service"),
                             rate > cfg.miss_rate_max, "slo",
                             rate, cfg.miss_rate_max, now)
        if cfg.shed_rate_max is not None and \
                d_submitted >= cfg.min_window_events:
            rate = d_shed / d_submitted
            self._transition(("shed_rate", "service"),
                             rate > cfg.shed_rate_max, "slo",
                             rate, cfg.shed_rate_max, now)
        if cfg.queue_wait_p95_ms_max is not None:
            p95 = float(snap.get("queue_wait_p95_ms", 0.0))
            self._transition(("queue_wait_p95", "service"),
                             p95 > cfg.queue_wait_p95_ms_max, "slo",
                             p95, cfg.queue_wait_p95_ms_max, now)

        # stall: backlog with no retirement progress for stall_after_s
        completed = cur["completed"]
        if (self._last_progress is None
                or completed != self._last_progress[1] or pending == 0):
            self._last_progress = (now, completed)
        stalled_for = now - self._last_progress[0]
        self._transition(("stall", "service"),
                         pending > 0 and stalled_for > cfg.stall_after_s,
                         "liveness", stalled_for, cfg.stall_after_s, now)

        # model rules: per-class measured-vs-projected TEPS
        roofline = snap.get("roofline") or {}
        for ck, r in roofline.items():
            if (r["completed"] < cfg.min_completed
                    or r["projected_teps"] <= 0.0 or r["busy_s"] <= 0.0):
                continue
            eff = r["efficiency"]
            if cfg.roofline_floor is not None:
                self._transition(("roofline_floor", ck),
                                 eff < cfg.roofline_floor, "model",
                                 eff, cfg.roofline_floor, now)
            if cfg.drift_tol is not None:
                lo, hi = 1.0 / (1.0 + cfg.drift_tol), 1.0 + cfg.drift_tol
                self._transition(("perfmodel_drift", ck),
                                 eff < lo or eff > hi, "model",
                                 eff, cfg.drift_tol, now)

        reg = self._metrics()
        if reg is not None:
            with self._lock:
                n_active = len(self._active)
            reg.set_gauge("gravfm_alerts_active", n_active,
                          help="Currently firing watchdog alerts")
            reg.set_counter("gravfm_watchdog_evaluations_total",
                            self.evaluations)
        return self.active_alerts()
