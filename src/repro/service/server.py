"""The graph query service: accept single queries, batch compatible
ones under their latency deadlines, dispatch to cached compiled plans,
return per-query :class:`EngineResult`\\ s.

Two scheduling policies share the admission/plan/stats machinery
(``scheduling=`` constructor arg):

  bucketed   — form a batch, run its whole superstep loop to
      completion, return to the queue (batching.py). Simple, maximal
      sharing, but every member pays the slowest member's depth.

  continuous — a fixed-width slot array per class steps one superstep
      at a time; finished queries retire mid-flight and new arrivals
      splice into freed slots between supersteps (continuous.py, built
      on the engines' step-granular SuperstepProgram). Short queries
      stop paying long-query latency.

Two operating modes as well:

  synchronous — ``submit()`` queues and returns a Future; dispatch
      happens when a batch fills, when ``poll()`` observes a due
      deadline (or pumps a superstep), or on ``flush()``.
      Deterministic; what the tests and benchmarks drive.

  async — ``start()`` spawns a scheduler thread that sleeps until the
      earliest pending flush time (or a new arrival) and dispatches due
      batches / pumps in-flight supersteps; ``submit()`` then behaves
      like a fire-and-forget RPC whose Future resolves within the
      request's deadline budget.

On top of both sit a bounded-LRU **result cache** (identical
(graph, version, kernel, mode, query kwargs) hits resolve without
touching the scheduler) and optional **admission control** (requests
whose deadline is already infeasible given the backlog and the class's
observed per-superstep cost fail fast with :class:`AdmissionError`).

Multi-tenant serving (PR 3) adds the :class:`~repro.store.GraphStore`
underneath: graphs are **versioned** (``publish`` swaps in version N+1
atomically — in-flight queries drain on N, new arrivals bind N+1) and
**memory-budgeted** (LRU eviction of unpinned graphs when
``memory_budget`` — or ``platform.m_board`` — is exceeded, transparent
refault on next query). Per-tenant **quotas** (token-bucket admission)
and **fair-share weights** (weighted slots in the continuous scheduler)
are configured with :meth:`set_tenant`.

Budget evictions **spill to host** by default (PR 4): the evicted
layout's arrays are demoted to host copies and the version keeps its
compiled plans, so a refault is a device re-upload — no re-partition,
zero re-traces. ``spill_budget`` caps the host tier (0 restores the
discard-on-evict behavior), and faults **materialize outside the store
lock**, so one tenant's cold fault cannot head-of-line-block another
tenant's submits. ``store_spills`` / ``store_spilled_bytes`` /
``store_discards`` / ``store_refault_upload_ms`` join the stats
endpoint.

Continuous lanes are **preemptible** (PR 5): admission is
deadline-priority (``QueryRequest.priority``, then aged deadlines, then
predicted depth — see continuous.py), and a tight-deadline arrival that
finds every slot busy parks the laxest active lane's carry on the host
(charged against the store's spill budget) and takes its slot; the
parked query is restored bit-identically when a slot frees, with
deadline aging guaranteeing it cannot starve. ``preemption=False``
restores the strictly run-to-retire behavior; ``aging_rate`` tunes the
starvation-protection clock. ``preemptions`` / ``parked_lanes`` /
``lane_restores`` / ``park_restore_ms`` / ``depth_pred_abs_err`` join
the stats endpoint.

The paper's engine answers one traversal per elaborated design; this
server is the ROADMAP's "heavy traffic" counterpart — many BFS/SSSP
roots per superstep loop, one broadcast per superstep shared by the
whole batch, and steady-state serving that never re-partitions or
re-traces (see plans.py).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from ..core import perfmodel
from ..core.algorithms import ALGORITHMS
from ..core.engine import EngineResult
from ..core.graph import Graph
from ..store import GraphStore, StoreError, TenantRegistry
from .batching import (BATCH_BUCKETS, AdmissionError, Batcher, QueryClass,
                       QueryRequest, bucket_for)
from .continuous import ContinuousScheduler, class_key
from .metrics import (MetricsRegistry, Watchdog, WatchdogConfig,
                      feed_service_snapshot)
from .plans import PlanCache, PlanKey
from .stats import ServiceStats
from .trace import TraceBus

__all__ = ["GraphQueryService"]


class GraphQueryService:
    """Batched multi-query front-end over the GraVF-M engine."""

    def __init__(self, *, num_shards: int = 4, max_batch: int = 32,
                 backend: str = "ref", partition_method: str = "greedy",
                 exchange: str = "",
                 overlap: bool = False,
                 root_depth_buckets: bool = True,
                 slack_ms: float = 5.0,
                 scheduling: str = "bucketed",
                 slots: Optional[int] = None,
                 max_supersteps: Optional[int] = None,
                 result_cache_size: int = 256,
                 admission_control: bool = False,
                 preemption: bool = True,
                 aging_rate: float = 4.0,
                 preempt_margin_s: float = 0.05,
                 depth_bucket_s: float = 0.1,
                 memory_budget: Optional[float] = None,
                 spill_budget: Optional[float] = None,
                 platform=None,
                 versioned: bool = True,
                 store: Optional[GraphStore] = None,
                 tenants: Optional[TenantRegistry] = None,
                 plan_cache: Optional[PlanCache] = None,
                 stats: Optional[ServiceStats] = None,
                 tracing: bool = True,
                 trace_capacity: int = 65536,
                 roofline_platform=None,
                 metrics: bool = True,
                 watchdog: bool = False,
                 watchdog_config: Optional[WatchdogConfig] = None,
                 profile_phases: bool = False):
        assert scheduling in ("bucketed", "continuous")
        self.num_shards = num_shards
        self.max_batch = max_batch
        self.backend = backend
        self.partition_method = partition_method
        # default shard exchange schedule: "" serves via the single-host
        # Engine; "allgather"/"ring"/"frontier"/"unicast"/"combined"
        # serve via a num_shards-device ShardEngine. A request's
        # ``exchange`` field overrides per query class.
        self.exchange = exchange
        # default exchange pipelining: overlap the exchange collective
        # with local scatter/combine (bit-identical; shard classes
        # only). A request's ``overlap`` field opts in per query; both
        # schedules of a class share one engine, so mixing them serves
        # from the same device-resident graph with zero steady-state
        # re-traces.
        self.overlap = bool(overlap and exchange)
        # per-root depth prediction: bucket the depth EWMA by the
        # root's out-degree decile ("d0".."d9") so depth packing and
        # victim selection see root-conditioned estimates
        self.root_depth_buckets = root_depth_buckets
        self._degree_deciles: Dict[Any, Any] = {}  # (gid, ver) -> (deg, cuts)
        self.scheduling = scheduling
        self.max_supersteps = max_supersteps
        self.result_cache_size = result_cache_size
        self.admission_control = admission_control
        self.stats = stats or (plan_cache.stats if plan_cache
                               else ServiceStats())
        # Lifecycle event bus. Always constructed (so dump_trace/
        # trace_snapshot exist either way); tracing=False leaves it
        # disabled and every emit is one attribute read.
        self.trace = TraceBus(capacity=trace_capacity, enabled=tracing)
        # Aggregate metrics registry (same always-constructed contract):
        # a pull-time collector maps stats_snapshot() onto counters/
        # gauges at scrape, so serving pays nothing per query.
        self.metrics = MetricsRegistry(enabled=metrics)
        self.metrics.add_collector(self._collect_metrics)
        self.profile_phases = profile_phases
        self._watchdog: Optional[Watchdog] = None
        self._watchdog_on = watchdog
        self._watchdog_config = watchdog_config
        if plan_cache is not None:
            # the cache brings its own store; silently dropping these
            # would leave an operator believing residency is capped
            if (store is not None or memory_budget is not None
                    or spill_budget is not None
                    or platform is not None or not versioned):
                raise ValueError(
                    "plan_cache and store/memory_budget/spill_budget/"
                    "platform/versioned are mutually exclusive — "
                    "configure the GraphStore the PlanCache was built "
                    "with instead")
            self.plans = plan_cache
        else:
            store = store or GraphStore(
                budget_bytes=memory_budget, platform=platform,
                versioned=versioned, num_shards=num_shards,
                method=partition_method,
                spill_budget_bytes=spill_budget)
            self.plans = PlanCache(stats=self.stats, store=store)
        # One shared counter object, or the cache-level hits/misses/traces
        # split off from the endpoint and under-report.
        self.plans.stats = self.stats
        self.store: GraphStore = self.plans.store
        self.tenants = tenants or TenantRegistry()
        self._batcher = Batcher(max_batch=max_batch, slack_ms=slack_ms)
        self._slots = slots or max_batch
        self._continuous: Optional[ContinuousScheduler] = None
        if scheduling == "continuous":
            self._continuous = ContinuousScheduler(
                slots=self._slots, max_supersteps=max_supersteps,
                stats=self.stats, get_stepper=self._stepper_for,
                on_result=self._store_result,
                tenant_weight=self.tenants.weight,
                acquire=self._acquire_class,
                preemption=preemption, aging_rate=aging_rate,
                preempt_margin_s=preempt_margin_s,
                depth_bucket_s=depth_bucket_s,
                park_charge=self.store.reserve_parked,
                park_release=self.store.release_parked,
                depth_bucket_of=self._depth_bucket_of,
                trace=self.trace, metrics=self.metrics,
                profile=profile_phases)
        # Result cache PARTITIONED BY TENANT: each tenant gets its own
        # bounded LRU of ``result_cache_size`` entries, so one tenant's
        # burst of novel queries cannot evict another tenant's hot
        # results. The partition COUNT is itself LRU-bounded — tenant
        # is a free-form request field, and without the cap a stream of
        # distinct tenant names would grow the cache without limit.
        self._result_cache: \
            "collections.OrderedDict[str, collections.OrderedDict]" = \
            collections.OrderedDict()
        self._rc_max_tenants = 64
        # Leaf lock: _store_result is called from the scheduler thread
        # while it holds the continuous scheduler's lock, so the cache
        # must never share the service lock (ABBA deadlock with submit).
        self._rc_lock = threading.Lock()  # lock: rcache
        # superseded versions' cached results can never match a lookup
        # again (new arrivals bind the new version) — purge them instead
        # of letting dead entries squeeze live ones out of the LRU
        self.store.add_evict_listener(self._purge_stale_results)
        # residency transitions land on the same bus as query lifecycle
        # events, so a trace shows "this query's restore stalled on that
        # graph's refault" on one timeline
        self.store.set_trace(self.trace)
        # roofline telemetry: class key -> the §5 performance model's
        # projected TEPS (T_sys). The projector runs outside the stats
        # lock and is cached per class (limits() is pure arithmetic but
        # host_graph takes the store lock).
        self._class_meta: Dict[str, QueryClass] = {}
        self._limits_cache: \
            Dict[str, Optional[Dict[str, float]]] = {}
        self._roofline_platform = (roofline_platform or platform
                                   or perfmodel.PAPER_PLATFORM)
        self.stats.set_roofline_projector(self._project_teps)
        self._lock = threading.RLock()  # lock: server
        self._wake = threading.Condition(self._lock)  # lock: server
        # Serializes plan lookup + execution: PlanCache is not internally
        # locked (its contract is "callers serialize dispatch"), and a
        # full-batch submit() can race the scheduler thread's poll().
        self._dispatch_lock = threading.Lock()  # lock: dispatch
        self._thread: Optional[threading.Thread] = None
        self._running = False

    # ---------------- admission ---------------------------------------
    def add_graph(self, graph_id: str, graph: Graph,
                  **kwargs) -> "GraphQueryService":
        """Register + partition a graph for serving. Idempotent for
        identical content; different content under an existing id is a
        **version publish** (new arrivals bind the new version while
        in-flight queries drain on the old one) — or, when the store was
        built with ``versioned=False``, a
        :class:`~repro.store.StoreError`."""
        self.publish(graph_id, graph, **kwargs)
        return self

    def publish(self, graph_id: str, graph: Graph, **kwargs) -> int:
        """Publish the next version of ``graph_id``; returns the version
        number now served to new arrivals."""
        kwargs.setdefault("num_shards", self.num_shards)
        kwargs.setdefault("method", self.partition_method)
        return self.store.publish(graph_id, graph, **kwargs)

    def set_tenant(self, name: str, *, weight: float = 1.0,
                   rate_qps: Optional[float] = None,
                   burst: Optional[float] = None) -> "GraphQueryService":
        """Configure one tenant's fair-share ``weight`` and optional
        token-bucket quota (``rate_qps`` sustained, ``burst`` headroom).
        Unconfigured tenants serve at weight 1.0, unlimited."""
        self.tenants.configure(name, weight=weight, rate_qps=rate_qps,
                               burst=burst)
        return self

    def warm(self, graph_id: str, kernel: str, *, mode: str = "gravfm",
             batch_sizes: Optional[List[int]] = None,
             exchange: Optional[str] = None,
             overlap: Optional[bool] = None) -> None:
        """Pre-trace plans for a query class so first requests don't pay
        compile latency (steady-state serving then re-traces nothing).
        Defaults to EVERY bucket up to max_batch — deadline flushes
        dispatch partial batches, so intermediate buckets are hot paths
        too. ``overlap`` warms that exchange schedule (default: the
        service's); warm both to serve per-request toggling re-trace
        free."""
        version = self.store.known_version(graph_id)
        exchange = self.exchange if exchange is None else exchange
        overlap = bool((self.overlap if overlap is None else overlap)
                       and exchange)
        kern = ALGORITHMS[kernel]() if kernel in ALGORITHMS else None
        if (self._continuous is not None and kern is not None
                and kern.query_params):
            # continuous serving compiles exactly one slot-width stepper
            # per class; pre-trace its init/admit/step/probe programs
            splan = self._stepper_for(QueryClass(
                graph_id, kernel, mode, self.num_shards, self.backend,
                version, exchange, overlap))
            qkw = {p: np.zeros((self._slots,), np.int32)
                   for p in splan.query_params}
            # profiled serving dispatches the phase programs instead of
            # the fused step — warm whichever path will actually run
            splan.stepper.profile = self.profile_phases
            carry, _, _ = splan.stepper.init(qkw)
            carry, _, _ = splan.stepper.admit(
                carry, qkw, np.zeros(self._slots, bool))
            carry, _, _ = splan.stepper.step(
                carry, np.zeros(self._slots, bool))
            # pre-trace the preemption verbs too: parking and restoring
            # lanes is then also a zero-re-trace steady-state operation
            ckpt = splan.stepper.fetch_lane(carry, 0)
            splan.stepper.restore(carry, ckpt,
                                  np.zeros(self._slots, bool))
            self.plans.sync_trace_counters()
            return
        if batch_sizes is None:
            sizes = sorted({bucket_for(n, self.max_batch)
                            for n in BATCH_BUCKETS if n <= self.max_batch}
                           | {1, self.max_batch})
        else:
            sizes = batch_sizes
        for b in sizes:
            self.plans.get_plan(
                self._plan_key(graph_id, kernel, mode, b, version,
                               exchange=exchange, overlap=overlap),
                method=self.partition_method, warm=True)
        self.plans.sync_trace_counters()

    def submit(self, req: QueryRequest) -> "Future[EngineResult]":
        """Queue one query; the Future resolves to its EngineResult."""
        return self._submit(req)[0]

    def _submit(self, req: QueryRequest):
        """submit() plus the QueryClass the request actually bound —
        callers that later flush/drain this specific request must use
        the returned class, not re-resolve the version (a concurrent
        publish would point them at a class the request isn't in)."""
        kernel = ALGORITHMS.get(req.kernel)
        if kernel is None:
            raise KeyError(f"unknown kernel {req.kernel!r}")
        kernel = kernel()
        # Exact-match validation: a missing param would make the outcome
        # traffic-dependent (kernel default when dispatched solo, KeyError
        # when co-batched), so require the full declared set up front.
        got, want = set(req.query_kwargs), set(kernel.query_params)
        if got != want:
            raise ValueError(
                f"{req.kernel} takes query params "
                f"{tuple(kernel.query_params)}; got "
                f"{sorted(got) or 'none'}"
                + (f" (missing {sorted(want - got)})" if want - got else ""))
        fut: "Future[EngineResult]" = Future()
        # New arrivals bind the latest published version; anything
        # already queued/in flight keeps draining on its bound version.
        version = self.store.known_version(req.graph_id)
        qclass = QueryClass.of(req, self.num_shards, self.backend, version,
                               exchange=self.exchange, overlap=self.overlap)
        batchable = (bool(kernel.query_params) and self.max_batch > 1)
        self.stats.record_submit()
        self.stats.record_tenant(req.tenant, submitted=1)
        self.trace.emit("submit", qid=req.qid, tenant=req.tenant,
                        klass=class_key(qclass),
                        deadline_ms=req.deadline_ms, kernel=req.kernel,
                        ts=req.arrival_s)
        # Result cache: an identical completed query resolves right here,
        # without touching either scheduler (and without charging the
        # tenant's token bucket — a hit consumes no engine resources).
        cached = self._lookup_result(req, version)
        if cached is not None:
            if fut.set_running_or_notify_cancel():
                fut.set_result(cached)
            latency_ms = (time.perf_counter() - req.arrival_s) * 1e3
            self.stats.record_result_hit(latency_ms)
            self.stats.record_tenant(req.tenant, completed=1,
                                     result_hits=1,
                                     latency_ms=latency_ms)
            self.trace.emit("retire", qid=req.qid, tenant=req.tenant,
                            klass=class_key(qclass), reason="cache")
            return fut, qclass
        # Per-tenant quota: shed when the tenant's token bucket is dry.
        if not self.tenants.admit(req.tenant):
            self.stats.record_shed()
            self.stats.record_tenant(req.tenant, shed=1)
            self.trace.emit("shed", qid=req.qid, tenant=req.tenant,
                            klass=class_key(qclass), reason="quota")
            fut.set_exception(AdmissionError(
                f"tenant {req.tenant!r} exceeded its rate quota "
                f"({self.tenants.policy(req.tenant).rate_qps} qps)"))
            return fut, qclass
        # Admission control: shed what cannot meet its deadline anyway.
        if self._should_shed(req, qclass):
            self.stats.record_shed()
            self.stats.record_tenant(req.tenant, shed=1)
            self.trace.emit("shed", qid=req.qid, tenant=req.tenant,
                            klass=class_key(qclass), reason="deadline")
            fut.set_exception(AdmissionError(
                f"deadline {req.deadline_ms:.1f}ms infeasible for "
                f"{class_key(qclass)} given current backlog"))
            return fut, qclass
        # The request now holds its OWN pin from enqueue to resolution
        # (the done-callback): without it a queued-but-undispatched
        # bucketed request leaves its version unpinned, and a publish()
        # in that window would retire the version out from under the
        # batch it is waiting in. Acquired only HERE — after the
        # cache-hit/quota/deadline-shed early exits — so requests that
        # never reach the engine cannot fault evicted graphs back in or
        # budget-sweep other tenants' residents.
        lease = None
        if version:
            lease = self.store.acquire(req.graph_id)
            if lease.version != version:    # publish raced the checks
                version = lease.version
                qclass = QueryClass.of(req, self.num_shards, self.backend,
                                       version, exchange=self.exchange,
                                       overlap=self.overlap)
            fut.add_done_callback(lambda _f: lease.release())
        # the class's graph/kernel/mode are now final (the lease rebind
        # above may have bumped the version) — remember them so the
        # roofline projector can resolve this class key to a workload
        self._class_meta.setdefault(class_key(qclass), qclass)
        try:
            if self._continuous is not None and batchable:
                # enqueue OUTSIDE the service lock: the scheduler thread
                # takes the scheduler lock first (pump), so nesting it
                # under self._wake here would invert the lock order
                self._continuous.submit(qclass, req, fut)
                with self._wake:
                    self._wake.notify()
                return fut, qclass
            with self._wake:
                ready = self._batcher.add(qclass, (req, fut), batchable)
                self._wake.notify()
            self.trace.emit("queue", qid=req.qid, tenant=req.tenant,
                            klass=class_key(qclass))
            if ready is not None:
                self._dispatch(*ready)
            return fut, qclass
        except BaseException:
            # the Future will never resolve, so its done-callback will
            # never fire — release the pin here or it leaks forever
            if lease is not None:
                lease.release()
            raise

    # ---------------- result cache / admission control ----------------
    def _purge_stale_results(self, graph_id: str, version: int) -> None:
        """Store-discard listener (fires under the store lock; spills
        never reach here). A spill-overflow discard keeps the version
        valid — a later cold fault is bit-identical, so its cached
        results stay. Only a SUPERSEDED version's entries are dead
        weight."""
        known = self.store.known_version(graph_id)
        if known and version >= known:
            return      # budget eviction of the live version: still valid
        with self._rc_lock:
            for part in self._result_cache.values():
                for k in [k for k in part
                          if k[0] == graph_id and k[1] == version]:
                    del part[k]

    def _result_key(self, req: QueryRequest, version: int):
        try:
            kw = tuple(sorted((k, np.asarray(v).item())
                              for k, v in req.query_kwargs.items()))
        except (TypeError, ValueError):
            return None    # non-scalar / unhashable kwargs: don't cache
        # version in the key: results computed on graph version N must
        # never answer queries bound to N+1
        return (req.graph_id, version, req.kernel, req.mode, kw)

    @staticmethod
    def _copy_result(res: EngineResult) -> EngineResult:
        """Defensive copy: cached entries and cache hits must not alias
        a caller's (mutable numpy) state arrays — a client editing its
        result in place would otherwise poison every later hit."""
        return EngineResult(
            state={k: np.array(v) for k, v in res.state.items()},
            supersteps=res.supersteps,
            messages=res.messages,
            comm=dict(res.comm),
            raw_state=jax.tree.map(np.array, res.raw_state),
        )

    def _lookup_result(self, req: QueryRequest,
                       version: int) -> Optional[EngineResult]:
        """Per-tenant partition lookup: a hit only ever comes from the
        requesting tenant's own LRU, so partitions are also an isolation
        boundary (tenant A can never observe whether tenant B ran a
        query)."""
        if self.result_cache_size <= 0:
            return None
        key = self._result_key(req, version)
        if key is None:
            return None
        with self._rc_lock:
            part = self._result_cache.get(req.tenant)
            res = part.get(key) if part is not None else None
            if res is not None:
                part.move_to_end(key)
                self._result_cache.move_to_end(req.tenant)
        return self._copy_result(res) if res is not None else None

    def _store_result(self, req: QueryRequest, res: EngineResult,
                      version: int = 0) -> None:
        if self.result_cache_size <= 0:
            return
        key = self._result_key(req, version)
        if key is None:
            return
        res = self._copy_result(res)
        with self._rc_lock:
            part = self._result_cache.get(req.tenant)
            if part is None:
                part = self._result_cache[req.tenant] = \
                    collections.OrderedDict()
                while len(self._result_cache) > self._rc_max_tenants:
                    self._result_cache.popitem(last=False)
            part[key] = res
            part.move_to_end(key)
            self._result_cache.move_to_end(req.tenant)
            # each tenant's partition is bounded independently — one
            # tenant filling its own LRU evicts only its own entries
            while len(part) > self.result_cache_size:
                part.popitem(last=False)

    def _should_shed(self, req: QueryRequest, qclass: QueryClass) -> bool:
        """Deadline-infeasibility test from the class's observed cost
        model (EWMA superstep wall time × EWMA depth × backlog waves).
        Conservative by construction: sheds nothing until both EWMAs
        have been observed."""
        if not self.admission_control:
            return False
        step_ms, depth = self.stats.class_cost_model(class_key(qclass))
        if step_ms is None or depth is None:
            return False
        if self._continuous is not None:
            backlog = self._continuous.backlog(qclass)
            width = self._slots
        else:
            with self._wake:
                backlog = self._batcher.pending_in_class(qclass)
            width = self.max_batch
        waves = 1 + backlog // max(width, 1)
        est_ms = step_ms * depth * waves
        return time.perf_counter() + est_ms / 1e3 > req.deadline_s

    def _depth_bucket_of(self, qclass: QueryClass,
                         req: QueryRequest) -> Optional[str]:
        """Root-degree-decile label ("d0".."d9") for per-root depth
        prediction: the query root's out-degree decile within its graph
        version. High-degree roots reach the frontier's bulk in fewer
        supersteps than leaf roots, so conditioning the depth EWMA on
        the decile sharpens both depth packing and victim selection.
        None (class-wide EWMA) for kernels without a root, unknown
        graphs, or when disabled. Called under the scheduler lock;
        host_graph takes the store lock below it (the declared
        scheduler -> store order)."""
        if not self.root_depth_buckets:
            return None
        root = req.query_kwargs.get("root")
        if root is None:
            return None
        key = (qclass.graph_id, qclass.version)
        entry = self._degree_deciles.get(key)
        if entry is None:
            try:
                g = self.store.host_graph(qclass.graph_id,
                                          qclass.version or None)
            except (StoreError, KeyError, ValueError):
                return None
            deg = g.out_degrees()
            # decile cut points over the degree distribution; a vertex's
            # bucket is how many cuts its degree exceeds
            cuts = np.quantile(deg, np.arange(1, 10) / 10.0)
            # bounded: superseded versions' tables are dead weight
            while len(self._degree_deciles) >= 64:
                self._degree_deciles.pop(next(iter(self._degree_deciles)))
            entry = self._degree_deciles[key] = (deg, cuts)
        deg, cuts = entry
        try:
            r = int(np.asarray(root).item())
        except (TypeError, ValueError):
            return None
        if not 0 <= r < deg.shape[0]:
            return None
        return f"d{int(np.searchsorted(cuts, deg[r], side='right'))}"

    def _acquire_class(self, qclass: QueryClass):
        """Pin ``qclass``'s graph version for the continuous scheduler —
        held from the class's first submit until its last lane retires.
        Unregistered graphs (version 0) carry no pin; the plan lookup
        raises for them instead."""
        if not qclass.version:
            return None
        return self.store.acquire(qclass.graph_id, qclass.version)

    def _stepper_for(self, qclass: QueryClass):
        with self._dispatch_lock:
            return self.plans.get_stepper(
                self._plan_key(qclass.graph_id, qclass.kernel, qclass.mode,
                               self._slots, qclass.version,
                               exchange=qclass.exchange,
                               overlap=getattr(qclass, "overlap", False)),
                method=self.partition_method)

    # ---------------- roofline projection ------------------------------
    def _project_limits(self, ck: str) -> Optional[Dict[str, float]]:
        """The §5 performance model's full ``limits()`` dict for one
        class key (L_PE/L_mem/L_if/L_net/T_sys on the class's graph
        workload at this service's shard count), cached per class. None
        when the graph is gone (superseded and drained) or the kernel
        has no algo profile to extrapolate from."""
        if ck in self._limits_cache:
            return self._limits_cache[ck]
        qclass = self._class_meta.get(ck)
        lim: Optional[Dict[str, float]] = None
        if qclass is not None:
            try:
                g = self.store.host_graph(qclass.graph_id,
                                          qclass.version or None)
                wl = perfmodel.Workload(num_vertices=g.num_vertices,
                                        num_edges=g.num_edges)
                algo = perfmodel.PAPER_ALGOS.get(qclass.kernel)
                if algo is None:
                    # unprofiled kernel: bfs's per-edge/-vertex op counts
                    # are the closest stand-in for a traversal kernel
                    algo = dataclasses.replace(
                        perfmodel.PAPER_ALGOS["bfs"], name=qclass.kernel)
                lim = perfmodel.limits(
                    self._roofline_platform, algo, wl,
                    n_nodes=self.num_shards,
                    mode=qclass.mode,
                    exchange=qclass.exchange or None)
                # overlapped-pipeline terms ride along: T_overlap is
                # the ceiling the pipelined schedule serves against,
                # T_serial the synchronous schedule's realistic limit
                lim = {**lim, **perfmodel.overlapped_limits(lim)}
            except (StoreError, KeyError, ValueError):
                lim = None
        self._limits_cache[ck] = lim
        return lim

    def projected_limits(self, ck: str) -> Optional[Dict[str, float]]:
        """Public per-term model projection for one class key; combine
        with :func:`~repro.core.perfmodel.phase_projection` to set a
        profiled phase split against the model term by term."""
        return self._project_limits(ck)

    def _project_teps(self, ck: str) -> Optional[float]:
        """Projected TEPS (``T_sys``) for one class key — what the
        stats roofline efficiency divides by. None when no projection
        exists; the efficiency metric then reports 0.0 rather than a
        made-up ratio."""
        lim = self._project_limits(ck)
        return float(lim["T_sys"]) if lim is not None else None

    # ---------------- trace export -------------------------------------
    def trace_snapshot(self):
        """Retained lifecycle events (``TraceEvent`` list, emission
        order); ``self.trace.spans()`` assembles them per query."""
        return self.trace.snapshot()

    def dump_trace(self, path: str) -> str:
        """Export the retained events as Chrome trace-event JSON —
        load the file in ``chrome://tracing`` or
        https://ui.perfetto.dev. Returns ``path``."""
        return self.trace.dump(path)

    def query(self, graph_id: str, kernel: str, *, mode: str = "gravfm",
              deadline_ms: float = 50.0, tenant: str = "default",
              **query_kwargs) -> EngineResult:
        """Synchronous convenience: submit one query and wait (flushing
        immediately, so latency = execution time)."""
        req = QueryRequest(
            graph_id=graph_id, kernel=kernel, query_kwargs=query_kwargs,
            mode=mode, deadline_ms=deadline_ms, tenant=tenant)
        # flush only this query's class — other clients' half-filled
        # batches keep accumulating toward their own deadlines. The
        # class comes from _submit, not a fresh version lookup: a
        # publish racing this call must not point the flush at a class
        # the request isn't queued in.
        fut, qclass = self._submit(req)
        self.flush(qclass)
        return fut.result()

    # ---------------- dispatch ----------------------------------------
    def _plan_key(self, graph_id: str, kernel: str, mode: str,
                  batch_size: int, version: int = 0,
                  exchange: Optional[str] = None,
                  overlap: Optional[bool] = None) -> PlanKey:
        ex = self.exchange if exchange is None else exchange
        ov = self.overlap if overlap is None else overlap
        return PlanKey(graph_id=graph_id, kernel=kernel, mode=mode,
                       num_shards=self.num_shards, batch_size=batch_size,
                       backend=self.backend, version=version,
                       exchange=ex, overlap=bool(ov and ex))

    def _dispatch(self, qclass: QueryClass, items: List[Any]) -> None:
        """Execute one formed batch: pad to the plan bucket, run, resolve
        futures, account stats."""
        # Transition every future to RUNNING; ones the client cancelled
        # while queued drop out here (and can no longer be cancelled, so
        # set_result below cannot raise InvalidStateError).
        live = [(r, f) for r, f in items if f.set_running_or_notify_cancel()]
        if not live:
            return
        reqs = [it[0] for it in live]
        futs = [it[1] for it in live]
        n = len(reqs)
        t0 = time.perf_counter()
        with self._dispatch_lock:
            self._dispatch_locked(qclass, reqs, futs, n, t0)

    def _dispatch_locked(self, qclass: QueryClass, reqs, futs, n: int,
                         t0: float) -> None:
        ck = class_key(qclass)
        for r in reqs:
            self.trace.emit("admit", qid=r.qid, tenant=r.tenant,
                            klass=ck, reason="batch", ts=t0,
                            batch_size=n)
            # submit->dispatch wait (the SLO watchdog's queue_wait_p95
            # rule; the continuous path records at lane admission)
            self.stats.record_queue_wait((t0 - r.arrival_s) * 1e3)
        traces_before = self.plans.sync_trace_counters()
        lease = None
        try:
            if qclass.version:
                # pin the graph version for the whole batch: the store
                # may not evict it mid-execution (faults it back in
                # first if it was evicted since registration)
                lease = self.store.acquire(qclass.graph_id, qclass.version)
            plan = self.plans.get_plan(
                self._plan_key(qclass.graph_id, qclass.kernel, qclass.mode,
                               bucket_for(n, self.max_batch),
                               qclass.version, exchange=qclass.exchange),
                method=self.partition_method)
            bucket = plan.key.batch_size
            cap = self.max_supersteps
            if bucket == 1:
                results = []
                for r in reqs:
                    results.extend(plan.execute(cap, **{
                        k: np.asarray(v) for k, v in r.query_kwargs.items()}))
            else:
                arrays = {}
                for p in plan.query_params:
                    col = [r.query_kwargs[p] for r in reqs]
                    col += [col[0]] * (bucket - n)   # pad lanes
                    arrays[p] = np.asarray(col)
                results = plan.execute(cap, **arrays)[:n]
        except Exception as exc:   # noqa: BLE001 — fail the whole batch
            for r, f in zip(reqs, futs):
                f.set_exception(exc)
                self.trace.emit("retire", qid=r.qid, tenant=r.tenant,
                                klass=ck, reason="error",
                                error=type(exc).__name__)
            return
        finally:
            if lease is not None:
                lease.release()
        now = time.perf_counter()
        wall = now - t0
        for f, res in zip(futs, results):
            f.set_result(res)
        traces_after = self.plans.sync_trace_counters()
        compiled = traces_after != traces_before
        self.stats.record_batch(
            n_queries=n, n_pad=max(0, bucket - n) if bucket > 1 else 0,
            # a traced dispatch's wall is compile-dominated: account it
            # to compile_time_s so busy_time_s (the qps_busy/TEPS
            # denominator) stays execution-only, matching the
            # continuous pump's accounting
            wall_s=0.0 if compiled else wall,
            messages=sum(r.messages for r in results),
            supersteps=max((r.supersteps for r in results), default=0),
            latencies_ms=[(now - r.arrival_s) * 1e3 for r in reqs],
            class_key=ck,
            wire_words=sum(float(r.comm.get("wire_words", 0.0))
                           for r in results))
        if compiled:
            self.stats.record_compile(wall)
        # feed the admission-control cost model + the result cache;
        # dispatches that traced (compiled) are excluded from the cost
        # model — a compile wall would poison the EWMA and, with
        # admission control on, shed the class forever
        batch_depth = max((r.supersteps for r in results), default=0)
        if batch_depth > 0 and not compiled:
            self.stats.record_superstep_time(ck, wall, n_steps=batch_depth)
        for r, res in zip(reqs, results):
            self.stats.record_query_depth(ck, res.supersteps)
            slack_s = r.deadline_s - now
            missed = slack_s < 0
            if missed:
                self.stats.record_deadline_miss()
            self.stats.record_tenant(
                r.tenant, completed=1, messages=res.messages,
                latency_ms=(now - r.arrival_s) * 1e3,
                deadline_misses=1 if missed else 0)
            self.trace.emit(
                "retire", qid=r.qid, tenant=r.tenant, klass=ck,
                reason="retired", supersteps=int(res.supersteps),
                messages=int(res.messages),
                deadline_slack_s=(slack_s if np.isfinite(slack_s)
                                  else None),
                ts=now)
            self._store_result(r, res, qclass.version)

    # ---------------- scheduling --------------------------------------
    def poll(self, now_s: Optional[float] = None) -> int:
        """Make one unit of scheduler progress: dispatch every batch
        whose deadline-driven flush time has arrived, and (continuous
        scheduling) pump one superstep across the in-flight slot arrays.
        Returns batches dispatched + queries retired."""
        with self._wake:
            due = self._batcher.due(now_s)
        for qc, items in due:
            self._dispatch(qc, items)
        n = len(due)
        if self._continuous is not None:
            n += self._continuous.pump()
        return n

    def flush(self, qclass: Optional[QueryClass] = None) -> int:
        """Run pending work to completion regardless of deadlines — all
        of it, or only ``qclass``'s: dispatch queued batches, and drain
        the continuous slot arrays (pump until queued + in-flight
        queries of the scope all retire)."""
        with self._wake:
            if qclass is None:
                batches = self._batcher.flush_all()
            else:
                items = self._batcher.pop_class(qclass)
                batches = [(qclass, items)] if items else []
        for qc, items in batches:
            self._dispatch(qc, items)
        n = len(batches)
        if self._continuous is not None:
            n += self._continuous.drain(qclass)
        return n

    def pending(self) -> int:
        with self._lock:
            n = len(self._batcher)
        if self._continuous is not None:
            n += self._continuous.pending()
        return n

    # ---------------- async scheduler thread --------------------------
    def start(self) -> "GraphQueryService":
        with self._lock:
            if self._running:
                return self
            self._running = True
            self._thread = threading.Thread(
                target=self._loop, name="gravfm-query-scheduler",
                daemon=True)
            self._thread.start()
        if self._watchdog_on:
            self.start_watchdog()
        return self

    def stop(self, drain: bool = True) -> None:
        self.stop_watchdog()
        with self._wake:
            self._running = False
            self._wake.notify()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None
        if drain:
            self.flush()

    def _loop(self) -> None:
        while True:
            with self._wake:
                if not self._running:
                    return
                busy = (self._continuous is not None
                        and self._continuous.has_work())
                nxt = self._batcher.next_flush_s()
                timeout = (None if nxt is None
                           else max(0.0, nxt - time.perf_counter()))
                # with in-flight continuous lanes, don't sleep — pump
                if not busy and (timeout is None or timeout > 0):
                    self._wake.wait(timeout=timeout)
                if not self._running:
                    return
            self.poll()

    # ---------------- stats endpoint ----------------------------------
    def stats_snapshot(self) -> Dict[str, Any]:
        """The service's /stats payload: throughput (qps, TEPS), latency
        percentiles, batch occupancy, plan-cache counters, graph-store
        residency (resident_bytes / evictions / faults), and the
        per-tenant breakdown."""
        # fold live engines' trace counters first: with the spill tier,
        # evictions no longer drop engines, so nothing else syncs
        # plan_traces on the continuous path
        self.plans.sync_trace_counters()
        snap: Dict[str, Any] = dict(self.stats.snapshot())
        snap["pending"] = self.pending()
        snap["scheduling"] = self.scheduling
        snap["parked_lanes"] = (self._continuous.parked()
                                if self._continuous is not None else 0)
        for k, v in self.store.snapshot().items():
            snap[f"store_{k}"] = v
        snap["tenants"] = self.stats.tenant_snapshot()
        snap["trace_events"] = self.trace.emitted
        snap["trace_dropped"] = self.trace.dropped
        return snap

    # ---------------- metrics endpoint ---------------------------------
    def _collect_metrics(self, reg: MetricsRegistry) -> None:
        """Pull-time feeder registered on :attr:`metrics`: maps the
        current stats snapshot (plus the per-term model limits for every
        live class) onto the registry. Runs outside the registry lock —
        stats_snapshot takes the stats/scheduler/store locks."""
        snap = self.stats_snapshot()
        feed_service_snapshot(
            reg, snap,
            store_counter_keys=type(self.store).METRIC_COUNTER_KEYS)
        for ck in (snap.get("roofline") or {}):
            lim = self._project_limits(ck)
            if lim is None:
                continue
            for term in ("L_PE", "L_mem", "L_if", "L_net", "T_sys",
                         "T_serial", "T_overlap"):
                if term not in lim or not np.isfinite(lim[term]):
                    continue
                reg.set_gauge(
                    "gravfm_model_limit_teps", float(lim[term]),
                    help="Perfmodel §5 limit terms (TEPS) per class",
                    **{"class": ck, "term": term})

    def metrics_snapshot(self) -> Dict[str, Any]:
        """JSON-able registry dump (collectors run first, so values are
        scrape-fresh)."""
        return self.metrics.snapshot()

    def metrics_text(self) -> str:
        """Prometheus text exposition of the registry — the scrape
        endpoint payload."""
        return self.metrics.expose_text()

    # ---------------- SLO watchdog -------------------------------------
    def start_watchdog(self, **overrides) -> Watchdog:
        """Start (or return) the background SLO watchdog; ``overrides``
        replace :class:`WatchdogConfig` fields for a fresh start."""
        if self._watchdog is None:
            self._watchdog = Watchdog(self, self._watchdog_config,
                                      **overrides)
            self._watchdog.start()
        return self._watchdog

    def stop_watchdog(self) -> None:
        if self._watchdog is not None:
            self._watchdog.stop()
            self._watchdog = None

    @property
    def watchdog(self) -> Optional[Watchdog]:
        return self._watchdog
