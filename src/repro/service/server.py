"""The graph query service: accept single queries, batch compatible
ones under their latency deadlines, dispatch to cached compiled plans,
return per-query :class:`EngineResult`\\ s.

Two operating modes share all the machinery:

  synchronous — ``submit()`` queues and returns a Future; dispatch
      happens when a batch fills, when ``poll()`` observes a due
      deadline, or on ``flush()``. Deterministic; what the tests and
      benchmarks drive.

  async — ``start()`` spawns a scheduler thread that sleeps until the
      earliest pending flush time (or a new arrival) and dispatches due
      batches; ``submit()`` then behaves like a fire-and-forget RPC whose
      Future resolves within the request's deadline budget.

The paper's engine answers one traversal per elaborated design; this
server is the ROADMAP's "heavy traffic" counterpart — many BFS/SSSP
roots per superstep loop, one broadcast per superstep shared by the
whole batch, and steady-state serving that never re-partitions or
re-traces (see plans.py).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Any, Dict, List, Optional

import numpy as np

from ..core.algorithms import ALGORITHMS
from ..core.engine import EngineResult
from ..core.graph import Graph
from .batching import (BATCH_BUCKETS, Batcher, QueryClass, QueryRequest,
                       bucket_for)
from .plans import PlanCache, PlanKey
from .stats import ServiceStats

__all__ = ["GraphQueryService"]


class GraphQueryService:
    """Batched multi-query front-end over the GraVF-M engine."""

    def __init__(self, *, num_shards: int = 4, max_batch: int = 32,
                 backend: str = "ref", partition_method: str = "greedy",
                 slack_ms: float = 5.0,
                 plan_cache: Optional[PlanCache] = None,
                 stats: Optional[ServiceStats] = None):
        self.num_shards = num_shards
        self.max_batch = max_batch
        self.backend = backend
        self.partition_method = partition_method
        self.stats = stats or (plan_cache.stats if plan_cache
                               else ServiceStats())
        self.plans = plan_cache or PlanCache(stats=self.stats)
        # One shared counter object, or the cache-level hits/misses/traces
        # split off from the endpoint and under-report.
        self.plans.stats = self.stats
        self._batcher = Batcher(max_batch=max_batch, slack_ms=slack_ms)
        self._lock = threading.RLock()
        self._wake = threading.Condition(self._lock)
        # Serializes plan lookup + execution: PlanCache is not internally
        # locked (its contract is "callers serialize dispatch"), and a
        # full-batch submit() can race the scheduler thread's poll().
        self._dispatch_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._running = False

    # ---------------- admission ---------------------------------------
    def add_graph(self, graph_id: str, graph: Graph,
                  **kwargs) -> "GraphQueryService":
        """Register + partition a graph for serving (idempotent)."""
        kwargs.setdefault("num_shards", self.num_shards)
        kwargs.setdefault("method", self.partition_method)
        self.plans.register_graph(graph_id, graph, **kwargs)
        return self

    def warm(self, graph_id: str, kernel: str, *, mode: str = "gravfm",
             batch_sizes: Optional[List[int]] = None) -> None:
        """Pre-trace plans for a query class so first requests don't pay
        compile latency (steady-state serving then re-traces nothing).
        Defaults to EVERY bucket up to max_batch — deadline flushes
        dispatch partial batches, so intermediate buckets are hot paths
        too."""
        if batch_sizes is None:
            sizes = sorted({bucket_for(n, self.max_batch)
                            for n in BATCH_BUCKETS if n <= self.max_batch}
                           | {1, self.max_batch})
        else:
            sizes = batch_sizes
        for b in sizes:
            self.plans.get_plan(self._plan_key(graph_id, kernel, mode, b),
                                method=self.partition_method, warm=True)
        self.plans.sync_trace_counters()

    def submit(self, req: QueryRequest) -> "Future[EngineResult]":
        """Queue one query; the Future resolves to its EngineResult."""
        kernel = ALGORITHMS.get(req.kernel)
        if kernel is None:
            raise KeyError(f"unknown kernel {req.kernel!r}")
        kernel = kernel()
        # Exact-match validation: a missing param would make the outcome
        # traffic-dependent (kernel default when dispatched solo, KeyError
        # when co-batched), so require the full declared set up front.
        got, want = set(req.query_kwargs), set(kernel.query_params)
        if got != want:
            raise ValueError(
                f"{req.kernel} takes query params "
                f"{tuple(kernel.query_params)}; got "
                f"{sorted(got) or 'none'}"
                + (f" (missing {sorted(want - got)})" if want - got else ""))
        fut: "Future[EngineResult]" = Future()
        qclass = QueryClass.of(req, self.num_shards, self.backend)
        batchable = (bool(kernel.query_params) and self.max_batch > 1)
        self.stats.record_submit()
        with self._wake:
            ready = self._batcher.add(qclass, (req, fut), batchable)
            self._wake.notify()
        if ready is not None:
            self._dispatch(*ready)
        return fut

    def query(self, graph_id: str, kernel: str, *, mode: str = "gravfm",
              deadline_ms: float = 50.0, **query_kwargs) -> EngineResult:
        """Synchronous convenience: submit one query and wait (flushing
        immediately, so latency = execution time)."""
        req = QueryRequest(
            graph_id=graph_id, kernel=kernel, query_kwargs=query_kwargs,
            mode=mode, deadline_ms=deadline_ms)
        fut = self.submit(req)
        # flush only this query's class — other clients' half-filled
        # batches keep accumulating toward their own deadlines
        self.flush(QueryClass.of(req, self.num_shards, self.backend))
        return fut.result()

    # ---------------- dispatch ----------------------------------------
    def _plan_key(self, graph_id: str, kernel: str, mode: str,
                  batch_size: int) -> PlanKey:
        return PlanKey(graph_id=graph_id, kernel=kernel, mode=mode,
                       num_shards=self.num_shards, batch_size=batch_size,
                       backend=self.backend)

    def _dispatch(self, qclass: QueryClass, items: List[Any]) -> None:
        """Execute one formed batch: pad to the plan bucket, run, resolve
        futures, account stats."""
        # Transition every future to RUNNING; ones the client cancelled
        # while queued drop out here (and can no longer be cancelled, so
        # set_result below cannot raise InvalidStateError).
        live = [(r, f) for r, f in items if f.set_running_or_notify_cancel()]
        if not live:
            return
        reqs = [it[0] for it in live]
        futs = [it[1] for it in live]
        n = len(reqs)
        t0 = time.perf_counter()
        with self._dispatch_lock:
            self._dispatch_locked(qclass, reqs, futs, n, t0)

    def _dispatch_locked(self, qclass: QueryClass, reqs, futs, n: int,
                         t0: float) -> None:
        try:
            plan = self.plans.get_plan(
                self._plan_key(qclass.graph_id, qclass.kernel, qclass.mode,
                               bucket_for(n, self.max_batch)),
                method=self.partition_method)
            bucket = plan.key.batch_size
            if bucket == 1:
                results = []
                for r in reqs:
                    results.extend(plan.execute(**{
                        k: np.asarray(v) for k, v in r.query_kwargs.items()}))
            else:
                arrays = {}
                for p in plan.query_params:
                    col = [r.query_kwargs[p] for r in reqs]
                    col += [col[0]] * (bucket - n)   # pad lanes
                    arrays[p] = np.asarray(col)
                results = plan.execute(**arrays)[:n]
        except Exception as exc:   # noqa: BLE001 — fail the whole batch
            for f in futs:
                f.set_exception(exc)
            return
        now = time.perf_counter()
        wall = now - t0
        for f, res in zip(futs, results):
            f.set_result(res)
        self.plans.sync_trace_counters()
        self.stats.record_batch(
            n_queries=n, n_pad=max(0, bucket - n) if bucket > 1 else 0,
            wall_s=wall,
            messages=sum(r.messages for r in results),
            supersteps=max((r.supersteps for r in results), default=0),
            latencies_ms=[(now - r.arrival_s) * 1e3 for r in reqs])

    # ---------------- scheduling --------------------------------------
    def poll(self, now_s: Optional[float] = None) -> int:
        """Dispatch every batch whose deadline-driven flush time has
        arrived; returns the number of batches dispatched."""
        with self._wake:
            due = self._batcher.due(now_s)
        for qc, items in due:
            self._dispatch(qc, items)
        return len(due)

    def flush(self, qclass: Optional[QueryClass] = None) -> int:
        """Dispatch pending batches regardless of deadlines — all of them,
        or only ``qclass``'s."""
        with self._wake:
            if qclass is None:
                batches = self._batcher.flush_all()
            else:
                items = self._batcher.pop_class(qclass)
                batches = [(qclass, items)] if items else []
        for qc, items in batches:
            self._dispatch(qc, items)
        return len(batches)

    def pending(self) -> int:
        with self._lock:
            return len(self._batcher)

    # ---------------- async scheduler thread --------------------------
    def start(self) -> "GraphQueryService":
        with self._lock:
            if self._running:
                return self
            self._running = True
            self._thread = threading.Thread(
                target=self._loop, name="gravfm-query-scheduler",
                daemon=True)
            self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        with self._wake:
            self._running = False
            self._wake.notify()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None
        if drain:
            self.flush()

    def _loop(self) -> None:
        while True:
            with self._wake:
                if not self._running:
                    return
                nxt = self._batcher.next_flush_s()
                timeout = (None if nxt is None
                           else max(0.0, nxt - time.perf_counter()))
                if timeout is None or timeout > 0:
                    self._wake.wait(timeout=timeout)
                if not self._running:
                    return
            self.poll()

    # ---------------- stats endpoint ----------------------------------
    def stats_snapshot(self) -> Dict[str, float]:
        """The service's /stats payload: throughput (qps, TEPS), latency
        percentiles, batch occupancy, and plan-cache counters."""
        snap = self.stats.snapshot()
        snap["pending"] = self.pending()
        return snap
