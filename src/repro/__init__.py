"""GraVF-M on TPU: distributed vertex-centric graph processing in JAX,
plus the production LM substrate for the assigned architecture pool.

Layout:
  core/     the paper's contribution (engine, partitioners, perf model)
  kernels/  Pallas edge-traversal kernels (+ jnp oracles)
  models/   assigned LM architectures
  configs/  --arch registry (10 archs x 4 shapes)
  train/    optimizer, loop, checkpointing, compression
  serve/    prefill/decode engine
  data/     deterministic synthetic pipeline
  launch/   mesh, multi-pod dry-run, train CLI
"""
