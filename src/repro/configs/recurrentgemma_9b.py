"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (kv=1, MQA)
d_ff=12288 vocab=256000 — RG-LRU + local attention, 1 attn : 2 recurrent
(arXiv:2402.19427). O(1) recurrent state + 2048-window attention ->
long_500k eligible."""
from ..models.lm import ArchCfg, LayerKind
from .common import reduce_cfg

_R = LayerKind(mixer="rglru", ffn="mlp")
_A = LayerKind(mixer="attn", ffn="mlp", window=2048)


def config() -> ArchCfg:
    return ArchCfg(
        name="recurrentgemma-9b", d_model=4096, n_heads=16, n_kv=1,
        head_dim=256, d_ff=12288, vocab=256000,
        block_pattern=(_R, _R, _A), repeats=12, tail=(_R, _R),
        lru_width=4096, act="gelu", norm_plus_one=True, embed_scale=True,
        tie_embeddings=True, long_context_ok=True)


def reduced() -> ArchCfg:
    return reduce_cfg(config())
