"""Assigned-architecture registry: ``--arch <id>`` resolves here.

10 LM-family architectures (each with full + reduced configs) plus the
paper's own graph workloads (graph_workloads.py)."""
from __future__ import annotations

from importlib import import_module
from typing import Dict

from .common import SHAPES, Shape, input_specs, shape_applicable

_MODULES = {
    "xlstm-350m": "xlstm_350m",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen3-4b": "qwen3_4b",
    "qwen2-72b": "qwen2_72b",
    "gemma3-27b": "gemma3_27b",
    "minitron-4b": "minitron_4b",
    "internvl2-76b": "internvl2_76b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "deepseek-v2-236b": "deepseek_v2_236b",
}

ARCH_IDS = tuple(_MODULES)


def get(name: str, *, reduced: bool = False):
    mod = import_module(f".{_MODULES[name]}", __package__)
    return mod.reduced() if reduced else mod.config()


def all_configs(reduced: bool = False) -> Dict[str, object]:
    return {n: get(n, reduced=reduced) for n in ARCH_IDS}
