"""seamless-m4t-medium [audio]: 12L d_model=1024 16H (kv=16) d_ff=4096
vocab=256206, enc-dec (arXiv:2308.11596). The audio frontend is a STUB:
input_specs provides precomputed frame embeddings; we build the
transformer backbone (12 enc + 12 dec)."""
from ..models.lm import ArchCfg, LayerKind
from .common import reduce_cfg

_A = LayerKind(mixer="attn", ffn="mlp")


def config() -> ArchCfg:
    return ArchCfg(
        name="seamless-m4t-medium", d_model=1024, n_heads=16, n_kv=16,
        head_dim=64, d_ff=4096, vocab=256206,
        block_pattern=(_A,), repeats=12,   # used by decoder; n_enc below
        family="encdec", n_enc=12, n_dec=12,
        act="gelu", tie_embeddings=True,
        # 256206 is not divisible by the 16-way TP degree; the table is
        # padded to 2048 (-> 258048) and padded ids are masked from the
        # softmax. The LOGICAL vocab stays 256206.
        vocab_pad_to=2048)


def reduced() -> ArchCfg:
    return reduce_cfg(config())
