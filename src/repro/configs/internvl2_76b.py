"""internvl2-76b [vlm]: 80L d_model=8192 64H (kv=8) d_ff=28672
vocab=128256 — InternViT + InternLM2 (arXiv:2404.16821). Per the
assignment the vision frontend is a STUB: input_specs provides 256
precomputed patch embeddings at d_model; we build the language backbone."""
from ..models.lm import ArchCfg, LayerKind
from .common import reduce_cfg


def config() -> ArchCfg:
    return ArchCfg(
        name="internvl2-76b", d_model=8192, n_heads=64, n_kv=8,
        head_dim=128, d_ff=28672, vocab=128256,
        block_pattern=(LayerKind(),), repeats=80,
        family="vlm", prefix_len=256, tie_embeddings=False)


def reduced() -> ArchCfg:
    return reduce_cfg(config())
