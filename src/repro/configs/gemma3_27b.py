"""gemma3-27b [dense]: 62L d_model=5376 32H (kv=16) d_ff=21504
vocab=262144, 5:1 local:global sliding-window pattern, 128k context
[hf:google/gemma-3 family]. Long-context eligible: 5/6 of layers are
1024-token local windows and decode is per-token linear."""
from ..models.lm import ArchCfg, LayerKind
from .common import reduce_cfg

_LOCAL = LayerKind(window=1024, rope_base=10_000.0)
_GLOBAL = LayerKind(rope_base=1_000_000.0)


def config() -> ArchCfg:
    return ArchCfg(
        name="gemma3-27b", d_model=5376, n_heads=32, n_kv=16, head_dim=128,
        d_ff=21504, vocab=262144,
        block_pattern=(_LOCAL,) * 5 + (_GLOBAL,), repeats=10,
        tail=(_LOCAL, _LOCAL),
        qk_norm=True, norm_plus_one=True, post_norms=True,
        embed_scale=True, act="gelu", tie_embeddings=True,
        long_context_ok=True)


def reduced() -> ArchCfg:
    return reduce_cfg(config())
