"""qwen2-72b [dense]: 80L d_model=8192 64H (kv=8) d_ff=29568
vocab=152064, GQA with QKV bias (arXiv:2407.10671)."""
from ..models.lm import ArchCfg, LayerKind
from .common import reduce_cfg


def config() -> ArchCfg:
    return ArchCfg(
        name="qwen2-72b", d_model=8192, n_heads=64, n_kv=8, head_dim=128,
        d_ff=29568, vocab=152064,
        block_pattern=(LayerKind(),), repeats=80,
        qkv_bias=True, tie_embeddings=False)


def reduced() -> ArchCfg:
    return reduce_cfg(config())
