"""qwen3-4b [dense]: 36L d_model=2560 32H (kv=8) d_ff=9728 vocab=151936,
qk_norm + GQA [hf:Qwen/Qwen3-8B family]."""
from ..models.lm import ArchCfg, LayerKind
from .common import reduce_cfg


def config() -> ArchCfg:
    return ArchCfg(
        name="qwen3-4b", d_model=2560, n_heads=32, n_kv=8, head_dim=128,
        d_ff=9728, vocab=151936,
        block_pattern=(LayerKind(),), repeats=36,
        qk_norm=True, tie_embeddings=True)


def reduced() -> ArchCfg:
    return reduce_cfg(config())
