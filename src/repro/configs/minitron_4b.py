"""minitron-4b [dense]: 32L d_model=3072 24H (kv=8) d_ff=9216
vocab=256000 — pruned nemotron (arXiv:2407.14679); squared-ReLU MLP,
no gating."""
from ..models.lm import ArchCfg, LayerKind
from .common import reduce_cfg


def config() -> ArchCfg:
    return ArchCfg(
        name="minitron-4b", d_model=3072, n_heads=24, n_kv=8, head_dim=128,
        d_ff=9216, vocab=256000,
        block_pattern=(LayerKind(),), repeats=32,
        act="relu2", tie_embeddings=False)


def reduced() -> ArchCfg:
    return reduce_cfg(config())
