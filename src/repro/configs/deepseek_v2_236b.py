"""deepseek-v2-236b [moe]: 60L d_model=5120 128H vocab=102400 — MLA
(kv_lora=512, decoupled RoPE 64) + fine-grained MoE: 160 routed experts
(d_ff=1536) top-6 + 2 shared (arXiv:2405.04434)."""
from ..models.lm import ArchCfg, LayerKind, MlaCfg, MoeCfg
from .common import reduce_cfg


def config() -> ArchCfg:
    return ArchCfg(
        name="deepseek-v2-236b", d_model=5120, n_heads=128, n_kv=128,
        head_dim=128, d_ff=1536, vocab=102400,
        block_pattern=(LayerKind(mixer="mla", ffn="moe"),), repeats=60,
        mla=MlaCfg(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64,
                   v_dim=128),
        moe=MoeCfg(n_routed=160, n_shared=2, topk=6, d_ff_expert=1536,
                   renormalize=True),
        tie_embeddings=False)


def reduced() -> ArchCfg:
    return reduce_cfg(config())
