"""Shared shape-set and input-spec machinery for the assigned
architectures.

Every LM-family arch is paired with the same four shapes:

  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> serve prefill
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token,
                                                 KV cache of seq_len)
  long_500k    seq 524,288 global_batch 1     -> serve_step; ONLY for
               sub-quadratic archs (cfg.long_context_ok) — skips recorded
               in DESIGN.md §Arch-applicability.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for the DATA inputs of each step; params
and caches get their own abstract builders in models/.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..models.lm import ArchCfg

__all__ = ["Shape", "SHAPES", "shape_applicable", "input_specs",
           "reduce_cfg"]


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}

ENC_LEN_CAP = 4_096  # encoder frame budget for enc-dec (seamless) shapes


def shape_applicable(cfg: ArchCfg, shape: Shape) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape.name == "long_500k" and not cfg.long_context_ok:
        return False, ("pure full-attention arch: 500k dense prefill is "
                       "quadratic; skipped per assignment rules "
                       "(DESIGN.md §8)")
    return True, ""


def input_specs(cfg: ArchCfg, shape: Shape) -> Dict[str, jax.ShapeDtypeStruct]:
    """Data inputs for the step function of (cfg, shape)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.family == "encdec":
        enc_len = min(S, ENC_LEN_CAP)
        if shape.kind == "train":
            return {
                "frames": jax.ShapeDtypeStruct((B, enc_len, cfg.d_model),
                                               jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        if shape.kind == "prefill":
            return {
                "frames": jax.ShapeDtypeStruct((B, enc_len, cfg.d_model),
                                               jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "pos": jax.ShapeDtypeStruct((), i32)}

    if cfg.family == "vlm" and shape.kind != "decode":
        n_text = S - cfg.prefix_len
        return {
            "patch_embeds": jax.ShapeDtypeStruct(
                (B, cfg.prefix_len, cfg.d_model), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((B, n_text), i32),
            **({"labels": jax.ShapeDtypeStruct((B, n_text), i32)}
               if shape.kind == "train" else {}),
        }

    if shape.kind == "train":
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32)}


# ---------------------------------------------------------------------------

def reduce_cfg(cfg: ArchCfg, **overrides) -> ArchCfg:
    """Same-family reduced config for CPU smoke tests: small widths, few
    layers/experts, tiny vocab. Pattern structure is preserved."""
    small: Dict = dict(
        d_model=64,
        n_heads=4,
        n_kv=min(cfg.n_kv, 2) if cfg.n_kv < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        repeats=min(cfg.repeats, 2),
        q_chunk=32,
        kv_chunk=32,
        prefix_len=4 if cfg.prefix_len else 0,
        n_enc=min(cfg.n_enc, 2),
        n_dec=min(cfg.n_dec, 2),
        remat=False,
        lru_width=64 if cfg.lru_width else None,
        xlstm_heads=2,
    )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe, n_routed=8, n_shared=min(cfg.moe.n_shared, 1),
            topk=2, d_ff_expert=32)
    if cfg.mla is not None:
        small["mla"] = dataclasses.replace(
            cfg.mla, q_lora=32, kv_lora=16, qk_nope=16, qk_rope=8, v_dim=16)
    # shrink windows proportionally
    new_pattern = tuple(
        dataclasses.replace(k, window=(16 if k.window else None))
        for k in cfg.block_pattern)
    new_tail = tuple(
        dataclasses.replace(k, window=(16 if k.window else None))
        for k in cfg.tail)
    small["block_pattern"] = new_pattern
    small["tail"] = new_tail
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
