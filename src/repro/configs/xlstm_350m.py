"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304.
sLSTM + mLSTM blocks (arXiv:2405.04517), 7:1 mLSTM:sLSTM ratio.
State is O(1) in sequence -> long_500k eligible."""
from ..models.lm import ArchCfg, LayerKind
from .common import reduce_cfg

_M = LayerKind(mixer="mlstm", ffn="none")
_S = LayerKind(mixer="slstm", ffn="none")


def config() -> ArchCfg:
    return ArchCfg(
        name="xlstm-350m", d_model=1024, n_heads=4, n_kv=4, head_dim=256,
        d_ff=0, vocab=50304,
        block_pattern=(_M,) * 7 + (_S,), repeats=3,
        xlstm_heads=4, tie_embeddings=True,
        long_context_ok=True)


def reduced() -> ArchCfg:
    return reduce_cfg(config())
