"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16, MHA) vocab=102400,
fine-grained MoE: 64 routed experts (d_ff=1408) top-6 + 2 shared
(arXiv:2401.06066). Deviation noted: the public model uses a dense FFN in
layer 0; the assignment specifies the uniform MoE stack we build here."""
from ..models.lm import ArchCfg, LayerKind, MoeCfg
from .common import reduce_cfg


def config() -> ArchCfg:
    return ArchCfg(
        name="deepseek-moe-16b", d_model=2048, n_heads=16, n_kv=16,
        head_dim=128, d_ff=1408, vocab=102400,
        block_pattern=(LayerKind(ffn="moe"),), repeats=28,
        moe=MoeCfg(n_routed=64, n_shared=2, topk=6, d_ff_expert=1408,
                   renormalize=False),
        tie_embeddings=False)


def reduced() -> ArchCfg:
    return reduce_cfg(config())
