"""Static-analysis suite for the serving stack.

Three CI-gated passes police the invariants the runtime only asserts
dynamically (``python -m repro.analysis check``):

* ``locks`` — the declared lock hierarchy (server -> scheduler ->
  dispatch -> store -> plans_sync -> leaves) with order-inversion,
  leaf-outcall, blocking-under-lock, and callback-under-lock rules.
* ``retrace`` — zero-steady-state-retrace hazards: tracer branches,
  jit built on hot paths, array-valued static args, closure-captured
  device arrays.
* ``taxonomy`` — trace kinds closed over ``trace.EVENT_KINDS`` and
  ``gravfm_*`` metric names well-formed, type-consistent, and
  documented in the README taxonomy tables.

Plus an informational ``deadcode`` pass (unused imports / unreferenced
private defs) that never gates.

See the README "Static analysis" section for the rule catalog,
annotation syntax (``# lock: <domain>``, ``# analysis: allow(<rule>)``,
``# analysis: traced``/``host``), and baseline workflow.
"""
from .cli import main, run_check
from .deadcode import DeadCodePass
from .findings import Baseline, Finding, SourceFile, load_source
from .locks import ATTR_DOMAINS, HIERARCHY, LockDomain, LockPass
from .retrace import RetracePass
from .taxonomy import TaxonomyPass

__all__ = [
    "main", "run_check", "Baseline", "Finding", "SourceFile",
    "load_source", "LockPass", "LockDomain", "HIERARCHY",
    "ATTR_DOMAINS", "RetracePass", "TaxonomyPass", "DeadCodePass",
]
