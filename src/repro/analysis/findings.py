"""Shared infrastructure for the static-analysis passes.

A :class:`Finding` is one rule violation at one source location. Each
finding carries a *fingerprint* — a stable hash of (rule, file,
enclosing scope, normalized source line) — so a baseline file can
suppress known findings without pinning line numbers: inserting code
above a finding does not invalidate its fingerprint, editing the
flagged line does.

Suppression annotations, checked on the flagged line (or, for findings
inside a multi-line statement, the statement's first line):

    # analysis: allow(RULE_ID)        suppress RULE_ID here, with a
                                      one-line justification in the
                                      same comment
    # analysis: allow(RULE_A, RULE_B) suppress several rules
    # analysis: traced                mark a def as jit-traced (seeds
                                      the retrace pass)
    # analysis: host                  mark a def as host-side (removes
                                      it from the traced set)
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

__all__ = ["Finding", "SourceFile", "Baseline", "load_source",
           "fingerprint_of"]

_ALLOW_RE = re.compile(r"#\s*analysis:\s*allow\(([^)]*)\)")
_MARK_RE = re.compile(r"#\s*analysis:\s*(traced|host)\b")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation. ``severity`` is ``"error"`` (gates the exit
    code) or ``"info"`` (report-only, e.g. the dead-code pass)."""
    rule: str
    path: str            # repo-relative, forward slashes
    line: int
    message: str
    severity: str = "error"
    fingerprint: str = ""

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def to_json(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def fingerprint_of(rule: str, path: str, scope: str, line_text: str) -> str:
    """Line-number-independent identity for baselining."""
    norm = " ".join(line_text.split())
    h = hashlib.sha1(
        f"{rule}|{path}|{scope}|{norm}".encode()).hexdigest()
    return h[:16]


class SourceFile:
    """One parsed module: AST plus the comment-level annotation maps the
    passes consult (``# analysis:`` suppressions and traced/host
    markers are comments, invisible to ``ast``)."""

    def __init__(self, path: Path, rel: str, text: str):
        self.path = path
        self.rel = rel
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=str(path))
        # line -> set of allowed rule ids ("*" allows everything)
        self.allow: Dict[int, Set[str]] = {}
        # line -> "traced" | "host"
        self.marks: Dict[int, str] = {}
        for i, ln in enumerate(self.lines, start=1):
            m = _ALLOW_RE.search(ln)
            if m:
                rules = {r.strip() for r in m.group(1).split(",")
                         if r.strip()}
                self.allow.setdefault(i, set()).update(rules)
            m = _MARK_RE.search(ln)
            if m:
                self.marks[i] = m.group(1)

    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1]
        return ""

    def allows(self, line: int, rule: str) -> bool:
        """True when ``rule`` is suppressed at ``line`` (annotation on
        the line itself or on the line directly above it)."""
        for ln in (line, line - 1):
            rules = self.allow.get(ln)
            if rules and (rule in rules or "*" in rules):
                return True
        return False

    def make(self, rule: str, node_or_line, scope: str, message: str,
             severity: str = "error") -> Finding:
        line = getattr(node_or_line, "lineno", node_or_line)
        return Finding(
            rule=rule, path=self.rel, line=line, message=message,
            severity=severity,
            fingerprint=fingerprint_of(rule, self.rel, scope,
                                       self.line_text(line)))


def load_source(root: Path, rel: str) -> SourceFile:
    p = Path(root) / rel
    return SourceFile(p, rel.replace("\\", "/"),
                      p.read_text(encoding="utf-8"))


class Baseline:
    """A JSON set of fingerprints to suppress ("known, accepted")."""

    def __init__(self, fingerprints: Iterable[str] = ()):
        self.fingerprints = set(fingerprints)

    @classmethod
    def load(cls, path) -> "Baseline":
        p = Path(path)
        if not p.exists():
            return cls()
        data = json.loads(p.read_text(encoding="utf-8") or "{}")
        if isinstance(data, list):         # bare list form
            return cls(data)
        return cls(data.get("fingerprints", []))

    def save(self, path, findings: Iterable[Finding] = ()) -> None:
        fps = sorted(self.fingerprints
                     | {f.fingerprint for f in findings})
        Path(path).write_text(
            json.dumps({"fingerprints": fps}, indent=2) + "\n",
            encoding="utf-8")

    def __contains__(self, finding: Finding) -> bool:
        return finding.fingerprint in self.fingerprints


def qualname_chain(stack: List[ast.AST]) -> str:
    parts = []
    for node in stack:
        name = getattr(node, "name", None)
        if name:
            parts.append(name)
    return ".".join(parts) or "<module>"


def attr_chain(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """``a.b.c`` -> ("a", "b", "c"); None when the chain bottoms out in
    something other than a Name (a call, a subscript, ...)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None
