"""Dead-code pass (informational; rules DC001-DC002).

* **DC001** unused import: a module-level import whose bound name is
  never referenced in the module (``__all__`` re-exports count as
  references; ``from __future__`` and intentionally-re-exported
  ``__init__`` imports are exempt — package ``__init__`` modules only
  report imports absent from ``__all__``).
* **DC002** unused private definition: a module-level ``_name``
  function/class never referenced elsewhere in its module.

Findings are ``severity="info"`` — they show up in the report and the
JSON artifact but never gate the exit code; the point is a standing
cleanup list, not a build break.
"""
from __future__ import annotations

import ast
from typing import List, Sequence, Set

from .findings import Finding, SourceFile

__all__ = ["DeadCodePass"]


def _ann_refs(node, refs: Set[str]) -> None:
    """Names inside a quoted annotation ('list[EngineResult]')."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        try:
            sub = ast.parse(node.value, mode="eval")
        except SyntaxError:
            return
        for n in ast.walk(sub):
            if isinstance(n, ast.Name):
                refs.add(n.id)


def _module_refs(tree: ast.Module) -> Set[str]:
    refs: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            refs.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.returns is not None:
                _ann_refs(node.returns, refs)
            args = node.args
            for a in (args.posonlyargs + args.args + args.kwonlyargs
                      + [x for x in (args.vararg, args.kwarg) if x]):
                if a.annotation is not None:
                    _ann_refs(a.annotation, refs)
        elif isinstance(node, ast.AnnAssign):
            _ann_refs(node.annotation, refs)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__":
                    for e in ast.walk(node.value):
                        if isinstance(e, ast.Constant) and \
                                isinstance(e.value, str):
                            refs.add(e.value)
    return refs


class DeadCodePass:
    name = "deadcode"

    def run(self, files: Sequence[SourceFile]) -> List[Finding]:
        findings: List[Finding] = []
        for sf in files:
            refs = _module_refs(sf.tree)
            is_pkg_init = sf.rel.endswith("__init__.py")
            for node in sf.tree.body:
                if isinstance(node, ast.ImportFrom):
                    if node.module == "__future__":
                        continue
                    for alias in node.names:
                        if alias.name == "*":
                            continue
                        bound = alias.asname or alias.name
                        self._check_import(sf, node, bound, refs,
                                           is_pkg_init, findings)
                elif isinstance(node, ast.Import):
                    for alias in node.names:
                        bound = alias.asname or \
                            alias.name.split(".")[0]
                        self._check_import(sf, node, bound, refs,
                                           is_pkg_init, findings)
                elif isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef,
                                       ast.ClassDef)):
                    name = node.name
                    if not name.startswith("_") or \
                            name.startswith("__"):
                        continue
                    uses = sum(1 for n in ast.walk(sf.tree)
                               if isinstance(n, ast.Name)
                               and n.id == name)
                    # the def itself binds no Name node; attribute
                    # references self._x are methods, not these
                    if uses == 0 and not sf.allows(node.lineno,
                                                   "DC002"):
                        findings.append(sf.make(
                            "DC002", node.lineno, name,
                            f"private module-level {name!r} is never "
                            f"referenced in its module",
                            severity="info"))
        return findings

    @staticmethod
    def _check_import(sf, node, bound, refs, is_pkg_init, findings):
        # the import statement itself does not create a Name node, so
        # any Name occurrence is a genuine use (or an __all__ entry)
        if bound in refs:
            return
        if is_pkg_init:
            return  # package re-export surface; __all__ covered above
        if sf.allows(node.lineno, "DC001"):
            return
        findings.append(sf.make(
            "DC001", node.lineno, "<module>",
            f"import {bound!r} is unused", severity="info"))
