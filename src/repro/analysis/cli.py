"""``python -m repro.analysis check`` — run the static-analysis suite.

Exit code 0 when no *new* error-severity findings remain (info
findings and baselined/annotated findings never gate); 1 otherwise.

    python -m repro.analysis check
    python -m repro.analysis check --baseline analysis-baseline.json
    python -m repro.analysis check --json > report.json
    python -m repro.analysis check --write-baseline analysis-baseline.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from .deadcode import DeadCodePass
from .findings import Baseline, Finding, SourceFile, load_source
from .locks import LockPass
from .retrace import RetracePass
from .taxonomy import TaxonomyPass

__all__ = ["run_check", "main", "LOCK_FILES", "RETRACE_FILES"]

# Files each pass polices. Lock files are the concurrency-bearing
# modules; retrace files are the compiled-program factories plus the
# steady-state serving paths.
LOCK_FILES = [
    "src/repro/service/server.py",
    "src/repro/service/continuous.py",
    "src/repro/service/stats.py",
    "src/repro/service/trace.py",
    "src/repro/service/metrics.py",
    "src/repro/service/plans.py",
    "src/repro/store/registry.py",
    "src/repro/store/tenancy.py",
]
RETRACE_FILES = [
    "src/repro/core/stepper.py",
    "src/repro/core/engine.py",
    "src/repro/core/engine_shardmap.py",
    "src/repro/service/plans.py",
    "src/repro/service/continuous.py",
    "src/repro/service/server.py",
]
# taxonomy + deadcode sweep everything live under src/repro; the seed
# leftovers keep their own (unshipped) vocabulary
EXCLUDE_DIRS = {"configs", "models", "train", "data"}
README = "README.md"


def _tree_files(root: Path) -> List[str]:
    out = []
    base = root / "src" / "repro"
    for p in sorted(base.rglob("*.py")):
        rel = p.relative_to(root).as_posix()
        parts = p.relative_to(base).parts
        if parts and parts[0] in EXCLUDE_DIRS:
            continue
        out.append(rel)
    return out


def run_check(root, baseline: Optional[Baseline] = None,
              lock_files: Optional[Sequence[str]] = None,
              retrace_files: Optional[Sequence[str]] = None,
              taxonomy_files: Optional[Sequence[str]] = None,
              deadcode_files: Optional[Sequence[str]] = None,
              readme: Optional[str] = README) -> Dict[str, object]:
    """Run all passes rooted at ``root``; returns the report dict."""
    root = Path(root)
    baseline = baseline or Baseline()

    def load(rels) -> List[SourceFile]:
        return [load_source(root, r) for r in rels
                if (root / r).exists()]

    lock_srcs = load(LOCK_FILES if lock_files is None else lock_files)
    retrace_srcs = load(RETRACE_FILES if retrace_files is None
                        else retrace_files)
    tree = _tree_files(root)
    tax_srcs = load(tree if taxonomy_files is None else taxonomy_files)
    dead_srcs = load(tree if deadcode_files is None else deadcode_files)

    readme_text = None
    if readme is not None and (root / readme).exists():
        readme_text = (root / readme).read_text(encoding="utf-8")

    per_pass = {
        "locks": LockPass().run(lock_srcs),
        "retrace": RetracePass().run(retrace_srcs),
        "taxonomy": TaxonomyPass(readme_text=readme_text).run(tax_srcs),
        "deadcode": DeadCodePass().run(dead_srcs),
    }

    findings: List[Finding] = [f for fs in per_pass.values() for f in fs]
    new = [f for f in findings
           if f.severity == "error" and f not in baseline]
    baselined = [f for f in findings
                 if f.severity == "error" and f in baseline]
    info = [f for f in findings if f.severity != "error"]
    return {
        "passes": {k: [f.to_json() for f in v]
                   for k, v in per_pass.items()},
        "new": new,
        "baselined": baselined,
        "info": info,
        "ok": not new,
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = ap.add_subparsers(dest="cmd", required=True)
    chk = sub.add_parser(
        "check", help="run the lock/retrace/taxonomy/dead-code passes")
    chk.add_argument("--root", default=".",
                     help="repo root (default: cwd)")
    chk.add_argument("--baseline", default=None,
                     help="baseline JSON of accepted fingerprints")
    chk.add_argument("--write-baseline", default=None, metavar="PATH",
                     help="write current error findings as the "
                          "baseline and exit 0")
    chk.add_argument("--json", action="store_true",
                     help="print the full JSON report to stdout")
    chk.add_argument("--json-out", default=None, metavar="PATH",
                     help="also write the JSON report to PATH")
    args = ap.parse_args(argv)

    baseline = (Baseline.load(args.baseline)
                if args.baseline else Baseline())
    report = run_check(args.root, baseline=baseline)
    new: List[Finding] = report["new"]          # type: ignore[assignment]
    info: List[Finding] = report["info"]        # type: ignore[assignment]

    if args.write_baseline:
        Baseline().save(args.write_baseline, new)
        print(f"wrote {len(new)} fingerprint(s) to "
              f"{args.write_baseline}")
        return 0

    payload = {
        "ok": report["ok"],
        "new": [f.to_json() for f in new],
        "baselined": [f.to_json() for f in report["baselined"]],
        "info": [f.to_json() for f in info],
        "passes": report["passes"],
    }
    if args.json_out:
        Path(args.json_out).write_text(
            json.dumps(payload, indent=2) + "\n", encoding="utf-8")
    if args.json:
        print(json.dumps(payload, indent=2))
    else:
        for f in new:
            print(f.render())
        for f in info:
            print(f"{f.render()} [info]")
        nb = len(report["baselined"])           # type: ignore[arg-type]
        print(f"analysis: {len(new)} new finding(s), {nb} baselined, "
              f"{len(info)} informational")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
