"""Retrace-hazard pass (rules RTR001-RTR005).

The serving stack's perf gates all assume *zero steady-state
re-traces*: compiled programs are built once (``__init__`` /
``_make_*`` / ``_build``) and every per-query / per-superstep dispatch
reuses them; graph data is a jit *argument* (the spill/refault and
exchange-switch machinery depends on data-as-arg). This pass flags the
source patterns that silently break that contract:

* **RTR001** tracer branch: a Python ``if``/``while`` whose condition
  derives from a parameter of a jit-traced function. Branches on
  static configuration (``self.*``, closure constants) are fine;
  ``x is None`` structure checks and static array attributes
  (``.shape``/``.ndim``/``.dtype``) are exempt.
* **RTR002** jit built on a hot path: ``jax.jit`` / ``shard_map`` /
  ``jax.pmap`` constructed outside module scope, ``__init__``,
  ``_build`` or ``make_*``/``_make*`` factories.
* **RTR003** bad static argument: ``static_argnums``/``static_argnames``
  whose spec is not an int/str (tuple) literal, or whose resolvable
  call sites pass an array/list/dict/set value in a static position
  (retrace per value — or an outright unhashable error).
* **RTR004** closure-captured array: a traced function closes over a
  name bound in a *host* scope by an array constructor
  (``jnp.asarray``/``zeros``/``device_put``/...) — it should be a jit
  argument so residency changes don't re-trace.
* **RTR005** unrolled collective pipeline: a Python ``for``/``while``
  loop inside a traced function whose body issues a device collective
  (``ppermute``/``all_to_all``/``all_gather``/``psum``/...). The loop
  unrolls at trace time, baking the Python-int window (double-buffer)
  index into every iteration — trace size grows with the window count
  and changing it re-traces. The pipeline must be a ``lax.fori_loop``/
  ``scan`` with the window and buffer-parity index in the loop carry
  (building a static permutation *table* with a comprehension is fine;
  issuing the collective per Python iteration is not).

Traced scopes are discovered from seeds (arguments to ``jax.jit``,
``jax.vmap``, ``lax.while_loop``/``fori_loop``/``scan``/``switch``/
``cond``, ``shard_map`` wrappers), closed over (a) functions defined
inside traced functions and (b) same-file defs whose name matches a
call made inside a traced function. A ``# analysis: traced`` comment
on the ``def`` line force-marks a function (for callbacks invoked from
traced code in *other* modules — the deliver kernels); ``# analysis:
host`` removes a def the propagation over-approximated.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set

from .findings import Finding, SourceFile, attr_chain

__all__ = ["RetracePass"]

JIT_WRAPPERS = {"jit", "pmap"}                   # jax.jit / jax.pmap
TRACE_TAKERS = {"while_loop", "fori_loop", "scan", "switch", "cond",
                "vmap", "jit", "pmap", "grad", "value_and_grad",
                "checkpoint", "remat", "eval_shape", "shard_map",
                "_shard_map", "custom_vjp", "custom_jvp"}
ARRAY_CTORS = {"asarray", "array", "zeros", "ones", "full", "arange",
               "linspace", "empty", "device_put", "zeros_like",
               "ones_like", "full_like"}
STATIC_ARRAY_ATTRS = {"shape", "ndim", "dtype", "size", "aval"}
HOT_JIT_ALLOWED = {"__init__", "_build", "__post_init__"}
COLLECTIVES = {"ppermute", "pshuffle", "all_to_all", "all_gather",
               "psum", "pmax", "pmin", "pmean", "psum_scatter"}


def _is_jit_call(call: ast.Call) -> Optional[str]:
    """'jit'-like wrapper name when this call builds a compiled
    program, else None."""
    chain = attr_chain(call.func)
    if not chain:
        return None
    name = chain[-1]
    if name in JIT_WRAPPERS and (len(chain) == 1 or chain[0] == "jax"):
        return name
    if name in ("shard_map", "_shard_map"):
        return name
    return None


class _FnInfo:
    __slots__ = ("node", "qual", "cls", "parent", "params", "sf")

    def __init__(self, node, qual, cls, parent, sf):
        self.node = node
        self.qual = qual
        self.cls = cls
        self.parent = parent      # enclosing _FnInfo or None
        self.sf = sf
        if isinstance(node, ast.Lambda):
            a = node.args
        else:
            a = node.args
        names = [p.arg for p in
                 a.posonlyargs + a.args + a.kwonlyargs]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        self.params = [n for n in names if n not in ("self", "cls")]


class RetracePass:
    name = "retrace"

    def run(self, files: Sequence[SourceFile]) -> List[Finding]:
        findings: List[Finding] = []
        for sf in files:
            infos = self._index(sf)
            traced = self._traced_set(sf, infos)
            for info in infos.values():
                if id(info.node) in traced:
                    self._check_traced(sf, info, infos, traced, findings)
            self._check_hot_jits(sf, infos, findings)
            self._check_static_args(sf, findings)
        return findings

    # ------------------------ discovery ------------------------------
    def _index(self, sf: SourceFile) -> Dict[int, _FnInfo]:
        infos: Dict[int, _FnInfo] = {}

        def visit(node, cls, parent):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.ClassDef):
                    visit(child, child.name, parent)
                elif isinstance(child, (ast.FunctionDef,
                                        ast.AsyncFunctionDef,
                                        ast.Lambda)):
                    name = getattr(child, "name", "<lambda>")
                    qual = (f"{parent.qual}.{name}" if parent
                            else (f"{cls}.{name}" if cls else name))
                    info = _FnInfo(child, qual, cls, parent, sf)
                    infos[id(child)] = info
                    visit(child, cls, info)
                else:
                    visit(child, cls, parent)

        visit(sf.tree, None, None)
        return infos

    def _traced_set(self, sf: SourceFile,
                    infos: Dict[int, _FnInfo]) -> Set[int]:
        by_name: Dict[str, List[_FnInfo]] = {}
        for info in infos.values():
            nm = getattr(info.node, "name", None)
            if nm:
                by_name.setdefault(nm, []).append(info)

        traced: Set[int] = set()
        # comment markers
        for info in infos.values():
            mark = sf.marks.get(info.node.lineno)
            if mark == "traced":
                traced.add(id(info.node))

        # seeds: function-valued arguments to jit/vmap/while_loop/...
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = attr_chain(node.func)
            if not chain or chain[-1] not in TRACE_TAKERS:
                continue
            cands = list(node.args) + [kw.value for kw in node.keywords]
            for arg in cands:
                if isinstance(arg, ast.Lambda):
                    traced.add(id(arg))
                elif isinstance(arg, ast.Name):
                    for info in by_name.get(arg.id, []):
                        traced.add(id(info.node))
                else:
                    ac = attr_chain(arg)
                    if ac and len(ac) >= 2:
                        for info in by_name.get(ac[-1], []):
                            traced.add(id(info.node))

        # closure: defs nested inside traced functions are traced; and
        # same-file defs called (by name) from traced bodies
        changed = True
        while changed:
            changed = False
            for info in infos.values():
                if id(info.node) in traced:
                    continue
                p = info.parent
                while p is not None:
                    if id(p.node) in traced:
                        traced.add(id(info.node))
                        changed = True
                        break
                    p = p.parent
            for info in list(infos.values()):
                if id(info.node) not in traced:
                    continue
                for node in ast.walk(info.node):
                    if not isinstance(node, ast.Call):
                        continue
                    chain = attr_chain(node.func)
                    if not chain:
                        continue
                    callee = chain[-1]
                    for cand in by_name.get(callee, []):
                        if id(cand.node) not in traced:
                            traced.add(id(cand.node))
                            changed = True

        # explicit host markers win over propagation
        for info in infos.values():
            if sf.marks.get(info.node.lineno) == "host":
                traced.discard(id(info.node))
        return traced

    # ------------------------ RTR001 + RTR004 ------------------------
    def _check_traced(self, sf, info, infos, traced, findings):
        node = info.node
        tainted: Set[str] = set(info.params)
        body = node.body if not isinstance(node, ast.Lambda) else []

        def expr_tainted(e) -> bool:
            for sub in ast.walk(e):
                if isinstance(sub, ast.Attribute) and \
                        sub.attr in STATIC_ARRAY_ATTRS:
                    return False  # handled by pruning below instead
            return any(isinstance(s, ast.Name) and s.id in tainted
                       for s in ast.walk(e))

        def prune_static(e):
            """Names reachable only through static attrs / len() don't
            count."""
            class _Taint(ast.NodeVisitor):
                def __init__(self):
                    self.hit = False

                def visit_Attribute(self, a):
                    if a.attr in STATIC_ARRAY_ATTRS:
                        return
                    self.generic_visit(a)

                def visit_Call(self, c):
                    ch = attr_chain(c.func)
                    if ch and ch[-1] in ("len", "isinstance", "hasattr",
                                         "getattr", "type"):
                        return
                    self.generic_visit(c)

                def visit_Name(self, n):
                    if n.id in tainted:
                        self.hit = True

            t = _Taint()
            t.visit(e)
            return t.hit

        def is_none_check(test) -> bool:
            return (isinstance(test, ast.Compare)
                    and len(test.ops) == 1
                    and isinstance(test.ops[0], (ast.Is, ast.IsNot))
                    and isinstance(test.comparators[0], ast.Constant)
                    and test.comparators[0].value is None)

        # forward pass: propagate taint through simple assignments,
        # flag if/while tests on tainted values
        def walk(stmts):
            for st in stmts:
                if isinstance(st, ast.Assign):
                    src_t = expr_tainted(st.value)
                    for t in st.targets:
                        for nm in ast.walk(t):
                            if isinstance(nm, ast.Name):
                                if src_t:
                                    tainted.add(nm.id)
                                else:
                                    tainted.discard(nm.id)
                elif isinstance(st, (ast.If, ast.While)):
                    if not is_none_check(st.test) and \
                            prune_static(st.test) and \
                            not sf.allows(st.lineno, "RTR001"):
                        kind = ("while"
                                if isinstance(st, ast.While) else "if")
                        findings.append(sf.make(
                            "RTR001", st.lineno, info.qual,
                            f"Python '{kind}' on a traced value inside "
                            f"jit-traced '{info.qual}' — concretization "
                            f"error or a re-trace per value; use "
                            f"lax.cond/select"))
                # recurse into nested statement bodies (not nested defs)
                for field in ("body", "orelse", "finalbody"):
                    sub = getattr(st, field, None)
                    if sub:
                        walk([s for s in sub
                              if not isinstance(s, (ast.FunctionDef,
                                                    ast.AsyncFunctionDef
                                                    ))])

        walk(body)
        self._check_unrolled_collectives(sf, info, findings)
        self._check_closure_arrays(sf, info, infos, traced, findings)

    # ------------------------ RTR005 ---------------------------------
    def _check_unrolled_collectives(self, sf, info, findings):
        """RTR005: device collectives issued from a Python loop inside
        a traced function — an unrolled exchange pipeline whose window
        / double-buffer index is a Python int instead of traced loop
        carry."""

        def first_collective(n) -> Optional[str]:
            # nested defs are traced scopes of their own (fori_loop /
            # scan bodies) — a collective there is the *fixed* pattern,
            # and the def is checked separately anyway
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
                return None
            if isinstance(n, ast.Call):
                ch = attr_chain(n.func)
                if ch and ch[-1] in COLLECTIVES:
                    return ch[-1]
            for child in ast.iter_child_nodes(n):
                hit = first_collective(child)
                if hit:
                    return hit
            return None

        def loops_of(stmts):
            for st in stmts:
                if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.Lambda)):
                    continue        # reported under the nested def
                if isinstance(st, (ast.For, ast.While)):
                    yield st
                for field in ("body", "orelse", "finalbody"):
                    sub = getattr(st, field, None)
                    if sub:
                        yield from loops_of(sub)

        node = info.node
        body = node.body if not isinstance(node, ast.Lambda) else []
        for st in loops_of(body):
            hit = first_collective(st)
            if hit and not sf.allows(st.lineno, "RTR005"):
                kind = "while" if isinstance(st, ast.While) else "for"
                findings.append(sf.make(
                    "RTR005", st.lineno, info.qual,
                    f"collective '{hit}' issued from a Python '{kind}' "
                    f"loop inside jit-traced '{info.qual}' — the "
                    f"pipeline unrolls at trace time with the window/"
                    f"double-buffer index baked in as a Python int; "
                    f"use lax.fori_loop/scan with the index in the "
                    f"loop carry"))

    def _check_closure_arrays(self, sf, info, infos, traced, findings):
        """RTR004: free names bound by array constructors in host
        scopes."""
        node = info.node
        local: Set[str] = set(info.params) | {"self", "cls"}
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name) and isinstance(sub.ctx,
                                                        ast.Store):
                local.add(sub.id)
            elif isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if sub is not node:
                    local.add(sub.name)
        free = set()
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load):
                if sub.id not in local:
                    free.add(sub.id)
        if not free:
            return
        p = info.parent
        while p is not None:
            if id(p.node) in traced:
                p = p.parent
                continue  # bindings inside a trace are fine
            for st in ast.walk(p.node):
                if not isinstance(st, ast.Assign):
                    continue
                names = [t.id for t in st.targets
                         if isinstance(t, ast.Name)]
                hit = [n for n in names if n in free]
                if not hit or not isinstance(st.value, ast.Call):
                    continue
                chain = attr_chain(st.value.func)
                if not chain:
                    continue
                if chain[-1] in ARRAY_CTORS and \
                        chain[0] in ("jnp", "jax", "np", "numpy"):
                    if chain[0] in ("np", "numpy") and \
                            chain[-1] != "device_put":
                        continue  # host numpy constants are static-safe
                    if not sf.allows(st.lineno, "RTR004"):
                        findings.append(sf.make(
                            "RTR004", st.lineno, p.qual,
                            f"device array {hit[0]!r} is closure-"
                            f"captured by jit-traced '{info.qual}' — "
                            f"pass it as an argument so rebinds don't "
                            f"re-trace"))
            p = p.parent

    # ------------------------ RTR002 ---------------------------------
    def _check_hot_jits(self, sf, infos, findings):
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            wrapper = _is_jit_call(node)
            if wrapper is None:
                continue
            encl = self._enclosing(sf, infos, node)
            if encl is None:
                continue  # module/class scope: fine
            ok = False
            p = encl
            while p is not None:
                name = getattr(p.node, "name", "")
                if name in HOT_JIT_ALLOWED or name.startswith("make") \
                        or name.startswith("_make"):
                    ok = True
                    break
                p = p.parent
            if not ok and not sf.allows(node.lineno, "RTR002"):
                findings.append(sf.make(
                    "RTR002", node.lineno, encl.qual,
                    f"'{wrapper}' constructed inside '{encl.qual}' — "
                    f"compiled programs must be built once in "
                    f"__init__/_build/make_* factories, not on the "
                    f"per-query/per-superstep path"))

    def _enclosing(self, sf, infos, node) -> Optional[_FnInfo]:
        best = None
        for info in infos.values():
            n = info.node
            if isinstance(n, ast.Lambda):
                continue
            if n.lineno <= node.lineno <= (n.end_lineno or n.lineno):
                if best is None or n.lineno > best.node.lineno:
                    if any(sub is node for sub in ast.walk(n)):
                        best = info
        return best

    # ------------------------ RTR003 ---------------------------------
    def _check_static_args(self, sf, findings):
        # jit calls with a static spec, and the local names they bind
        static_of: Dict[str, List[int]] = {}   # bound name -> positions
        static_names_of: Dict[str, Set[str]] = {}
        scope_of: Dict[str, str] = {}

        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Assign):
                continue
            v = node.value
            if not isinstance(v, ast.Call) or _is_jit_call(v) != "jit":
                continue
            spec_nums: List[int] = []
            spec_names: Set[str] = set()
            for kw in v.keywords:
                if kw.arg not in ("static_argnums", "static_argnames"):
                    continue
                val = kw.value
                items = (val.elts if isinstance(val, (ast.Tuple, ast.List))
                         else [val])
                for it in items:
                    if isinstance(it, ast.Constant) and \
                            isinstance(it.value, int):
                        spec_nums.append(it.value)
                    elif isinstance(it, ast.Constant) and \
                            isinstance(it.value, str):
                        spec_names.add(it.value)
                    elif not sf.allows(node.lineno, "RTR003"):
                        findings.append(sf.make(
                            "RTR003", node.lineno, "<module>",
                            f"{kw.arg} must be an int/str (tuple) "
                            f"literal; a computed spec defeats the "
                            f"static check"))
            if not spec_nums and not spec_names:
                continue
            for t in node.targets:
                if isinstance(t, ast.Name):
                    static_of[t.id] = spec_nums
                    static_names_of[t.id] = spec_names
                    scope_of[t.id] = "<module>"

        if not static_of:
            return

        def is_arrayish(e) -> bool:
            if isinstance(e, (ast.List, ast.Dict, ast.Set)):
                return True
            if isinstance(e, ast.Call):
                ch = attr_chain(e.func)
                return bool(ch) and ch[-1] in ARRAY_CTORS
            return False

        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if not isinstance(fn, ast.Name) or fn.id not in static_of:
                continue
            for pos in static_of[fn.id]:
                if pos < len(node.args) and is_arrayish(node.args[pos]) \
                        and not sf.allows(node.lineno, "RTR003"):
                    findings.append(sf.make(
                        "RTR003", node.lineno, scope_of[fn.id],
                        f"array/container value passed in static "
                        f"position {pos} of jitted {fn.id!r} — "
                        f"unhashable, or a re-trace per value"))
            for kw in node.keywords:
                if kw.arg in static_names_of.get(fn.id, ()) and \
                        is_arrayish(kw.value) and \
                        not sf.allows(node.lineno, "RTR003"):
                    findings.append(sf.make(
                        "RTR003", node.lineno, scope_of[fn.id],
                        f"array/container value passed for static "
                        f"argument {kw.arg!r} of jitted {fn.id!r}"))
