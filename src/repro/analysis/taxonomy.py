"""Taxonomy pass (rules TAX001-TAX006).

Keeps the observability vocabulary closed and documented:

* **TAX001** unknown trace kind: every ``bus.emit("<kind>", ...)`` /
  ``self._emit("<kind>", ...)`` string literal must be a member of
  ``trace.EVENT_KINDS`` (the runtime asserts this too, but only on the
  paths a test happens to drive).
* **TAX002** malformed metric name: every emitted ``gravfm_*`` name
  must match ``^gravfm_[a-z0-9_]+$``.
* **TAX003** suffix/type mismatch: counters end ``_total``;
  gauges/histograms must not.
* **TAX004** kind conflict: one name used as more than one metric type
  (the registry raises at runtime; this catches it at review time).
* **TAX005** undocumented metric family: every emitted name (or
  f-string family) must match a row of the README "Metric-name
  taxonomy" table (``{a,b}`` alternations and ``<k>`` wildcards
  expand).
* **TAX006** undocumented trace kind: every ``EVENT_KINDS`` member
  must appear in the README event-taxonomy table.

Dynamic (f-string) names resolve exactly when their substitutions
iterate literal string tuples in the same function; otherwise the
static prefix/suffix become a wildcard family checked against the
documented wildcard rows.
"""
from __future__ import annotations

import ast
import fnmatch
import itertools
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .findings import Finding, SourceFile, attr_chain

__all__ = ["TaxonomyPass", "parse_readme_metrics", "parse_readme_kinds"]

_NAME_RE = re.compile(r"^gravfm_[a-z0-9_]+$")
_TICK_RE = re.compile(r"`([^`]+)`")

_EMIT_METHODS = {"emit", "_emit"}
_METRIC_METHODS = {"inc": "counter", "set_counter": "counter",
                   "set_gauge": "gauge", "observe": "histogram"}


def _expand_braces(tok: str) -> List[str]:
    """``a_{x,y}_b`` -> [a_x_b, a_y_b]; multiple groups take the
    product."""
    parts = re.split(r"\{([^{}]*)\}", tok)
    fixed = parts[0::2]
    groups = [p.split(",") for p in parts[1::2]]
    out = []
    for combo in itertools.product(*groups) if groups else [()]:
        s = fixed[0]
        for g, f in zip(combo, fixed[1:]):
            s += g.strip() + f
        out.append(s)
    return out


def parse_readme_metrics(text: str) -> List[str]:
    """fnmatch patterns from the README metric-taxonomy table
    (``<k>`` -> ``*``)."""
    pats: List[str] = []
    in_section = False
    for line in text.splitlines():
        if "Metric-name taxonomy" in line:
            in_section = True
            continue
        if in_section and line.startswith("## "):
            break
        if not in_section or not line.lstrip().startswith("|"):
            continue
        first_cell = line.split("|")[1] if "|" in line else ""
        for tok in _TICK_RE.findall(first_cell):
            if not tok.startswith("gravfm_"):
                continue
            tok = re.sub(r"<[^<>]+>", "*", tok)
            pats.extend(_expand_braces(tok))
    return pats


def parse_readme_kinds(text: str) -> Set[str]:
    kinds: Set[str] = set()
    in_section = False
    for line in text.splitlines():
        if "Event taxonomy" in line:
            in_section = True
            continue
        if in_section and (line.startswith("## ")
                           or line.startswith("**")):
            break
        if not in_section or not line.lstrip().startswith("|"):
            continue
        first_cell = line.split("|")[1] if "|" in line else ""
        kinds.update(_TICK_RE.findall(first_cell))
    kinds.discard("kind")
    return kinds


def _literal_str(node) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


class TaxonomyPass:
    name = "taxonomy"

    def __init__(self, event_kinds: Optional[Set[str]] = None,
                 readme_text: Optional[str] = None):
        """``event_kinds``/``readme_text`` override discovery (tests);
        by default EVENT_KINDS is parsed out of ``service/trace.py``
        among the scanned files and the README is read by the CLI."""
        self.event_kinds = event_kinds
        self.readme_text = readme_text

    # ---------------- EVENT_KINDS discovery --------------------------
    @staticmethod
    def _find_event_kinds(files: Sequence[SourceFile]) -> Optional[Set[str]]:
        for sf in files:
            if sf.rel.rsplit("/", 1)[-1] != "trace.py":
                continue
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.Assign) and any(
                        isinstance(t, ast.Name)
                        and t.id == "EVENT_KINDS"
                        for t in node.targets):
                    try:
                        v = node.value
                        if isinstance(v, ast.Call):   # frozenset({...})
                            v = v.args[0]
                        return set(ast.literal_eval(v))
                    except Exception:
                        return None
        return None

    # ---------------- f-string family resolution ---------------------
    @staticmethod
    def _loop_literals(fn) -> Dict[str, List[str]]:
        """for-targets iterating literal string tuples -> values."""
        out: Dict[str, List[str]] = {}
        if fn is None:
            return out
        for node in ast.walk(fn):
            if not isinstance(node, ast.For):
                continue
            if not isinstance(node.target, ast.Name):
                continue
            if isinstance(node.iter, (ast.Tuple, ast.List)):
                vals = [_literal_str(e) for e in node.iter.elts]
                if all(v is not None for v in vals):
                    out[node.target.id] = vals  # type: ignore[assignment]
        return out

    def _name_variants(self, node, fn) -> Optional[List[str]]:
        """Concrete names, or wildcard families, for a metric-name
        argument. None when it cannot start with gravfm_."""
        s = _literal_str(node)
        if s is not None:
            return [s] if s.startswith("gravfm_") else None
        if not isinstance(node, ast.JoinedStr):
            return None
        loops = self._loop_literals(fn)
        parts: List[List[str]] = []
        for v in node.values:
            if isinstance(v, ast.Constant):
                parts.append([str(v.value)])
            elif isinstance(v, ast.FormattedValue) and \
                    isinstance(v.value, ast.Name) and \
                    v.value.id in loops:
                parts.append(loops[v.value.id])
            else:
                parts.append(["*"])
        names = ["".join(c) for c in itertools.product(*parts)]
        names = [re.sub(r"\*+", "*", n) for n in names]
        return [n for n in names if n.startswith("gravfm_")] or None

    # ---------------- main ------------------------------------------
    def run(self, files: Sequence[SourceFile]) -> List[Finding]:
        findings: List[Finding] = []
        kinds = self.event_kinds
        if kinds is None:
            kinds = self._find_event_kinds(files)

        doc_patterns = (parse_readme_metrics(self.readme_text)
                        if self.readme_text else None)
        doc_kinds = (parse_readme_kinds(self.readme_text)
                     if self.readme_text else None)

        # name -> (kind, first site) for TAX004
        seen_kind: Dict[str, Tuple[str, str, int]] = {}

        def check_name(sf, scope, node, name, mkind, line):
            if "*" not in name:
                if not _NAME_RE.match(name):
                    if not sf.allows(line, "TAX002"):
                        findings.append(sf.make(
                            "TAX002", line, scope,
                            f"malformed metric name {name!r} (want "
                            f"^gravfm_[a-z0-9_]+$)"))
                    return
                ends_total = name.endswith("_total")
                if mkind == "counter" and not ends_total and \
                        not sf.allows(line, "TAX003"):
                    findings.append(sf.make(
                        "TAX003", line, scope,
                        f"counter {name!r} must end with '_total'"))
                if mkind in ("gauge", "histogram") and ends_total and \
                        not sf.allows(line, "TAX003"):
                    findings.append(sf.make(
                        "TAX003", line, scope,
                        f"{mkind} {name!r} must not end with '_total'"))
                prev = seen_kind.get(name)
                if prev and prev[0] != mkind:
                    if not sf.allows(line, "TAX004"):
                        findings.append(sf.make(
                            "TAX004", line, scope,
                            f"{name!r} used as {mkind} here but as "
                            f"{prev[0]} at {prev[1]}:{prev[2]}"))
                else:
                    seen_kind.setdefault(name, (mkind, sf.rel, line))
            if doc_patterns is not None:
                sample = name.replace("*", "samplekey")
                if not any(fnmatch.fnmatchcase(sample, p)
                           for p in doc_patterns) and \
                        not sf.allows(line, "TAX005"):
                    findings.append(sf.make(
                        "TAX005", line, scope,
                        f"metric family {name!r} is not documented in "
                        f"the README metric-name taxonomy table"))

        for sf in files:
            # enclosing-function map for loop-literal resolution
            encl: Dict[int, ast.AST] = {}

            def map_encl(node, fn):
                for child in ast.iter_child_nodes(node):
                    nfn = fn
                    if isinstance(child, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                        nfn = child
                    encl[id(child)] = nfn
                    map_encl(child, nfn)

            map_encl(sf.tree, None)

            for node in ast.walk(sf.tree):
                # _SNAP_COUNTERS / _SNAP_GAUGES literal dict values
                if isinstance(node, ast.Assign) and isinstance(
                        node.value, ast.Dict):
                    tname = "".join(t.id for t in node.targets
                                    if isinstance(t, ast.Name))
                    mkind = {"_SNAP_COUNTERS": "counter",
                             "_SNAP_GAUGES": "gauge"}.get(tname)
                    if mkind:
                        for v in node.value.values:
                            s = _literal_str(v)
                            if s:
                                check_name(sf, tname, v, s, mkind,
                                           v.lineno)
                    continue
                if not isinstance(node, ast.Call):
                    continue
                chain = attr_chain(node.func)
                if not chain:
                    continue
                method = chain[-1]
                fn = encl.get(id(node))
                scope = getattr(fn, "name", "<module>")
                # ---- trace kinds --------------------------------
                if method in _EMIT_METHODS and kinds is not None:
                    arg = None
                    if node.args:
                        arg = node.args[0]
                    for kw in node.keywords:
                        if kw.arg == "kind":
                            arg = kw.value
                    k = _literal_str(arg) if arg is not None else None
                    if k is not None and k not in kinds and \
                            not sf.allows(node.lineno, "TAX001"):
                        findings.append(sf.make(
                            "TAX001", node.lineno, scope,
                            f"trace kind {k!r} is not in "
                            f"trace.EVENT_KINDS"))
                # ---- metric names -------------------------------
                mkind = _METRIC_METHODS.get(method)
                if mkind and node.args:
                    variants = self._name_variants(node.args[0], fn)
                    for name in variants or ():
                        check_name(sf, scope, node, name, mkind,
                                   node.lineno)

        # ---- README completeness of EVENT_KINDS ---------------------
        if kinds is not None and doc_kinds is not None:
            trace_sf = next(
                (sf for sf in files
                 if sf.rel.rsplit("/", 1)[-1] == "trace.py"), None)
            for k in sorted(kinds - doc_kinds):
                if trace_sf is not None:
                    findings.append(trace_sf.make(
                        "TAX006", 1, "EVENT_KINDS",
                        f"trace kind {k!r} is in EVENT_KINDS but "
                        f"missing from the README event-taxonomy "
                        f"table"))
        return findings
